"""L2 correctness: the JAX model (scan over the fused cell + dense head)
vs the pure-jnp reference, plus the int8 fixed-point variant's accuracy
bound."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def params():
    return model.init_params()


@pytest.fixture(scope="module")
def window():
    return model.make_synthetic_window(seed=0)


def test_forecast_matches_reference(params, window):
    got = model.forecast(params, window)
    want = ref.lstm_forecast_ref(window, params)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_forecast_deterministic(params, window):
    a = model.forecast(params, window)
    b = model.forecast(params, window)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_forecast_shape_and_dtype(params, window):
    out = model.forecast(params, window)
    assert out.shape == (1,)
    assert out.dtype == jnp.float32


def test_step_composes_to_forecast(params, window):
    # manually unrolling lstm_step must equal the scanned forecast
    h = jnp.zeros((1, model.HIDDEN), jnp.float32)
    c = jnp.zeros((1, model.HIDDEN), jnp.float32)
    for t in range(window.shape[0]):
        h, c = model.lstm_step(params, window[t : t + 1, :], h, c)
    manual = (h @ params["w_out"] + params["b_out"])[0]
    scanned = model.forecast(params, window)
    np.testing.assert_allclose(manual, scanned, rtol=1e-5, atol=1e-6)


def test_int8_variant_close_to_f32(params, window):
    f32 = float(model.forecast(params, window)[0])
    q = float(model.forecast_int8(params, window)[0])
    # int8 activation path: bounded quantization error, not equality
    assert abs(f32 - q) < 0.1, (f32, q)
    assert abs(f32 - q) > 0.0  # it must actually quantize


def test_different_windows_different_forecasts(params):
    w0 = model.make_synthetic_window(seed=0)
    w1 = model.make_synthetic_window(seed=1, t0=11.0)
    f0 = float(model.forecast(params, w0)[0])
    f1 = float(model.forecast(params, w1)[0])
    assert f0 != f1


def test_params_deterministic_across_processes():
    a = model.init_params()
    b = model.init_params()
    for key in a:
        np.testing.assert_array_equal(np.asarray(a[key]), np.asarray(b[key]))


def test_hidden_size_is_papers_20(params):
    assert params["w_h"].shape == (20, 80)


def test_jit_forecast(params, window):
    jitted = jax.jit(lambda w: model.forecast(params, w))
    np.testing.assert_allclose(
        jitted(window), model.forecast(params, window), rtol=1e-5, atol=1e-6
    )


if __name__ == "__main__":
    pytest.main([__file__, "-q"])


def test_batched_forecast_matches_singles(params):
    windows = jnp.stack(
        [model.make_synthetic_window(seed=s, t0=3.0 * s) for s in range(4)]
    )
    batched = model.forecast_batched(params, windows)
    singles = jnp.stack([model.forecast(params, w)[0] for w in windows])
    np.testing.assert_allclose(batched, singles, rtol=1e-5, atol=1e-6)
