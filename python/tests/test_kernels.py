"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes so the kernels are exercised well beyond the
paper's fixed geometry (hidden=20, input=6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dense import dense
from compile.kernels.lstm_cell import (
    lstm_cell,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.quant import dequantize, quantize


def make_cell_inputs(batch, inp, hidden, seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 6)
    return (
        jax.random.normal(ks[0], (batch, inp), jnp.float32),
        jax.random.normal(ks[1], (batch, hidden), jnp.float32),
        jax.random.normal(ks[2], (batch, hidden), jnp.float32),
        jax.random.normal(ks[3], (inp, 4 * hidden), jnp.float32) / np.sqrt(inp),
        jax.random.normal(ks[4], (hidden, 4 * hidden), jnp.float32) / np.sqrt(hidden),
        jax.random.normal(ks[5], (4 * hidden,), jnp.float32) * 0.1,
    )


class TestLstmCell:
    def test_matches_ref_paper_geometry(self):
        x, h, c, wx, wh, b = make_cell_inputs(1, 6, 20)
        h_k, c_k = lstm_cell(x, h, c, wx, wh, b)
        h_r, c_r = ref.lstm_cell_ref(x, h, c, wx, wh, b)
        np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        batch=st.integers(1, 4),
        inp=st.integers(1, 16),
        hidden=st.integers(1, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis_sweep(self, batch, inp, hidden, seed):
        x, h, c, wx, wh, b = make_cell_inputs(batch, inp, hidden, seed)
        h_k, c_k = lstm_cell(x, h, c, wx, wh, b)
        h_r, c_r = ref.lstm_cell_ref(x, h, c, wx, wh, b)
        np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-6)

    def test_cell_state_bounded(self):
        # |h| <= 1 by construction (sigmoid * tanh)
        x, h, c, wx, wh, b = make_cell_inputs(2, 8, 24, seed=7)
        h_k, _ = lstm_cell(x, h, c, wx, wh, b)
        assert np.all(np.abs(np.asarray(h_k)) <= 1.0)

    def test_jit_compatible(self):
        x, h, c, wx, wh, b = make_cell_inputs(1, 6, 20)
        jitted = jax.jit(lambda *a: lstm_cell(*a))
        h_k, c_k = jitted(x, h, c, wx, wh, b)
        h_r, c_r = ref.lstm_cell_ref(x, h, c, wx, wh, b)
        np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-6)

    def test_vmem_footprint_paper_geometry_fits(self):
        # H=20, I=6: the whole working set is a few tens of KiB — far
        # under the ~16 MiB/core VMEM. Documented in EXPERIMENTS.md §Perf.
        bytes_ = vmem_footprint_bytes(1, 6, 20)
        assert bytes_ < 64 * 1024, bytes_

    def test_mxu_utilization_is_tiny_for_paper_geometry(self):
        u = mxu_utilization_estimate(1, 6, 20)
        assert 0.0 < u < 0.05  # documented: why FPGA wins on energy


class TestDense:
    @settings(max_examples=20, deadline=None)
    @given(
        batch=st.integers(1, 8),
        hidden=st.integers(1, 64),
        out=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, batch, hidden, out, seed):
        k = jax.random.PRNGKey(seed)
        ks = jax.random.split(k, 3)
        x = jax.random.normal(ks[0], (batch, hidden), jnp.float32)
        w = jax.random.normal(ks[1], (hidden, out), jnp.float32)
        b = jax.random.normal(ks[2], (out,), jnp.float32)
        np.testing.assert_allclose(
            dense(x, w, b), ref.dense_ref(x, w, b), rtol=1e-5, atol=1e-6
        )


class TestQuant:
    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.integers(1, 8),
        cols=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
        scale=st.floats(1e-3, 1.0),
    )
    def test_quantize_matches_ref(self, rows, cols, seed, scale):
        x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols), jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(quantize(x, scale)), np.asarray(ref.quantize_ref(x, scale))
        )

    def test_round_trip_error_bounded_by_half_step(self):
        x = jnp.linspace(-1.9, 1.9, 256).reshape(16, 16)
        scale = 2.0 / 127.0
        rt = dequantize(quantize(x, scale), scale)
        assert np.max(np.abs(np.asarray(rt - x))) <= scale / 2 + 1e-7

    def test_saturation(self):
        x = jnp.array([[-100.0, 100.0]])
        q = np.asarray(quantize(x, 0.1))
        assert q.tolist() == [[-127, 127]]

    def test_dequantize_dtype(self):
        q = quantize(jnp.ones((2, 2)), 0.5)
        assert q.dtype == jnp.int8
        d = dequantize(q, 0.5)
        assert d.dtype == jnp.float32


def test_kernels_reject_nothing_silently():
    # pallas interpret mode must produce finite outputs on finite inputs
    x, h, c, wx, wh, b = make_cell_inputs(1, 6, 20, seed=3)
    h_k, c_k = lstm_cell(x, h, c, wx, wh, b)
    assert np.all(np.isfinite(np.asarray(h_k)))
    assert np.all(np.isfinite(np.asarray(c_k)))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
