"""AOT pipeline: lowering produces loadable HLO text + coherent manifest.

These tests exercise the exact code `make artifacts` runs, into a temp
dir, and verify the HLO text parses back through xla_client (the same
parser family the rust side's xla_extension uses)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build_artifacts(str(out))
    return str(out), manifest


def test_all_artifacts_written(built):
    out, manifest = built
    assert len(manifest["artifacts"]) == 4
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), path
        assert os.path.getsize(path) > 1000


def test_manifest_round_trips_as_json(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded["hidden_size"] == 20
    assert loaded["window"] == model.WINDOW
    assert [a["name"] for a in loaded["artifacts"]] == [
        "lstm_step",
        "lstm_forecast",
        "lstm_forecast_int8",
        "lstm_forecast_batch8",
    ]


def test_hlo_text_is_parseable(built):
    out, manifest = built
    from jax._src.lib import xla_client as xc

    for a in manifest["artifacts"]:
        with open(os.path.join(out, a["file"])) as f:
            text = f.read()
        assert text.startswith("HloModule"), a["name"]
        # round-trip through the HLO text parser (what rust does)
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None


def test_selfcheck_forecast_matches_model(built):
    _, manifest = built
    params = model.init_params(manifest["seed"])
    window = model.make_synthetic_window(seed=manifest["selfcheck"]["window_seed"])
    got = float(model.forecast(params, window)[0])
    assert abs(got - manifest["selfcheck"]["forecast"]) < 1e-6


def test_selfcheck_window_serialized_correctly(built):
    _, manifest = built
    window = model.make_synthetic_window(seed=0)
    flat = np.asarray(window).reshape(-1)
    np.testing.assert_allclose(flat, manifest["selfcheck"]["window"], rtol=1e-6)


def test_weights_are_baked_not_inputs(built):
    out, manifest = built
    step = next(a for a in manifest["artifacts"] if a["name"] == "lstm_step")
    # only x, h, c — no weight parameters on the request path
    assert step["inputs"] == [[1, 6], [1, 20], [1, 20]]


def test_lowered_step_numerics_via_jax_executable(built):
    # Compile the lowered artifact through jax itself and compare with the
    # eager model — catches lowering bugs before rust ever runs.
    params = model.init_params()
    x = model.make_synthetic_window(seed=3)[0:1, :]
    h = jnp.zeros((1, model.HIDDEN), jnp.float32)
    c = jnp.zeros((1, model.HIDDEN), jnp.float32)
    compiled = jax.jit(lambda x, h, c: model.lstm_step(params, x, h, c)).lower(x, h, c).compile()
    h2, c2 = compiled(x, h, c)
    h_ref, c_ref = model.lstm_step(params, x, h, c)
    np.testing.assert_allclose(h2, h_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c2, c_ref, rtol=1e-5, atol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])


def test_cost_analysis_matches_theory():
    # §Perf: the lowered step's FLOPs must be within 25% of the
    # hand-counted matmul FLOPs (no redundant recomputation), and the
    # forecast body must not blow up vs a single step (scan, not unroll).
    from compile import analysis

    results = analysis.analyze_all()
    step = results["lstm_step"]["flops"]
    theory = analysis.theoretical_step_flops()
    assert 1.0 <= step / theory < 1.25, (step, theory)
    body = results["lstm_forecast"]["flops"]
    assert body < step * 2, "scan body must stay ~one step (no unrolling)"
