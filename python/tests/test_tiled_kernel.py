"""Tiled LSTM kernel vs the oracle and vs the untiled kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lstm_cell import lstm_cell
from compile.kernels.lstm_cell_tiled import (
    lstm_cell_tiled,
    pack_gates,
    unpack_gates,
    vmem_footprint_bytes_tiled,
)
from tests.test_kernels import make_cell_inputs


def test_pack_unpack_round_trip():
    w = jax.random.normal(jax.random.PRNGKey(0), (6, 80), jnp.float32)
    np.testing.assert_array_equal(np.asarray(unpack_gates(pack_gates(w, 20))), np.asarray(w))


def test_pack_matches_split_convention():
    # pack_gates must agree with jnp.split(gates, 4) gate ordering
    hidden = 8
    w = jnp.arange(4 * hidden, dtype=jnp.float32).reshape(1, 4 * hidden)
    packed = pack_gates(w, hidden)
    splits = jnp.split(w, 4, axis=-1)
    for g in range(4):
        np.testing.assert_array_equal(np.asarray(packed[0, g]), np.asarray(splits[g][0]))


@pytest.mark.parametrize("hidden,block_h", [(20, 5), (20, 20), (64, 16), (128, 32)])
def test_tiled_matches_ref(hidden, block_h):
    x, h, c, wx, wh, b = make_cell_inputs(1, 6, hidden, seed=1)
    h_t, c_t = lstm_cell_tiled(x, h, c, wx, wh, b, block_h=block_h)
    h_r, c_r = ref.lstm_cell_ref(x, h, c, wx, wh, b)
    np.testing.assert_allclose(h_t, h_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_t, c_r, rtol=1e-5, atol=1e-6)


def test_tiled_matches_untiled_kernel():
    x, h, c, wx, wh, b = make_cell_inputs(2, 8, 32, seed=3)
    h_t, c_t = lstm_cell_tiled(x, h, c, wx, wh, b, block_h=8)
    h_u, c_u = lstm_cell(x, h, c, wx, wh, b)
    np.testing.assert_allclose(h_t, h_u, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_t, c_u, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    batch=st.integers(1, 3),
    inp=st.integers(1, 12),
    blocks=st.integers(1, 6),
    block_h=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiled_hypothesis_sweep(batch, inp, blocks, block_h, seed):
    hidden = blocks * block_h
    x, h, c, wx, wh, b = make_cell_inputs(batch, inp, hidden, seed)
    h_t, c_t = lstm_cell_tiled(x, h, c, wx, wh, b, block_h=block_h)
    h_r, c_r = ref.lstm_cell_ref(x, h, c, wx, wh, b)
    np.testing.assert_allclose(h_t, h_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c_t, c_r, rtol=1e-5, atol=1e-6)


def test_bad_block_size_rejected():
    x, h, c, wx, wh, b = make_cell_inputs(1, 6, 20)
    with pytest.raises(ValueError, match="must divide"):
        lstm_cell_tiled(x, h, c, wx, wh, b, block_h=7)


def test_tiling_shrinks_vmem_footprint():
    whole = vmem_footprint_bytes_tiled(1, 6, 512, 512)
    tiled = vmem_footprint_bytes_tiled(1, 6, 512, 128)
    assert tiled < whole / 2


def test_jit_compatible():
    x, h, c, wx, wh, b = make_cell_inputs(1, 6, 40, seed=5)
    jitted = jax.jit(lambda *a: lstm_cell_tiled(*a, block_h=10))
    h_t, _ = jitted(x, h, c, wx, wh, b)
    h_r, _ = ref.lstm_cell_ref(x, h, c, wx, wh, b)
    np.testing.assert_allclose(h_t, h_r, rtol=1e-5, atol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
