"""L2 profiling: HLO cost analysis of the lowered modules (§Perf).

Runs XLA's cost analysis over each AOT artifact's computation to report
FLOPs, transcendentals and bytes accessed — the numbers behind the §Perf
claims about the lowered module (no redundant recomputation, scan keeps
one loop body). Usage:

    cd python && python -m compile.analysis
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def cost_of(fn, *specs) -> dict:
    """Lower `fn` and run XLA's HLO cost analysis on the module."""
    lowered = jax.jit(fn).lower(*specs)
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    props = xc._xla.hlo_module_cost_analysis(
        xc._xla.get_default_c_api_topology.__self__ if False else _cpu_client(),
        comp.as_hlo_module(),
    )
    return dict(props)


_CLIENT = None


def _cpu_client():
    global _CLIENT
    if _CLIENT is None:
        _CLIENT = jax.devices("cpu")[0].client
    return _CLIENT


def analyze_all(seed: int = 0x15D4) -> dict:
    """Cost analysis for every artifact variant; returns {name: props}."""
    params = model.init_params(seed)
    window_spec = jax.ShapeDtypeStruct((model.WINDOW, model.INPUT), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((1, model.INPUT), jnp.float32)
    h_spec = jax.ShapeDtypeStruct((1, model.HIDDEN), jnp.float32)

    out = {
        "lstm_step": cost_of(
            lambda x, h, c: model.lstm_step(params, x, h, c), x_spec, h_spec, h_spec
        ),
        "lstm_forecast": cost_of(
            lambda w: (model.forecast(params, w),), window_spec
        ),
        "lstm_forecast_int8": cost_of(
            lambda w: (model.forecast_int8(params, w),), window_spec
        ),
    }
    return out


def theoretical_step_flops(
    batch: int = 1, inp: int = model.INPUT, hidden: int = model.HIDDEN
) -> int:
    """Hand-counted MACs×2 for one LSTM step (matmuls only)."""
    return 2 * batch * (inp * 4 * hidden + hidden * 4 * hidden)


def main() -> None:
    results = analyze_all()
    print(f"{'module':24s} {'flops':>12s} {'transcendentals':>16s} {'bytes':>12s}")
    for name, props in results.items():
        print(
            f"{name:24s} {props.get('flops', float('nan')):>12.0f} "
            f"{props.get('transcendentals', float('nan')):>16.0f} "
            f"{props.get('bytes accessed', float('nan')):>12.0f}"
        )
    step_flops = results["lstm_step"].get("flops", 0)
    theory = theoretical_step_flops()
    print(
        f"\nlstm_step matmul FLOPs (theory): {theory} "
        f"(analysis/theory = {step_flops / theory:.2f}; the overhead is the "
        f"elementwise gate math). NOTE: XLA cost analysis counts a while-loop "
        f"body once, so the scanned forecast reports ~1 step of FLOPs; the "
        f"true total is WINDOW (= {model.WINDOW}) times the body."
    )


if __name__ == "__main__":
    main()
