"""L1 Pallas kernel: grid-tiled LSTM cell for scaled-up hidden sizes.

The paper's accelerator is hidden-size 20 — whole-model-in-VMEM, no grid
needed (see `lstm_cell.py`). This variant is the schedule you'd use when
scaling the same design point up (H in the hundreds+): a 1-D grid over
hidden-dimension blocks, with BlockSpecs expressing the HBM→VMEM tiling
that the FPGA design did with BRAM banking.

Layout trick: the Flax-convention weight matrix (I, 4H) interleaves the
four gates along one axis, which BlockSpec cannot slice non-contiguously.
We pre-pack weights to (I, 4, H) (`pack_gates`) so a hidden-block j sees
a contiguous (I, 4, bh) tile carrying all four gates for exactly its
slice of the hidden state. The recurrent input h is *not* blocked — every
block needs the full previous hidden state for its matmul (the recurrence
is all-to-all), so h rides in whole while c/h' /c' are blocked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pack_gates(w, hidden: int):
    """(…, 4H) Flax-layout → (…, 4, H) block-sliceable layout."""
    return w.reshape(*w.shape[:-1], 4, hidden)


def unpack_gates(w_packed):
    """Inverse of :func:`pack_gates`."""
    return w_packed.reshape(*w_packed.shape[:-2], -1)


def _tiled_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out_ref, c_out_ref):
    """One hidden-block program: full-x/full-h matmuls against this
    block's packed weight tile, then the blockwise state update."""
    x = x_ref[...]  # (B, I)
    h = h_ref[...]  # (B, H)  — full recurrent input
    c = c_ref[...]  # (B, bh) — this block's cell state
    # packed tiles: (I, 4, bh) and (H, 4, bh)
    gates = (
        jnp.einsum("bi,igk->bgk", x, wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.einsum("bh,hgk->bgk", h, wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )  # (B, 4, bh)
    i = gates[:, 0, :]
    f = gates[:, 1, :]
    g = gates[:, 2, :]
    o = gates[:, 3, :]
    c_next = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_next = jax.nn.sigmoid(o) * jnp.tanh(c_next)
    h_out_ref[...] = h_next.astype(h_out_ref.dtype)
    c_out_ref[...] = c_next.astype(c_out_ref.dtype)


def lstm_cell_tiled(x, h, c, w_x, w_h, b, *, block_h: int, interpret: bool = True):
    """Grid-tiled LSTM step.

    Args match `lstm_cell` (w_x (I,4H), w_h (H,4H), b (4H,)); `block_h`
    must divide the hidden size. Returns (h_next, c_next).
    """
    batch, hidden = h.shape
    inp = x.shape[1]
    if hidden % block_h != 0:
        raise ValueError(f"block_h {block_h} must divide hidden {hidden}")
    n_blocks = hidden // block_h

    wx_p = pack_gates(w_x, hidden)  # (I, 4, H)
    wh_p = pack_gates(w_h, hidden)  # (H, 4, H)
    b_p = pack_gates(b.reshape(1, -1), hidden)  # (1, 4, H)

    grid = (n_blocks,)
    out_shape = [
        jax.ShapeDtypeStruct((batch, hidden), h.dtype),
        jax.ShapeDtypeStruct((batch, hidden), c.dtype),
    ]
    h_next, c_next = pl.pallas_call(
        _tiled_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, inp), lambda j: (0, 0)),  # x: whole
            pl.BlockSpec((batch, hidden), lambda j: (0, 0)),  # h: whole
            pl.BlockSpec((batch, block_h), lambda j: (0, j)),  # c: block j
            pl.BlockSpec((inp, 4, block_h), lambda j: (0, 0, j)),  # wx tile
            pl.BlockSpec((hidden, 4, block_h), lambda j: (0, 0, j)),  # wh tile
            pl.BlockSpec((1, 4, block_h), lambda j: (0, 0, j)),  # b tile
        ],
        out_specs=[
            pl.BlockSpec((batch, block_h), lambda j: (0, j)),
            pl.BlockSpec((batch, block_h), lambda j: (0, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(x, h, c, wx_p, wh_p, b_p)
    return h_next, c_next


def vmem_footprint_bytes_tiled(
    batch: int, inp: int, hidden: int, block_h: int, dtype_bytes: int = 4
) -> int:
    """Per-program VMEM estimate: whole x/h + one block of everything
    else. For H=512, bh=128 this is ~1.3 MB vs ~4.5 MB untiled (§Perf)."""
    per_program = (
        batch * inp  # x
        + batch * hidden  # h (whole)
        + batch * block_h  # c block
        + inp * 4 * block_h  # wx tile
        + hidden * 4 * block_h  # wh tile
        + 4 * block_h  # b tile
        + 2 * batch * block_h  # outputs
        + batch * 4 * block_h  # gates
    )
    return per_program * dtype_bytes


@functools.lru_cache(maxsize=None)
def _noop():  # keep functools import purposeful under linting
    return None
