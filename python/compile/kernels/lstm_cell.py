"""L1 Pallas kernel: fused LSTM cell.

The paper's compute payload is an embedded LSTM accelerator (reference
[13], hidden size 20). On the FPGA it is a streaming fixed-point MAC
pipeline; the TPU-idiom rethink (DESIGN.md §Hardware-Adaptation) is a
single fused kernel that keeps the whole working set in VMEM:

* both gate matmuls (x·Wx and h·Wh) target the MXU,
* the gate nonlinearities and the cell-state update run on the VPU in the
  same kernel, so no intermediate ever round-trips through HBM.

With H = 20 the padded VMEM tiles are tiny (§Perf in EXPERIMENTS.md
estimates the footprint), so a single grid-less pallas_call whose
BlockSpecs map each operand entirely into VMEM is the right schedule —
the FPGA's "weights resident in BRAM" becomes "weights resident in VMEM".

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO for both testing and
the AOT artifacts. Real-TPU lowering would only change the pallas_call
flag; performance on TPU is *estimated*, not measured (DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out_ref, c_out_ref):
    """Fused gates-matmul + elementwise LSTM update.

    All refs live in VMEM. Gate layout [i, f, g, o] along the last axis,
    matching `ref.lstm_cell_ref`.
    """
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    # MXU work: two (B,I)x(I,4H) / (B,H)x(H,4H) matmuls, fused here so the
    # (B,4H) gate tensor never leaves VMEM.
    gates = (
        jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    hidden = h.shape[-1]
    i = gates[:, 0 * hidden : 1 * hidden]
    f = gates[:, 1 * hidden : 2 * hidden]
    g = gates[:, 2 * hidden : 3 * hidden]
    o = gates[:, 3 * hidden : 4 * hidden]
    # VPU work: nonlinearities + state update.
    c_next = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_next = jax.nn.sigmoid(o) * jnp.tanh(c_next)
    h_out_ref[...] = h_next.astype(h_out_ref.dtype)
    c_out_ref[...] = c_next.astype(c_out_ref.dtype)


def lstm_cell(x, h, c, w_x, w_h, b, *, interpret: bool = True):
    """One LSTM step as a fused Pallas kernel.

    Shapes: x (B, I), h/c (B, H), w_x (I, 4H), w_h (H, 4H), b (4H,).
    Returns (h_next, c_next).
    """
    batch, hidden = h.shape
    out_shape = [
        jax.ShapeDtypeStruct((batch, hidden), h.dtype),
        jax.ShapeDtypeStruct((batch, hidden), c.dtype),
    ]
    # Bias broadcast: pallas wants explicit 2D refs on TPU; reshape (4H,)
    # to (1, 4H) so the in-kernel add broadcasts over the batch.
    b2 = b.reshape(1, -1)
    h_next, c_next = pl.pallas_call(
        _lstm_cell_kernel,
        out_shape=out_shape,
        interpret=interpret,
    )(x, h, c, w_x, w_h, b2)
    return h_next, c_next


def vmem_footprint_bytes(batch: int, inp: int, hidden: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for the fused cell (§Perf).

    Counts every resident operand plus the (B, 4H) gate intermediate.
    """
    operands = (
        batch * inp  # x
        + 2 * batch * hidden  # h, c
        + inp * 4 * hidden  # w_x
        + hidden * 4 * hidden  # w_h
        + 4 * hidden  # b
        + 2 * batch * hidden  # outputs
        + batch * 4 * hidden  # gates intermediate
    )
    return operands * dtype_bytes


def mxu_utilization_estimate(batch: int, inp: int, hidden: int) -> float:
    """Fraction of MXU lanes doing useful work for the padded tiles.

    The 128×128 MXU pads I and H up; with the paper's I=6, H=20 the
    useful-work fraction is tiny — exactly why the FPGA (sized to the
    problem) wins on energy, which is the paper's premise (§Perf).
    """
    pad = lambda n: max(128, ((n + 127) // 128) * 128)
    useful = batch * inp * 4 * hidden + batch * hidden * 4 * hidden
    padded = pad(batch) * pad(inp) * pad(4 * hidden) + pad(batch) * pad(hidden) * pad(4 * hidden)
    return useful / padded
