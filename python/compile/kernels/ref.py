"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package must match its oracle to float32 tolerance (pytest + hypothesis
sweep shapes and dtypes). Keeping the oracles dependency-free (no pallas,
no custom ops) makes them auditable line-by-line against the LSTM
equations in the paper's reference [13].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, w_x, w_h, b):
    """One LSTM cell step.

    Gate layout follows the JAX/Flax convention: the 4H gate dimension is
    split as [i, f, g, o] (input, forget, cell, output).

    Args:
      x:   (B, I)  input at this timestep
      h:   (B, H)  previous hidden state
      c:   (B, H)  previous cell state
      w_x: (I, 4H) input projection
      w_h: (H, 4H) recurrent projection
      b:   (4H,)   bias

    Returns:
      (h_next, c_next), each (B, H).
    """
    gates = x @ w_x + h @ w_h + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_next = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_next = jax.nn.sigmoid(o) * jnp.tanh(c_next)
    return h_next, c_next


def dense_ref(x, w, b):
    """Dense head: (B, H) @ (H, O) + (O,) -> (B, O)."""
    return x @ w + b


def quantize_ref(x, scale):
    """Symmetric int8 quantization: round(x/scale) clamped to [-127, 127]."""
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8)


def dequantize_ref(q, scale):
    """Inverse of :func:`quantize_ref` (modulo rounding)."""
    return q.astype(jnp.float32) * scale


def lstm_forecast_ref(window, params):
    """Run the LSTM over a (T, I) window and emit a scalar forecast.

    Mirrors the paper's reference-[13] accelerator: hidden-size-20 LSTM,
    dense head on the final hidden state.
    """
    w_x, w_h, b, w_out, b_out = (
        params["w_x"],
        params["w_h"],
        params["b"],
        params["w_out"],
        params["b_out"],
    )
    hidden = w_h.shape[0]
    h = jnp.zeros((1, hidden), dtype=window.dtype)
    c = jnp.zeros((1, hidden), dtype=window.dtype)
    for t in range(window.shape[0]):
        h, c = lstm_cell_ref(window[t : t + 1, :], h, c, w_x, w_h, b)
    return dense_ref(h, w_out, b_out)[0]
