"""L1 Pallas kernels: symmetric int8 quantize / dequantize.

The paper's FPGA accelerator (reference [13]) is fixed-point; these
kernels mirror that numeric regime on the TPU path (DESIGN.md
§Hardware-Adaptation: 8-bit MACs → int8 storage, f32 accumulation). The
quantized forecast variant in `model.py` uses them to bound the accuracy
cost of the fixed-point substitution."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, o_ref, *, inv_scale: float):
    q = jnp.clip(jnp.round(x_ref[...] * inv_scale), -127.0, 127.0)
    o_ref[...] = q.astype(jnp.int8)


def quantize(x, scale: float, *, interpret: bool = True):
    """Symmetric int8 quantization with a static scale."""
    from functools import partial

    return pl.pallas_call(
        partial(_quantize_kernel, inv_scale=1.0 / scale),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int8),
        interpret=interpret,
    )(x)


def _dequantize_kernel(q_ref, o_ref, *, scale: float):
    o_ref[...] = q_ref[...].astype(jnp.float32) * scale


def dequantize(q, scale: float, *, interpret: bool = True):
    """Inverse of :func:`quantize` (modulo rounding)."""
    from functools import partial

    return pl.pallas_call(
        partial(_dequantize_kernel, scale=scale),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=interpret,
    )(q)
