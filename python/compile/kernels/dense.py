"""L1 Pallas kernel: dense output head.

The forecast head that maps the LSTM's final hidden state to the output.
Trivial compute, but kept as its own kernel so the AOT graph mirrors the
FPGA accelerator's structure (LSTM core + dense head as separate pipeline
stages in reference [13])."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    ).astype(o_ref.dtype)


def dense(x, w, b, *, interpret: bool = True):
    """(B, H) @ (H, O) + (O,) -> (B, O) as a Pallas kernel."""
    batch = x.shape[0]
    out = w.shape[1]
    b2 = b.reshape(1, -1)
    return pl.pallas_call(
        _dense_kernel,
        out_shape=jax.ShapeDtypeStruct((batch, out), x.dtype),
        interpret=interpret,
    )(x, w, b2)
