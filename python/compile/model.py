"""L2: the LSTM accelerator model (JAX, build-time only).

The compute payload the FPGA runs per inference request (paper reference
[13]): a hidden-size-20 LSTM over a short time-series window plus a dense
forecast head. Written in JAX calling the L1 Pallas kernels so the whole
forward pass lowers into a single HLO module for the rust runtime.

Weights are *baked into the artifact as constants* — the closest analogue
of an FPGA bitstream, where the trained weights are part of the
configuration image. The rust request path therefore feeds only the
sensor window.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.dense import dense
from compile.kernels.lstm_cell import lstm_cell
from compile.kernels.quant import dequantize, quantize

# The paper's accelerator geometry (reference [13]): hidden size 20.
HIDDEN = 20
# Time-series input: 6 sensor channels over a 24-step window (a typical
# IoT duty-cycle workload shape; the paper's exact window is not given).
INPUT = 6
WINDOW = 24

# Fixed-point scale for the int8 variant (the FPGA accelerator is 8-bit
# fixed point); chosen to cover the [-2, 2] activation range.
QUANT_SCALE = 2.0 / 127.0


def init_params(seed: int = 0x15D4, hidden: int = HIDDEN, inp: int = INPUT):
    """Deterministic 'trained' weights.

    A real deployment would load trained weights; for the reproduction the
    weights only need to be fixed and well-conditioned (scaled-normal init
    keeps activations in the sigmoid/tanh sweet spot).
    """
    k = jax.random.PRNGKey(seed)
    k_wx, k_wh, k_b, k_wo, k_bo = jax.random.split(k, 5)
    scale_x = 1.0 / jnp.sqrt(inp)
    scale_h = 1.0 / jnp.sqrt(hidden)
    return {
        "w_x": jax.random.normal(k_wx, (inp, 4 * hidden), jnp.float32) * scale_x,
        "w_h": jax.random.normal(k_wh, (hidden, 4 * hidden), jnp.float32) * scale_h,
        "b": jax.random.normal(k_b, (4 * hidden,), jnp.float32) * 0.1,
        "w_out": jax.random.normal(k_wo, (hidden, 1), jnp.float32) * scale_h,
        "b_out": jax.random.normal(k_bo, (1,), jnp.float32) * 0.1,
    }


def lstm_step(params, x_t, h, c, *, interpret: bool = True):
    """One cell step through the fused Pallas kernel."""
    return lstm_cell(
        x_t, h, c, params["w_x"], params["w_h"], params["b"], interpret=interpret
    )


def forecast(params, window, *, interpret: bool = True):
    """Full inference: (WINDOW, INPUT) -> scalar forecast.

    `lax.scan` over the fused cell keeps the lowered HLO compact (one loop
    body) — the structural analogue of the FPGA pipeline iterating the
    window through one physical MAC array.
    """
    hidden = params["w_h"].shape[0]
    h0 = jnp.zeros((1, hidden), dtype=window.dtype)
    c0 = jnp.zeros((1, hidden), dtype=window.dtype)

    def body(carry, x_t):
        h, c = carry
        h, c = lstm_step(params, x_t[None, :], h, c, interpret=interpret)
        return (h, c), ()

    (h, _), _ = jax.lax.scan(body, (h0, c0), window)
    return dense(h, params["w_out"], params["b_out"], interpret=interpret)[0]


def forecast_int8(params, window, *, interpret: bool = True):
    """Fixed-point variant: activations quantized to int8 between steps.

    Mirrors the 8-bit FPGA datapath of reference [13]: hidden state is
    stored at int8 precision between cell steps (weights stay f32 here;
    the FPGA keeps them at fixed point in BRAM — the activation path is
    what bounds accuracy).
    """
    hidden = params["w_h"].shape[0]
    h0 = jnp.zeros((1, hidden), dtype=window.dtype)
    c0 = jnp.zeros((1, hidden), dtype=window.dtype)

    def body(carry, x_t):
        h, c = carry
        h, c = lstm_step(params, x_t[None, :], h, c, interpret=interpret)
        h = dequantize(
            quantize(h, QUANT_SCALE, interpret=interpret),
            QUANT_SCALE,
            interpret=interpret,
        )
        return (h, c), ()

    (h, _), _ = jax.lax.scan(body, (h0, c0), window)
    return dense(h, params["w_out"], params["b_out"], interpret=interpret)[0]


def make_synthetic_window(seed: int = 0, t0: float = 0.0):
    """A deterministic sensor window (superposed sines + seeded noise) —
    the synthetic stand-in for the paper's periodically-gathered sensor
    data."""
    t = jnp.arange(WINDOW, dtype=jnp.float32)[:, None] + t0
    ch = jnp.arange(INPUT, dtype=jnp.float32)[None, :]
    base = jnp.sin(0.19 * t + 0.7 * ch) + 0.4 * jnp.sin(0.067 * t * (ch + 1.0))
    noise = 0.05 * jax.random.normal(jax.random.PRNGKey(seed), (WINDOW, INPUT))
    return (base + noise).astype(jnp.float32)


def forecast_batched(params, windows, *, interpret: bool = True):
    """Batched inference: (B, WINDOW, INPUT) -> (B,) forecasts.

    `jax.vmap` over the single-window forecast: XLA fuses the batch into
    the scanned cell's matmuls, so a burst of queued requests costs one
    executable dispatch instead of B — the serving-framework idiom for
    the bursty-arrival case (`coordinator::multi_sim`).
    """
    return jax.vmap(lambda w: forecast(params, w, interpret=interpret)[0])(windows)
