"""AOT lowering: JAX/Pallas model -> HLO text artifacts + manifest.

Run once at build time (`make artifacts`); the rust runtime loads the HLO
text via `HloModuleProto::from_text_file`, compiles it on the PJRT CPU
client and executes it on the request path. Python never runs at serve
time.

Interchange format is HLO *text*, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts (weights baked in as constants — the bitstream analogue):
  lstm_step.hlo.txt       (x(1,6), h(1,20), c(1,20)) -> (h', c')
  lstm_forecast.hlo.txt   (window(24,6),)            -> (forecast(1,),)
  lstm_forecast_int8.hlo.txt  same signature, int8 activation path
  manifest.json           shapes/dtypes for the rust loader
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    `print_large_constants=True` is load-bearing: the default HLO printer
    elides big constants as `{...}`, which the rust side's unverified-
    module parser silently reads back as zeros — the baked LSTM weights
    would vanish. (Caught by the runtime self-check.)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax 0.8 emits source_end_line/column metadata the 0.5.1-era HLO
    # parser on the rust side rejects; metadata is debug-only, drop it.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def build_artifacts(out_dir: str, seed: int = 0x15D4) -> dict:
    """Lower every model variant; returns the manifest dict."""
    params = model.init_params(seed)

    window_spec = jax.ShapeDtypeStruct((model.WINDOW, model.INPUT), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((1, model.INPUT), jnp.float32)
    h_spec = jax.ShapeDtypeStruct((1, model.HIDDEN), jnp.float32)

    # Weights are closed over -> lowered as HLO constants.
    def step_fn(x, h, c):
        return model.lstm_step(params, x, h, c)

    def forecast_fn(window):
        return (model.forecast(params, window),)

    def forecast_int8_fn(window):
        return (model.forecast_int8(params, window),)

    def forecast_batch_fn(windows):
        return (model.forecast_batched(params, windows),)

    entries = [
        {
            "name": "lstm_step",
            "fn": step_fn,
            "specs": [x_spec, h_spec, h_spec],
            "inputs": [[1, model.INPUT], [1, model.HIDDEN], [1, model.HIDDEN]],
            "outputs": [[1, model.HIDDEN], [1, model.HIDDEN]],
        },
        {
            "name": "lstm_forecast",
            "fn": forecast_fn,
            "specs": [window_spec],
            "inputs": [[model.WINDOW, model.INPUT]],
            "outputs": [[1]],
        },
        {
            "name": "lstm_forecast_int8",
            "fn": forecast_int8_fn,
            "specs": [window_spec],
            "inputs": [[model.WINDOW, model.INPUT]],
            "outputs": [[1]],
        },
        {
            "name": "lstm_forecast_batch8",
            "fn": forecast_batch_fn,
            "specs": [
                jax.ShapeDtypeStruct((8, model.WINDOW, model.INPUT), jnp.float32)
            ],
            "inputs": [[8, model.WINDOW, model.INPUT]],
            "outputs": [[8]],
        },
    ]

    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "schema_version": 1,
        "seed": seed,
        "hidden_size": model.HIDDEN,
        "input_size": model.INPUT,
        "window": model.WINDOW,
        "quant_scale": model.QUANT_SCALE,
        "dtype": "f32",
        "artifacts": [],
    }
    for e in entries:
        lowered = jax.jit(e["fn"]).lower(*e["specs"])
        text = to_hlo_text(lowered)
        fname = f"{e['name']}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": e["name"],
                "file": fname,
                "inputs": e["inputs"],
                "outputs": e["outputs"],
            }
        )
        print(f"  lowered {e['name']:24s} -> {fname} ({len(text)} chars)")

    # Reference outputs on a known window so the rust runtime can
    # self-check numerics end to end (quickstart + integration test).
    window = model.make_synthetic_window(seed=0)
    ref_forecast = float(model.forecast(params, window)[0])
    ref_forecast_int8 = float(model.forecast_int8(params, window)[0])
    manifest["selfcheck"] = {
        "window_seed": 0,
        "forecast": ref_forecast,
        "forecast_int8": ref_forecast_int8,
        # full window, row-major, so rust needs no RNG reimplementation
        "window": [float(v) for v in window.reshape(-1)],
    }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json (selfcheck forecast = {ref_forecast:.6f})")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--seed", type=int, default=0x15D4)
    args = parser.parse_args()
    print(f"AOT-lowering LSTM accelerator artifacts into {args.out_dir}")
    build_artifacts(args.out_dir, args.seed)


if __name__ == "__main__":
    main()
