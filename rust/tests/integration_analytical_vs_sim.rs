//! Integration: the analytical model (Eqs 1–4) vs the discrete-event
//! simulation of the full device substrate must agree — the reproduction
//! of the paper's §5.3 validation logic, across strategies and periods.

use idlewait::config::paper_default;
use idlewait::config::schema::{ArrivalSpec, PolicySpec};
use idlewait::coordinator::requests::Periodic;
use idlewait::energy::analytical::Analytical;
use idlewait::strategies::simulate::simulate;
use idlewait::strategies::strategy::build;
use idlewait::util::units::{Duration, Energy};

/// DES driven to the analytical n_max must stay within the (shrunken)
/// budget for every strategy × period combination — Eq 3's criterion.
#[test]
fn des_matches_eq3_across_grid() {
    let mut cfg = paper_default();
    // 20 J budget → a few thousand items max; fast enough for a grid
    cfg.workload.energy_budget = Energy::from_joules(20.0);
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);

    for kind in [
        PolicySpec::OnOff,
        PolicySpec::IdleWaiting,
        PolicySpec::IdleWaitingM1,
        PolicySpec::IdleWaitingM12,
    ] {
        for t_ms in [37.0, 40.0, 60.0, 89.0, 90.0, 120.0] {
            let t_req = Duration::from_millis(t_ms);
            let Some(expected) = model.predict(kind, t_req).n_max else {
                continue;
            };
            let mut capped = cfg.clone();
            capped.workload.arrival = ArrivalSpec::Periodic { period: t_req };
            capped.workload.max_items = Some(expected);
            let mut policy = build(kind, &model);
            let mut arrivals = Periodic { period: t_req };
            let report = simulate(&capped, policy.as_mut(), &mut arrivals);
            assert_eq!(report.items, expected, "{kind} at {t_ms} ms");
            assert!(
                report.energy_exact <= cfg.workload.energy_budget * 1.0005,
                "{kind} at {t_ms} ms: {} J > {} J",
                report.energy_exact.joules(),
                cfg.workload.energy_budget.joules()
            );
        }
    }
}

/// Running one item beyond n_max must break the budget (tightness of Eq 3).
#[test]
fn eq3_is_tight_against_des() {
    let mut cfg = paper_default();
    cfg.workload.energy_budget = Energy::from_joules(5.0);
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let t_req = Duration::from_millis(50.0);
    let n = model
        .n_max_idle_waiting(t_req, model.item.idle_power_baseline)
        .unwrap();

    let mut capped = cfg.clone();
    capped.workload.max_items = Some(n + 1);
    capped.workload.arrival = ArrivalSpec::Periodic { period: t_req };
    let mut policy = build(PolicySpec::IdleWaiting, &model);
    let mut arrivals = Periodic { period: t_req };
    let report = simulate(&capped, policy.as_mut(), &mut arrivals);
    assert!(
        report.energy_exact > cfg.workload.energy_budget,
        "n_max+1 items must exceed the budget ({} J <= {} J)",
        report.energy_exact.joules(),
        cfg.workload.energy_budget.joules()
    );
}

/// Full-budget DES at the paper's 40 ms: the real §5.3 validation run
/// (~1.1M simulated items across both strategies).
#[test]
fn full_budget_validation_at_40ms() {
    let cfg = paper_default();
    let result = idlewait::experiments::validation::run(&cfg, 40.0);
    for row in &result.rows {
        assert!(row.items_gap < 0.002, "{}: {}", row.policy, row.items_gap);
        assert!(row.lifetime_gap < 0.002, "{}", row.policy);
        assert!(row.monitor_rel_error < 0.03);
    }
    // absolute item counts near the paper's Fig 8 values
    let onoff = result.row(PolicySpec::OnOff);
    assert!(onoff.des_items.abs_diff(346_073) < 300, "{}", onoff.des_items);
    let iw = result.row(PolicySpec::IdleWaiting);
    assert!(iw.des_items.abs_diff(771_807) < 800, "{}", iw.des_items);
}

/// The DES's per-item marginal energy must equal the analytical per-item
/// energy for both strategies (differential check, immune to init terms).
#[test]
fn marginal_item_energy_matches() {
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let t_req = Duration::from_millis(40.0);

    for (kind, expected_mj) in [
        (PolicySpec::OnOff, model.item.e_item_onoff().millijoules()),
        (
            PolicySpec::IdleWaiting,
            (model.item.e_active + model.e_idle(t_req, model.item.idle_power_baseline))
                .millijoules(),
        ),
    ] {
        let run = |n: u64| {
            let mut capped = cfg.clone();
            capped.workload.max_items = Some(n);
            let mut policy = build(kind, &model);
            let mut arrivals = Periodic { period: t_req };
            simulate(&capped, policy.as_mut(), &mut arrivals)
                .energy_exact
                .millijoules()
        };
        let e1k = run(1000);
        let e2k = run(2000);
        let marginal = (e2k - e1k) / 1000.0;
        let rel = (marginal - expected_mj).abs() / expected_mj;
        assert!(rel < 5e-4, "{kind}: marginal {marginal} vs {expected_mj}");
    }
}

/// The oracle ≥ best fixed policy on periodic workloads (it should
/// degenerate to the winner, at the M1+2 idle mode it is built with).
#[test]
fn oracle_degenerates_to_winner_on_periodic() {
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    // 40 ms is below, 600 ms above the 499.06 ms M1+2 crossover
    for t_ms in [40.0, 600.0] {
        let t_req = Duration::from_millis(t_ms);
        let oracle = model.predict(PolicySpec::Oracle, t_req).n_max.unwrap();
        let onoff = model.predict(PolicySpec::OnOff, t_req).n_max.unwrap_or(0);
        let iw = model.predict(PolicySpec::IdleWaitingM12, t_req).n_max.unwrap_or(0);
        assert_eq!(oracle, onoff.max(iw), "t={t_ms}");
    }
}
