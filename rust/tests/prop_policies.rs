//! Properties of the online gap policies (via the in-tree mini-prop
//! framework): the deterministic and randomized ski-rental competitive
//! bounds, and the predictors' degeneracy on periodic arrivals.

use idlewait::config::paper_default;
use idlewait::config::schema::ArrivalSpec;
use idlewait::coordinator::requests::{Periodic, TraceReplay};
use idlewait::device::rails::PowerSaving;
use idlewait::energy::analytical::Analytical;
use idlewait::strategies::simulate::{simulate, SimReport};
use idlewait::strategies::strategy::{
    EmaPredictor, IdleWaiting, OnOff, Oracle, Policy, RandomizedSkiRental, Timeout,
    WindowedQuantile,
};
use idlewait::testing::competitive::{competitive_bound, CompetitiveSpec};
use idlewait::testing::prop::{check, Below, InRange};
use idlewait::util::rng::Xoshiro256ss;
use idlewait::util::units::Duration;

fn model() -> Analytical {
    let cfg = paper_default();
    Analytical::new(&cfg.item, cfg.workload.energy_budget)
}

/// Run a policy over an explicit gap trace (each gap used exactly once:
/// n gaps → n+1 items).
fn run_trace(policy: &mut dyn Policy, gaps: &[Duration]) -> SimReport {
    let mut cfg = paper_default();
    cfg.workload.max_items = Some(gaps.len() as u64 + 1);
    let mut arrivals = TraceReplay::new(gaps.to_vec());
    simulate(&cfg, policy, &mut arrivals)
}

/// The DES cost of one power-on + configuration (FSM mechanism), in mJ —
/// measured, so the gap-energy extraction is self-consistent with the
/// simulator rather than with Table 2.
fn config_cycle_mj() -> f64 {
    let mut cfg = paper_default();
    cfg.workload.max_items = Some(1);
    let mut arrivals = Periodic {
        period: Duration::from_millis(40.0),
    };
    let report = simulate(&cfg, &mut OnOff, &mut arrivals);
    let m = model();
    report.energy_exact.millijoules() - m.item.e_active.millijoules()
}

/// Energy attributable to the gaps alone: total minus the active phases
/// and minus the initial configuration. Reconfigurations after power-off
/// gaps stay included — they are the price of the off decision.
fn gap_energy_mj(report: &SimReport, config_cycle_mj: f64) -> f64 {
    let m = model();
    report.energy_exact.millijoules()
        - report.items as f64 * m.item.e_active.millijoules()
        - config_cycle_mj
}

/// Ski-rental bound: on ANY positive gap trace, the Timeout policy at
/// τ = crossover spends at most 2× the clairvoyant oracle's gap energy
/// (plus the ~1e-4 relative FSM-vs-Table-2 config-energy difference).
#[test]
fn prop_timeout_is_2_competitive_vs_oracle() {
    let m = model();
    let c = config_cycle_mj();
    check::<Below<1_000>>("timeout-2-competitive", 12, |seed| {
        let mut rng = Xoshiro256ss::new(seed.0 ^ 0x5C11);
        // gaps straddling the 89.21 ms crossover, heavy on both sides
        let gaps: Vec<Duration> = (0..24)
            .map(|_| {
                if rng.bernoulli(0.5) {
                    Duration::from_millis(rng.uniform(0.5, 89.0))
                } else {
                    Duration::from_millis(rng.uniform(89.5, 1500.0))
                }
            })
            .collect();
        let timeout = gap_energy_mj(
            &run_trace(&mut Timeout::from_model(&m, PowerSaving::BASELINE), &gaps),
            c,
        );
        let oracle = gap_energy_mj(
            &run_trace(&mut Oracle::from_model(&m, PowerSaving::BASELINE), &gaps),
            c,
        );
        timeout <= 2.0 * oracle * 1.01 + 1e-6
    });
}

/// The oracle is a genuine lower bound for the policies it is the
/// benchmark of: never more gap energy than either static policy.
#[test]
fn prop_oracle_lower_bounds_the_statics() {
    let m = model();
    let c = config_cycle_mj();
    check::<Below<1_000>>("oracle-lower-bound", 8, |seed| {
        let mut rng = Xoshiro256ss::new(seed.0 ^ 0x0AC1E);
        let gaps: Vec<Duration> = (0..24)
            .map(|_| Duration::from_millis(rng.uniform(0.5, 1000.0)))
            .collect();
        let oracle = gap_energy_mj(
            &run_trace(&mut Oracle::from_model(&m, PowerSaving::BASELINE), &gaps),
            c,
        );
        let onoff = gap_energy_mj(&run_trace(&mut OnOff, &gaps), c);
        let iw = gap_energy_mj(&run_trace(&mut IdleWaiting::baseline(), &gaps), c);
        let slack = 1.001; // FSM vs Table-2 config-energy tolerance
        oracle <= onoff * slack + 1e-6 && oracle <= iw * slack + 1e-6
    });
}

/// Randomized ski-rental bound: against adversarial constant-gap traces
/// (the worst case for any ski-rental rule is a gap just past the chosen
/// timeout), the *expected* gap energy of `RandomizedSkiRental` — the
/// average over its per-gap timeout draws — stays within
/// e/(e−1) ≈ 1.582 (+ ε for sampling noise and the ~1e-4 FSM-vs-Table-2
/// config-energy difference) of the clairvoyant oracle's. The classic
/// density equalizes the ratio, so this holds on both sides of
/// τ ≈ 89.17 ms; gaps are drawn from 60–400 ms (below ~30 ms the
/// optimum shrinks toward zero and the fire-event noise would need far
/// more draws for the same confidence).
///
/// The seed count is *derived from the evidence*, not fixed: the shared
/// [`competitive_bound`] harness keeps adding seeded realizations until
/// the 95% confidence interval of the mean clears the bound, and the
/// property asserts that the whole interval — not just the point
/// estimate — sits under the limit.
#[test]
fn prop_randomized_ski_rental_is_e_over_e_minus_1_competitive() {
    let m = model();
    let c = config_cycle_mj();
    let bound = std::f64::consts::E / (std::f64::consts::E - 1.0);
    check::<InRange<60, 400>>("randomized-ski-rental-ratio", 10, |gap_ms| {
        let gaps = vec![Duration::from_millis(gap_ms.0); 120];
        let oracle = gap_energy_mj(
            &run_trace(&mut Oracle::from_model(&m, PowerSaving::BASELINE), &gaps),
            c,
        );
        let spec = CompetitiveSpec {
            slack: 1.08,
            // genuinely randomized: never materially below the optimum
            floor_frac: 0.95,
            ..CompetitiveSpec::new("randomized-ski-rental", oracle, bound)
        };
        // expectation over the timeout draw: seeded runs until the
        // interval is decisive
        let report = competitive_bound(&spec, |seed| {
            let mut p =
                RandomizedSkiRental::from_model(&m, PowerSaving::BASELINE, None, 0xBEE5 + seed);
            gap_energy_mj(&run_trace(&mut p, &gaps), c)
        });
        if !report.holds() {
            eprintln!("gap {} ms: {}", gap_ms.0, report.render());
        }
        report.holds()
    });
}

/// On strictly periodic arrivals below the crossover, the windowed
/// quantile degenerates to the exact crossover decision — i.e. to
/// Idle-Waiting, bit-for-bit on energy: the hedged first gap already
/// pure-idles (idle window < τ), and every later windowed quantile of a
/// constant gap equals the period.
#[test]
fn windowed_quantile_degenerates_to_idle_waiting_below_crossover() {
    let mut cfg = paper_default();
    cfg.workload.max_items = Some(400);
    let m = model();
    let run = |policy: &mut dyn Policy| {
        let mut arrivals = Periodic {
            period: Duration::from_millis(40.0),
        };
        simulate(&cfg, policy, &mut arrivals)
    };
    let wq = run(&mut WindowedQuantile::from_model(
        &m,
        PowerSaving::BASELINE,
        32,
        0.9,
    ));
    let iw = run(&mut IdleWaiting::baseline());
    assert_eq!(wq.items, iw.items);
    assert_eq!(wq.configurations, 1);
    assert_eq!(wq.decisions.idled, 399);
    assert_eq!(wq.decisions.powered_off, 0);
    assert_eq!(wq.energy_exact, iw.energy_exact, "exact degeneracy");
}

/// Above the crossover the windowed quantile converges to On-Off after
/// the single hedged first gap, paying at most one ski-rental premium
/// (τ · P_idle) over the pure On-Off run — the other side of the exact
/// crossover decision.
#[test]
fn windowed_quantile_degenerates_to_onoff_above_crossover() {
    let mut cfg = paper_default();
    cfg.workload.arrival = ArrivalSpec::Periodic {
        period: Duration::from_millis(200.0),
    };
    cfg.workload.max_items = Some(400);
    let m = model();
    let run = |policy: &mut dyn Policy| {
        let mut arrivals = Periodic {
            period: Duration::from_millis(200.0),
        };
        simulate(&cfg, policy, &mut arrivals)
    };
    let wq = run(&mut WindowedQuantile::from_model(
        &m,
        PowerSaving::BASELINE,
        32,
        0.9,
    ));
    let onoff = run(&mut OnOff);
    assert_eq!(wq.items, onoff.items);
    // first gap: hedge (timer expires), then pure power-off decisions
    assert_eq!(wq.decisions.timeouts_expired, 1);
    assert_eq!(wq.decisions.powered_off, 399);
    assert_eq!(wq.configurations, onoff.configurations);
    let tau = idlewait::energy::crossover::ski_rental_timeout(&m, m.item.idle_power_baseline);
    let premium_mj = (m.item.idle_power_baseline * tau).millijoules();
    let extra = wq.energy_exact.millijoules() - onoff.energy_exact.millijoules();
    assert!(
        extra >= 0.0 && extra <= premium_mj * 1.01,
        "extra {extra} vs premium {premium_mj}"
    );
}

/// The windowed quantile never plans worse than the hedged cold start on
/// a two-mode gap mix where both modes sit on the same side of the
/// crossover: once the window warms up, every quantile of the window is
/// inside the mode range, so the decision matches the oracle's for every
/// gap in that range.
#[test]
fn prop_windowed_quantile_matches_oracle_when_modes_agree() {
    let m = model();
    let c = config_cycle_mj();
    check::<Below<1_000>>("quantile-matches-oracle-same-side", 8, |seed| {
        let mut rng = Xoshiro256ss::new(seed.0 ^ 0x7A11);
        // all gaps strictly below the 89.21 ms baseline crossover
        let gaps: Vec<Duration> = (0..40)
            .map(|_| Duration::from_millis(rng.uniform(5.0, 80.0)))
            .collect();
        let wq = gap_energy_mj(
            &run_trace(
                &mut WindowedQuantile::from_model(&m, PowerSaving::BASELINE, 16, 0.5),
                &gaps,
            ),
            c,
        );
        let oracle = gap_energy_mj(
            &run_trace(&mut Oracle::from_model(&m, PowerSaving::BASELINE), &gaps),
            c,
        );
        // identical decisions after the first (hedged, pure-idle) gap
        (wq - oracle).abs() < 1e-6
    });
}

/// On strictly periodic arrivals below the crossover, the EMA predictor
/// degenerates to Idle-Waiting exactly: its hedged first gap already
/// pure-idles (idle window < τ), and every later prediction equals the
/// period.
#[test]
fn ema_degenerates_to_idle_waiting_below_crossover() {
    let mut cfg = paper_default();
    cfg.workload.max_items = Some(400);
    let m = model();
    let run = |policy: &mut dyn Policy| {
        let mut arrivals = Periodic {
            period: Duration::from_millis(40.0),
        };
        simulate(&cfg, policy, &mut arrivals)
    };
    let ema = run(&mut EmaPredictor::from_model(
        &m,
        PowerSaving::BASELINE,
        EmaPredictor::DEFAULT_ALPHA,
    ));
    let iw = run(&mut IdleWaiting::baseline());
    assert_eq!(ema.items, iw.items);
    assert_eq!(ema.configurations, 1);
    assert_eq!(ema.decisions.idled, 399);
    assert_eq!(ema.decisions.powered_off, 0);
    assert_eq!(ema.energy_exact, iw.energy_exact, "exact degeneracy");
}

/// Above the crossover the EMA predictor converges to On-Off after the
/// single hedged first gap, paying at most one ski-rental premium
/// (τ · P_idle) over the pure On-Off run.
#[test]
fn ema_degenerates_to_onoff_above_crossover() {
    let mut cfg = paper_default();
    cfg.workload.arrival = ArrivalSpec::Periodic {
        period: Duration::from_millis(200.0),
    };
    cfg.workload.max_items = Some(400);
    let m = model();
    let run = |policy: &mut dyn Policy| {
        let mut arrivals = Periodic {
            period: Duration::from_millis(200.0),
        };
        simulate(&cfg, policy, &mut arrivals)
    };
    let ema = run(&mut EmaPredictor::from_model(
        &m,
        PowerSaving::BASELINE,
        EmaPredictor::DEFAULT_ALPHA,
    ));
    let onoff = run(&mut OnOff);
    assert_eq!(ema.items, onoff.items);
    // first gap: hedge (timer expires), then pure power-off decisions
    assert_eq!(ema.decisions.timeouts_expired, 1);
    assert_eq!(ema.decisions.powered_off, 399);
    assert_eq!(ema.configurations, onoff.configurations);
    let tau = idlewait::energy::crossover::ski_rental_timeout(&m, m.item.idle_power_baseline);
    let premium_mj = (m.item.idle_power_baseline * tau).millijoules();
    let extra = ema.energy_exact.millijoules() - onoff.energy_exact.millijoules();
    assert!(
        extra >= 0.0 && extra <= premium_mj * 1.01,
        "extra {extra} vs premium {premium_mj}"
    );
}
