//! Failure injection: every subsystem must fail loudly and cleanly, not
//! silently account wrong energy.

use idlewait::config::loader::{load_str, LoadError, PAPER_DEFAULT_YAML};
use idlewait::config::paper_default;
use idlewait::config::schema::{FpgaModel, SpiConfig};
use idlewait::coordinator::requests::Periodic;
use idlewait::device::board::{Board, BoardError};
use idlewait::device::flash::{Flash, FlashError};
use idlewait::device::fpga::{Fpga, FpgaError};
use idlewait::device::rails::PowerSaving;
use idlewait::energy::analytical::Analytical;
use idlewait::strategies::simulate::simulate;
use idlewait::strategies::strategy::OnOff;
use idlewait::util::units::{Duration, Energy, Power};

// ---- device-level misuse ----

#[test]
fn configure_unpowered_fpga_rejected() {
    let mut fpga = Fpga::new(FpgaModel::Xc7s15);
    let flash = Flash::new();
    assert!(matches!(
        fpga.configure(&flash, "lstm", SpiConfig::optimal()),
        Err(FpgaError::PoweredOff(_))
    ));
}

#[test]
fn inference_without_configuration_rejected() {
    let mut fpga = Fpga::new(FpgaModel::Xc7s15);
    fpga.power_on();
    assert!(matches!(fpga.begin_work(), Err(FpgaError::NotConfigured)));
}

#[test]
fn missing_bitstream_slot_rejected() {
    let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
    let err = board.power_on_and_configure("wrong_slot", SpiConfig::optimal());
    assert!(matches!(
        err,
        Err(BoardError::Fpga(FpgaError::Flash(FlashError::EmptySlot(_))))
    ));
}

#[test]
fn unsupported_spi_settings_rejected_by_flash() {
    let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
    for bad in [
        SpiConfig { buswidth: 8, freq_mhz: 33.0, compressed: false },
        SpiConfig { buswidth: 4, freq_mhz: 80.0, compressed: false },
        SpiConfig { buswidth: 4, freq_mhz: 1.0, compressed: false },
    ] {
        // note: board tracks a fresh power-on per attempt
        let result = board.power_on_and_configure("lstm", bad);
        assert!(result.is_err(), "{bad:?} must be rejected");
        board.fpga.power_off();
    }
}

#[test]
fn configuration_lost_after_power_cycle_enforced() {
    let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
    board
        .power_on_and_configure("lstm", SpiConfig::optimal())
        .unwrap();
    board.fpga.power_off();
    board.fpga.power_on();
    // attempting to work without reconfiguring is an error, not silence
    assert!(board
        .run_item_phases(&[(Power::from_milliwatts(100.0), Duration::from_millis(1.0))])
        .is_err());
}

// ---- budget exhaustion mid-operation ----

#[test]
fn exhaustion_during_configuration_stops_cleanly() {
    let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
    // drain to just under one configuration's worth
    let remaining = Energy::from_millijoules(5.0);
    let drain = board.battery.remaining() - remaining;
    board.spend(Power::from_watts(1.0), drain / Power::from_watts(1.0)).unwrap();
    let before_items = board.fpga.configurations;
    let err = board.power_on_and_configure("lstm", SpiConfig::optimal());
    assert!(matches!(err, Err(BoardError::Exhausted(_))));
    // configuration was attempted exactly once; energy never exceeded cap
    assert_eq!(board.fpga.configurations, before_items + 1);
    assert!(board.battery.drawn() <= board.battery.capacity());
}

#[test]
fn simulate_stops_at_exhaustion_without_counting_partial_item() {
    let mut cfg = paper_default();
    // budget fits exactly 2 On-Off items plus change
    cfg.workload.energy_budget = Energy::from_millijoules(25.0);
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let expected = model.n_max_onoff(Duration::from_millis(40.0)).unwrap();
    assert_eq!(expected, 2);
    // the full-board simulate uses the 4147 J battery; emulate the small
    // budget via max_items and verify the DES energy for 2 items fits
    cfg.workload.max_items = Some(expected);
    let mut arrivals = Periodic {
        period: Duration::from_millis(40.0),
    };
    let report = simulate(&cfg, &mut OnOff, &mut arrivals);
    assert_eq!(report.items, 2);
    assert!(report.energy_exact <= cfg.workload.energy_budget);
}

// ---- config-layer failures ----

#[test]
fn zoo_of_malformed_configs() {
    let cases: Vec<(String, &str)> = vec![
        (PAPER_DEFAULT_YAML.replace("strategy: idle-waiting", "strategy: wrong"), "strategy"),
        (PAPER_DEFAULT_YAML.replace("energy_budget_j: 4147", "energy_budget_j: nope"), "number"),
        (PAPER_DEFAULT_YAML.replace("power_mw: 327.9", "power_mw: -1"), "positive"),
        (PAPER_DEFAULT_YAML.replace("model: XC7S15", "model: VIRTEX7"), "FPGA"),
        (PAPER_DEFAULT_YAML.replace("request_period_ms: 40.0", "request_period_ms: 0"), "positive"),
    ];
    for (doc, needle) in cases {
        let err = load_str(&doc).unwrap_err();
        let msg = format!("{err:#}").to_lowercase();
        assert!(
            msg.contains(&needle.to_lowercase()),
            "expected '{needle}' in '{msg}'"
        );
    }
}

#[test]
fn yaml_injection_of_unsupported_features_rejected() {
    for feature in ["a: &x 1", "a: *x", "a: !tag v", "a: |\n  block", "a: {f: 1}"] {
        assert!(matches!(load_str(feature), Err(LoadError::Yaml(_))), "{feature}");
    }
}

// ---- runtime failures (artifact layer) ----

#[test]
fn corrupt_manifest_rejected() {
    let dir = std::env::temp_dir().join("idlewait_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(idlewait::runtime::artifact::Manifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), "{\"artifacts\": []}").unwrap();
    assert!(idlewait::runtime::artifact::Manifest::load(&dir).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn retention_mode_blocks_work_until_exit() {
    let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
    board
        .power_on_and_configure("lstm", SpiConfig::optimal())
        .unwrap();
    board.fpga.enter_idle(PowerSaving::M12).unwrap();
    // begin_work restores rails (the paper verified config survives);
    // but the state machine must pass through the idle-exit path — the
    // invariant is that work NEVER happens at retention voltage.
    board.fpga.begin_work().unwrap();
    assert_eq!(board.fpga.state.name(), "busy");
}

#[test]
fn double_power_on_is_a_bug_in_debug() {
    // power_on on an already-on FPGA indicates a driver bug; debug builds
    // assert. In release it is tolerated (idempotent rails) — here we
    // only verify the off→on→off→on path stays consistent.
    let mut fpga = Fpga::new(FpgaModel::Xc7s15);
    fpga.power_on();
    fpga.power_off();
    fpga.power_on();
    assert_eq!(fpga.power_ons, 2);
}
