//! Fleet DES guarantees: byte-identical output at any thread count, a
//! size-1 homogeneous fleet bit-equal to the single-device batched
//! simulator, and the routing phase cross-checked against the
//! event-driven `multi_sim` energy accounting.

use idlewait::config::paper_default;
use idlewait::config::schema::{FleetClassSpec, PolicyParams, PolicySpec};
use idlewait::coordinator::fleet::{run_fleet, survey_device, FleetOptions, Placement};
use idlewait::coordinator::multi_sim::{run as run_multi, MultiSimConfig};
use idlewait::coordinator::scheduler::Policy as SchedPolicy;
use idlewait::coordinator::tracegen::{generate_durations, TraceKind};
use idlewait::energy::analytical::Analytical;
use idlewait::runner::grid::derive_seed;
use idlewait::runner::SweepRunner;
use idlewait::strategies::simulate::simulate_batch;
use idlewait::strategies::strategy::build_with;
use idlewait::testing::assert_sim_reports_bit_identical;
use idlewait::util::units::Energy;

/// A heterogeneous 1000-device fleet (4 survey shards, mixture draws,
/// reservoir merging, routing) rendered at `--threads 1` vs several
/// parallel widths: the report and the CSV must be byte-identical. One
/// class runs the contextual bandit, so a device's online cell state is
/// part of what must not leak across shards or schedule orders.
#[test]
fn fleet_output_identical_at_any_thread_count() {
    let mut cfg = paper_default();
    cfg.fleet.devices = 1000;
    cfg.fleet.seed = 99;
    cfg.fleet.classes = vec![
        FleetClassSpec {
            weight: 3.0,
            policy: PolicySpec::IdleWaitingM12,
            params: PolicyParams::default(),
            battery: None,
        },
        FleetClassSpec {
            weight: 1.0,
            policy: PolicySpec::RandomizedSkiRental,
            params: PolicyParams::default(),
            battery: Some(Energy::from_joules(2000.0)),
        },
        FleetClassSpec {
            weight: 1.0,
            policy: PolicySpec::BanditPolicy,
            params: PolicyParams::default(),
            battery: None,
        },
    ];
    let options = FleetOptions {
        steps: 24,
        requests: 120,
        placement: Placement::PreferIdleAwake,
    };
    let reference = run_fleet(&cfg, &options, &SweepRunner::single()).unwrap();
    let ref_text = reference.render();
    let ref_csv = reference.to_csv().render();
    for threads in [2, 3, 7, 16] {
        let report = run_fleet(&cfg, &options, &SweepRunner::new(threads)).unwrap();
        assert_eq!(report.render(), ref_text, "render, threads={threads}");
        assert_eq!(report.to_csv().render(), ref_csv, "csv, threads={threads}");
    }
}

/// A size-1 homogeneous fleet's survey is the single-device batched
/// simulator: every `SimReport` field bit-equal to `simulate_batch` with
/// the device-0 derived seed — including a seed-sensitive randomized
/// policy, so the per-device seed plumbing is what's being pinned.
#[test]
fn size_one_fleet_matches_simulate_batch_bit_for_bit() {
    let mut cfg = paper_default();
    cfg.fleet.devices = 1;
    cfg.fleet.seed = 123;
    cfg.workload.policy = PolicySpec::RandomizedSkiRental;
    let gaps = generate_durations(TraceKind::BurstyIot, 96, 40.0, 5);

    let fleet_report = survey_device(&cfg, &gaps, 0);

    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let mut params = cfg.workload.params;
    params.seed = derive_seed(cfg.fleet.seed, 0);
    let mut policy = build_with(cfg.workload.policy, &model, &params);
    let solo_report = simulate_batch(&cfg, policy.as_mut(), &gaps);

    assert_sim_reports_bit_identical(&fleet_report, &solo_report, "size-1 fleet vs simulate_batch");
}

/// The routing phase against `multi_sim` semantics: a 2-device
/// prefer-configured fleet concentrates a periodic stream on one
/// device that configures once and never misses — the same shape the
/// event-driven multi-accelerator simulation produces for a pure
/// single-slot FIFO stream — and the two accountings agree on total
/// energy to within 5%.
#[test]
fn prefer_configured_routing_matches_multi_sim_energy() {
    let requests = 400u64;
    let mut cfg = paper_default();
    cfg.fleet.devices = 2;
    cfg.fleet.seed = 7;

    let options = FleetOptions {
        steps: 0,
        requests: requests as usize,
        placement: Placement::PreferConfigured,
    };
    let fleet = run_fleet(&cfg, &options, &SweepRunner::single())
        .unwrap()
        .route;
    assert_eq!(fleet.served, requests);
    assert_eq!(fleet.dropped, 0);
    assert_eq!(fleet.deaths, 0);
    assert_eq!(fleet.misses, 0);
    // prefer-configured sticks to the device it warmed up: exactly one
    // configuration, the second device untouched
    assert_eq!(fleet.configurations, 1);
    let items = fleet.device_items.as_ref().unwrap();
    assert_eq!(items.max, requests as f64);
    assert_eq!(items.min, 0.0);

    let multi = run_multi(
        &cfg,
        &MultiSimConfig {
            mix: 0.0, // every request targets slot A: one image, FIFO order
            requests,
            burst: 1,
            policy: SchedPolicy::Fifo,
            gap_policy: cfg.workload.policy,
            slot_policies: Vec::new(),
            seed: 7,
        },
    );
    assert_eq!(multi.served, requests);
    assert_eq!(multi.reordered, 0);
    assert!(multi.reconfigurations <= 1, "{}", multi.reconfigurations);

    let fleet_j = fleet.total_energy.joules();
    let multi_j = multi.energy.joules();
    let rel = (fleet_j - multi_j).abs() / multi_j;
    assert!(
        rel < 0.05,
        "fleet {fleet_j:.4} J vs multi_sim {multi_j:.4} J (rel {rel:.4})"
    );
}
