//! Determinism and conservation obligations of the fault-injection
//! subsystem: the `repro faults` sweep must be byte-identical at any
//! thread count, a fault stream must be a pure function of its seed, and
//! every joule the retry machinery reports destroyed must be a joule the
//! battery actually drew — pinned down to a hand-computed closed form
//! for the 1-failure-1-retry case, compared bit for bit.

use idlewait::config::paper_default;
use idlewait::config::schema::{FaultSpec, PolicySpec};
use idlewait::device::calib::POWER_ON_TRANSIENT_MJ;
use idlewait::device::config_fsm::ConfigProfile;
use idlewait::device::faults::{ConfigFaultKind, FaultState};
use idlewait::device::flash::StoredImage;
use idlewait::device::Bitstream;
use idlewait::energy::analytical::Analytical;
use idlewait::experiments::faults::{run_threaded, FaultsConfig};
use idlewait::runner::SweepRunner;
use idlewait::strategies::simulate::simulate_batch;
use idlewait::strategies::strategy::build;
use idlewait::util::units::{Duration, Energy};

/// The sweep grid is scheduled across worker threads, but every cell's
/// fault stream is seeded from the experiment seed and the cell index
/// alone — so the CSV (every float formatted from its exact bits) must
/// be byte-identical at 1, 4, and all-cores thread counts.
#[test]
fn fault_sweep_csv_is_byte_identical_at_any_thread_count() {
    let cfg = paper_default();
    let fc = FaultsConfig {
        items: 200,
        ..FaultsConfig::default()
    };
    let reference = run_threaded(&cfg, &fc, &SweepRunner::single());
    let ref_csv = reference.to_csv().render();
    let ref_render = reference.render();
    for runner in [SweepRunner::new(4), SweepRunner::auto()] {
        let r = run_threaded(&cfg, &fc, &runner);
        assert_eq!(r.to_csv().render(), ref_csv, "CSV must not depend on threads");
        assert_eq!(r.render(), ref_render, "report must not depend on threads");
    }
    // the sweep exercised the fault machinery at all
    assert!(
        reference.rows.iter().any(|r| r.retries > 0),
        "sweep produced no retries — fault rates not wired through"
    );
}

/// A fault stream is a pure function of `(spec, seed)`: two streams with
/// the same seed agree on every question; a different seed diverges.
#[test]
fn same_seed_means_same_fault_sequence() {
    let spec = FaultSpec {
        config_crc_rate: 0.2,
        spi_corrupt_rate: 0.2,
        brownout_config_rate: 0.1,
        flash_read_rate: 0.1,
        brownout_infer_rate: 0.2,
        ..FaultSpec::none()
    };
    let mut a = FaultState::with_seed(&spec, 99);
    let mut b = FaultState::with_seed(&spec, 99);
    let mut c = FaultState::with_seed(&spec, 100);
    let mut diverged = false;
    for i in 0..200 {
        let (fa, fb, fc) = (
            a.next_config_fault(),
            b.next_config_fault(),
            c.next_config_fault(),
        );
        assert_eq!(fa, fb, "draw {i}: same seed must give the same fault");
        diverged |= fa != fc;
        assert_eq!(a.next_infer_fault(), b.next_infer_fault(), "infer draw {i}");
    }
    assert_eq!(a.draws(), b.draws());
    assert_eq!(a.counters(), b.counters());
    assert!(diverged, "200 draws from different seeds never diverged");
}

/// The 1-failure-1-retry closed form, bit for bit. A CRC fault is only
/// detectable once the full bitstream is in (fraction pinned to 1.0), so
/// a run whose *first* configuration attempt CRC-faults destroys exactly
///
/// ```text
/// inrush + Σ stage_power × span   (spans replaying the truncated walk)
/// ```
///
/// and because that attempt is the first energy event of the run, the
/// ledger's delta is an exact left-fold from zero — the hand computation
/// below reproduces it to the last bit of the f64.
#[test]
fn one_retry_closed_form_matches_bit_for_bit() {
    let mut cfg = paper_default();
    cfg.workload.max_items = Some(1);
    let gaps = [Duration::from_millis(40.0)];
    let spec_with_seed = |seed: u64| FaultSpec {
        config_crc_rate: 0.5,
        seed,
        ..FaultSpec::none()
    };
    // find a seed whose first question faults (CRC) and second is clean —
    // P ≈ 0.25 per seed, so the search space is far more than enough
    let mut chosen = None;
    for seed in 0..4096u64 {
        let mut probe = FaultState::new(&spec_with_seed(seed));
        let first = probe.next_config_fault();
        let second = probe.next_config_fault();
        if let (Some(f), None) = (first, second) {
            if f.kind == ConfigFaultKind::CrcError {
                chosen = Some((seed, f));
                break;
            }
        }
    }
    let (seed, fault) = chosen.expect("a CRC-then-clean seed exists in 0..4096");
    assert_eq!(fault.fraction, 1.0, "CRC faults waste the full load");

    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let mut policy = build(PolicySpec::IdleWaiting, &model);
    let clean = simulate_batch(&cfg, policy.as_mut(), &gaps);
    let mut faulted_cfg = cfg.clone();
    faulted_cfg.faults = spec_with_seed(seed);
    let mut policy = build(PolicySpec::IdleWaiting, &model);
    let faulted = simulate_batch(&faulted_cfg, policy.as_mut(), &gaps);

    assert_eq!(faulted.retries, 1);
    assert_eq!(faulted.shed_requests, 0);
    assert_eq!(faulted.items, clean.items, "the retry still serves the item");

    // hand-replay the partial attempt: the same profile the sim's cost
    // table caches, the same inrush constant, the same truncated walk
    let image = StoredImage::new(
        Bitstream::lstm_accelerator(cfg.platform.fpga),
        cfg.platform.spi.compressed,
    );
    let profile = ConfigProfile::compute(cfg.platform.fpga, cfg.platform.spi, &image);
    let cutoff = profile.total_time() * fault.fraction;
    let mut elapsed = Duration::ZERO;
    let mut destroyed = Energy::ZERO;
    destroyed += Energy::from_millijoules(POWER_ON_TRANSIENT_MJ);
    for s in &profile.stages {
        if elapsed >= cutoff {
            break;
        }
        let span = s.time.min(cutoff - elapsed);
        destroyed += s.power * span;
        elapsed += span;
    }
    assert_eq!(
        faulted.recovery_energy.joules().to_bits(),
        destroyed.joules().to_bits(),
        "ledger {} J vs closed form {} J",
        faulted.recovery_energy.joules(),
        destroyed.joules()
    );
    // conservation: the faulted run drew exactly the destroyed energy on
    // top of the clean run (backoff passes time powered off, no energy)
    let delta = faulted.energy_exact.joules() - clean.energy_exact.joules();
    assert!(
        (delta - destroyed.joules()).abs() < 1e-12,
        "delta {delta} J vs destroyed {} J",
        destroyed.joules()
    );
    assert!(faulted.energy_exact > clean.energy_exact);
    // one extra power-on (the failed attempt), no extra configuration
    assert_eq!(faulted.power_ons, clean.power_ons + 1);
    assert_eq!(faulted.configurations, clean.configurations);
}

/// Across the whole sweep, destroyed energy stays within the total drawn
/// (the ledger never invents joules) and is zero exactly when no retry
/// fired.
#[test]
fn recovery_energy_never_exceeds_total_drawn() {
    let cfg = paper_default();
    let fc = FaultsConfig {
        items: 200,
        ..FaultsConfig::default()
    };
    let r = run_threaded(&cfg, &fc, &SweepRunner::auto());
    for row in &r.rows {
        assert!(
            row.recovery_energy_mj <= row.energy_mj,
            "{}/{}: destroyed {} mJ > drawn {} mJ",
            row.rate,
            row.policy,
            row.recovery_energy_mj,
            row.energy_mj
        );
        if row.retries == 0 {
            assert_eq!(
                row.recovery_energy_mj, 0.0,
                "{}/{}: recovery energy without a retry",
                row.rate, row.policy
            );
        }
    }
}
