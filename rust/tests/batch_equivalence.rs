//! Batched-kernel equivalence: the structure-of-arrays gap kernel
//! (`GapBatch` + `ReplayCore::execute_batch` + the chunked trace driver)
//! must be **bit-identical** to the scalar event-driven fast path AND to
//! the golden `Board`-FSM reference on every `SimReport` field, for
//! every policy on every bundled corpus trace, at trace sizes straddling
//! every chunk boundary (1, `GAP_BATCH` − 1, `GAP_BATCH`,
//! `GAP_BATCH` + 1, full trace). This suite is the proof obligation the
//! batched perf win carries: a kernel that drifts by one ULP — or plans
//! one gap too many near a chunk edge — fails here.

use std::path::Path;
use std::sync::Arc;

use idlewait::config::paper_default;
use idlewait::config::schema::PolicySpec;
use idlewait::coordinator::requests::{trace_mean, TraceReplay};
use idlewait::energy::analytical::Analytical;
use idlewait::strategies::simulate::{
    simulate, simulate_batch, simulate_golden, PrefixSim, SimWorker, GAP_BATCH,
};
use idlewait::strategies::strategy::build;
use idlewait::testing::assert_sim_reports_bit_identical as assert_identical;
use idlewait::util::units::Duration;

fn corpus_traces() -> Vec<(String, Vec<Duration>)> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../workloads");
    ["bursty_iot.csv", "diurnal_poisson.csv", "onoff_mmpp.csv"]
        .iter()
        .map(|name| {
            let replay = TraceReplay::from_file(root.join(name)).expect("bundled corpus trace");
            (name.to_string(), replay.gaps().to_vec())
        })
        .collect()
}

/// The chunk-boundary-straddling prefix sizes for a trace of `len` gaps,
/// clamped and deduplicated (the 256-gap corpus trace collapses the
/// `GAP_BATCH`/`GAP_BATCH + 1`/full cases into two).
fn boundary_sizes(len: usize) -> Vec<usize> {
    let mut sizes: Vec<usize> = [1, GAP_BATCH - 1, GAP_BATCH, GAP_BATCH + 1, len]
        .iter()
        .map(|&n| n.min(len))
        .collect();
    sizes.sort_unstable();
    sizes.dedup();
    sizes
}

/// Every `PolicySpec` × every corpus trace × every chunk-boundary size:
/// batched == scalar fast == scalar golden, bit for bit on every field.
#[test]
fn every_policy_every_trace_every_boundary_is_bit_identical() {
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    for (trace_name, gaps) in corpus_traces() {
        for n in boundary_sizes(gaps.len()) {
            let slice = &gaps[..n];
            let mut capped = cfg.clone();
            capped.workload.max_items = Some(n as u64 + 1);
            for spec in PolicySpec::ALL {
                let tag = format!("{spec} on {trace_name}[..{n}]");
                let mut policy = build(spec, &model);
                let batched = simulate_batch(&capped, policy.as_mut(), slice);
                let mut policy = build(spec, &model);
                let mut arrivals = TraceReplay::new(slice.to_vec());
                let scalar = simulate(&capped, policy.as_mut(), &mut arrivals);
                assert_identical(&batched, &scalar, &format!("batched vs scalar: {tag}"));
                let mut policy = build(spec, &model);
                let mut arrivals = TraceReplay::new(slice.to_vec());
                let golden = simulate_golden(&capped, policy.as_mut(), &mut arrivals);
                assert_identical(&batched, &golden, &format!("batched vs golden: {tag}"));
            }
        }
    }
}

/// Installing an all-zero `FaultSpec` — even with non-default seed and
/// retry knobs — is invisible: batched and scalar runs stay bit-identical
/// to the stock fault-free config on every field. Disabled fault
/// machinery must cost nothing: not one RNG draw, not one ULP.
#[test]
fn fault_spec_none_leaves_every_path_bit_identical() {
    use idlewait::config::schema::FaultSpec;
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let (trace_name, gaps) = corpus_traces().swap_remove(2);
    let mut capped = cfg.clone();
    capped.workload.max_items = Some(gaps.len() as u64 + 1);
    let mut dressed_cfg = capped.clone();
    dressed_cfg.faults = FaultSpec::none();
    dressed_cfg.faults.seed = 0xDEAD_BEEF;
    dressed_cfg.faults.retry_max = 9;
    for spec in PolicySpec::ALL {
        let tag = format!("{spec} on {trace_name}: FaultSpec::none");
        let mut policy = build(spec, &model);
        let plain = simulate_batch(&capped, policy.as_mut(), &gaps);
        let mut policy = build(spec, &model);
        let dressed = simulate_batch(&dressed_cfg, policy.as_mut(), &gaps);
        assert_identical(&plain, &dressed, &format!("batched: {tag}"));
        let mut policy = build(spec, &model);
        let mut arrivals = TraceReplay::new(gaps.clone());
        let scalar = simulate(&dressed_cfg, policy.as_mut(), &mut arrivals);
        assert_identical(&plain, &scalar, &format!("scalar: {tag}"));
        assert_eq!(dressed.retries, 0);
        assert_eq!(dressed.shed_requests, 0);
    }
}

/// The batched driver on a golden-reference worker (`SimWorker::golden`
/// + `run_batch`) equals the scalar golden path: chunking composes with
/// the `Board` FSM, not just with the gap-cost kernel.
#[test]
fn batched_golden_worker_matches_scalar_golden_on_the_corpus() {
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    for (trace_name, gaps) in corpus_traces() {
        let mut capped = cfg.clone();
        capped.workload.max_items = Some(gaps.len() as u64 + 1);
        for spec in [
            PolicySpec::OnOff,
            PolicySpec::Timeout,
            PolicySpec::WindowedQuantile,
            PolicySpec::BayesMixture,
            PolicySpec::BanditPolicy,
        ] {
            let mut policy = build(spec, &model);
            let batched = SimWorker::golden(&capped).run_batch(
                &capped,
                policy.as_mut(),
                &gaps,
                &format!("trace({} gaps)", gaps.len()),
                trace_mean(&gaps),
            );
            let mut policy = build(spec, &model);
            let mut arrivals = TraceReplay::new(gaps.clone());
            let golden = simulate_golden(&capped, policy.as_mut(), &mut arrivals);
            assert_identical(&batched, &golden, &format!("{spec} on {trace_name} (golden)"));
        }
    }
}

/// Resuming a `PrefixSim` across chunk boundaries (`GAP_BATCH` − 1 →
/// `GAP_BATCH` + 1 → full trace) equals from-scratch capped runs: a
/// resumed run chunks the tail differently than a fresh run chunks the
/// whole, which must never change a value — only the grouping of work.
/// The learned policies are the sharpest case: their posterior/cell
/// state carries across the resume and must land bit-identical to a
/// fresh policy replaying the same prefix.
#[test]
fn prefix_resume_across_chunk_boundaries_matches_from_scratch() {
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    // diurnal_poisson: 384 gaps > GAP_BATCH + 1, so every rung is real
    let (name, gaps) = corpus_traces().swap_remove(1);
    assert!(gaps.len() > GAP_BATCH + 1, "corpus trace shorter than a chunk");
    let shared: Arc<[Duration]> = gaps.clone().into();
    for spec in [
        PolicySpec::IdleWaitingM12,
        PolicySpec::WindowedQuantile,
        PolicySpec::BayesMixture,
        PolicySpec::BanditPolicy,
    ] {
        let mut sim = PrefixSim::new(&cfg, build(spec, &model), shared.clone());
        for prefix in [GAP_BATCH - 1, GAP_BATCH + 1, gaps.len()] {
            let resumed = sim.advance_to(prefix);
            let mut capped = cfg.clone();
            capped.workload.max_items = Some(prefix as u64 + 1);
            let mut policy = build(spec, &model);
            let mut arrivals = TraceReplay::new(gaps[..prefix].to_vec());
            let scratch = simulate(&capped, policy.as_mut(), &mut arrivals);
            assert_identical(&resumed, &scratch, &format!("{spec} on {name} prefix {prefix}"));
        }
    }
}
