//! Integration: the `repro` CLI surface (library-level invocation of the
//! same entry the binary uses), including the exp4 CSV schema contract,
//! the gen-trace round trip and thread-count byte-identity of the
//! policy × tunable × trace grid.

use idlewait::cli;
use idlewait::coordinator::requests::TraceReplay;
use idlewait::coordinator::tracegen::{self, TraceKind};

fn sv(v: &[&str]) -> Vec<String> {
    v.iter().map(|s| s.to_string()).collect()
}

/// The exp4 CSV header is a published schema — downstream notebooks key
/// on these column names, so changes must be deliberate.
const EXP4_CSV_HEADER: &str = "policy,params,arrival,items,energy_mj,lifetime_h,\
                               mean_latency_ms,gaps_idled,gaps_powered_off,\
                               timeouts_expired,late_requests";

#[test]
fn usage_without_args() {
    cli::run(&[]).unwrap();
}

#[test]
fn every_experiment_command_runs() {
    cli::run(&sv(&["fig2"])).unwrap();
    cli::run(&sv(&["exp1"])).unwrap();
    cli::run(&sv(&["exp1", "--model", "XC7S25", "--full"])).unwrap();
    cli::run(&sv(&["exp2", "--step", "2"])).unwrap();
    cli::run(&sv(&["exp3", "--step", "2"])).unwrap();
    cli::run(&sv(&["plan", "--period", "40"])).unwrap();
    cli::run(&sv(&["plan", "--period", "300", "--budget", "1000"])).unwrap();
}

#[test]
fn csv_export_via_cli() {
    let dir = std::env::temp_dir().join("idlewait_cli_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp1.csv");
    cli::run(&sv(&["exp1", "--csv", path.to_str().unwrap()])).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), 67); // header + 66 sweep points
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bad_inputs_error_cleanly() {
    assert!(cli::run(&sv(&["no-such-command"])).is_err());
    assert!(cli::run(&sv(&["exp1", "--model", "XC9999"])).is_err());
    assert!(cli::run(&sv(&["exp2", "--bogus-flag"])).is_err());
    assert!(cli::run(&sv(&["plan"])).is_err()); // missing --period
    assert!(cli::run(&sv(&["serve", "--variant", "fp64"])).is_err());
}

#[test]
fn custom_config_file_via_cli() {
    let dir = std::env::temp_dir().join("idlewait_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fast_idle.yaml");
    // an accelerator with half the idle power → crossover roughly doubles
    let doc = idlewait::config::loader::PAPER_DEFAULT_YAML
        .replace("idle_power_mw: 134.3", "idle_power_mw: 67.15");
    std::fs::write(&path, doc).unwrap();
    cli::run(&sv(&["exp2", "--step", "2", "--config", path.to_str().unwrap()])).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn exp4_csv_schema_is_stable() {
    let dir = std::env::temp_dir().join("idlewait_cli_exp4_schema");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp4.csv");
    cli::run(&sv(&[
        "exp4",
        "--items",
        "50",
        "--csv",
        path.to_str().unwrap(),
    ]))
    .unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().next().unwrap(), EXP4_CSV_HEADER);
    // header + (variants + the tuned row) × the six built-in arrivals
    let expected_rows = (idlewait::experiments::exp4_policies::variants().len() + 1)
        * idlewait::experiments::exp4_policies::ARRIVALS.len();
    assert_eq!(text.lines().count(), expected_rows + 1);
    // every policy name appears in the body
    for spec in idlewait::config::schema::PolicySpec::ALL {
        assert!(
            text.lines().any(|l| l.starts_with(spec.name())),
            "{} missing from CSV",
            spec.name()
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn exp4_csv_byte_identical_at_thread_extremes() {
    let dir = std::env::temp_dir().join("idlewait_cli_exp4_threads");
    std::fs::create_dir_all(&dir).unwrap();
    let serial = dir.join("serial.csv");
    let parallel = dir.join("parallel.csv");
    cli::run(&sv(&[
        "exp4",
        "--items",
        "50",
        "--threads",
        "1",
        "--csv",
        serial.to_str().unwrap(),
    ]))
    .unwrap();
    // --threads 0 = all available cores (the other extreme)
    cli::run(&sv(&[
        "exp4",
        "--items",
        "50",
        "--threads",
        "0",
        "--csv",
        parallel.to_str().unwrap(),
    ]))
    .unwrap();
    let a = std::fs::read(&serial).unwrap();
    let b = std::fs::read(&parallel).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "exp4 CSV must be byte-identical at any --threads");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gen_trace_round_trips_through_the_replayer() {
    let dir = std::env::temp_dir().join("idlewait_cli_gentrace");
    std::fs::create_dir_all(&dir).unwrap();
    for (kind_flag, kind) in [
        ("bursty-iot", TraceKind::BurstyIot),
        ("diurnal-poisson", TraceKind::DiurnalPoisson),
        ("onoff-mmpp", TraceKind::OnOffMmpp),
    ] {
        let path = dir.join(format!("{kind_flag}.csv"));
        cli::run(&sv(&[
            "gen-trace",
            "--kind",
            kind_flag,
            "--gaps",
            "48",
            "--period",
            "40",
            "--seed",
            "9",
            "--out",
            path.to_str().unwrap(),
        ]))
        .unwrap();
        // replaying the written file yields the identical gap sequence
        let mut replay = TraceReplay::from_file(&path).unwrap();
        assert_eq!(replay.len(), 48, "{kind_flag}");
        for (i, want) in tracegen::generate_durations(kind, 48, 40.0, 9)
            .into_iter()
            .enumerate()
        {
            assert_eq!(replay.next_gap(), want, "{kind_flag} gap {i}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bundled_workload_corpus_loads_and_matches_its_manifest() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../workloads");
    // (file, kind, gaps, seed) — period 40 ms throughout, per each file's
    // `# regenerate:` header. The content check keeps the bundled files
    // honest: retuning a generator in tracegen.rs without regenerating
    // the corpus must fail here, not silently diverge.
    for (file, kind, gaps, seed) in [
        ("bursty_iot.csv", TraceKind::BurstyIot, 256usize, 1u64),
        ("diurnal_poisson.csv", TraceKind::DiurnalPoisson, 384, 2),
        ("onoff_mmpp.csv", TraceKind::OnOffMmpp, 320, 3),
    ] {
        let path = root.join(file);
        let mut replay = TraceReplay::from_file(&path)
            .unwrap_or_else(|e| panic!("loading {}: {e}", path.display()));
        assert_eq!(replay.len(), gaps, "{file}");
        let expect = tracegen::generate_durations(kind, gaps, 40.0, seed);
        for (i, want) in expect.into_iter().enumerate() {
            let got = replay.next_gap();
            if kind == TraceKind::BurstyIot {
                // uniform-arithmetic generator: bit-exact everywhere
                assert_eq!(got, want, "{file} gap {i}");
            } else {
                // exponential/sinusoidal generators go through libm
                // (ln/sin), which may differ by an ulp across platforms —
                // a tight relative tolerance still catches any retune
                let rel = (got.secs() - want.secs()).abs() / want.secs();
                assert!(rel < 1e-9, "{file} gap {i}: {got:?} vs {want:?}");
            }
        }
    }
}

#[test]
fn exp4_replays_a_config_trace_column() {
    let dir = std::env::temp_dir().join("idlewait_cli_exp4_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../workloads/bursty_iot.csv");
    let cfg_path = dir.join("trace_cfg.yaml");
    let doc = idlewait::config::loader::PAPER_DEFAULT_YAML.replace(
        "  request_period_ms: 40.0\n",
        &format!(
            "  request_period_ms: 40.0\n  arrival_kind: trace\n  trace_path: {}\n",
            trace.display()
        ),
    );
    std::fs::write(&cfg_path, doc).unwrap();
    let csv_path = dir.join("exp4.csv");
    cli::run(&sv(&[
        "exp4",
        "--items",
        "50",
        "--config",
        cfg_path.to_str().unwrap(),
        "--csv",
        csv_path.to_str().unwrap(),
    ]))
    .unwrap();
    let text = std::fs::read_to_string(&csv_path).unwrap();
    let trace_rows = text.lines().filter(|l| l.contains(",trace,")).count();
    assert_eq!(
        trace_rows,
        idlewait::experiments::exp4_policies::variants().len() + 1,
        "every variant (incl. the tuned row) gets a trace column"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_requires_artifacts_or_fails_with_context() {
    // when artifacts exist this serves; when absent it must error with
    // the make-artifacts hint rather than panic
    let result = cli::run(&sv(&["serve", "--requests", "3"]));
    if idlewait::runtime::artifact::default_dir()
        .join("manifest.json")
        .exists()
    {
        result.unwrap();
    } else {
        let err = format!("{:#}", result.unwrap_err());
        assert!(err.contains("artifacts"), "{err}");
    }
}
