//! Integration: the `repro` CLI surface (library-level invocation of the
//! same entry the binary uses).

use idlewait::cli;

fn sv(v: &[&str]) -> Vec<String> {
    v.iter().map(|s| s.to_string()).collect()
}

#[test]
fn usage_without_args() {
    cli::run(&[]).unwrap();
}

#[test]
fn every_experiment_command_runs() {
    cli::run(&sv(&["fig2"])).unwrap();
    cli::run(&sv(&["exp1"])).unwrap();
    cli::run(&sv(&["exp1", "--model", "XC7S25", "--full"])).unwrap();
    cli::run(&sv(&["exp2", "--step", "2"])).unwrap();
    cli::run(&sv(&["exp3", "--step", "2"])).unwrap();
    cli::run(&sv(&["plan", "--period", "40"])).unwrap();
    cli::run(&sv(&["plan", "--period", "300", "--budget", "1000"])).unwrap();
}

#[test]
fn csv_export_via_cli() {
    let dir = std::env::temp_dir().join("idlewait_cli_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp1.csv");
    cli::run(&sv(&["exp1", "--csv", path.to_str().unwrap()])).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), 67); // header + 66 sweep points
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bad_inputs_error_cleanly() {
    assert!(cli::run(&sv(&["no-such-command"])).is_err());
    assert!(cli::run(&sv(&["exp1", "--model", "XC9999"])).is_err());
    assert!(cli::run(&sv(&["exp2", "--bogus-flag"])).is_err());
    assert!(cli::run(&sv(&["plan"])).is_err()); // missing --period
    assert!(cli::run(&sv(&["serve", "--variant", "fp64"])).is_err());
}

#[test]
fn custom_config_file_via_cli() {
    let dir = std::env::temp_dir().join("idlewait_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fast_idle.yaml");
    // an accelerator with half the idle power → crossover roughly doubles
    let doc = idlewait::config::loader::PAPER_DEFAULT_YAML
        .replace("idle_power_mw: 134.3", "idle_power_mw: 67.15");
    std::fs::write(&path, doc).unwrap();
    cli::run(&sv(&["exp2", "--step", "2", "--config", path.to_str().unwrap()])).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_requires_artifacts_or_fails_with_context() {
    // when artifacts exist this serves; when absent it must error with
    // the make-artifacts hint rather than panic
    let result = cli::run(&sv(&["serve", "--requests", "3"]));
    if idlewait::runtime::artifact::default_dir()
        .join("manifest.json")
        .exists()
    {
        result.unwrap();
    } else {
        let err = format!("{:#}", result.unwrap_err());
        assert!(err.contains("artifacts"), "{err}");
    }
}
