//! Serving-coordinator determinism: `serve_multi` and the exp5 grid are
//! pure functions of (config, options, sources) — reruns and thread
//! counts must be byte-identical, per the CLI's `--threads` contract.

use idlewait::config::paper_default;
use idlewait::config::schema::{PolicyParams, PolicySpec};
use idlewait::coordinator::scheduler::Policy as SchedPolicy;
use idlewait::coordinator::{poisson_sources, serve_multi, MultiServeOptions, ServeSource};
use idlewait::experiments::exp5_serving::{self, Exp5Config};
use idlewait::runner::SweepRunner;
use idlewait::util::units::Duration;

fn e5() -> Exp5Config {
    Exp5Config {
        requests: 50,
        sources: 4,
        period_ms: 40.0,
        seed: 11,
    }
}

/// The exp5 policy × load grid: threads 1 vs N vs auto → byte-identical
/// CSV (order + formatting + values).
#[test]
fn exp5_csv_identical_at_any_thread_count() {
    let cfg = paper_default();
    let reference = exp5_serving::run_threaded(&cfg, &e5(), &SweepRunner::single())
        .to_csv()
        .render();
    for threads in [2, 5, 8] {
        let out = exp5_serving::run_threaded(&cfg, &e5(), &SweepRunner::new(threads))
            .to_csv()
            .render();
        assert_eq!(out, reference, "threads={threads}");
    }
    let auto = exp5_serving::run_threaded(&cfg, &e5(), &SweepRunner::auto())
        .to_csv()
        .render();
    assert_eq!(auto, reference, "threads=0 (auto)");
}

/// Rerunning the same exp5 grid in-process reproduces the exact CSV —
/// no hidden global state between runs.
#[test]
fn exp5_reruns_are_byte_identical() {
    let cfg = paper_default();
    let runner = SweepRunner::new(3);
    let a = exp5_serving::run_threaded(&cfg, &e5(), &runner).to_csv().render();
    let b = exp5_serving::run_threaded(&cfg, &e5(), &runner).to_csv().render();
    assert_eq!(a, b);
}

/// The raw coordinator: identical (options, sources) inputs produce the
/// same rendered metrics and counters across independent runs.
#[test]
fn serve_multi_reruns_are_byte_identical() {
    let cfg = paper_default();
    let opts = MultiServeOptions {
        sched: SchedPolicy::BatchBySlot { window: 8 },
        max_queue: 64,
        gap_policy: PolicySpec::IdleWaitingM12,
        params: PolicyParams::default(),
    };
    let gap = Duration::from_millis(160.0);
    let sources = poisson_sources(4, 60, gap, gap, 13);
    let a = serve_multi(&cfg, &opts, &sources);
    let b = serve_multi(&cfg, &opts, &sources);
    assert_eq!(a.metrics.render(), b.metrics.render());
    assert_eq!(a.served, b.served);
    assert_eq!(a.reconfigurations, b.reconfigurations);
    assert_eq!(a.reordered, b.reordered);
    assert_eq!(
        a.metrics.sim_energy.millijoules().to_bits(),
        b.metrics.sim_energy.millijoules().to_bits()
    );
}

/// The end-to-end acceptance check: same-slot batching beats FIFO on
/// energy at an equal (zero) deadline-miss rate, on identical arrival
/// streams. Two periodic clients pinned to opposite accelerator slots
/// arrive together every tick — FIFO switches images twice per tick,
/// batching once — and the generous slack keeps both schedules
/// deadline-clean, so the comparison isolates energy.
#[test]
fn batching_beats_fifo_on_energy_at_equal_miss_rate() {
    let cfg = paper_default();
    let periodic = |slot: usize| {
        let mut gaps = vec![Duration::from_millis(80.0); 40];
        gaps[0] = Duration::ZERO;
        ServeSource {
            slot,
            gaps: gaps.into(),
            slack: Duration::from_millis(4000.0),
        }
    };
    let sources = [periodic(0), periodic(1)];
    let run = |sched| {
        let opts = MultiServeOptions {
            sched,
            max_queue: 512,
            gap_policy: PolicySpec::IdleWaitingM12,
            params: PolicyParams::default(),
        };
        serve_multi(&cfg, &opts, &sources)
    };
    let fifo = run(SchedPolicy::Fifo);
    let batched = run(SchedPolicy::BatchBySlot { window: 8 });
    assert_eq!(fifo.metrics.miss_rate(), 0.0, "fifo misses");
    assert_eq!(batched.metrics.miss_rate(), 0.0, "batched misses");
    assert_eq!(fifo.served, 80);
    assert_eq!(batched.served, 80);
    assert!(
        batched.reconfigurations < fifo.reconfigurations,
        "batched {} vs fifo {}",
        batched.reconfigurations,
        fifo.reconfigurations
    );
    assert!(
        batched.metrics.sim_energy.millijoules() < fifo.metrics.sim_energy.millijoules(),
        "batched {} mJ vs fifo {} mJ",
        batched.metrics.sim_energy.millijoules(),
        fifo.metrics.sim_energy.millijoules()
    );
}
