//! Golden-number regression harness: the paper's headline constants,
//! pinned against BOTH the analytical model (Eqs 1–4 closed forms) and
//! the lifetime discrete-event simulation, with explicit tolerances.
//!
//! The point of this suite is that no future refactor can silently
//! drift the reproduction away from the paper:
//!
//! * **40.13×** configuration-energy reduction (worst → optimal SPI
//!   setting, Experiment 1 / Fig 7), with the 41.4× time companion.
//! * **89.21 ms** Idle-Waiting↔On-Off crossover at baseline idle power
//!   and **499.06 ms** with power-saving methods 1+2 (§5.4).
//! * **≈12.39×** lifetime extension of Idle-Waiting M1+2 over On-Off at
//!   the paper's 40 ms request period and 4147 J battery budget.
//!
//! Each constant is checked through two independent code paths where the
//! architecture provides them, so a regression in either the closed
//! forms or the event-driven runtime trips the harness.

use idlewait::config::schema::{ArrivalSpec, PolicySpec};
use idlewait::config::{paper_default, SimConfig};
use idlewait::coordinator::requests::Periodic;
use idlewait::device::rails::PowerSaving;
use idlewait::energy::analytical::Analytical;
use idlewait::energy::crossover;
use idlewait::experiments::exp1;
use idlewait::runner::SweepRunner;
use idlewait::strategies::simulate::{simulate, SimReport};
use idlewait::strategies::strategy::{IdleWaiting, OnOff, Policy};
use idlewait::util::units::Duration;

fn model() -> Analytical {
    let cfg = paper_default();
    Analytical::new(&cfg.item, cfg.workload.energy_budget)
}

/// Run a policy on strictly periodic arrivals for `items` items.
fn run_periodic(policy: &mut dyn Policy, period_ms: f64, items: u64) -> SimReport {
    let mut cfg = paper_default();
    cfg.workload.arrival = ArrivalSpec::Periodic {
        period: Duration::from_millis(period_ms),
    };
    cfg.workload.max_items = Some(items);
    let mut arrivals = Periodic {
        period: Duration::from_millis(period_ms),
    };
    simulate(&cfg, policy, &mut arrivals)
}

/// DES per-item energy (mJ, including the gap after each item) for a
/// policy at a period, measured over `items` items. The one-time init
/// cost is amortized across the run, matching the asymptotic closed
/// forms to O(1/items).
fn des_energy_per_item_mj(policy: &mut dyn Policy, period_ms: f64, items: u64) -> f64 {
    let r = run_periodic(policy, period_ms, items);
    assert_eq!(r.items, items, "budget must not exhaust during measurement");
    r.energy_exact.millijoules() / items as f64
}

// ---------------------------------------------------------------------------
// 40.13× configuration-energy reduction (Experiment 1)
// ---------------------------------------------------------------------------

/// Paper §5.2: the optimal configuration setting (Quad SPI, 66 MHz,
/// compressed) reduces configuration energy 40.13× and configuration
/// time 41.4× vs the worst setting (Single SPI, 3 MHz, uncompressed).
#[test]
fn golden_config_energy_reduction_40_13x() {
    let r = exp1::run_threaded(
        idlewait::config::schema::FpgaModel::Xc7s15,
        &SweepRunner::single(),
    );
    let energy = r.energy_improvement();
    assert!((energy - 40.13).abs() < 0.15, "energy reduction {energy} vs paper 40.13");
    let time = r.time_improvement();
    assert!((time - 41.4).abs() < 0.1, "time reduction {time} vs paper 41.4");
    // the optimal point itself is Table 2's configuration phase
    assert!((r.optimal().config_time_ms() - 36.145).abs() < 0.01);
    assert!((r.optimal().config_energy_mj() - 11.85).abs() < 0.02);
}

// ---------------------------------------------------------------------------
// 89.21 ms / 499.06 ms crossovers
// ---------------------------------------------------------------------------

/// Analytical path: the closed-form asymptotic crossover and the
/// finite-budget bisection both land on the paper's numbers.
#[test]
fn golden_crossovers_analytical() {
    let m = model();
    let baseline = crossover::asymptotic(&m, m.item.idle_power(PolicySpec::IdleWaiting));
    assert!(
        (baseline.millis() - 89.21).abs() < 0.05,
        "baseline crossover {} vs paper 89.21 ms",
        baseline.millis()
    );
    let m12 = crossover::asymptotic(&m, m.item.idle_power(PolicySpec::IdleWaitingM12));
    assert!(
        (m12.millis() - 499.06).abs() < 0.15,
        "M1+2 crossover {} vs paper 499.06 ms",
        m12.millis()
    );
    // the exact finite-budget solver agrees at the paper's 0.01 ms sweep
    // resolution
    for (p_idle, expect_ms, tol) in [
        (m.item.idle_power(PolicySpec::IdleWaiting), 89.21, 0.06),
        (m.item.idle_power(PolicySpec::IdleWaitingM12), 499.06, 0.16),
    ] {
        let exact = crossover::exact(
            &m,
            p_idle,
            Duration::from_millis(37.0),
            Duration::from_millis(600.0),
            Duration::from_millis(0.01),
        )
        .expect("crossover bracketed");
        assert!(
            (exact.millis() - expect_ms).abs() < tol,
            "exact crossover {} vs paper {expect_ms} ms",
            exact.millis()
        );
    }
}

/// DES path: per-item energies measured by the event-driven simulator
/// flip winners across each crossover. Brackets at ±1.5% of the
/// crossover pin the DES to the same break-even points.
#[test]
fn golden_crossovers_des() {
    let items = 2_000;
    // baseline idle mode vs On-Off around 89.21 ms
    for (period_ms, iw_wins) in [(88.0, true), (90.5, false)] {
        let iw = des_energy_per_item_mj(&mut IdleWaiting::baseline(), period_ms, items);
        let onoff = des_energy_per_item_mj(&mut OnOff, period_ms, items);
        assert_eq!(
            iw < onoff,
            iw_wins,
            "at {period_ms} ms: iw {iw} mJ vs onoff {onoff} mJ (paper crossover 89.21 ms)"
        );
    }
    // M1+2 idle mode vs On-Off around 499.06 ms
    for (period_ms, iw_wins) in [(492.0, true), (507.0, false)] {
        let iw = des_energy_per_item_mj(&mut IdleWaiting::method12(), period_ms, items);
        let onoff = des_energy_per_item_mj(&mut OnOff, period_ms, items);
        assert_eq!(
            iw < onoff,
            iw_wins,
            "at {period_ms} ms: m12 {iw} mJ vs onoff {onoff} mJ (paper crossover 499.06 ms)"
        );
    }
}

// ---------------------------------------------------------------------------
// ≈12.39× lifetime at 40 ms / 4147 J
// ---------------------------------------------------------------------------

/// Analytical path: Eqs 3–4 at the paper's setup (40 ms, 4147 J).
#[test]
fn golden_lifetime_extension_12_39x_analytical() {
    let cfg = paper_default();
    assert!((cfg.workload.energy_budget.joules() - 4147.0).abs() < 1e-9);
    assert!((cfg.platform.battery_budget.joules() - 4147.0).abs() < 1e-9);
    let m = model();
    let t = Duration::from_millis(40.0);
    let onoff = m.predict(PolicySpec::OnOff, t);
    let m12 = m.predict(PolicySpec::IdleWaitingM12, t);
    // the paper's Fig 8 anchor: ≈346,073 On-Off items regardless of T_req
    let n_onoff = onoff.n_max.unwrap();
    assert!(n_onoff.abs_diff(346_073) <= 150, "onoff n_max {n_onoff}");
    let ratio = m12.n_max.unwrap() as f64 / n_onoff as f64;
    assert!((ratio - 12.39).abs() < 0.05, "lifetime ratio {ratio} vs paper 12.39");
    // and in wall-clock terms: ≈3.85 h → ≈47.6 h
    assert!((onoff.lifetime.hours() - 3.845).abs() < 0.01, "{}", onoff.lifetime.hours());
    assert!((m12.lifetime.hours() - 47.65).abs() < 0.2, "{}", m12.lifetime.hours());
}

/// DES path, part 1: running On-Off to genuine budget exhaustion on the
/// event-driven simulator reproduces the ≈346,073-item endpoint.
#[test]
fn golden_onoff_exhaustion_des() {
    let mut cfg: SimConfig = paper_default();
    cfg.workload.arrival = ArrivalSpec::Periodic {
        period: Duration::from_millis(40.0),
    };
    cfg.workload.max_items = None; // run until the 4147 J battery is empty
    let mut arrivals = Periodic {
        period: Duration::from_millis(40.0),
    };
    let r = simulate(&cfg, &mut OnOff, &mut arrivals);
    // DES configuration energy comes from the FSM mechanism, Eq 1 from
    // Table 2; they agree to ~1e-4 relative, hence the ±500 item window.
    assert!(
        r.items.abs_diff(346_073) <= 500,
        "DES On-Off exhaustion: {} items vs paper 346,073",
        r.items
    );
    assert!((r.lifetime.hours() - 3.845).abs() < 0.02, "{}", r.lifetime.hours());
    // On-Off reconfigures every item; the final, budget-exhausted
    // configure attempt may or may not have been counted before the stop
    assert!(
        r.configurations == r.items || r.configurations == r.items + 1,
        "items {} vs configurations {}",
        r.items,
        r.configurations
    );
}

/// DES path, part 2: the 12.39× ratio from measured per-item energies.
/// n_max per policy is budget / per-item energy (the init term is
/// amortized to O(1/items)), so the DES-implied ratio must match the
/// paper without simulating the 4.3M-item M1+2 run to exhaustion.
#[test]
fn golden_lifetime_extension_12_39x_des() {
    let items = 20_000;
    let onoff = des_energy_per_item_mj(&mut OnOff, 40.0, items);
    let m12 = des_energy_per_item_mj(
        &mut IdleWaiting {
            saving: PowerSaving::M12,
        },
        40.0,
        items,
    );
    let ratio = onoff / m12;
    assert!(
        (ratio - 12.39).abs() < 0.08,
        "DES per-item ratio {ratio} vs paper 12.39 (onoff {onoff} mJ, m12 {m12} mJ)"
    );
}
