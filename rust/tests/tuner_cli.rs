//! Integration: the `repro tune` surface — CLI-level thread-count
//! byte-identity of the trajectory CSV, the emitted best-params flags
//! line being accepted by `repro serve` verbatim, and tuned fragments
//! driving a heterogeneous `repro multi` fleet.

use idlewait::cli;
use idlewait::config::paper_default;
use idlewait::config::schema::PolicySpec;
use idlewait::coordinator::requests::TraceReplay;
use idlewait::runner::SweepRunner;
use idlewait::tuner::{self, SearchStrategy, TuneConfig};

fn sv(v: &[&str]) -> Vec<String> {
    v.iter().map(|s| s.to_string()).collect()
}

fn bursty_trace() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../workloads/bursty_iot.csv")
}

/// The tune CSV header is a published schema, like exp4's.
const TUNE_CSV_HEADER: &str = "stage,eval,candidate,policy,saving,timeout_ms,ema_alpha,\
                               window,quantile,gaps,score,energy_mj_per_item,lifetime_h,\
                               late_rate,items";

#[test]
fn tune_csv_byte_identical_at_thread_extremes() {
    let dir = std::env::temp_dir().join("idlewait_tune_threads");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = bursty_trace();
    let run_at = |threads: &str, name: &str| -> Vec<u8> {
        let path = dir.join(name);
        cli::run(&sv(&[
            "tune",
            "--policy",
            "windowed-quantile",
            "--trace",
            trace.to_str().unwrap(),
            "--search",
            "halving",
            "--budget",
            "12",
            "--threads",
            threads,
            "--csv",
            path.to_str().unwrap(),
        ]))
        .unwrap();
        std::fs::read(&path).unwrap()
    };
    let serial = run_at("1", "serial.csv");
    let parallel = run_at("0", "parallel.csv");
    assert!(!serial.is_empty());
    assert_eq!(
        serial, parallel,
        "tune trajectory CSV must be byte-identical at any --threads"
    );
    let text = String::from_utf8(serial).unwrap();
    assert_eq!(text.lines().next().unwrap(), TUNE_CSV_HEADER);
    // the trajectory must end with the two validation rows
    assert!(text.lines().filter(|l| l.starts_with("validation,")).count() == 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tune_every_search_strategy_via_cli() {
    let trace = bursty_trace();
    for search in ["grid", "random", "halving"] {
        cli::run(&sv(&[
            "tune",
            "--policy",
            "timeout",
            "--trace",
            trace.to_str().unwrap(),
            "--search",
            search,
            "--budget",
            "8",
        ]))
        .unwrap_or_else(|e| panic!("{search}: {e:#}"));
    }
}

#[test]
fn tune_rejects_bad_inputs() {
    let trace = bursty_trace();
    let trace = trace.to_str().unwrap();
    for argv in [
        vec!["tune", "--policy", "warp-drive", "--trace", trace],
        vec!["tune", "--policy", "quantile", "--trace", "/nonexistent/gaps.csv"],
        vec!["tune", "--policy", "quantile", "--trace", trace, "--search", "annealing"],
        vec!["tune", "--policy", "quantile", "--trace", trace, "--objective", "vibes"],
        vec!["tune", "--policy", "quantile", "--trace", trace, "--split", "2"],
        vec!["tune", "--policy", "quantile", "--trace", trace, "--budget", "0"],
        vec!["tune", "--policy", "quantile", "--trace", trace, "--max-late-rate", "7"],
        vec!["tune", "--policy", "quantile"], // no trace anywhere
    ] {
        assert!(cli::run(&sv(&argv)).is_err(), "{argv:?}");
    }
    // missing-trace errors must name the offending path
    let err = cli::run(&sv(&[
        "tune",
        "--policy",
        "quantile",
        "--trace",
        "/nonexistent/gaps.csv",
    ]))
    .unwrap_err();
    assert!(format!("{err:#}").contains("/nonexistent/gaps.csv"), "{err:#}");
}

/// The acceptance-criteria path: tune on the bursty-IoT corpus, beat the
/// defaults on the held-out split, and have `repro serve` accept the
/// emitted flags line verbatim.
#[test]
fn tuned_flags_line_is_accepted_by_serve_verbatim() {
    let cfg = paper_default();
    let gaps = TraceReplay::from_file(bursty_trace()).unwrap().shared_gaps();
    let tc = TuneConfig {
        search: SearchStrategy::Halving,
        budget: 16,
        seed: 3,
        ..TuneConfig::for_spec(PolicySpec::WindowedQuantile)
    };
    let outcome = tuner::tune(&cfg, &tc, &gaps, &SweepRunner::auto()).unwrap();
    assert!(
        outcome.best_val.score < outcome.base_val.score,
        "tuned {} must beat the defaults {} on the held-out split",
        outcome.best_val.score,
        outcome.base_val.score
    );

    // feed the emitted flags to `repro serve` exactly as printed
    let line = tuner::flags_line(outcome.spec, &outcome.best);
    let mut argv = vec!["serve".to_string()];
    argv.extend(line.split_whitespace().map(|s| s.to_string()));
    argv.extend(["--requests".to_string(), "2".to_string()]);
    let result = cli::run(&argv);
    // with artifacts present this serves; without them the flags must
    // still parse+validate and fail only at the artifact lookup
    if idlewait::runtime::artifact::default_dir()
        .join("manifest.json")
        .exists()
    {
        result.unwrap();
    } else {
        let err = format!("{:#}", result.unwrap_err());
        assert!(err.contains("artifacts"), "flags line not accepted: {err}");
    }
}

#[test]
fn tuned_fragment_drives_a_heterogeneous_multi_fleet() {
    let dir = std::env::temp_dir().join("idlewait_tune_multi");
    std::fs::create_dir_all(&dir).unwrap();
    let fragment = dir.join("slot_b.yaml");
    cli::run(&sv(&[
        "tune",
        "--policy",
        "windowed-quantile",
        "--trace",
        bursty_trace().to_str().unwrap(),
        "--search",
        "random",
        "--budget",
        "8",
        "--emit",
        fragment.to_str().unwrap(),
    ]))
    .unwrap();
    // the emitted fragment loads back into (spec, params)
    let (spec, params) = tuner::load_fragment(&fragment).unwrap();
    assert_eq!(spec, PolicySpec::WindowedQuantile);
    assert!(params.validate().is_ok());
    // and a tuned heterogeneous fleet runs end-to-end
    cli::run(&sv(&[
        "multi",
        "--requests",
        "200",
        "--slot-b-params",
        fragment.to_str().unwrap(),
    ]))
    .unwrap();
    // a broken fragment fails with the path in the message
    assert!(cli::run(&sv(&[
        "multi",
        "--requests",
        "50",
        "--slot-b-params",
        "/nonexistent/frag.yaml",
    ]))
    .is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
