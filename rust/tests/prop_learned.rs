//! Properties of the learned gap policies (`BayesMixture`,
//! `BanditPolicy`): the Oracle ≤ learned ≤ e/(e−1)·Oracle sandwich on
//! every corpus trace and on adversarial synthetics, per-seed bit
//! determinism, convergence to the crossover decision on periodic
//! arrivals, thread-count byte-identity of the policy sweep, and the
//! bursty-IoT holdout win over the fixed `Timeout` baseline.
//!
//! Warm-up discipline for the sandwich: each learner takes one full
//! plan/observe pass over the trace before the measured run, so the
//! bound pins steady-state behaviour (the cold-start hedge is itself
//! only 2-competitive and is covered by the spec's slack elsewhere).
//! The stated tolerance is multiplicative slack on e/(e−1): 1.05 where
//! the learner provably collapses to the exact crossover decision
//! (constant gaps), 1.10 on mixed traces, covering model misfit, regime
//! transitions and the ~1e-4 FSM-vs-Table-2 config-energy difference.

use std::path::Path;
use std::sync::Arc;

use idlewait::config::paper_default;
use idlewait::config::schema::{ArrivalSpec, PolicyParams, PolicySpec};
use idlewait::coordinator::requests::{Periodic, TraceReplay};
use idlewait::device::rails::{PowerSaving, RailSet};
use idlewait::energy::analytical::Analytical;
use idlewait::energy::crossover;
use idlewait::experiments::exp4_policies::{run_threaded, Exp4Config};
use idlewait::runner::SweepRunner;
use idlewait::strategies::simulate::{simulate, SimReport};
use idlewait::strategies::strategy::{build_with, GapContext, OnOff, Oracle, Policy};
use idlewait::testing::competitive::{competitive_bound, CompetitiveSpec};
use idlewait::testing::report::assert_sim_reports_bit_identical;
use idlewait::tuner::tune::evaluate;
use idlewait::tuner::{train, TrainConfig};
use idlewait::util::units::Duration;

/// The randomized ski-rental competitive ratio e/(e−1) ≈ 1.582.
const BOUND: f64 = std::f64::consts::E / (std::f64::consts::E - 1.0);

/// The two learned policy variants under test.
const LEARNED: [PolicySpec; 2] = [PolicySpec::BayesMixture, PolicySpec::BanditPolicy];

fn model() -> Analytical {
    let cfg = paper_default();
    Analytical::new(&cfg.item, cfg.workload.energy_budget)
}

/// Build a learned policy at its default tunables (M1+2 idle mode) with
/// an explicit seed.
fn learned_policy(spec: PolicySpec, seed: u64) -> Box<dyn Policy> {
    let m = model();
    let params = PolicyParams {
        seed,
        ..PolicyParams::default()
    };
    build_with(spec, &m, &params)
}

/// One full warm-up pass: plan and observe every gap in arrival order,
/// exactly as the simulator interleaves them, without scoring energy.
fn warm(policy: &mut dyn Policy, gaps: &[Duration]) {
    let mut now = Duration::ZERO;
    for (i, &gap) in gaps.iter().enumerate() {
        let ctx = GapContext {
            items_done: i as u64 + 1,
            now,
            queued: 0,
        };
        let _ = policy.plan_gap(&ctx);
        policy.observe(gap);
        now = now + gap;
    }
}

/// Run a policy over an explicit gap trace (each gap used exactly once:
/// n gaps → n+1 items).
fn run_trace(policy: &mut dyn Policy, gaps: &[Duration]) -> SimReport {
    let mut cfg = paper_default();
    cfg.workload.max_items = Some(gaps.len() as u64 + 1);
    let mut arrivals = TraceReplay::new(gaps.to_vec());
    simulate(&cfg, policy, &mut arrivals)
}

/// The DES cost of one power-on + configuration (FSM mechanism), in mJ.
fn config_cycle_mj() -> f64 {
    let mut cfg = paper_default();
    cfg.workload.max_items = Some(1);
    let mut arrivals = Periodic {
        period: Duration::from_millis(40.0),
    };
    let report = simulate(&cfg, &mut OnOff, &mut arrivals);
    let m = model();
    report.energy_exact.millijoules() - m.item.e_active.millijoules()
}

/// Energy attributable to the gaps alone: total minus the active phases
/// and minus the initial configuration. Reconfigurations after power-off
/// gaps stay included — they are the price of the off decision.
fn gap_energy_mj(report: &SimReport, config_cycle_mj: f64) -> f64 {
    let m = model();
    report.energy_exact.millijoules()
        - report.items as f64 * m.item.e_active.millijoules()
        - config_cycle_mj
}

/// The bundled corpus traces, in corpus order.
fn corpus() -> Vec<(&'static str, Arc<[Duration]>)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../workloads");
    ["bursty_iot.csv", "diurnal_poisson.csv", "onoff_mmpp.csv"]
        .iter()
        .map(|name| {
            let replay = TraceReplay::from_file(dir.join(name))
                .unwrap_or_else(|e| panic!("corpus trace {name}: {e}"));
            (*name, replay.shared_gaps())
        })
        .collect()
}

/// Pin `oracle ≤ warmed-learner ≤ BOUND × oracle × slack` on one trace,
/// via the shared evidence-driven [`competitive_bound`] harness. The
/// seed varies the learner's init jitter (a no-op for the RNG-free
/// bandit, whose interval is then zero-width). Returns a failure line
/// instead of asserting so callers can report every violation at once.
fn sandwich(
    name: &'static str,
    gaps: &[Duration],
    spec: PolicySpec,
    slack: f64,
) -> Option<String> {
    let m = model();
    let c = config_cycle_mj();
    let oracle = gap_energy_mj(
        &run_trace(&mut Oracle::from_model(&m, PowerSaving::M12), gaps),
        c,
    );
    let cspec = CompetitiveSpec {
        slack,
        // the oracle really is a lower bound: a learner materially below
        // it means the energy accounting broke, not that it learned well
        floor_frac: 0.995,
        ..CompetitiveSpec::new(name, oracle, BOUND)
    };
    let report = competitive_bound(&cspec, |seed| {
        let mut policy = learned_policy(spec, seed);
        warm(policy.as_mut(), gaps);
        gap_energy_mj(&run_trace(policy.as_mut(), gaps), c)
    });
    if report.holds() {
        None
    } else {
        Some(format!("{} [{}]: {}", name, spec.name(), report.render()))
    }
}

/// The acceptance sandwich: on every bundled corpus trace, both learned
/// policies sit between the clairvoyant oracle and e/(e−1) × oracle
/// (slack 1.10) after one warm-up pass.
#[test]
fn learned_policies_are_sandwiched_on_every_corpus_trace() {
    let mut failures = Vec::new();
    for (name, gaps) in corpus() {
        for spec in LEARNED {
            if let Some(f) = sandwich(name, &gaps, spec, 1.10) {
                failures.push(f);
            }
        }
    }
    assert!(
        failures.is_empty(),
        "sandwich violations:\n{}",
        failures.join("\n")
    );
}

/// The sandwich on adversarial synthetics: constant gaps on either side
/// of the M1+2 crossover (the classic ski-rental adversary — a warmed
/// learner must collapse to the exact crossover decision, slack 1.05),
/// and a regime-switching block-bimodal trace (32 burst gaps, then 32
/// silences, repeated) whose blocks the feature EMA must track.
///
/// Deliberately NOT an i.i.d. bimodal mix: for a per-cell deterministic
/// rule an i.i.d. short/long coin flip is indistinguishable inside one
/// context cell, and the best single action there provably exceeds
/// e/(e−1) (it only satisfies the deterministic 2× bound). The e/(e−1)
/// claim for the learners is about *learnable* structure, so the
/// adversary switches regimes in blocks the context features can see.
#[test]
fn learned_policies_hold_the_sandwich_on_adversarial_synthetics() {
    let constant_short = vec![Duration::from_millis(40.0); 160];
    let constant_long = vec![Duration::from_millis(600.0); 160];
    let mut blocks = Vec::with_capacity(256);
    for _ in 0..4 {
        for _ in 0..32 {
            blocks.push(Duration::from_millis(16.0));
        }
        for _ in 0..32 {
            blocks.push(Duration::from_millis(640.0));
        }
    }
    let synthetics: [(&'static str, &[Duration], f64); 3] = [
        ("constant-40ms", &constant_short, 1.05),
        ("constant-600ms", &constant_long, 1.05),
        ("block-bimodal", &blocks, 1.10),
    ];
    let mut failures = Vec::new();
    for (name, gaps, slack) in synthetics {
        for spec in LEARNED {
            if let Some(f) = sandwich(name, gaps, spec, slack) {
                failures.push(f);
            }
        }
    }
    assert!(
        failures.is_empty(),
        "sandwich violations:\n{}",
        failures.join("\n")
    );
}

/// Same seed ⇒ bit-identical `SimReport`: the learners' online updates
/// are plain arithmetic in observation order and the only randomness
/// (the mixture's init jitter) is consumed at construction.
#[test]
fn learned_policies_are_bit_deterministic_per_seed() {
    let (name, gaps) = corpus().remove(0);
    for spec in LEARNED {
        let run = |seed: u64| {
            let mut policy = learned_policy(spec, seed);
            run_trace(policy.as_mut(), &gaps)
        };
        assert_sim_reports_bit_identical(
            &run(7),
            &run(7),
            &format!("{} on {name}, seed 7", spec.name()),
        );
    }
}

/// On strictly periodic arrivals below the M1+2 crossover, both learners
/// degenerate to Idle-Waiting bit-for-bit: the cold-start hedge timeout
/// is τ > period (the timer never fires, so the hedged gaps already
/// spend pure idle energy), and every converged plan idles.
#[test]
fn learned_policies_degenerate_to_idle_waiting_below_crossover() {
    let mut cfg = paper_default();
    cfg.workload.max_items = Some(400);
    let m = model();
    let run = |policy: &mut dyn Policy| {
        let mut arrivals = Periodic {
            period: Duration::from_millis(40.0),
        };
        simulate(&cfg, policy, &mut arrivals)
    };
    let iw = run(build_with(PolicySpec::IdleWaitingM12, &m, &PolicyParams::default()).as_mut());
    for spec in LEARNED {
        let r = run(learned_policy(spec, 0).as_mut());
        assert_eq!(r.items, iw.items, "{}", spec.name());
        assert_eq!(r.configurations, 1, "{}", spec.name());
        assert_eq!(r.decisions.idled, 399, "{}", spec.name());
        assert_eq!(r.decisions.powered_off, 0, "{}", spec.name());
        assert_eq!(r.decisions.timeouts_expired, 0, "{}", spec.name());
        assert_eq!(
            r.energy_exact,
            iw.energy_exact,
            "{}: exact degeneracy",
            spec.name()
        );
    }
}

/// Above the crossover on periodic arrivals, both learners converge to
/// the On-Off decision: every gap ends powered off (the transient plans
/// are expiring hedges, never pure idles), planned power-offs dominate
/// once the posterior/cells warm up, and the total energy exceeds pure
/// On-Off by at most the transient's rent.
#[test]
fn learned_policies_converge_to_power_off_above_crossover() {
    let mut cfg = paper_default();
    cfg.workload.arrival = ArrivalSpec::Periodic {
        period: Duration::from_secs(2.0),
    };
    cfg.workload.max_items = Some(400);
    let m = model();
    let run = |policy: &mut dyn Policy| {
        let mut arrivals = Periodic {
            period: Duration::from_secs(2.0),
        };
        simulate(&cfg, policy, &mut arrivals)
    };
    let onoff = run(&mut OnOff);
    let p_idle = RailSet::idle_power(PowerSaving::M12);
    let tau = crossover::ski_rental_timeout(&m, p_idle);
    let premium_mj = (p_idle * tau).millijoules();
    for spec in LEARNED {
        let r = run(learned_policy(spec, 0).as_mut());
        assert_eq!(r.items, onoff.items, "{}", spec.name());
        // every gap powers off: hedges expire (2 s > τ), nothing idles
        assert_eq!(r.decisions.idled, 0, "{}", spec.name());
        assert_eq!(
            r.decisions.powered_off + r.decisions.timeouts_expired,
            399,
            "{}",
            spec.name()
        );
        assert!(
            r.decisions.powered_off >= 360,
            "{}: only {} of 399 gaps converged to a planned power-off",
            spec.name(),
            r.decisions.powered_off
        );
        assert_eq!(r.configurations, onoff.configurations, "{}", spec.name());
        // each transient hedge rents at most τ·P_idle before buying
        let extra = r.energy_exact.millijoules() - onoff.energy_exact.millijoules();
        assert!(
            extra >= -1e-6 && extra <= 40.0 * premium_mj,
            "{}: extra {extra} mJ vs per-hedge premium {premium_mj} mJ",
            spec.name()
        );
    }
}

/// The acceptance holdout: trained on the bursty-IoT corpus's 70% train
/// split, both learned policies beat the default fixed `Timeout` on
/// energy over the held-out 30% — at an equal-or-lower late rate. The
/// bandit goes through `tuner::train` (the `repro train` path, which
/// scores the trained table against the same baseline); the mixture is
/// deployed cold on the identical holdout slice.
#[test]
fn learned_policies_beat_the_fixed_timeout_on_the_bursty_holdout() {
    let cfg = paper_default();
    let m = model();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../workloads");
    let gaps = TraceReplay::from_file(dir.join("bursty_iot.csv"))
        .expect("bursty corpus trace")
        .shared_gaps();
    let runner = SweepRunner::single();
    let tc = TrainConfig::default();
    let outcome = train(&cfg, &tc, &gaps, &runner).expect("train on the bursty corpus");
    let timeout = outcome.timeout_val.metrics;
    assert!(
        outcome.beats_timeout_on_holdout(),
        "trained bandit {} must not lose to timeout {} on the holdout",
        outcome.best_val.score,
        outcome.timeout_val.score
    );
    assert!(
        outcome.best_val.metrics.energy_mj_per_item < timeout.energy_mj_per_item,
        "trained bandit {} mJ/item must beat timeout {} mJ/item",
        outcome.best_val.metrics.energy_mj_per_item,
        timeout.energy_mj_per_item
    );
    assert!(
        outcome.best_val.metrics.late_rate <= timeout.late_rate,
        "trained bandit late rate {} exceeds timeout {}",
        outcome.best_val.metrics.late_rate,
        timeout.late_rate
    );

    // the mixture, deployed cold on the same held-out slice
    let split = ((gaps.len() as f64 * tc.split).round() as usize).clamp(1, gaps.len() - 1);
    let bayes = evaluate(
        &cfg,
        &m,
        PolicySpec::BayesMixture,
        &PolicyParams::default(),
        &tc.objective,
        &gaps[split..],
    );
    assert!(
        bayes.metrics.energy_mj_per_item < timeout.energy_mj_per_item,
        "bayes {} mJ/item must beat timeout {} mJ/item on the holdout",
        bayes.metrics.energy_mj_per_item,
        timeout.energy_mj_per_item
    );
    assert!(
        bayes.metrics.late_rate <= timeout.late_rate,
        "bayes late rate {} exceeds timeout {}",
        bayes.metrics.late_rate,
        timeout.late_rate
    );
}

/// The policy-grid sweep (which now carries both learned variants on
/// its `PolicySpec::ALL` axis) renders byte-identical CSV at
/// `--threads 1`, `--threads 4` and `--threads auto` — the learners'
/// online state never leaks across cells or schedule orders.
#[test]
fn exp4_sweep_with_learned_variants_is_byte_identical_at_any_thread_count() {
    let cfg = paper_default();
    let e4 = Exp4Config {
        items: 400,
        period_ms: 40.0,
        seed: 4,
    };
    let csv = |runner: &SweepRunner| {
        run_threaded(&cfg, &e4, runner)
            .expect("exp4 grid")
            .to_csv()
            .render()
    };
    let serial = csv(&SweepRunner::single());
    assert!(
        serial.contains("bayes-mixture") && serial.contains("bandit"),
        "the sweep must cover both learned variants"
    );
    assert_eq!(
        serial,
        csv(&SweepRunner::new(4)),
        "--threads 4 must be byte-identical to serial"
    );
    assert_eq!(
        serial,
        csv(&SweepRunner::auto()),
        "--threads auto must be byte-identical to serial"
    );
}
