//! Integration: the full AOT bridge — python-lowered Pallas/JAX HLO
//! executed from rust via PJRT — plus the serving loop on top of it.
//!
//! All tests skip (with a notice) when `make artifacts` has not run;
//! `make test` always builds artifacts first.

use idlewait::config::paper_default;
use idlewait::coordinator::requests::{Periodic, Poisson};
use idlewait::coordinator::server::{serve, ServerConfig};
use idlewait::runtime::artifact::default_dir;
use idlewait::runtime::inference::{LstmRuntime, Variant};
use idlewait::strategies::strategy::{IdleWaiting, OnOff};
use idlewait::util::units::Duration;

fn runtime() -> Option<std::rc::Rc<LstmRuntime>> {
    if !default_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(idlewait::runtime::pool::runtime(default_dir()).unwrap())
}

#[test]
fn self_check_proves_l1_l2_l3_numerics_agree() {
    let Some(rt) = runtime() else { return };
    let err = rt.self_check().unwrap();
    assert!(err < 1e-4, "rust-vs-jax err {err}");
}

#[test]
fn forecast_is_deterministic_across_calls() {
    let Some(rt) = runtime() else { return };
    let w = rt.manifest.selfcheck.window.clone();
    let a = rt.forecast(&w, Variant::Forecast).unwrap().forecast;
    let b = rt.forecast(&w, Variant::Forecast).unwrap().forecast;
    assert_eq!(a, b);
}

#[test]
fn forecast_responds_to_input_changes() {
    let Some(rt) = runtime() else { return };
    let w = rt.manifest.selfcheck.window.clone();
    let base = rt.forecast(&w, Variant::Forecast).unwrap().forecast;
    let mut perturbed = w.clone();
    for v in perturbed.iter_mut().take(24) {
        *v += 0.5;
    }
    let moved = rt.forecast(&perturbed, Variant::Forecast).unwrap().forecast;
    assert_ne!(base, moved, "forecast must depend on the window");
    assert!((base - moved).abs() < 5.0, "bounded response");
}

#[test]
fn step_recurrence_is_contractive_on_zero_input() {
    let Some(rt) = runtime() else { return };
    // with zero inputs the hidden state stays bounded and converges
    let zeros_x = vec![0f32; rt.manifest.input_size];
    let mut h = vec![0f32; rt.manifest.hidden_size];
    let mut c = vec![0f32; rt.manifest.hidden_size];
    for _ in 0..50 {
        let (h2, c2) = rt.step(&zeros_x, &h, &c).unwrap();
        h = h2;
        c = c2;
        assert!(h.iter().all(|v| v.abs() <= 1.0));
        assert!(c.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn serving_500_requests_with_both_variants() {
    let Some(rt) = runtime() else { return };
    let sim = paper_default();
    for variant in [Variant::Forecast, Variant::ForecastInt8] {
        let cfg = ServerConfig {
            sim: &sim,
            variant,
            max_requests: 500,
        };
        let mut arr = Periodic {
            period: Duration::from_millis(40.0),
        };
        let report = serve(&cfg, &rt, &mut IdleWaiting::method12(), &mut arr).unwrap();
        assert_eq!(report.metrics.requests, 500, "{variant:?}");
        assert_eq!(report.configurations, 1);
        assert_eq!(report.metrics.deadline_misses, 0, "{variant:?}");
        // host inference must comfortably fit the paper's 40 ms period
        let s = report.metrics.latency_summary().unwrap();
        assert!(s.p95 < 40.0, "{variant:?}: p95 {} ms", s.p95);
    }
}

#[test]
fn serving_energy_ledger_matches_strategy_choice() {
    let Some(rt) = runtime() else { return };
    let sim = paper_default();
    let run = |policy: &mut dyn idlewait::strategies::strategy::Policy| {
        let cfg = ServerConfig {
            sim: &sim,
            variant: Variant::Forecast,
            max_requests: 50,
        };
        let mut arr = Periodic {
            period: Duration::from_millis(40.0),
        };
        serve(&cfg, &rt, policy, &mut arr).unwrap()
    };
    let onoff = run(&mut OnOff);
    let iw = run(&mut IdleWaiting::baseline());
    // On-Off pays ~11.98 mJ per request, IW ~5.37 + one-time init
    assert!(onoff.metrics.sim_energy > iw.metrics.sim_energy);
    assert_eq!(onoff.configurations, 50);
    assert_eq!(iw.configurations, 1);
    let ratio = onoff.metrics.sim_energy / iw.metrics.sim_energy;
    assert!(ratio > 1.9 && ratio < 2.6, "ratio {ratio}");
}

#[test]
fn serving_survives_bursty_poisson_arrivals() {
    let Some(rt) = runtime() else { return };
    let sim = paper_default();
    let cfg = ServerConfig {
        sim: &sim,
        variant: Variant::Forecast,
        max_requests: 200,
    };
    let mut arr = Poisson::new(Duration::from_millis(40.0), Duration::from_millis(0.05), 7);
    let report = serve(&cfg, &rt, &mut IdleWaiting::baseline(), &mut arr).unwrap();
    assert_eq!(report.metrics.requests, 200);
    assert!(report.metrics.sim_energy.joules() > 0.0);
}

#[test]
fn manifest_metadata_matches_model_geometry() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.manifest.hidden_size, 20); // the paper's accelerator
    assert_eq!(rt.manifest.input_size, 6);
    assert_eq!(rt.manifest.window, 24);
    assert_eq!(
        rt.manifest.selfcheck.window.len(),
        rt.manifest.window * rt.manifest.input_size
    );
}
