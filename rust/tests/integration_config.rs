//! Integration: the config system end to end — YAML and JSON documents
//! through parse → schema → validation → use by the analytical model.

use idlewait::config::loader::{load_str, LoadError, PAPER_DEFAULT_YAML};
use idlewait::config::paper_default;
use idlewait::config::schema::{ArrivalSpec, PolicySpec};
use idlewait::energy::analytical::Analytical;
use idlewait::util::units::Duration;

#[test]
fn paper_default_round_trips_through_yaml() {
    let cfg = load_str(PAPER_DEFAULT_YAML).unwrap();
    assert_eq!(cfg, paper_default());
}

#[test]
fn custom_accelerator_profile_flows_to_model() {
    // §5.3: "Profiling other accelerators is also feasible, simply
    // requiring an adjustment of the characteristics listed in Table 2."
    let doc = PAPER_DEFAULT_YAML
        .replace("power_mw: 327.9", "power_mw: 400.0")
        .replace("idle_power_mw: 134.3", "idle_power_mw: 90.0");
    let cfg = load_str(&doc).unwrap();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    // new config energy: 400 mW × 36.145 ms = 14.458 mJ
    assert!((model.item.e_config.millijoules() - 14.458).abs() < 0.01);
    // crossover moves with the new parameters
    let t = idlewait::energy::crossover::asymptotic(&model, model.item.idle_power_baseline);
    let expected = (14.458 + 0.1244) / 0.090 + 0.0401; // ms
    assert!((t.millis() - expected).abs() < 0.05, "{}", t.millis());
}

#[test]
fn json_and_yaml_yield_identical_configs() {
    let json_doc = r#"{
      "workload": {"energy_budget_j": 4147, "request_period_ms": 40.0,
                   "strategy": "idle-waiting"},
      "workload_item": {
        "phases": [
          {"name": "configuration", "power_mw": 327.9, "time_ms": 36.145},
          {"name": "data_loading", "power_mw": 138.7, "time_ms": 0.01},
          {"name": "inference", "power_mw": 171.4, "time_ms": 0.0281},
          {"name": "data_offloading", "power_mw": 144.1, "time_ms": 0.002}
        ],
        "idle_power_mw": 134.3,
        "power_on_transient_mj": 0.1244
      },
      "platform": {
        "fpga": {"model": "XC7S15"},
        "spi": {"buswidth": 4, "freq_mhz": 66, "compressed": true},
        "battery_budget_j": 4147,
        "flash_standby_mw": 15.2
      }
    }"#;
    let from_json = load_str(json_doc).unwrap();
    assert_eq!(from_json, paper_default());
}

#[test]
fn arrival_kinds_parse_and_flow() {
    let doc = PAPER_DEFAULT_YAML.replace(
        "  request_period_ms: 40.0",
        "  request_period_ms: 40.0\n  arrival_kind: jittered\n  jitter_std_ms: 5.0\n  min_period_ms: 1.0",
    );
    let cfg = load_str(&doc).unwrap();
    match cfg.workload.arrival {
        ArrivalSpec::Jittered {
            period,
            std_dev,
            min_period,
        } => {
            assert_eq!(period, Duration::from_millis(40.0));
            assert_eq!(std_dev, Duration::from_millis(5.0));
            assert_eq!(min_period, Duration::from_millis(1.0));
        }
        other => panic!("expected jittered, got {other:?}"),
    }
}

#[test]
fn every_policy_name_loads() {
    for name in [
        "on-off",
        "idle-waiting",
        "idle-waiting-m1",
        "idle-waiting-m12",
        "adaptive", // legacy alias for oracle
        "oracle",
        "timeout",
        "ema-predictor",
        "windowed-quantile",
        "randomized-ski-rental",
    ] {
        let doc = PAPER_DEFAULT_YAML.replace("strategy: idle-waiting\n", &format!("strategy: {name}\n"));
        let cfg = load_str(&doc).unwrap();
        assert_eq!(cfg.workload.policy.name(), PolicySpec::parse(name).unwrap().name());
    }
}

#[test]
fn policy_params_load_end_to_end() {
    let doc = PAPER_DEFAULT_YAML.replace(
        "  strategy: idle-waiting\n",
        "  strategy: windowed-quantile\n  policy_params:\n    window: 24\n    quantile: 0.8\n    saving: m1\n",
    );
    let cfg = load_str(&doc).unwrap();
    assert_eq!(cfg.workload.params.window, 24);
    assert!((cfg.workload.params.quantile - 0.8).abs() < 1e-12);
    assert_eq!(
        cfg.workload.params.saving,
        idlewait::device::rails::PowerSaving::M1
    );
}

#[test]
fn malformed_documents_produce_typed_errors() {
    // yaml syntax
    assert!(matches!(load_str("a:\n\tb: 1"), Err(LoadError::Yaml(_))));
    // json syntax
    assert!(matches!(load_str("{\"a\": }"), Err(LoadError::Json(_))));
    // schema
    let missing = PAPER_DEFAULT_YAML.replace("  energy_budget_j: 4147\n", "");
    assert!(matches!(load_str(&missing), Err(LoadError::Config(_))));
    // semantic
    let bad = PAPER_DEFAULT_YAML.replace("buswidth: 4", "buswidth: 5");
    assert!(matches!(load_str(&bad), Err(LoadError::Invalid(_))));
}

#[test]
fn comments_and_formatting_are_tolerated() {
    let doc = format!("# leading comment\n\n{PAPER_DEFAULT_YAML}\n# trailing\n");
    assert_eq!(load_str(&doc).unwrap(), paper_default());
}
