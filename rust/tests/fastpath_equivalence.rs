//! Fast-path vs golden-path equivalence: the gap-cost kernel
//! (`GapCostTable` + allocation-free `execute_plan`/`configure_slot`)
//! must be **bit-identical** to the original `Board`-FSM accounting on
//! every reported quantity — energy ledgers (exact and PAC1934-sampled),
//! item counts, lifetime, decision counters, late counts — for every
//! policy on every bundled workload trace. This suite is the proof
//! obligation the perf work carries: a fast path that drifts by one ULP
//! fails here.

use std::path::Path;
use std::sync::Arc;

use idlewait::config::paper_default;
use idlewait::config::schema::PolicySpec;
use idlewait::coordinator::requests::{Periodic, Poisson, TraceReplay};
use idlewait::energy::analytical::Analytical;
use idlewait::strategies::simulate::{simulate, simulate_golden, PrefixSim, SimReport};
use idlewait::strategies::strategy::build;
use idlewait::testing::assert_sim_reports_bit_identical as assert_identical;
use idlewait::util::units::Duration;

fn corpus_traces() -> Vec<(String, Vec<Duration>)> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../workloads");
    ["bursty_iot.csv", "diurnal_poisson.csv", "onoff_mmpp.csv"]
        .iter()
        .map(|name| {
            let replay = TraceReplay::from_file(root.join(name)).expect("bundled corpus trace");
            (name.to_string(), replay.gaps().to_vec())
        })
        .collect()
}

/// Every `PolicySpec` × every bundled `workloads/` corpus trace:
/// identical `SimReport`s down to the last bit.
#[test]
fn every_policy_on_every_corpus_trace_is_bit_identical() {
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    for (trace_name, gaps) in corpus_traces() {
        let mut capped = cfg.clone();
        capped.workload.max_items = Some(gaps.len() as u64 + 1);
        for spec in PolicySpec::ALL {
            let mut policy = build(spec, &model);
            let mut arrivals = TraceReplay::new(gaps.clone());
            let fast = simulate(&capped, policy.as_mut(), &mut arrivals);
            let mut policy = build(spec, &model);
            let mut arrivals = TraceReplay::new(gaps.clone());
            let golden = simulate_golden(&capped, policy.as_mut(), &mut arrivals);
            assert_identical(&fast, &golden, &format!("{spec} on {trace_name}"));
        }
    }
}

/// An explicit all-zero `FaultSpec` (non-default seed/retry knobs
/// included) leaves both the fast path and the golden `Board`-FSM path
/// bit-identical to the untouched default config: the fault hooks take
/// the same code paths and draw no randomness when disabled.
#[test]
fn fault_spec_none_is_invisible_on_both_paths() {
    use idlewait::config::schema::FaultSpec;
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let (trace_name, gaps) = corpus_traces().swap_remove(0);
    let mut capped = cfg.clone();
    capped.workload.max_items = Some(gaps.len() as u64 + 1);
    let mut dressed_cfg = capped.clone();
    dressed_cfg.faults = FaultSpec::none();
    dressed_cfg.faults.seed = 0x5EED;
    dressed_cfg.faults.retry_max = 7;
    for spec in PolicySpec::ALL {
        let tag = format!("{spec} on {trace_name}: FaultSpec::none");
        let mut policy = build(spec, &model);
        let mut arrivals = TraceReplay::new(gaps.clone());
        let plain = simulate(&capped, policy.as_mut(), &mut arrivals);
        let mut policy = build(spec, &model);
        let mut arrivals = TraceReplay::new(gaps.clone());
        let fast = simulate(&dressed_cfg, policy.as_mut(), &mut arrivals);
        assert_identical(&plain, &fast, &format!("fast: {tag}"));
        let mut policy = build(spec, &model);
        let mut arrivals = TraceReplay::new(gaps.clone());
        let golden = simulate_golden(&dressed_cfg, policy.as_mut(), &mut arrivals);
        assert_identical(&plain, &golden, &format!("golden: {tag}"));
        assert_eq!(fast.retries, 0);
        assert_eq!(fast.recovery_energy.joules(), 0.0);
    }
}

/// Tight Poisson arrivals drive the late/queueing paths (zero idle
/// windows, mid-busy arrivals); the paths must still agree bit-for-bit.
#[test]
fn late_and_queueing_paths_are_bit_identical() {
    let mut cfg = paper_default();
    cfg.workload.max_items = Some(400);
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let mut saw_lates = false;
    for spec in PolicySpec::ALL {
        let arrivals = || Poisson::new(Duration::from_millis(2.0), Duration::from_millis(0.05), 11);
        let mut policy = build(spec, &model);
        let fast = simulate(&cfg, policy.as_mut(), &mut arrivals());
        let mut policy = build(spec, &model);
        let golden = simulate_golden(&cfg, policy.as_mut(), &mut arrivals());
        saw_lates |= fast.late_requests > 0;
        assert_identical(&fast, &golden, &format!("{spec} under tight poisson"));
    }
    // at least the reconfiguring policies must have queued behind the
    // 36 ms preamble on 2 ms gaps, or this test isn't covering the path
    assert!(saw_lates, "tight poisson produced no late requests");
}

/// The golden paper constants through the fast path: per-item energies
/// (Table 2's 11.983 mJ On-Off item, the 5.373 mJ Idle-Waiting item at
/// 40 ms) and the 89.21 ms crossover win-flip, asserted on BOTH paths so
/// a fast-path regression cannot hide behind a stale golden value.
#[test]
fn paper_constants_hold_on_both_paths() {
    let mut cfg = paper_default();
    cfg.workload.max_items = Some(200);
    let run = |golden: bool, policy: PolicySpec, period_ms: f64| -> SimReport {
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let mut policy = build(policy, &model);
        let mut arrivals = Periodic {
            period: Duration::from_millis(period_ms),
        };
        if golden {
            simulate_golden(&cfg, policy.as_mut(), &mut arrivals)
        } else {
            simulate(&cfg, policy.as_mut(), &mut arrivals)
        }
    };
    for golden in [false, true] {
        let label = if golden { "golden" } else { "fast" };
        let onoff = run(golden, PolicySpec::OnOff, 40.0);
        let per_item = onoff.energy_exact.millijoules() / onoff.items as f64;
        assert!((per_item - 11.983).abs() < 0.01, "{label}: on-off item {per_item} mJ");
        let iw = run(golden, PolicySpec::IdleWaiting, 40.0);
        let per_item = iw.energy_exact.millijoules() / iw.items as f64;
        assert!((per_item - 5.373).abs() < 0.01, "{label}: idle-waiting item {per_item} mJ");
        // 89.21 ms baseline crossover: idle-waiting wins below, loses above
        let below = run(golden, PolicySpec::IdleWaiting, 85.0).energy_exact.joules()
            / run(golden, PolicySpec::OnOff, 85.0).energy_exact.joules();
        let above = run(golden, PolicySpec::IdleWaiting, 95.0).energy_exact.joules()
            / run(golden, PolicySpec::OnOff, 95.0).energy_exact.joules();
        assert!(below < 1.0 && above > 1.0, "{label}: crossover flip {below} / {above}");
    }
}

/// The resumable prefix simulation (tuner rungs) equals from-scratch
/// runs on a real corpus trace, at every rung size — for a static
/// policy and for both learned policies, whose carried-over online
/// state must replay bit-identically.
#[test]
fn prefix_resume_on_corpus_trace_matches_from_scratch() {
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let (name, gaps) = corpus_traces().swap_remove(0);
    let shared: Arc<[Duration]> = gaps.clone().into();
    for spec in [PolicySpec::Timeout, PolicySpec::BayesMixture, PolicySpec::BanditPolicy] {
        let mut sim = PrefixSim::new(&cfg, build(spec, &model), shared.clone());
        for prefix in [16usize, 32, 64, gaps.len()] {
            let resumed = sim.advance_to(prefix);
            let mut capped = cfg.clone();
            capped.workload.max_items = Some(prefix as u64 + 1);
            let mut policy = build(spec, &model);
            let mut arrivals = TraceReplay::new(gaps[..prefix].to_vec());
            let scratch = simulate(&capped, policy.as_mut(), &mut arrivals);
            assert_identical(&resumed, &scratch, &format!("{spec} on {name} prefix {prefix}"));
        }
    }
}
