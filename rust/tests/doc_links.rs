//! Documentation link check: every relative markdown link in the repo's
//! user-facing docs must point at a file that exists, so the README ↔
//! docs/ cross-references cannot rot silently. (CI runs the same check;
//! having it in tier-1 means a broken link fails `cargo test` locally
//! too.)

use std::path::{Path, PathBuf};

/// The documents whose links are part of the user-facing contract.
fn documents() -> Vec<PathBuf> {
    let root = repo_root();
    let mut docs = vec![
        root.join("README.md"),
        root.join("ROADMAP.md"),
        root.join("rust/ARCHITECTURE.md"),
        root.join("workloads/README.md"),
    ];
    if let Ok(entries) = std::fs::read_dir(root.join("docs")) {
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().is_some_and(|e| e == "md") {
                docs.push(path);
            }
        }
    }
    docs
}

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

/// Extract `[text](target)` link targets from markdown, ignoring code
/// fences (``` blocks) where brackets are code, not links.
fn link_targets(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let bytes = line.as_bytes();
        let mut i = 0;
        while i < bytes.len() {
            if bytes[i] == b']' && i + 1 < bytes.len() && bytes[i + 1] == b'(' {
                if let Some(end) = line[i + 2..].find(')') {
                    out.push(line[i + 2..i + 2 + end].to_string());
                    i += 2 + end;
                }
            }
            i += 1;
        }
    }
    out
}

#[test]
fn doc_set_is_present() {
    // the docs this PR series promises must exist and be non-trivial
    for name in ["README.md", "docs/CLI.md", "docs/TUNING.md"] {
        let path = repo_root().join(name);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{name} must exist: {e}"));
        assert!(text.len() > 500, "{name} looks like a stub ({} bytes)", text.len());
    }
    // README links both guides
    let readme = std::fs::read_to_string(repo_root().join("README.md")).unwrap();
    assert!(readme.contains("docs/CLI.md"), "README must link docs/CLI.md");
    assert!(readme.contains("docs/TUNING.md"), "README must link docs/TUNING.md");
}

#[test]
fn relative_markdown_links_resolve() {
    let mut checked = 0usize;
    let mut broken = Vec::new();
    for doc in documents() {
        let Ok(text) = std::fs::read_to_string(&doc) else {
            continue;
        };
        let base = doc.parent().unwrap().to_path_buf();
        for target in link_targets(&text) {
            // external and intra-page links are out of scope
            if target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with("mailto:")
                || target.starts_with('#')
            {
                continue;
            }
            let file = target.split('#').next().unwrap();
            if file.is_empty() {
                continue;
            }
            let resolved = base.join(file);
            checked += 1;
            if !resolved.exists() {
                broken.push(format!("{}: {target}", doc.display()));
            }
        }
    }
    assert!(checked > 0, "the link check must find links to check");
    assert!(broken.is_empty(), "broken doc links:\n{}", broken.join("\n"));
}

#[test]
fn cli_guide_covers_every_subcommand() {
    // every command the CLI dispatches must be documented in docs/CLI.md
    let guide = std::fs::read_to_string(repo_root().join("docs/CLI.md")).unwrap();
    for cmd in [
        "fig2", "exp1", "exp2", "exp3", "exp4", "exp5", "gen-trace", "tune", "train",
        "validate", "ablate", "multi", "fleet", "faults", "serve", "plan", "bench",
        "bench-compare", "all",
    ] {
        assert!(
            guide.contains(&format!("`repro {cmd}`")),
            "docs/CLI.md is missing a section for `repro {cmd}`"
        );
    }
}
