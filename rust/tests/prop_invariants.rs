//! Property-based invariants over the energy model, device substrate and
//! simulation core (via the in-tree mini-prop framework, DESIGN.md §3).

use idlewait::config::paper_default;
use idlewait::config::schema::{FpgaModel, SpiConfig, PolicySpec};
use idlewait::coordinator::requests::Periodic;
use idlewait::device::battery::Battery;
use idlewait::device::bitstream::Bitstream;
use idlewait::device::compression::compress;
use idlewait::device::spi::{loading_power, transfer_time};
use idlewait::energy::analytical::Analytical;
use idlewait::energy::crossover;
use idlewait::sim::{EventQueue, SimTime};
use idlewait::strategies::simulate::simulate;
use idlewait::strategies::strategy::build;
use idlewait::testing::prop::{check, default_cases, Below, InRange};
use idlewait::util::rng::Xoshiro256ss;
use idlewait::util::units::{Duration, Energy, Power};

fn model() -> Analytical {
    let cfg = paper_default();
    Analytical::new(&cfg.item, cfg.workload.energy_budget)
}

/// Idle-Waiting n_max is non-increasing in the request period (more idle
/// time per item can never allow MORE items).
#[test]
fn prop_iw_items_monotone_decreasing_in_period() {
    let m = model();
    check::<(InRange<1, 2000>, InRange<1, 1000>)>(
        "iw-monotone-period",
        default_cases(),
        |(a, delta)| {
            let t1 = Duration::from_millis(a.0.max(0.05));
            let t2 = t1 + Duration::from_millis(delta.0);
            let n1 = m.n_max_idle_waiting(t1, m.item.idle_power_baseline).unwrap();
            let n2 = m.n_max_idle_waiting(t2, m.item.idle_power_baseline).unwrap();
            n2 <= n1
        },
    );
}

/// n_max is non-decreasing in the budget, for every strategy.
#[test]
fn prop_items_monotone_in_budget() {
    let cfg = paper_default();
    check::<(InRange<1, 5000>, InRange<37, 600>)>(
        "items-monotone-budget",
        default_cases(),
        |(budget_j, t_ms)| {
            let t = Duration::from_millis(t_ms.0);
            let small = Analytical::new(&cfg.item, Energy::from_joules(budget_j.0));
            let large = Analytical::new(&cfg.item, Energy::from_joules(budget_j.0 * 2.0));
            PolicySpec::ALL.iter().all(|&k| {
                let a = small.predict(k, t).n_max.unwrap_or(0);
                let b = large.predict(k, t).n_max.unwrap_or(0);
                b >= a
            })
        },
    );
}

/// Lower idle power can never hurt: items(m12) ≥ items(m1) ≥ items(base).
#[test]
fn prop_power_saving_never_hurts() {
    let m = model();
    check::<InRange<1, 1000>>("saving-ordering", default_cases(), |t_ms| {
        let t = Duration::from_millis(t_ms.0.max(0.05));
        let base = m.n_max_idle_waiting(t, m.item.idle_power(PolicySpec::IdleWaiting));
        let m1 = m.n_max_idle_waiting(t, m.item.idle_power(PolicySpec::IdleWaitingM1));
        let m12 = m.n_max_idle_waiting(t, m.item.idle_power(PolicySpec::IdleWaitingM12));
        m12 >= m1 && m1 >= base
    });
}

/// The asymptotic crossover is the unique sign change of the per-item
/// energy difference.
#[test]
fn prop_crossover_is_the_sign_change() {
    let m = model();
    check::<InRange<37, 1000>>("crossover-sign", default_cases(), |t_ms| {
        let t = Duration::from_millis(t_ms.0);
        let p = m.item.idle_power_baseline;
        let cross = crossover::asymptotic(&m, p);
        let e_iw = m.item.e_active + m.e_idle(t, p);
        let e_onoff = m.item.e_item_onoff();
        if (t.millis() - cross.millis()).abs() < 0.01 {
            true // too close to resolve in f64 comparison noise
        } else if t < cross {
            e_iw < e_onoff
        } else {
            e_iw > e_onoff
        }
    });
}

/// SPI transfer time decreases with line rate; loading power increases.
#[test]
fn prop_spi_monotonicity() {
    check::<(Below<3>, Below<11>, Below<2>)>("spi-monotone", default_cases(), |(w, f, c)| {
        let spi = SpiConfig {
            buswidth: SpiConfig::BUSWIDTHS[w.0 as usize],
            freq_mhz: SpiConfig::FREQS_MHZ[f.0 as usize],
            compressed: c.0 == 1,
        };
        let faster = SpiConfig {
            buswidth: 4,
            freq_mhz: 66.0,
            ..spi
        };
        let bits = 1_000_000;
        transfer_time(&faster, bits) <= transfer_time(&spi, bits)
            && loading_power(FpgaModel::Xc7s15, &faster)
                >= loading_power(FpgaModel::Xc7s15, &spi)
    });
}

/// Frame-dedup compression never produces a larger stream, and the ratio
/// is monotone non-increasing in occupancy.
#[test]
fn prop_compression_bounds() {
    check::<(Below<1334>, Below<1000>)>("compression-bounds", 64, |(occ, seed)| {
        let bs = Bitstream::synthesize(FpgaModel::Xc7s15, occ.0, seed.0);
        let c = compress(&bs);
        c.bits <= c.original_bits && c.ratio() >= 1.0
    });
}

/// The battery never over-draws and never rejects an affordable draw.
#[test]
fn prop_battery_conservation() {
    check::<(InRange<1, 100>, Below<64>)>("battery-conservation", 128, |(cap_j, seed)| {
        let mut battery = Battery::new(Energy::from_joules(cap_j.0));
        let mut rng = Xoshiro256ss::new(seed.0);
        for _ in 0..200 {
            let amount = Energy::from_joules(rng.uniform(0.0, cap_j.0 / 20.0));
            let before = battery.drawn();
            let affordable = before + amount <= battery.capacity();
            match battery.try_draw(amount) {
                Ok(()) => {
                    if !affordable {
                        return false; // overdraw accepted
                    }
                }
                Err(_) => {
                    if affordable {
                        return false; // affordable draw rejected
                    }
                    if battery.drawn() != before {
                        return false; // failed draw consumed energy
                    }
                }
            }
        }
        battery.drawn() <= battery.capacity()
    });
}

/// Event queue: random (time, id) schedules always pop in (time, insertion)
/// order.
#[test]
fn prop_event_queue_total_order() {
    check::<Below<10_000>>("event-queue-order", 64, |seed| {
        let mut rng = Xoshiro256ss::new(seed.0);
        let mut q = EventQueue::new();
        for i in 0..500u64 {
            q.schedule(SimTime::from_nanos(rng.below(50)), i);
        }
        let mut last: Option<(SimTime, u64)> = None;
        while let Some((t, id)) = q.pop() {
            if let Some((lt, lid)) = last {
                if t < lt || (t == lt && id < lid) {
                    return false;
                }
            }
            last = Some((t, id));
        }
        true
    });
}

/// DES item count equals Eq 3 for random small budgets and periods (the
/// strongest cross-model invariant, randomized).
#[test]
fn prop_des_equals_analytical_randomized() {
    let base_cfg = paper_default();
    check::<(InRange<1, 8>, InRange<37, 120>, Below<4>)>(
        "des-eq3-random",
        24, // each case simulates a few hundred items
        |(budget_j, t_ms, kind_idx)| {
            let kind = [
                PolicySpec::OnOff,
                PolicySpec::IdleWaiting,
                PolicySpec::IdleWaitingM1,
                PolicySpec::IdleWaitingM12,
            ][kind_idx.0 as usize];
            let t_req = Duration::from_millis(t_ms.0);
            let model = Analytical::new(&base_cfg.item, Energy::from_joules(budget_j.0));
            let Some(expected) = model.predict(kind, t_req).n_max else {
                return true;
            };
            let mut capped = base_cfg.clone();
            capped.workload.max_items = Some(expected + 5);
            let mut policy = build(kind, &model);
            let mut arrivals = Periodic { period: t_req };
            let report = simulate(&capped, policy.as_mut(), &mut arrivals);
            // the DES (full 4147 J board) must afford ≥ expected items, and
            // its energy after `expected` items must fit the random budget:
            // check via marginal accounting
            if report.items < expected {
                return false;
            }
            // energy for expected items ≈ eq-sum; tolerance for the FSM vs
            // Table-2 config-energy difference (~1e-4 relative)
            let per = report.energy_exact.joules() / report.items as f64;
            let eq_total = match kind {
                PolicySpec::OnOff => model.e_sum_onoff(expected),
                _ => model.e_sum_idle_waiting(
                    expected,
                    t_req,
                    model.item.idle_power(kind),
                ),
            };
            let approx = per * expected as f64;
            (approx - eq_total.joules()).abs() / eq_total.joules() < 0.05
        },
    );
}

/// Power × time algebra: energies computed two ways always agree.
#[test]
fn prop_unit_algebra() {
    check::<(InRange<0, 1000>, InRange<0, 1000>)>("unit-algebra", default_cases(), |(p, t)| {
        let power = Power::from_milliwatts(p.0);
        let time = Duration::from_millis(t.0);
        let e = power * time;
        let back_p = if t.0 > 0.0 { e / time } else { power };
        let back_t = if p.0 > 0.0 { e / power } else { time };
        (back_p.milliwatts() - p.0).abs() < 1e-9 * p.0.max(1.0)
            && (back_t.millis() - t.0).abs() < 1e-9 * t.0.max(1.0)
    });
}

/// JSON round-trip: any value the generator produces must survive
/// render → parse exactly (the manifest path depends on this).
#[test]
fn prop_json_round_trip() {
    use idlewait::util::json::Json;

    fn gen_value(rng: &mut Xoshiro256ss, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => {
                // round-trippable numbers: avoid float-format edge noise
                // by generating dyadic rationals
                let mantissa = rng.below(1 << 20) as f64 - (1 << 19) as f64;
                Json::Num(mantissa / 64.0)
            }
            3 => {
                let len = rng.below(12) as usize;
                let s: String = (0..len)
                    .map(|_| {
                        let choices = [
                            'a', 'Z', '0', ' ', '"', '\\', '\n', '\t', 'é', '☕', '{',
                        ];
                        *rng.choose(&choices)
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr(
                (0..rng.below(5)).map(|_| gen_value(rng, depth - 1)).collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    check::<Below<100_000>>("json-round-trip", 200, |seed| {
        let mut rng = Xoshiro256ss::new(seed.0);
        let value = gen_value(&mut rng, 3);
        let compact = Json::parse(&value.render());
        let pretty = Json::parse(&value.render_pretty());
        compact.as_ref() == Ok(&value) && pretty.as_ref() == Ok(&value)
    });
}

/// The YAML parser must never panic on arbitrary printable input
/// (errors are fine; crashes are not).
#[test]
fn prop_yaml_never_panics() {
    use idlewait::config::yaml;

    check::<Below<1_000_000>>("yaml-no-panic", 300, |seed| {
        let mut rng = Xoshiro256ss::new(seed.0);
        let len = rng.below(200) as usize;
        let doc: String = (0..len)
            .map(|_| {
                let choices = [
                    'a', 'b', ':', ' ', '-', '\n', '#', '"', '\'', '[', ']', '{', '}',
                    '&', '*', '!', '|', '>', '1', '.', '~',
                ];
                *rng.choose(&choices)
            })
            .collect();
        let _ = yaml::parse(&doc); // must return, not panic
        true
    });
}
