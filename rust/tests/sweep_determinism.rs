//! Sweep-engine determinism: `SweepRunner` output at `threads = N` must
//! be identical — same order, same bytes in rendered CSV — to
//! `threads = 1`, for the real experiment grids (exp2 and the ablation
//! grids), via the in-tree property framework over random thread counts
//! and step sizes.

use idlewait::config::paper_default;
use idlewait::coordinator::requests::Periodic;
use idlewait::experiments::exp4_policies::{self, Exp4Config};
use idlewait::experiments::{ablation, exp2, exp3};
use idlewait::runner::{Grid, SweepRunner};
use idlewait::strategies::simulate::SimWorker;
use idlewait::strategies::strategy::OnOff;
use idlewait::testing::prop::{check, Below, InRange};
use idlewait::util::csv::Csv;
use idlewait::util::units::Duration;

/// exp2 at a coarse step: threads 1 vs N → byte-identical CSV.
#[test]
fn exp2_csv_identical_at_any_thread_count() {
    let cfg = paper_default();
    let reference = exp2::run_threaded(&cfg, 0.5, &SweepRunner::single())
        .to_csv()
        .render();
    for threads in [2, 3, 4, 7, 16] {
        let out = exp2::run_threaded(&cfg, 0.5, &SweepRunner::new(threads))
            .to_csv()
            .render();
        assert_eq!(out, reference, "threads={threads}");
    }
}

/// Property: random (threads, step) pairs agree with the serial runner
/// on the full rendered CSV (order + formatting + values).
#[test]
fn prop_exp2_thread_count_is_unobservable() {
    let cfg = paper_default();
    check::<(Below<32>, InRange<1, 10>)>("exp2-thread-invariance", 12, |(threads, step)| {
        let step_ms = step.0.max(1.0);
        let serial = exp2::run_threaded(&cfg, step_ms, &SweepRunner::single())
            .to_csv()
            .render();
        let parallel = exp2::run_threaded(
            &cfg,
            step_ms,
            &SweepRunner::new(threads.0 as usize + 1),
        )
        .to_csv()
        .render();
        serial == parallel
    });
}

#[test]
fn exp3_csv_identical_at_any_thread_count() {
    let cfg = paper_default();
    let reference = exp3::run_threaded(&cfg, 0.5, &SweepRunner::single())
        .to_csv()
        .render();
    for threads in [2, 5, 8] {
        let out = exp3::run_threaded(&cfg, 0.5, &SweepRunner::new(threads))
            .to_csv()
            .render();
        assert_eq!(out, reference, "threads={threads}");
    }
}

/// The exp4 policy × arrival grid contains stochastic arrival processes
/// and stateful online policies — its CSV must still be byte-identical
/// at any thread count (streams derive from the experiment seed and the
/// arrival column, never from scheduling).
#[test]
fn exp4_csv_identical_at_any_thread_count() {
    let cfg = paper_default();
    let e4 = Exp4Config {
        items: 200,
        period_ms: 40.0,
        seed: 9,
    };
    let reference = exp4_policies::run_threaded(&cfg, &e4, &SweepRunner::single())
        .unwrap()
        .to_csv()
        .render();
    for threads in [2, 5, 8] {
        let out = exp4_policies::run_threaded(&cfg, &e4, &SweepRunner::new(threads))
            .unwrap()
            .to_csv()
            .render();
        assert_eq!(out, reference, "threads={threads}");
    }
}

/// The ablation grids (flash floor, transient sensitivity, multi-accel
/// scheduling) render identically at any thread count — including the
/// stochastic multi-accel one, whose per-cell request streams are a pure
/// function of the caller seed.
#[test]
fn ablation_grids_identical_at_any_thread_count() {
    let cfg = paper_default();
    let floor_ref = ablation::flash_floor_threaded(&cfg, &SweepRunner::single()).render();
    let trans_ref =
        ablation::transient_sensitivity_threaded(&cfg, &SweepRunner::single()).render();
    let multi_ref =
        ablation::multi_accel_threaded(&cfg, 500, 7, &SweepRunner::single()).render();
    for threads in [2, 4, 9] {
        let runner = SweepRunner::new(threads);
        assert_eq!(
            ablation::flash_floor_threaded(&cfg, &runner).render(),
            floor_ref,
            "flash floor, threads={threads}"
        );
        assert_eq!(
            ablation::transient_sensitivity_threaded(&cfg, &runner).render(),
            trans_ref,
            "transient, threads={threads}"
        );
        assert_eq!(
            ablation::multi_accel_threaded(&cfg, 500, 7, &runner).render(),
            multi_ref,
            "multi-accel, threads={threads}"
        );
    }
}

/// Adversarially uneven cell costs for the work-stealing runner: a grid
/// whose DES cells span three orders of magnitude of work (2 → 2000
/// simulated items), laid out so a static contiguous chunking would pack
/// all heavy cells into one worker. The rendered CSV must stay
/// byte-identical at `--threads` 1, 4 and 0 (= auto), per the CLI's
/// thread-count semantics.
#[test]
fn uneven_cost_grid_csv_identical_at_threads_1_4_auto() {
    let cfg = paper_default();
    // heavy cells first, then a long cheap tail — the worst case for
    // static chunking, irrelevant for index-keyed result slots
    let mut items_per_cell: Vec<u64> = vec![2_000, 1_500, 1_000];
    items_per_cell.extend((0..57u64).map(|i| 2 + (i % 7) * 30));
    let grid = Grid::new(items_per_cell);

    let sweep = |runner: &SweepRunner| -> String {
        let rows = runner.run_with_state(
            &grid,
            || SimWorker::new(&cfg),
            |worker, cell| {
                let mut capped = cfg.clone();
                capped.workload.max_items = Some(*cell.params);
                let mut arrivals = Periodic {
                    period: Duration::from_millis(40.0),
                };
                let report = worker.run(&capped, &mut OnOff, &mut arrivals);
                (
                    cell.index,
                    *cell.params,
                    report.energy_exact.millijoules(),
                    report.configurations,
                )
            },
        );
        let mut csv = Csv::new(&["cell", "items", "energy_mj", "configurations"]);
        for (index, items, energy, configs) in rows {
            csv.row(&[
                index.to_string(),
                items.to_string(),
                format!("{energy}"),
                configs.to_string(),
            ]);
        }
        csv.render()
    };

    let reference = sweep(&SweepRunner::single());
    assert_eq!(sweep(&SweepRunner::new(4)), reference, "--threads 4");
    assert_eq!(sweep(&SweepRunner::auto()), reference, "--threads 0 (auto)");
}

/// Property over the raw runner: per-cell PRNG streams depend only on
/// (base seed, index), never on the thread count.
#[test]
fn prop_cell_streams_thread_invariant() {
    check::<(Below<64>, Below<1000>)>("cell-stream-invariance", 32, |(threads, seed)| {
        let grid = Grid::new(vec![(); 97]);
        let serial = SweepRunner::single()
            .with_seed(seed.0)
            .run(&grid, |cell| cell.rng().next_u64_raw());
        let parallel = SweepRunner::new(threads.0 as usize + 1)
            .with_seed(seed.0)
            .run(&grid, |cell| cell.rng().next_u64_raw());
        serial == parallel
    });
}
