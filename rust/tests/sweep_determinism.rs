//! Sweep-engine determinism: `SweepRunner` output at `threads = N` must
//! be identical — same order, same bytes in rendered CSV — to
//! `threads = 1`, for the real experiment grids (exp2 and the ablation
//! grids), via the in-tree property framework over random thread counts
//! and step sizes.

use idlewait::config::paper_default;
use idlewait::experiments::exp4_policies::{self, Exp4Config};
use idlewait::experiments::{ablation, exp2, exp3};
use idlewait::runner::{Grid, SweepRunner};
use idlewait::testing::prop::{check, Below, InRange};

/// exp2 at a coarse step: threads 1 vs N → byte-identical CSV.
#[test]
fn exp2_csv_identical_at_any_thread_count() {
    let cfg = paper_default();
    let reference = exp2::run_threaded(&cfg, 0.5, &SweepRunner::single())
        .to_csv()
        .render();
    for threads in [2, 3, 4, 7, 16] {
        let out = exp2::run_threaded(&cfg, 0.5, &SweepRunner::new(threads))
            .to_csv()
            .render();
        assert_eq!(out, reference, "threads={threads}");
    }
}

/// Property: random (threads, step) pairs agree with the serial runner
/// on the full rendered CSV (order + formatting + values).
#[test]
fn prop_exp2_thread_count_is_unobservable() {
    let cfg = paper_default();
    check::<(Below<32>, InRange<1, 10>)>("exp2-thread-invariance", 12, |(threads, step)| {
        let step_ms = step.0.max(1.0);
        let serial = exp2::run_threaded(&cfg, step_ms, &SweepRunner::single())
            .to_csv()
            .render();
        let parallel = exp2::run_threaded(
            &cfg,
            step_ms,
            &SweepRunner::new(threads.0 as usize + 1),
        )
        .to_csv()
        .render();
        serial == parallel
    });
}

#[test]
fn exp3_csv_identical_at_any_thread_count() {
    let cfg = paper_default();
    let reference = exp3::run_threaded(&cfg, 0.5, &SweepRunner::single())
        .to_csv()
        .render();
    for threads in [2, 5, 8] {
        let out = exp3::run_threaded(&cfg, 0.5, &SweepRunner::new(threads))
            .to_csv()
            .render();
        assert_eq!(out, reference, "threads={threads}");
    }
}

/// The exp4 policy × arrival grid contains stochastic arrival processes
/// and stateful online policies — its CSV must still be byte-identical
/// at any thread count (streams derive from the experiment seed and the
/// arrival column, never from scheduling).
#[test]
fn exp4_csv_identical_at_any_thread_count() {
    let cfg = paper_default();
    let e4 = Exp4Config {
        items: 200,
        period_ms: 40.0,
        seed: 9,
    };
    let reference = exp4_policies::run_threaded(&cfg, &e4, &SweepRunner::single())
        .unwrap()
        .to_csv()
        .render();
    for threads in [2, 5, 8] {
        let out = exp4_policies::run_threaded(&cfg, &e4, &SweepRunner::new(threads))
            .unwrap()
            .to_csv()
            .render();
        assert_eq!(out, reference, "threads={threads}");
    }
}

/// The ablation grids (flash floor, transient sensitivity, multi-accel
/// scheduling) render identically at any thread count — including the
/// stochastic multi-accel one, whose per-cell request streams are a pure
/// function of the caller seed.
#[test]
fn ablation_grids_identical_at_any_thread_count() {
    let cfg = paper_default();
    let floor_ref = ablation::flash_floor_threaded(&cfg, &SweepRunner::single()).render();
    let trans_ref =
        ablation::transient_sensitivity_threaded(&cfg, &SweepRunner::single()).render();
    let multi_ref =
        ablation::multi_accel_threaded(&cfg, 500, 7, &SweepRunner::single()).render();
    for threads in [2, 4, 9] {
        let runner = SweepRunner::new(threads);
        assert_eq!(
            ablation::flash_floor_threaded(&cfg, &runner).render(),
            floor_ref,
            "flash floor, threads={threads}"
        );
        assert_eq!(
            ablation::transient_sensitivity_threaded(&cfg, &runner).render(),
            trans_ref,
            "transient, threads={threads}"
        );
        assert_eq!(
            ablation::multi_accel_threaded(&cfg, 500, 7, &runner).render(),
            multi_ref,
            "multi-accel, threads={threads}"
        );
    }
}

/// Property over the raw runner: per-cell PRNG streams depend only on
/// (base seed, index), never on the thread count.
#[test]
fn prop_cell_streams_thread_invariant() {
    check::<(Below<64>, Below<1000>)>("cell-stream-invariance", 32, |(threads, seed)| {
        let grid = Grid::new(vec![(); 97]);
        let serial = SweepRunner::single()
            .with_seed(seed.0)
            .run(&grid, |cell| cell.rng().next_u64_raw());
        let parallel = SweepRunner::new(threads.0 as usize + 1)
            .with_seed(seed.0)
            .run(&grid, |cell| cell.rng().next_u64_raw());
        serial == parallel
    });
}
