//! Integration: every experiment reproduces the paper's published
//! numbers within the tolerances stated in EXPERIMENTS.md.

use idlewait::config::paper_default;
use idlewait::config::schema::FpgaModel;
use idlewait::device::rails::PowerSaving;
use idlewait::experiments::{exp1, exp2, exp3, fig2, paper, validation};

#[test]
fn fig2_config_dominates() {
    let f = fig2::run();
    assert!((f.config_fraction() - paper::fig2::CONFIG_FRACTION).abs() < 0.002);
}

#[test]
fn exp1_full_reproduction() {
    let r = exp1::run(FpgaModel::Xc7s15);
    let opt = r.optimal();
    assert!((opt.config_time_ms() - paper::exp1::OPT_TIME_MS).abs() < 0.01);
    assert!((opt.config_energy_mj() - paper::exp1::OPT_ENERGY_MJ).abs() < 0.02);
    assert!((opt.config_power_mw() - paper::exp1::OPT_POWER_MW).abs() < 0.4);
    assert!((r.worst().config_energy_mj() - paper::exp1::WORST_ENERGY_MJ).abs() < 1.0);
    assert!((r.energy_improvement() - paper::exp1::ENERGY_IMPROVEMENT).abs() < 0.15);
    assert!((r.time_improvement() - paper::exp1::TIME_IMPROVEMENT).abs() < 0.1);
    // setup stage invariants (Fig 7 column 2)
    for p in &r.points {
        assert!((p.profile.setup().power.milliwatts() - paper::exp1::SETUP_POWER_MW).abs() < 1e-9);
        assert!((p.profile.setup().time.millis() - paper::exp1::SETUP_TIME_MS).abs() < 1e-9);
    }
}

#[test]
fn exp1_xc7s25_spotcheck() {
    let r = exp1::run(FpgaModel::Xc7s25);
    assert!((r.optimal().config_time_ms() - paper::exp1::XC7S25_TIME_MS).abs() < 0.05);
    assert!((r.optimal().config_energy_mj() - paper::exp1::XC7S25_ENERGY_MJ).abs() < 0.05);
}

#[test]
fn exp2_full_resolution_reproduction() {
    let cfg = paper_default();
    // the paper's own 0.01 ms sweep resolution (11,001 points)
    let r = exp2::run(&cfg, paper::exp2::T_REQ_STEP_MS);
    assert_eq!(r.samples.len(), 11_001);
    assert!(r.at(10.0).iw_items.abs_diff(paper::exp2::IW_ITEMS_MAX) < 600);
    assert!(r.at(120.0).iw_items.abs_diff(paper::exp2::IW_ITEMS_MIN) < 60);
    assert!(r
        .at(40.0)
        .onoff_items
        .unwrap()
        .abs_diff(paper::exp2::ONOFF_ITEMS)
        < 150);
    assert!((r.ratio_at_40ms() - paper::exp2::RATIO_AT_40MS).abs() < 0.005);
    assert!((r.crossover_ms - paper::exp2::CROSSOVER_MS).abs() < 0.02);
    assert!((r.iw_avg_lifetime_h() - paper::exp2::IW_AVG_LIFETIME_H).abs() < 0.02);
    // On-Off not represented below its configuration time (Fig 8 note)
    assert!(r.at(36.10).onoff_items.is_none());
    assert!(r.at(36.20).onoff_items.is_some());
}

#[test]
fn exp2_crossover_separates_the_strategies() {
    let cfg = paper_default();
    let r = exp2::run(&cfg, 0.01);
    for s in &r.samples {
        let Some(onoff) = s.onoff_items else { continue };
        if s.t_req_ms < r.crossover_ms - 0.02 {
            assert!(s.iw_items >= onoff, "IW must win below crossover at {}", s.t_req_ms);
        } else if s.t_req_ms > r.crossover_ms + 0.02 {
            assert!(onoff >= s.iw_items, "On-Off must win above crossover at {}", s.t_req_ms);
        }
    }
}

#[test]
fn exp3_full_reproduction() {
    let cfg = paper_default();
    let r = exp3::run(&cfg, 0.01);
    assert!((r.idle_baseline_mw - paper::exp3::BASELINE_IDLE_MW).abs() < 1e-9);
    assert!((r.idle_m1_mw - paper::exp3::M1_IDLE_MW).abs() < 1e-9);
    assert!((r.idle_m12_mw - paper::exp3::M12_IDLE_MW).abs() < 0.05);
    assert!((r.m1_items_x() - paper::exp3::M1_ITEMS_X).abs() < 0.03);
    assert!((r.m12_items_x() - paper::exp3::M12_ITEMS_X).abs() < 0.04);
    assert!((r.avg_lifetime_h(PowerSaving::M1) - paper::exp3::M1_AVG_LIFETIME_H).abs() < 0.3);
    assert!((r.avg_lifetime_h(PowerSaving::M12) - paper::exp3::M12_AVG_LIFETIME_H).abs() < 0.4);
    assert!((r.m12_crossover_ms - paper::exp3::M12_CROSSOVER_MS).abs() < 0.2);
    assert!((r.m12_vs_onoff_at_40ms - paper::exp3::M12_VS_ONOFF_AT_40MS).abs() < 0.05);
}

#[test]
fn validation_gaps_tighter_than_papers_hw_gap() {
    let cfg = paper_default();
    let v = validation::run(&cfg, 40.0);
    for row in &v.rows {
        // our model-vs-mechanism gap must be tighter than the paper's
        // hardware-vs-model 2.8% — and the instrument error bounded by it
        assert!(row.items_gap < paper::exp2::HW_ITEMS_GAP);
        assert!(row.lifetime_gap < paper::exp2::HW_LIFETIME_GAP);
        assert!(row.monitor_rel_error < 0.03);
    }
}

#[test]
fn csv_outputs_write_to_disk() {
    let dir = std::env::temp_dir().join("idlewait_exp_csv");
    let cfg = paper_default();
    exp1::run(FpgaModel::Xc7s15)
        .to_csv()
        .write_to(dir.join("exp1.csv"))
        .unwrap();
    exp2::run(&cfg, 1.0).to_csv().write_to(dir.join("exp2.csv")).unwrap();
    exp3::run(&cfg, 1.0).to_csv().write_to(dir.join("exp3.csv")).unwrap();
    for f in ["exp1.csv", "exp2.csv", "exp3.csv"] {
        let text = std::fs::read_to_string(dir.join(f)).unwrap();
        assert!(text.lines().count() > 10, "{f}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
