//! Workload-item phases (paper Fig 1/2, Table 2).
//!
//! A workload item is the sequence of operations the FPGA performs per
//! inference request: configuration, data loading, inference, data
//! offloading — plus, under Idle-Waiting, the idle gap until the next
//! request. This module gives the phases identity (for breakdowns like
//! Fig 2) on top of the raw `PhaseSpec` power/duration pairs.

use crate::config::schema::WorkloadItemSpec;
use crate::util::units::{Duration, Energy, Power};

/// Phase identity within a workload item.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// FPGA configuration.
    Configuration,
    /// Input transfer.
    DataLoading,
    /// The accelerated inference.
    Inference,
    /// Output transfer.
    DataOffloading,
    /// Between-request idling.
    Idle,
}

impl Phase {
    /// The four active (non-idle) phases, in execution order.
    pub const ACTIVE: [Phase; 4] = [
        Phase::Configuration,
        Phase::DataLoading,
        Phase::Inference,
        Phase::DataOffloading,
    ];

    /// Phase name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Configuration => "configuration",
            Phase::DataLoading => "data_loading",
            Phase::Inference => "inference",
            Phase::DataOffloading => "data_offloading",
            Phase::Idle => "idle",
        }
    }
}

/// Power and duration of a phase instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseProfile {
    /// Which phase this profile describes.
    pub phase: Phase,
    /// Average power over the phase.
    pub power: Power,
    /// Phase duration.
    pub time: Duration,
}

impl PhaseProfile {
    /// Phase energy: `power × time`.
    pub fn energy(&self) -> Energy {
        self.power * self.time
    }
}

/// The active phases of an item from its spec (Table 2 order).
pub fn active_profiles(item: &WorkloadItemSpec) -> [PhaseProfile; 4] {
    [
        PhaseProfile {
            phase: Phase::Configuration,
            power: item.configuration.power,
            time: item.configuration.time,
        },
        PhaseProfile {
            phase: Phase::DataLoading,
            power: item.data_loading.power,
            time: item.data_loading.time,
        },
        PhaseProfile {
            phase: Phase::Inference,
            power: item.inference.power,
            time: item.inference.time,
        },
        PhaseProfile {
            phase: Phase::DataOffloading,
            power: item.data_offloading.power,
            time: item.data_offloading.time,
        },
    ]
}

/// Per-phase energy breakdown with fractions (the Fig 2 pie).
#[derive(Debug, Clone)]
pub struct Breakdown {
    /// Per-phase energies, in execution order.
    pub entries: Vec<(Phase, Energy)>,
    /// Sum over all entries.
    pub total: Energy,
}

impl Breakdown {
    /// The Fig 2 energy breakdown of one workload item.
    pub fn of_item(item: &WorkloadItemSpec) -> Breakdown {
        let entries: Vec<(Phase, Energy)> = active_profiles(item)
            .iter()
            .map(|p| (p.phase, p.energy()))
            .collect();
        let total = entries.iter().map(|(_, e)| *e).sum();
        Breakdown { entries, total }
    }

    /// Fraction of total energy attributable to `phase`, in [0, 1].
    pub fn fraction(&self, phase: Phase) -> f64 {
        self.entries
            .iter()
            .find(|(p, _)| *p == phase)
            .map(|(_, e)| *e / self.total)
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    #[test]
    fn active_profile_energies_match_table2() {
        let item = paper_default().item;
        let profiles = active_profiles(&item);
        let e: Vec<f64> = profiles.iter().map(|p| p.energy().microjoules()).collect();
        assert!((e[0] - 11852.0).abs() < 10.0); // configuration
        assert!((e[1] - 1.387).abs() < 1e-3); // data loading
        assert!((e[2] - 4.816).abs() < 1e-2); // inference
        assert!((e[3] - 0.2882).abs() < 1e-3); // data offloading
    }

    #[test]
    fn configuration_dominates_optimized_item() {
        // Even at the optimal configuration setting, configuration is
        // >99.9% of the (active) item — the motivation for Idle-Waiting.
        let item = paper_default().item;
        let b = Breakdown::of_item(&item);
        assert!(b.fraction(Phase::Configuration) > 0.999);
    }

    #[test]
    fn fractions_sum_to_one() {
        let item = paper_default().item;
        let b = Breakdown::of_item(&item);
        let sum: f64 = Phase::ACTIVE.iter().map(|p| b.fraction(*p)).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn idle_fraction_of_active_breakdown_is_zero() {
        let item = paper_default().item;
        let b = Breakdown::of_item(&item);
        assert_eq!(b.fraction(Phase::Idle), 0.0);
    }

    #[test]
    fn phase_names() {
        assert_eq!(Phase::Configuration.name(), "configuration");
        assert_eq!(Phase::Idle.name(), "idle");
    }
}
