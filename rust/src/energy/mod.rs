//! Energy model: workload-item phases (Table 2), the paper's analytical
//! model (Eqs 1–4) and the strategy crossover solvers.

pub mod analytical;
pub mod crossover;
pub mod phase;

pub use analytical::{Analytical, ItemEnergetics, Prediction};
pub use phase::{Breakdown, Phase, PhaseProfile};
