//! The paper's analytical model (§4.3, Equations 1–4).
//!
//! * Eq 1: `E_Sum^OnOff(n)    = Σᵢ E_Item^OnOff`
//! * Eq 2: `E_Sum^IdleWait(n) = E_Init + Σᵢ E_Item^IdleWait + Σᵢⁿ⁻¹ E_Idle`
//! * Eq 3: `n_max = max{ n ∈ ℕ | E_Sum(n) ≤ E_Budget }`
//! * Eq 4: `T_lifetime = n_max · T_req`
//!
//! Per-item energies are derived from the workload-item description
//! (Table 2) plus the calibrated power-on transient (DESIGN.md §6):
//!
//! * `E_Item^OnOff   = E_transient + E_config + E_active`
//! * `E_Init         = E_transient + E_config` (one-time, Idle-Waiting)
//! * `E_Item^IdleWait = E_active` (configuration-related overheads zero)
//! * `E_Idle         = P_idle · (T_req − T_latency_noconfig)`

use crate::config::schema::{PolicySpec, WorkloadItemSpec};
use crate::device::rails::{PowerSaving, RailSet};
use crate::util::units::{Duration, Energy, Power};

/// Energy quantities derived once from a workload-item description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ItemEnergetics {
    /// Configuration-phase energy (Table 2: ≈11.85 mJ at optimal settings).
    pub e_config: Energy,
    /// Data loading + inference + data offloading energy (≈6.49 µJ).
    pub e_active: Energy,
    /// Power-cycle transient charged per On-Off item (≈0.124 mJ).
    pub e_transient: Energy,
    /// Item latency including configuration (On-Off critical path).
    pub latency_with_config: Duration,
    /// Item latency excluding configuration (Idle-Waiting critical path).
    pub latency_without_config: Duration,
    /// Baseline idle power from the item description (134.3 mW).
    pub idle_power_baseline: Power,
}

impl ItemEnergetics {
    /// Derive the per-item energy quantities from a Table 2 description.
    pub fn from_spec(item: &WorkloadItemSpec) -> ItemEnergetics {
        ItemEnergetics {
            e_config: item.configuration.energy(),
            e_active: item.active_energy_without_config(),
            e_transient: item.power_on_transient,
            latency_with_config: item.latency_with_config(),
            latency_without_config: item.latency_without_config(),
            idle_power_baseline: item.idle_power,
        }
    }

    /// Full per-item energy under On-Off.
    pub fn e_item_onoff(&self) -> Energy {
        self.e_transient + self.e_config + self.e_active
    }

    /// One-time initial overhead under Idle-Waiting.
    pub fn e_init(&self) -> Energy {
        self.e_transient + self.e_config
    }

    /// Idle power for a policy: the baseline comes from the measured
    /// item description; the power-saving methods from the rail model.
    /// The advanced policies idle at M1+2 — the same mode
    /// `strategies::strategy::build` constructs them with, so the closed
    /// form describes the policy that actually runs.
    pub fn idle_power(&self, kind: PolicySpec) -> Power {
        match kind {
            PolicySpec::IdleWaiting => self.idle_power_baseline,
            PolicySpec::IdleWaitingM1 => RailSet::idle_power(PowerSaving::M1),
            PolicySpec::IdleWaitingM12
            | PolicySpec::Oracle
            | PolicySpec::Timeout
            | PolicySpec::EmaPredictor
            | PolicySpec::WindowedQuantile
            | PolicySpec::RandomizedSkiRental
            | PolicySpec::BayesMixture
            | PolicySpec::BanditPolicy => RailSet::idle_power(PowerSaving::M12),
            PolicySpec::OnOff => self.idle_power_baseline,
        }
    }
}

/// Result of an analytical evaluation for one (policy, T_req) point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    /// Policy evaluated.
    pub policy: PolicySpec,
    /// Request period evaluated at.
    pub t_req: Duration,
    /// Eq 3: maximum executable workload items. `None` = infeasible
    /// (On-Off with T_req below the item latency — Fig 8's gap).
    pub n_max: Option<u64>,
    /// Eq 4: system lifetime.
    pub lifetime: Duration,
    /// Mean per-item energy at large n (reporting).
    pub e_per_item: Energy,
}

/// The analytical model bound to an item description and a budget.
#[derive(Debug, Clone)]
pub struct Analytical {
    /// Per-item energy quantities.
    pub item: ItemEnergetics,
    /// The energy budget (Eq 3's E_Budget).
    pub budget: Energy,
}

impl Analytical {
    /// Bind the model to an item description and budget.
    pub fn new(item: &WorkloadItemSpec, budget: Energy) -> Analytical {
        Analytical {
            item: ItemEnergetics::from_spec(item),
            budget,
        }
    }

    /// Eq 1: cumulative On-Off energy for n items.
    pub fn e_sum_onoff(&self, n: u64) -> Energy {
        self.item.e_item_onoff() * n as f64
    }

    /// Eq 2: cumulative Idle-Waiting energy for n items at `t_req` with
    /// idle power `p_idle`.
    pub fn e_sum_idle_waiting(&self, n: u64, t_req: Duration, p_idle: Power) -> Energy {
        if n == 0 {
            return self.item.e_init();
        }
        let e_idle = self.e_idle(t_req, p_idle);
        self.item.e_init()
            + self.item.e_active * n as f64
            + e_idle * (n - 1) as f64
    }

    /// Per-gap idle energy: `P_idle · (T_req − T_latency)`.
    pub fn e_idle(&self, t_req: Duration, p_idle: Power) -> Energy {
        let t_idle = t_req - self.item.latency_without_config;
        debug_assert!(t_idle.secs() >= 0.0, "period shorter than item latency");
        p_idle * t_idle
    }

    /// On-Off feasibility (paper §5.3: no On-Off below 36.15 ms).
    pub fn onoff_feasible(&self, t_req: Duration) -> bool {
        t_req >= self.item.latency_with_config
    }

    /// Eq 3 for On-Off: `floor(E_Budget / E_Item)`, or None if infeasible.
    pub fn n_max_onoff(&self, t_req: Duration) -> Option<u64> {
        if !self.onoff_feasible(t_req) {
            return None;
        }
        Some((self.budget / self.item.e_item_onoff()).floor() as u64)
    }

    /// Eq 3 for Idle-Waiting at idle power `p_idle`:
    /// `n ≤ (E_Budget − E_Init + E_Idle) / (E_Item + E_Idle)`.
    pub fn n_max_idle_waiting(&self, t_req: Duration, p_idle: Power) -> Option<u64> {
        if t_req < self.item.latency_without_config {
            return None;
        }
        let e_idle = self.e_idle(t_req, p_idle);
        let per_item = self.item.e_active + e_idle;
        let numerator = self.budget - self.item.e_init() + e_idle;
        if numerator.joules() < 0.0 {
            return Some(0);
        }
        Some((numerator / per_item).floor() as u64)
    }

    /// Evaluate Eqs 3–4 for a policy at `t_req`. The online policies'
    /// closed forms assume strictly periodic arrivals (the only case with
    /// a closed form) **at their default tunables** — the M1+2 idle mode
    /// and the analytical break-even τ that `strategy::build` constructs
    /// them with; configured `PolicyParams` overrides apply to the
    /// simulation paths, not to these predictions. The oracle picks the
    /// per-item winner; `Timeout`
    /// additionally pays the ski-rental premium `P_idle·τ` per gap
    /// whenever powering off wins; `EmaPredictor` and `WindowedQuantile`
    /// lock onto the winner after one observation (every windowed
    /// quantile of a constant gap is that gap), so asymptotically they
    /// equal the oracle; `RandomizedSkiRental` pays the expected cost of
    /// its per-gap timeout draw (see the branch below for the integral).
    pub fn predict(&self, policy: PolicySpec, t_req: Duration) -> Prediction {
        let (n_max, e_per_item) = match policy {
            PolicySpec::OnOff => (self.n_max_onoff(t_req), self.item.e_item_onoff()),
            PolicySpec::IdleWaiting
            | PolicySpec::IdleWaitingM1
            | PolicySpec::IdleWaitingM12 => {
                let p_idle = self.item.idle_power(policy);
                (
                    self.n_max_idle_waiting(t_req, p_idle),
                    self.item.e_active + self.e_idle(t_req, p_idle),
                )
            }
            PolicySpec::Oracle
            | PolicySpec::EmaPredictor
            | PolicySpec::WindowedQuantile
            | PolicySpec::BayesMixture
            | PolicySpec::BanditPolicy => {
                // per-gap winner at the M1+2 idle mode these policies are
                // built with; the predictors (and both learned policies —
                // the posterior mean and the per-cell action costs of a
                // constant gap are that gap's) degenerate to it
                let onoff = self.predict(PolicySpec::OnOff, t_req);
                let iw = self.predict(PolicySpec::IdleWaitingM12, t_req);
                return if onoff.n_max.unwrap_or(0) >= iw.n_max.unwrap_or(0) {
                    Prediction { policy, ..onoff }
                } else {
                    Prediction { policy, ..iw }
                };
            }
            PolicySpec::RandomizedSkiRental => {
                // Expected per-gap cost of drawing the timeout T from the
                // e/(e−1)-competitive density p(t) = e^(t/τ)/(τ(e−1)) on
                // [0, τ], against the fixed idle window w = T_req − T_lat:
                //
                //   E[gap] = P_idle·E[min(T, w)] + F(w)·E_buy
                //
                // with E_buy the power-cycle + reconfiguration cost and
                //   F(w)         = (e^(w/τ) − 1)/(e − 1)          (w ≤ τ)
                //   E[min(T,w)]  = ∫₀ʷ t·p(t) dt + w·(1 − F(w))
                //                = (w·e^(w/τ) − τ·e^(w/τ) + τ)/(e − 1)
                //                  + w·(e − e^(w/τ))/(e − 1).
                // At w ≥ τ this collapses to E[T] = τ/(e − 1) and F = 1,
                // i.e. exactly e/(e−1) × the oracle's cost — the classic
                // competitive guarantee, here in joules.
                let p_idle = self.item.idle_power(policy);
                let tau = crate::energy::crossover::ski_rental_timeout(self, p_idle);
                let w = (t_req - self.item.latency_without_config)
                    .secs()
                    .clamp(0.0, tau.secs());
                let e = std::f64::consts::E;
                let ew = (w / tau.secs()).exp();
                let fire_prob = (ew - 1.0) / (e - 1.0);
                let expected_idle_secs = (w * ew - tau.secs() * ew + tau.secs()) / (e - 1.0)
                    + w * (e - ew) / (e - 1.0);
                let e_buy = self.item.e_transient + self.item.e_config;
                let per_item = self.item.e_active
                    + p_idle * Duration::from_secs(expected_idle_secs)
                    + e_buy * fire_prob;
                let n = Some((self.budget / per_item).floor() as u64);
                return Prediction {
                    policy,
                    t_req,
                    n_max: n,
                    lifetime: t_req * n.unwrap_or(0) as f64,
                    e_per_item: per_item,
                };
            }
            PolicySpec::Timeout => {
                let p_idle = self.item.idle_power(policy);
                let iw = self.predict(PolicySpec::IdleWaitingM12, t_req);
                let onoff = self.predict(PolicySpec::OnOff, t_req);
                return if onoff.n_max.unwrap_or(0) >= iw.n_max.unwrap_or(0) {
                    // every gap: idle until τ expires, then power off
                    let tau = crate::energy::crossover::ski_rental_timeout(self, p_idle);
                    let per_item = self.item.e_item_onoff() + p_idle * tau;
                    let n = Some((self.budget / per_item).floor() as u64);
                    Prediction {
                        policy,
                        t_req,
                        n_max: n,
                        lifetime: t_req * n.unwrap_or(0) as f64,
                        e_per_item: per_item,
                    }
                } else {
                    // the timer never fires before the next request
                    Prediction { policy, ..iw }
                };
            }
        };
        Prediction {
            policy,
            t_req,
            n_max,
            lifetime: t_req * n_max.unwrap_or(0) as f64, // Eq 4
            e_per_item,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn model() -> Analytical {
        let cfg = paper_default();
        Analytical::new(&cfg.item, cfg.workload.energy_budget)
    }

    fn ms(x: f64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn e_item_onoff_is_calibrated() {
        let m = model();
        assert!(
            (m.item.e_item_onoff().millijoules() - 11.983).abs() < 0.001,
            "{}",
            m.item.e_item_onoff().millijoules()
        );
    }

    #[test]
    fn onoff_nmax_matches_paper_fig8() {
        // paper: 346,073 items independent of T_req (≥ 36.15 ms)
        let m = model();
        for t in [40.0, 60.0, 90.0, 120.0] {
            let n = m.n_max_onoff(ms(t)).unwrap();
            assert!(n.abs_diff(346_073) <= 150, "t={t}: n={n}");
        }
    }

    #[test]
    fn onoff_infeasible_below_config_time() {
        let m = model();
        assert_eq!(m.n_max_onoff(ms(36.0)), None);
        assert_eq!(m.n_max_onoff(ms(10.0)), None);
        assert!(m.n_max_onoff(ms(36.19)).is_some());
    }

    #[test]
    fn idle_waiting_nmax_matches_paper_extremes() {
        // paper Fig 8: ≈3,085,319 at 10 ms; ≈257,305 at 120 ms
        let m = model();
        let n10 = m
            .n_max_idle_waiting(ms(10.0), m.item.idle_power_baseline)
            .unwrap();
        assert!(n10.abs_diff(3_085_319) < 600, "n10={n10}");
        let n120 = m
            .n_max_idle_waiting(ms(120.0), m.item.idle_power_baseline)
            .unwrap();
        assert!(n120.abs_diff(257_305) < 60, "n120={n120}");
    }

    #[test]
    fn idle_waiting_beats_onoff_2_23x_at_40ms() {
        let m = model();
        let iw = m.predict(PolicySpec::IdleWaiting, ms(40.0)).n_max.unwrap();
        let onoff = m.predict(PolicySpec::OnOff, ms(40.0)).n_max.unwrap();
        let ratio = iw as f64 / onoff as f64;
        assert!((ratio - 2.23).abs() < 0.005, "ratio={ratio}");
    }

    #[test]
    fn method12_yields_12_39x_lifetime_at_40ms() {
        // paper conclusion: ≈12.39× the On-Off items/lifetime at 40 ms
        let m = model();
        let m12 = m.predict(PolicySpec::IdleWaitingM12, ms(40.0)).n_max.unwrap();
        let onoff = m.predict(PolicySpec::OnOff, ms(40.0)).n_max.unwrap();
        let ratio = m12 as f64 / onoff as f64;
        assert!((ratio - 12.39).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn idle_waiting_lifetime_approx_8_58h() {
        let m = model();
        for t in [10.0, 40.0, 80.0, 120.0] {
            let p = m.predict(PolicySpec::IdleWaiting, ms(t));
            assert!(
                (p.lifetime.hours() - 8.58).abs() < 0.03,
                "t={t}: {}h",
                p.lifetime.hours()
            );
        }
    }

    #[test]
    fn onoff_lifetime_linear_in_t_req() {
        let m = model();
        let l40 = m.predict(PolicySpec::OnOff, ms(40.0)).lifetime;
        let l80 = m.predict(PolicySpec::OnOff, ms(80.0)).lifetime;
        assert!((l80 / l40 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn eq2_matches_manual_sum() {
        let m = model();
        let p_idle = m.item.idle_power_baseline;
        let n = 1000u64;
        let manual = m.item.e_init()
            + m.item.e_active * n as f64
            + m.e_idle(ms(40.0), p_idle) * (n - 1) as f64;
        let eq2 = m.e_sum_idle_waiting(n, ms(40.0), p_idle);
        assert!((manual.joules() - eq2.joules()).abs() < 1e-12);
    }

    #[test]
    fn eq3_boundary_exactness() {
        // E_Sum(n_max) ≤ budget < E_Sum(n_max + 1)
        let m = model();
        let p_idle = m.item.idle_power_baseline;
        let n = m.n_max_idle_waiting(ms(40.0), p_idle).unwrap();
        assert!(m.e_sum_idle_waiting(n, ms(40.0), p_idle) <= m.budget);
        assert!(m.e_sum_idle_waiting(n + 1, ms(40.0), p_idle) > m.budget);
        let n = m.n_max_onoff(ms(40.0)).unwrap();
        assert!(m.e_sum_onoff(n) <= m.budget);
        assert!(m.e_sum_onoff(n + 1) > m.budget);
    }

    #[test]
    fn oracle_picks_the_winner() {
        let m = model();
        // short period → Idle-Waiting (at the oracle's M1+2 mode) wins
        let a = m.predict(PolicySpec::Oracle, ms(40.0));
        let iw = m.predict(PolicySpec::IdleWaitingM12, ms(40.0));
        assert_eq!(a.n_max, iw.n_max);
        // beyond the 499.06 ms M1+2 crossover → On-Off wins
        let a = m.predict(PolicySpec::Oracle, ms(600.0));
        let onoff = m.predict(PolicySpec::OnOff, ms(600.0));
        assert_eq!(a.n_max, onoff.n_max);
    }

    #[test]
    fn timeout_pays_the_ski_rental_premium_when_off_wins() {
        let m = model();
        let p_idle = m.item.idle_power(PolicySpec::Timeout);
        // below the M1+2 crossover the timer never fires: identical to IW
        let t = m.predict(PolicySpec::Timeout, ms(200.0));
        let iw = m.predict(PolicySpec::IdleWaitingM12, ms(200.0));
        assert_eq!(t.n_max, iw.n_max);
        // above the crossover: On-Off plus P_idle·τ per item
        let t = m.predict(PolicySpec::Timeout, ms(600.0));
        let onoff = m.predict(PolicySpec::OnOff, ms(600.0));
        let tau = crate::energy::crossover::ski_rental_timeout(&m, p_idle);
        let premium = p_idle * tau;
        assert!(t.n_max.unwrap() < onoff.n_max.unwrap());
        assert!(
            (t.e_per_item - (m.item.e_item_onoff() + premium)).abs().millijoules() < 1e-9
        );
        // never worse than 2× the oracle's per-item energy
        let oracle = m.predict(PolicySpec::Oracle, ms(600.0));
        assert!(t.e_per_item <= oracle.e_per_item * 2.0 + m.item.e_active);
    }

    #[test]
    fn ema_prediction_equals_oracle_closed_form() {
        let m = model();
        for t_ms in [40.0, 200.0, 600.0] {
            assert_eq!(
                m.predict(PolicySpec::EmaPredictor, ms(t_ms)).n_max,
                m.predict(PolicySpec::Oracle, ms(t_ms)).n_max,
                "t={t_ms}"
            );
        }
    }

    #[test]
    fn windowed_quantile_prediction_equals_oracle_closed_form() {
        // every windowed quantile of a constant gap is that gap, so on
        // periodic arrivals the predictor locks onto the per-gap winner
        let m = model();
        for t_ms in [40.0, 200.0, 600.0] {
            assert_eq!(
                m.predict(PolicySpec::WindowedQuantile, ms(t_ms)).n_max,
                m.predict(PolicySpec::Oracle, ms(t_ms)).n_max,
                "t={t_ms}"
            );
        }
    }

    #[test]
    fn learned_predictions_equal_oracle_closed_form() {
        // on strictly periodic arrivals both learned policies converge to
        // the per-gap winner: the Bayes posterior mean is the period, and
        // every visited bandit cell's cheapest action is the oracle's
        let m = model();
        for spec in [PolicySpec::BayesMixture, PolicySpec::BanditPolicy] {
            for t_ms in [40.0, 200.0, 600.0] {
                assert_eq!(
                    m.predict(spec, ms(t_ms)).n_max,
                    m.predict(PolicySpec::Oracle, ms(t_ms)).n_max,
                    "{spec} t={t_ms}"
                );
            }
        }
    }

    #[test]
    fn randomized_ski_rental_expected_cost_far_beyond_tau() {
        // w ≥ τ: the timer always fires; the expected per-item energy is
        // On-Off plus the expected rent P_idle·τ/(e−1) — exactly e/(e−1)
        // of the oracle's per-gap (buy) cost.
        let m = model();
        let p_idle = m.item.idle_power(PolicySpec::RandomizedSkiRental);
        let tau = crate::energy::crossover::ski_rental_timeout(&m, p_idle);
        let r = m.predict(PolicySpec::RandomizedSkiRental, ms(600.0));
        let e = std::f64::consts::E;
        let expect = m.item.e_item_onoff() + p_idle * tau * (1.0 / (e - 1.0));
        assert!(
            (r.e_per_item - expect).abs().millijoules() < 1e-9,
            "{} vs {}",
            r.e_per_item.millijoules(),
            expect.millijoules()
        );
        // in expectation it beats the deterministic 2-competitive rule
        let det = m.predict(PolicySpec::Timeout, ms(600.0));
        assert!(r.e_per_item < det.e_per_item);
        assert!(r.n_max.unwrap() > det.n_max.unwrap());
    }

    #[test]
    fn randomized_ski_rental_short_period_cost_between_idle_and_onoff() {
        // w ≪ τ: the timer rarely fires, so the expected cost sits just
        // above pure M1+2 idling but far below paying a reconfiguration
        // per item.
        let m = model();
        let r = m.predict(PolicySpec::RandomizedSkiRental, ms(40.0));
        let iw = m.predict(PolicySpec::IdleWaitingM12, ms(40.0));
        let onoff = m.predict(PolicySpec::OnOff, ms(40.0));
        assert!(r.e_per_item > iw.e_per_item);
        assert!(r.e_per_item < onoff.e_per_item);
        // and never worse than e/(e−1) × the oracle in expectation
        let oracle = m.predict(PolicySpec::Oracle, ms(40.0));
        let ratio = r.e_per_item.millijoules() / oracle.e_per_item.millijoules();
        assert!(ratio < std::f64::consts::E / (std::f64::consts::E - 1.0) + 1e-9, "{ratio}");
    }

    #[test]
    fn zero_items_allowed_if_budget_tiny() {
        let cfg = paper_default();
        let m = Analytical::new(&cfg.item, Energy::from_millijoules(1.0));
        // budget below even E_Init
        assert_eq!(
            m.n_max_idle_waiting(ms(40.0), m.item.idle_power_baseline),
            Some(0)
        );
        assert_eq!(m.n_max_onoff(ms(40.0)), Some(0));
    }

    #[test]
    fn method_idle_powers_from_rail_model() {
        let m = model();
        assert!((m.item.idle_power(PolicySpec::IdleWaiting).milliwatts() - 134.3).abs() < 1e-9);
        assert!((m.item.idle_power(PolicySpec::IdleWaitingM1).milliwatts() - 34.2).abs() < 1e-9);
        assert!((m.item.idle_power(PolicySpec::IdleWaitingM12).milliwatts() - 24.0).abs() < 0.05);
    }
}
