//! Crossover-point solver: the request period where Idle-Waiting and
//! On-Off break even (paper: 89.21 ms baseline, 499.06 ms with both
//! power-saving methods).
//!
//! Two solvers:
//!
//! * [`asymptotic`] — closed form. At large n the E_Init amortizes away
//!   and the strategies tie when per-item energies match:
//!   `E_Item^OnOff = E_active + P_idle · (T* − T_latency)` ⟹
//!   `T* = (E_Item^OnOff − E_active)/P_idle + T_latency`.
//! * [`exact`] — bisection on the integer n_max difference under the
//!   finite budget; validates that the closed form is the right answer to
//!   within the sweep resolution the paper used (0.01 ms).

use crate::energy::analytical::Analytical;
use crate::util::units::{Duration, Power};

/// Closed-form asymptotic crossover for a given idle power.
pub fn asymptotic(model: &Analytical, p_idle: Power) -> Duration {
    let surplus = model.item.e_item_onoff() - model.item.e_active;
    surplus / p_idle + model.item.latency_without_config
}

/// The ski-rental break-even timeout τ for the `Timeout` gap policy: the
/// idle duration whose energy equals one power cycle + reconfiguration
/// (the "buy" cost `E_transient + E_config`). Idling up to τ and then
/// cutting power is the classic deterministic 2-competitive rule against
/// the clairvoyant oracle. Equals [`asymptotic`] minus the item latency,
/// because the crossover is stated in whole-gap terms while τ is an idle
/// window.
pub fn ski_rental_timeout(model: &Analytical, p_idle: Power) -> Duration {
    (model.item.e_item_onoff() - model.item.e_active) / p_idle
}

/// Exact finite-budget crossover by bisection: the largest `T_req` (within
/// `[lo, hi]`, to `tol`) where Idle-Waiting still executes at least as many
/// items as On-Off. Returns `None` if there is no sign change in the range.
pub fn exact(
    model: &Analytical,
    p_idle: Power,
    lo: Duration,
    hi: Duration,
    tol: Duration,
) -> Option<Duration> {
    let iw_wins = |t: Duration| -> bool {
        let iw = model.n_max_idle_waiting(t, p_idle).unwrap_or(0);
        let onoff = model.n_max_onoff(t).unwrap_or(0);
        iw >= onoff
    };
    let (mut lo, mut hi) = (lo, hi);
    if !iw_wins(lo) || iw_wins(hi) {
        return None; // no crossover bracketed
    }
    while (hi - lo).secs() > tol.secs() {
        let mid = Duration::from_secs((lo.secs() + hi.secs()) / 2.0);
        if iw_wins(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(lo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::config::schema::PolicySpec;
    use crate::util::units::Energy;

    fn model() -> Analytical {
        let cfg = paper_default();
        Analytical::new(&cfg.item, cfg.workload.energy_budget)
    }

    #[test]
    fn baseline_crossover_is_89_21ms() {
        let m = model();
        let t = asymptotic(&m, m.item.idle_power(PolicySpec::IdleWaiting));
        assert!((t.millis() - 89.21).abs() < 0.02, "t={}", t.millis());
    }

    #[test]
    fn method12_crossover_is_499_06ms() {
        let m = model();
        let t = asymptotic(&m, m.item.idle_power(PolicySpec::IdleWaitingM12));
        assert!((t.millis() - 499.06).abs() < 0.1, "t={}", t.millis());
    }

    #[test]
    fn method1_crossover_around_350ms() {
        // not quoted by the paper; implied by its model (34.2 mW)
        let m = model();
        let t = asymptotic(&m, m.item.idle_power(PolicySpec::IdleWaitingM1));
        assert!((t.millis() - 350.2).abs() < 0.5, "t={}", t.millis());
    }

    #[test]
    fn exact_agrees_with_asymptotic_at_paper_resolution() {
        let m = model();
        for kind in [
            PolicySpec::IdleWaiting,
            PolicySpec::IdleWaitingM1,
            PolicySpec::IdleWaitingM12,
        ] {
            let p = m.item.idle_power(kind);
            let closed = asymptotic(&m, p);
            let bisected = exact(
                &m,
                p,
                Duration::from_millis(37.0),
                Duration::from_millis(600.0),
                Duration::from_millis(0.01), // the paper's sweep step
            )
            .unwrap();
            assert!(
                (closed.millis() - bisected.millis()).abs() < 0.05,
                "{kind}: closed={} exact={}",
                closed.millis(),
                bisected.millis()
            );
        }
    }

    #[test]
    fn no_crossover_when_range_misses_it() {
        let m = model();
        let p = m.item.idle_power(PolicySpec::IdleWaiting);
        assert!(exact(
            &m,
            p,
            Duration::from_millis(37.0),
            Duration::from_millis(50.0),
            Duration::from_millis(0.01)
        )
        .is_none());
    }

    #[test]
    fn crossover_scales_with_idle_power() {
        // halving idle power should roughly double the crossover period
        let m = model();
        let t1 = asymptotic(&m, Power::from_milliwatts(100.0));
        let t2 = asymptotic(&m, Power::from_milliwatts(50.0));
        assert!((t2.millis() / t1.millis() - 2.0).abs() < 0.01);
    }

    #[test]
    fn crossover_below_latency_never_happens() {
        // with absurdly high idle power the formula floors at the latency
        let m = model();
        let t = asymptotic(&m, Power::from_watts(10_000.0));
        assert!(t >= m.item.latency_without_config);
    }

    #[test]
    fn ski_rental_timeout_is_crossover_minus_latency() {
        let m = model();
        for kind in [
            PolicySpec::IdleWaiting,
            PolicySpec::IdleWaitingM1,
            PolicySpec::IdleWaitingM12,
        ] {
            let p = m.item.idle_power(kind);
            let tau = ski_rental_timeout(&m, p);
            let cross = asymptotic(&m, p);
            assert!(
                ((cross - tau).millis() - m.item.latency_without_config.millis()).abs() < 1e-12,
                "{kind}"
            );
            // τ·P_idle must equal the power-cycle "buy" cost exactly
            let buy = m.item.e_item_onoff() - m.item.e_active;
            assert!((tau * p - buy).abs().millijoules() < 1e-12, "{kind}");
        }
    }

    #[test]
    fn bigger_budget_does_not_move_asymptotic_crossover() {
        let cfg = paper_default();
        let small = Analytical::new(&cfg.item, Energy::from_joules(100.0));
        let large = Analytical::new(&cfg.item, Energy::from_joules(100_000.0));
        let p = small.item.idle_power(PolicySpec::IdleWaiting);
        assert_eq!(
            asymptotic(&small, p).millis(),
            asymptotic(&large, p).millis()
        );
    }
}
