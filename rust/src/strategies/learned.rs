//! Learned gap policies: an online Bayesian mixture gap model and a
//! contextual bandit over discretized [`GapContext`] features.
//!
//! Both policies work in **p_idle-normalized cost units**: idling
//! through a gap of `g` seconds costs `g`, buying (power off + later
//! reconfigure) costs the break-even timeout τ from
//! [`crossover::ski_rental_timeout`] — the scale at which the ski-rental
//! literature states its bounds. Minimizing expected normalized cost per
//! gap therefore minimizes expected gap energy at the policy's idle
//! mode, and the property suite (`tests/prop_learned.rs`) sandwiches
//! both learners between the clairvoyant [`Oracle`] lower bound and the
//! e/(e−1) randomized upper bound.
//!
//! Determinism contract: neither policy samples during planning.
//! [`BayesMixture`] uses its seed only to jitter the initial component
//! means (one [`SplitMix64`] stream consumed at construction), and
//! [`BanditPolicy`] is RNG-free; all online updates are plain f64
//! arithmetic in observation order, so the sweep byte-identity
//! guarantees at any `--threads N` carry over unchanged.
//!
//! [`Oracle`]: crate::strategies::strategy::Oracle

use crate::config::schema::{PolicyParams, PolicySpec, PolicyTable};
use crate::device::rails::PowerSaving;
use crate::energy::analytical::Analytical;
use crate::energy::crossover;
use crate::strategies::replay::GapBatch;
use crate::strategies::strategy::{GapContext, GapPlan, Policy};
use crate::util::rng::SplitMix64;
use crate::util::units::Duration;

/// Floor for observed gaps (seconds) so a zero-length gap cannot produce
/// an infinite component rate.
const MIN_GAP_SECS: f64 = 1e-9;

/// One exponential mixture component with a Gamma posterior over its
/// arrival rate λ: `shape / rate_total` is the posterior-mean rate,
/// `rate_total / shape` the posterior-mean gap.
#[derive(Debug, Clone, Copy)]
struct Component {
    /// Gamma shape: prior pseudo-count + responsibility-weighted count.
    shape: f64,
    /// Gamma rate: prior mean + responsibility-weighted gap seconds.
    rate_total: f64,
    /// Mixture-weight numerator (responsibility mass).
    mass: f64,
}

impl Component {
    /// Posterior-mean arrival rate λ (1/seconds).
    fn rate(&self) -> f64 {
        self.shape / self.rate_total
    }

    /// Posterior-mean gap (seconds).
    fn mean(&self) -> f64 {
        self.rate_total / self.shape
    }
}

/// Online Bayesian mixture-of-exponentials gap model: K ∈ 2..=4
/// components whose rate posteriors take responsibility-weighted
/// conjugate updates per observed gap, planned by posterior expected
/// cost.
///
/// Planning compares, in normalized units (buy = τ):
///
/// * **Idle**: `E[g] = Σ wₖ·mₖ`
/// * **Off**: `τ`
/// * **IdleThenOff(t)**: `E[min(g, t)] + P(g > t)·τ`
///   `= Σ wₖ·(mₖ·(1 − e^(−t/mₖ)) + e^(−t/mₖ)·τ)`
///
/// over a deterministic candidate-timeout set (component means and 3×
/// means clamped to (0, τ], plus τ itself). On a unimodal gap stream
/// this degenerates to the crossover decision (idle iff the mean gap is
/// below τ); on multi-modal streams the interior IdleThenOff timeouts
/// rent through the short mode and buy at the long one.
#[derive(Debug, Clone)]
pub struct BayesMixture {
    /// Idle mode used while configured.
    pub saving: PowerSaving,
    /// Break-even gap duration of the idle mode (reporting only).
    pub crossover: Duration,
    /// The normalized buy cost τ (also the cold-start hedge timeout).
    pub tau: Duration,
    /// Cold-start hedge timeout (`policy_params.timeout_ms` overrides τ).
    pub hedge: Duration,
    components: Vec<Component>,
    /// Observations folded in so far.
    observed: u64,
}

impl BayesMixture {
    /// Initial component means as multiples of τ: spread geometrically so
    /// the prior covers burst gaps (≈τ/20) through long silences (≈8τ).
    const MEAN_LADDER: [f64; 4] = [0.05, 0.5, 2.0, 8.0];

    /// Build from the analytical model with `k` components (clamped to
    /// 2..=4), seeding the deterministic init jitter from `seed`.
    pub fn from_model(
        model: &Analytical,
        saving: PowerSaving,
        k: usize,
        seed: u64,
    ) -> BayesMixture {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        let tau = crossover::ski_rental_timeout(model, p_idle);
        let k = k.clamp(2, 4);
        let mut jitter = SplitMix64::new(seed);
        let components = Self::MEAN_LADDER[..k]
            .iter()
            .map(|&ladder| {
                // multiplicative jitter in [0.9, 1.1): distinct seeds start
                // from distinct priors without changing the ladder's shape
                let u = (jitter.next() >> 11) as f64 / (1u64 << 53) as f64;
                let mean = tau.secs() * ladder * (0.9 + 0.2 * u);
                Component {
                    shape: 1.0,
                    rate_total: mean.max(MIN_GAP_SECS),
                    mass: 1.0,
                }
            })
            .collect();
        BayesMixture {
            saving,
            crossover: crossover::asymptotic(model, p_idle),
            tau,
            hedge: tau,
            components,
            observed: 0,
        }
    }

    /// Number of mixture components K.
    pub fn component_count(&self) -> usize {
        self.components.len()
    }

    /// Observations folded into the posterior so far.
    pub fn observed(&self) -> u64 {
        self.observed
    }

    /// Posterior expected gap `E[g] = Σ wₖ·mₖ` in seconds.
    pub fn expected_gap(&self) -> f64 {
        let total: f64 = self.components.iter().map(|c| c.mass).sum();
        self.components
            .iter()
            .map(|c| (c.mass / total) * c.mean())
            .sum()
    }

    /// Expected normalized cost of `IdleThenOff(t)` under the posterior.
    fn idle_then_off_cost(&self, t: f64, tau: f64, total_mass: f64) -> f64 {
        self.components
            .iter()
            .map(|c| {
                let w = c.mass / total_mass;
                let survive = (-c.rate() * t).exp();
                w * (c.mean() * (1.0 - survive) + survive * tau)
            })
            .sum()
    }

    /// The posterior-optimal plan: the cheapest of Idle, Off and
    /// IdleThenOff over the candidate-timeout set, ties broken in that
    /// order (deterministic).
    fn posterior_plan(&self) -> GapPlan {
        let tau = self.tau.secs();
        let total_mass: f64 = self.components.iter().map(|c| c.mass).sum();
        let mut best_plan = GapPlan::Idle(self.saving);
        let mut best_cost = self.expected_gap();
        if tau < best_cost {
            best_plan = GapPlan::PowerOff;
            best_cost = tau;
        }
        // candidate timeouts: each component mean and 3× mean (the knee of
        // its survival curve), clamped into (0, τ], plus τ itself
        let mut consider = |t: f64| {
            let t = t.clamp(MIN_GAP_SECS, tau);
            let cost = self.idle_then_off_cost(t, tau, total_mass);
            if cost < best_cost {
                best_cost = cost;
                best_plan = GapPlan::IdleThenOff {
                    saving: self.saving,
                    timeout: Duration::from_secs(t),
                };
            }
        };
        for i in 0..self.components.len() {
            let mean = self.components[i].mean();
            consider(mean);
            consider(3.0 * mean);
        }
        consider(tau);
        best_plan
    }
}

impl Policy for BayesMixture {
    fn kind(&self) -> PolicySpec {
        PolicySpec::BayesMixture
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        if self.observed == 0 {
            // cold start: no evidence yet → the 2-competitive hedge
            return GapPlan::IdleThenOff {
                saving: self.saving,
                timeout: self.hedge,
            };
        }
        self.posterior_plan()
    }

    fn observe(&mut self, actual_gap: Duration) {
        let g = actual_gap.secs().max(MIN_GAP_SECS);
        // responsibilities under the posterior-mean rates, computed in log
        // space (log-sum-exp) so huge gaps cannot underflow every component
        let mut log_like = [0.0f64; 4];
        for (ll, c) in log_like.iter_mut().zip(&self.components) {
            let rate = c.rate();
            *ll = c.mass.ln() + rate.ln() - rate * g;
        }
        let k = self.components.len();
        let max_ll = log_like[..k].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut resp = [0.0f64; 4];
        let mut total = 0.0;
        for (r, ll) in resp[..k].iter_mut().zip(&log_like[..k]) {
            *r = (ll - max_ll).exp();
            total += *r;
        }
        for (c, r) in self.components.iter_mut().zip(&resp[..k]) {
            let r = r / total;
            c.shape += r;
            c.rate_total += r * g;
            c.mass += r;
        }
        self.observed += 1;
    }

    /// Same plan/observe interleaving as the default loop, statically
    /// dispatched so the mixture updates inline over the batch — the
    /// post-batch posterior is bit-identical to the scalar path's.
    fn plan_gaps(&mut self, ctxs: &[GapContext], gaps: &[Duration], out: &mut GapBatch) {
        debug_assert_eq!(ctxs.len(), gaps.len());
        for (ctx, &gap) in ctxs.iter().zip(gaps) {
            let plan = self.plan_gap(ctx);
            out.push(gap, plan);
            self.observe(gap);
        }
    }

    fn label(&self) -> String {
        format!(
            "bayes-mixture({}, k {}, tau {:.2} ms)",
            self.saving.label(),
            self.components.len(),
            self.tau.millis()
        )
    }
}

/// The bandit's action alphabet, in deterministic tie-break order:
/// idle first (cheapest when wrong by a little), then the hedge, then
/// the irreversible power-off.
const ACTIONS: [u8; 3] = [b'i', b't', b'o'];

/// Per-cell running statistics: observation count and the running-mean
/// normalized cost of each action, updated counterfactually (every
/// realized gap prices all three actions, not just the chosen one).
#[derive(Debug, Clone, Copy)]
struct CellStats {
    count: u64,
    cost: [f64; 3],
}

impl Default for CellStats {
    fn default() -> Self {
        CellStats {
            count: 0,
            cost: [0.0; 3],
        }
    }
}

/// Contextual bandit / tabular-Q gap policy over 64 discretized
/// [`GapContext`] cells: 4 recent-gap-EMA buckets (relative to the
/// crossover) × 2 coefficient-of-variation buckets × 4 diurnal-phase
/// buckets (from `ctx.now`) × 2 queue-depth buckets (`ctx.queued`).
///
/// Because the realized gap prices **all three** actions (idle costs
/// `g`, off costs τ, idle-then-off costs `min(g, τ) + [g > τ]·τ` in
/// normalized units), the policy needs no exploration: every cell's
/// running-mean action costs converge from full information, and the
/// greedy argmin is deterministic. Cold cells fall back to an
/// offline-trained [`PolicyTable`] (`repro train --emit`) when one is
/// loaded, else to the 2-competitive hedge.
#[derive(Debug, Clone)]
pub struct BanditPolicy {
    /// Idle mode used while configured.
    pub saving: PowerSaving,
    /// Break-even gap duration of the idle mode (EMA bucket scale).
    pub crossover: Duration,
    /// Normalized buy cost τ; also the `t` action's timeout.
    pub tau: Duration,
    /// Feature-EMA smoothing factor in (0, 1].
    pub alpha: f64,
    /// Offline-trained fallback for cold cells, if loaded.
    table: Option<PolicyTable>,
    /// EMA of observed gaps in seconds (`None` until the first gap).
    ema: Option<f64>,
    /// EMA of squared deviations from the gap EMA (variance proxy).
    var_ema: f64,
    cells: [CellStats; PolicyTable::CELLS],
    /// Cell the most recent `plan_gap` planned in, so `observe` credits
    /// the realized gap to the context it was planned under.
    last_cell: Option<usize>,
}

impl BanditPolicy {
    /// Online estimates take over from the table/hedge once a cell has
    /// seen this many gaps.
    pub const MIN_CELL_OBS: u64 = 3;

    /// Diurnal feature period in seconds: one day of the bundled diurnal
    /// corpus (96 gaps at the paper's 40 ms duty cycle).
    pub const DIURNAL_CYCLE_SECS: f64 = 96.0 * 0.040;

    /// Build from the analytical model, optionally with an
    /// offline-trained action table for cold cells.
    pub fn from_model(
        model: &Analytical,
        saving: PowerSaving,
        alpha: f64,
        table: Option<PolicyTable>,
    ) -> BanditPolicy {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        BanditPolicy {
            saving,
            crossover: crossover::asymptotic(model, p_idle),
            tau: crossover::ski_rental_timeout(model, p_idle),
            alpha: alpha.clamp(f64::EPSILON, 1.0),
            table,
            ema: None,
            var_ema: 0.0,
            cells: [CellStats::default(); PolicyTable::CELLS],
            last_cell: None,
        }
    }

    /// Whether an offline-trained table is loaded.
    pub fn trained(&self) -> bool {
        self.table.is_some()
    }

    /// The context cell the policy would plan `ctx` in, under its current
    /// feature state. Public so offline training replays the exact
    /// bucketing the online policy uses.
    pub fn context_cell(&self, ctx: &GapContext) -> usize {
        let cross = self.crossover.secs();
        let ema_bucket = match self.ema {
            None => 0,
            Some(m) => {
                let r = m / cross;
                if r < 0.25 {
                    0
                } else if r < 1.0 {
                    1
                } else if r < 4.0 {
                    2
                } else {
                    3
                }
            }
        };
        let var_bucket = match self.ema {
            Some(m) if m > 0.0 && self.var_ema.sqrt() / m >= 0.5 => 1,
            _ => 0,
        };
        let frac = (ctx.now.secs() / Self::DIURNAL_CYCLE_SECS).fract();
        let phase_bucket = ((frac * 4.0) as usize).min(3);
        let queue_bucket = usize::from(ctx.queued > 0);
        ((ema_bucket * 2 + var_bucket) * 4 + phase_bucket) * 2 + queue_bucket
    }

    /// The normalized cost every action would have paid on a realized gap
    /// of `gap_secs`, given buy cost `tau_secs` — the full-information
    /// counterfactual update (order matches [`ACTIONS`]).
    pub fn action_costs(tau_secs: f64, gap_secs: f64) -> [f64; 3] {
        let idle = gap_secs;
        let hedge = if gap_secs > tau_secs {
            2.0 * tau_secs
        } else {
            gap_secs
        };
        let off = tau_secs;
        [idle, hedge, off]
    }

    /// Map an action letter onto its [`GapPlan`].
    fn plan_for_action(&self, action: u8) -> GapPlan {
        match action {
            b'i' => GapPlan::Idle(self.saving),
            b'o' => GapPlan::PowerOff,
            _ => GapPlan::IdleThenOff {
                saving: self.saving,
                timeout: self.tau,
            },
        }
    }

    /// The greedy action for a warm cell: strict-min scan in [`ACTIONS`]
    /// order, so ties resolve deterministically toward idling.
    fn greedy_action(stats: &CellStats) -> u8 {
        let mut best = ACTIONS[0];
        let mut best_cost = stats.cost[0];
        for (a, &cost) in ACTIONS.iter().zip(&stats.cost).skip(1) {
            if cost < best_cost {
                best = *a;
                best_cost = cost;
            }
        }
        best
    }

    /// The greedy per-cell action table under the current statistics:
    /// warm cells take their argmin action, cold cells the hedge. This is
    /// what `repro train` emits after replaying a training split.
    pub fn greedy_table(&self) -> PolicyTable {
        let mut table = PolicyTable::hedge();
        for (slot, stats) in table.0.iter_mut().zip(&self.cells) {
            if stats.count >= Self::MIN_CELL_OBS {
                *slot = Self::greedy_action(stats);
            }
        }
        table
    }

    /// Gaps credited to `cell` so far.
    pub fn cell_count(&self, cell: usize) -> u64 {
        self.cells[cell].count
    }
}

impl Policy for BanditPolicy {
    fn kind(&self) -> PolicySpec {
        PolicySpec::BanditPolicy
    }

    fn plan_gap(&mut self, ctx: &GapContext) -> GapPlan {
        let cell = self.context_cell(ctx);
        self.last_cell = Some(cell);
        let stats = &self.cells[cell];
        let action = if stats.count >= Self::MIN_CELL_OBS {
            Self::greedy_action(stats)
        } else if let Some(table) = &self.table {
            table.0[cell]
        } else {
            b't'
        };
        self.plan_for_action(action)
    }

    fn observe(&mut self, actual_gap: Duration) {
        let g = actual_gap.secs().max(MIN_GAP_SECS);
        // credit the counterfactual action costs to the planning cell
        // (absent when observe arrives before any plan, e.g. fleet replay)
        if let Some(cell) = self.last_cell {
            let stats = &mut self.cells[cell];
            stats.count += 1;
            let n = stats.count as f64;
            for (mean, cost) in stats
                .cost
                .iter_mut()
                .zip(Self::action_costs(self.tau.secs(), g))
            {
                *mean += (cost - *mean) / n;
            }
        }
        // then roll the context features forward
        match self.ema {
            None => {
                self.ema = Some(g);
                self.var_ema = 0.0;
            }
            Some(m) => {
                let d = g - m;
                self.ema = Some(m + self.alpha * d);
                self.var_ema += self.alpha * (d * d - self.var_ema);
            }
        }
    }

    /// Same plan/observe interleaving as the default loop, statically
    /// dispatched so the cell updates inline over the batch — the
    /// post-batch table state is bit-identical to the scalar path's.
    fn plan_gaps(&mut self, ctxs: &[GapContext], gaps: &[Duration], out: &mut GapBatch) {
        debug_assert_eq!(ctxs.len(), gaps.len());
        for (ctx, &gap) in ctxs.iter().zip(gaps) {
            let plan = self.plan_gap(ctx);
            out.push(gap, plan);
            self.observe(gap);
        }
    }

    fn label(&self) -> String {
        format!(
            "bandit({}, alpha {:.2}, {})",
            self.saving.label(),
            self.alpha,
            if self.table.is_some() { "trained" } else { "cold" }
        )
    }
}

/// Build a [`BayesMixture`] from config-level tunables (`components`,
/// `seed`, `saving`, with `timeout_ms` overriding the cold-start hedge).
pub fn bayes_from_params(model: &Analytical, params: &PolicyParams) -> BayesMixture {
    let mut b = BayesMixture::from_model(model, params.saving, params.components, params.seed);
    if let Some(timeout) = params.timeout {
        b.hedge = timeout; // cold-start hedge override
    }
    b
}

/// Build a [`BanditPolicy`] from config-level tunables (`ema_alpha`,
/// `table`, `saving`).
pub fn bandit_from_params(model: &Analytical, params: &PolicyParams) -> BanditPolicy {
    BanditPolicy::from_model(model, params.saving, params.ema_alpha, params.table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn model() -> Analytical {
        let cfg = paper_default();
        Analytical::new(&cfg.item, cfg.workload.energy_budget)
    }

    fn ctx() -> GapContext {
        GapContext {
            items_done: 0,
            now: Duration::ZERO,
            queued: 0,
        }
    }

    #[test]
    fn bayes_cold_start_hedges_then_becomes_deterministic_per_seed() {
        let m = model();
        let mut a = BayesMixture::from_model(&m, PowerSaving::M12, 3, 7);
        let mut b = BayesMixture::from_model(&m, PowerSaving::M12, 3, 7);
        assert!(matches!(a.plan_gap(&ctx()), GapPlan::IdleThenOff { .. }));
        for i in 0..64 {
            let gap = Duration::from_millis(if i % 5 == 4 { 900.0 } else { 20.0 });
            a.observe(gap);
            b.observe(gap);
            assert_eq!(a.plan_gap(&ctx()), b.plan_gap(&ctx()), "gap {i}");
        }
        assert_eq!(a.observed(), 64);
        assert_eq!(a.component_count(), 3);
    }

    #[test]
    fn bayes_converges_to_the_crossover_decision_on_constant_gaps() {
        let m = model();
        // constant short gaps: the posterior mean sits far below τ → idle
        let mut short = BayesMixture::from_model(&m, PowerSaving::M12, 2, 0);
        for _ in 0..200 {
            short.observe(Duration::from_millis(40.0));
        }
        assert!(short.expected_gap() < short.tau.secs());
        match short.plan_gap(&ctx()) {
            GapPlan::Idle(_) => {}
            // a never-expiring hedge is energy-equivalent to idling
            GapPlan::IdleThenOff { timeout, .. } => {
                assert!(timeout > Duration::from_millis(40.0), "{timeout:?}")
            }
            other => panic!("expected idle-shaped plan, got {other:?}"),
        }
        // constant long gaps: the posterior mean sits above τ → power off
        let mut long = BayesMixture::from_model(&m, PowerSaving::M12, 2, 0);
        for _ in 0..200 {
            long.observe(Duration::from_secs(2.0));
        }
        assert_eq!(long.plan_gap(&ctx()), GapPlan::PowerOff);
    }

    #[test]
    fn bayes_separates_a_bimodal_stream_with_an_interior_timeout() {
        let m = model();
        let mut p = BayesMixture::from_model(&m, PowerSaving::M12, 3, 1);
        // bursty shape: 4 short gaps then a long silence, repeated
        for i in 0..400 {
            let gap = Duration::from_millis(if i % 5 == 4 { 660.0 } else { 16.0 });
            p.observe(gap);
        }
        match p.plan_gap(&ctx()) {
            GapPlan::IdleThenOff { timeout, .. } => {
                // rents through the 16 ms bursts, buys before τ
                assert!(timeout > Duration::from_millis(16.0), "{timeout:?}");
                assert!(timeout <= p.tau, "{timeout:?} vs tau {:?}", p.tau);
            }
            other => panic!("expected an interior ski-rental plan, got {other:?}"),
        }
    }

    #[test]
    fn bayes_survives_enormous_gaps_without_nan() {
        let m = model();
        let mut p = BayesMixture::from_model(&m, PowerSaving::M12, 4, 0);
        p.observe(Duration::from_secs(1e6));
        p.observe(Duration::ZERO);
        p.observe(Duration::from_millis(40.0));
        assert!(p.expected_gap().is_finite());
        // whatever the plan, it must be well-formed
        let _ = p.plan_gap(&ctx());
    }

    #[test]
    fn bandit_cold_cells_hedge_and_trained_cells_follow_the_table() {
        let m = model();
        let mut cold = BanditPolicy::from_model(&m, PowerSaving::M12, 0.2, None);
        assert!(matches!(cold.plan_gap(&ctx()), GapPlan::IdleThenOff { .. }));
        assert!(!cold.trained());

        let mut table = PolicyTable::hedge();
        let cell = cold.context_cell(&ctx());
        table.0[cell] = b'o';
        let mut trained = BanditPolicy::from_model(&m, PowerSaving::M12, 0.2, Some(table));
        assert!(trained.trained());
        assert_eq!(trained.plan_gap(&ctx()), GapPlan::PowerOff);
    }

    #[test]
    fn bandit_learns_the_crossover_decision_per_cell() {
        let m = model();
        let mut p = BanditPolicy::from_model(&m, PowerSaving::M12, 0.2, None);
        // constant short gaps: the (only) visited cell learns to idle
        for _ in 0..16 {
            let _ = p.plan_gap(&ctx());
            p.observe(Duration::from_millis(40.0));
        }
        assert_eq!(p.plan_gap(&ctx()), GapPlan::Idle(PowerSaving::M12));

        // constant long gaps: the visited cells learn to power off
        let mut p = BanditPolicy::from_model(&m, PowerSaving::M12, 0.2, None);
        for _ in 0..32 {
            let _ = p.plan_gap(&ctx());
            p.observe(Duration::from_secs(3.0));
        }
        assert_eq!(p.plan_gap(&ctx()), GapPlan::PowerOff);
    }

    #[test]
    fn bandit_greedy_table_reflects_learned_cells() {
        let m = model();
        let mut p = BanditPolicy::from_model(&m, PowerSaving::M12, 0.2, None);
        for _ in 0..16 {
            let _ = p.plan_gap(&ctx());
            p.observe(Duration::from_millis(40.0));
        }
        let cell = p.context_cell(&ctx());
        let table = p.greedy_table();
        assert_eq!(table.0[cell], b'i');
        // unvisited cells keep the hedge
        assert!(table.0.iter().filter(|&&a| a == b't').count() >= 60);
        assert!(p.cell_count(cell) > 0);
    }

    #[test]
    fn bandit_action_costs_price_the_ski_rental_shapes() {
        let tau = 0.5;
        // short gap: idle and hedge pay the gap, off pays the buy
        assert_eq!(BanditPolicy::action_costs(tau, 0.02), [0.02, 0.02, 0.5]);
        // long gap: idle pays the gap, hedge pays rent + buy, off the buy
        assert_eq!(BanditPolicy::action_costs(tau, 2.0), [2.0, 1.0, 0.5]);
    }

    #[test]
    fn bandit_queue_depth_and_phase_split_cells() {
        let m = model();
        let p = BanditPolicy::from_model(&m, PowerSaving::M12, 0.2, None);
        let base = ctx();
        let queued = GapContext { queued: 2, ..base };
        assert_ne!(p.context_cell(&base), p.context_cell(&queued));
        let later = GapContext {
            now: Duration::from_secs(BanditPolicy::DIURNAL_CYCLE_SECS / 2.0),
            ..base
        };
        assert_ne!(p.context_cell(&base), p.context_cell(&later));
    }

    #[test]
    fn bandit_observe_before_any_plan_is_harmless() {
        let m = model();
        let mut p = BanditPolicy::from_model(&m, PowerSaving::M12, 0.2, None);
        // the fleet replay path observes the previous gap before planning
        p.observe(Duration::from_millis(40.0));
        assert_eq!(p.cell_count(p.context_cell(&ctx())), 0);
        let _ = p.plan_gap(&ctx());
    }
}
