//! The gap-policy subsystem: what the platform does in the gap between
//! finishing a workload item and the next inference request.
//!
//! A [`Policy`] decides **at item-completion time, without seeing the
//! upcoming gap** — the deployable formulation of the paper's §7 future
//! work ("irregularly occurring inference requests"). It emits a
//! [`GapPlan`]:
//!
//! * **`Idle(saving)`** — stay configured at a Table 3 power-saving level
//!   (the paper's Idle-Waiting, Fig 6).
//! * **`PowerOff`** — cut the rails immediately; pay power-on transient +
//!   full reconfiguration at the next request (On-Off, Fig 5).
//! * **`IdleThenOff { saving, timeout }`** — the ski-rental shape: idle up
//!   to `timeout`, then cut power if no request arrived.
//!
//! After the gap resolves, the runtime calls [`Policy::observe`] with the
//! realized gap so policies can learn online. The clairvoyant per-gap
//! chooser that used to be called `Adaptive` survives as [`Oracle`] — it
//! is the offline upper bound, reachable only through the
//! [`OraclePolicy`] escape hatch ([`decide`]), never through the blind
//! [`Policy::plan_gap`] path.
//!
//! Built-in policies:
//!
//! | policy | information used | behaviour |
//! |---|---|---|
//! | [`OnOff`] | none | always `PowerOff` |
//! | [`IdleWaiting`] | none | always `Idle(saving)` |
//! | [`Oracle`] | the true upcoming gap | off iff gap > crossover |
//! | [`Timeout`] | none (τ from the model) | always `IdleThenOff` at the break-even τ — classically 2-competitive vs the oracle |
//! | [`EmaPredictor`] | observed gap history | idle iff EMA-predicted gap < crossover |
//! | [`WindowedQuantile`] | last W observed gaps | idle iff the q-quantile of the window < crossover — robust on heavy tails |
//! | [`RandomizedSkiRental`] | none (τ + its own RNG) | `IdleThenOff` at a timeout drawn per gap from the e/(e−1)-competitive density over [0, τ] |
//! | [`BayesMixture`] | observed gap history | posterior-expected-cost argmin over Idle/Off/IdleThenOff under an online mixture-of-exponentials gap model |
//! | [`BanditPolicy`] | observed gaps + [`GapContext`] features | per-cell greedy action over 64 discretized contexts, counterfactually priced; cold cells fall back to a trained table or the hedge |
//!
//! Every policy's tunables (`saving`, `timeout_ms`, `ema_alpha`,
//! `window`, `quantile`, `seed`, `components`, `table`) come from the config-level
//! [`PolicyParams`] table via [`build_with`]; [`build`] uses the
//! defaults, which reproduce the paper's setup.
//!
//! [`BayesMixture`]: crate::strategies::learned::BayesMixture
//! [`BanditPolicy`]: crate::strategies::learned::BanditPolicy

use crate::config::schema::{PolicyParams, PolicySpec};
use crate::device::rails::PowerSaving;
use crate::energy::analytical::Analytical;
use crate::energy::crossover;
use crate::strategies::replay::GapBatch;
use crate::util::rng::Xoshiro256ss;
use crate::util::units::Duration;

/// What to do during an inter-request gap, decided before the gap is
/// known. Executed by `ReplayCore::execute_plan` so every runtime shares
/// one energy-accounting path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GapPlan {
    /// Hold configuration at the given power-saving level.
    Idle(PowerSaving),
    /// Cut FPGA rails immediately; configuration is lost.
    PowerOff,
    /// Idle at `saving` for up to `timeout`, then cut power (ski-rental).
    IdleThenOff {
        saving: PowerSaving,
        timeout: Duration,
    },
}

/// What a policy may look at when planning a gap — everything known at
/// item-completion time, and nothing about the future.
#[derive(Debug, Clone, Copy)]
pub struct GapContext {
    /// Workload items completed so far in this run.
    pub items_done: u64,
    /// Simulated time at item completion.
    pub now: Duration,
    /// Requests already waiting behind the item just served (the serving
    /// coordinator's queue depth; 0 in single-stream contexts). A queued
    /// burst is known work, not a forecast — policies may plan "stay
    /// configured" on it without clairvoyance.
    pub queued: u64,
}

/// Escape hatch for clairvoyant policies: sees the true upcoming gap.
/// Only the offline analyses (lifetime DES, serving loop) route through
/// it via [`decide`]; online contexts fall back to [`Policy::plan_gap`].
pub trait OraclePolicy {
    /// The plan for a gap whose true length is known.
    fn plan_for(&self, gap: Duration) -> GapPlan;
}

/// A stateful gap policy. Object-safe so the simulator and the serving
/// coordinator can hold `Box<dyn Policy>`.
pub trait Policy: Send {
    /// Which config-level spec this policy implements.
    fn kind(&self) -> PolicySpec;

    /// Plan the upcoming gap from observed state only — the gap length is
    /// deliberately absent.
    fn plan_gap(&mut self, ctx: &GapContext) -> GapPlan;

    /// Feed back the realized gap once it has resolved (online learning).
    fn observe(&mut self, _actual_gap: Duration) {}

    /// Plan a whole batch of gaps into `out` (appending), interleaving
    /// plan/observe per gap exactly as the scalar loop would, so stateful
    /// policies see the identical observation order. Stateless policies
    /// override this with a single flat fill ([`GapBatch::push_uniform`]);
    /// the default is the faithful scalar loop.
    fn plan_gaps(&mut self, ctxs: &[GapContext], gaps: &[Duration], out: &mut GapBatch) {
        debug_assert_eq!(ctxs.len(), gaps.len());
        for (ctx, &gap) in ctxs.iter().zip(gaps) {
            let plan = self.plan_gap(ctx);
            out.push(gap, plan);
            self.observe(gap);
        }
    }

    /// Human-readable label for reports.
    fn label(&self) -> String {
        self.kind().name().to_string()
    }

    /// Clairvoyant view, if this policy is an offline upper bound.
    fn as_oracle(&self) -> Option<&dyn OraclePolicy> {
        None
    }
}

/// Resolve a policy's plan for a gap the runtime already knows: oracle
/// policies get the true gap (offline upper bound), online policies plan
/// blind from `ctx` alone.
pub fn decide(policy: &mut dyn Policy, ctx: &GapContext, actual_gap: Duration) -> GapPlan {
    if let Some(oracle) = policy.as_oracle() {
        return oracle.plan_for(actual_gap);
    }
    policy.plan_gap(ctx)
}

/// Batched [`decide`]: resolve plans for a slice of gaps the runtime
/// already knows, clearing and refilling `out`. Oracle policies get the
/// true gap per element (offline upper bound); online policies route
/// through [`Policy::plan_gaps`], which stateless policies implement as a
/// single structure-of-arrays fill.
pub fn decide_batch(
    policy: &mut dyn Policy,
    ctxs: &[GapContext],
    gaps: &[Duration],
    out: &mut GapBatch,
) {
    debug_assert_eq!(ctxs.len(), gaps.len());
    out.clear();
    if policy.as_oracle().is_some() {
        for &gap in gaps {
            let plan = policy
                .as_oracle()
                .expect("oracle checked above")
                .plan_for(gap);
            out.push(gap, plan);
            policy.observe(gap);
        }
        return;
    }
    policy.plan_gaps(ctxs, gaps, out);
}

/// The paper's On-Off strategy (Fig 5).
#[derive(Debug, Clone, Copy, Default)]
pub struct OnOff;

impl Policy for OnOff {
    fn kind(&self) -> PolicySpec {
        PolicySpec::OnOff
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        GapPlan::PowerOff
    }

    /// Stateless: one flat fill, no per-gap virtual dispatch.
    fn plan_gaps(&mut self, ctxs: &[GapContext], gaps: &[Duration], out: &mut GapBatch) {
        debug_assert_eq!(ctxs.len(), gaps.len());
        out.push_uniform(gaps, GapPlan::PowerOff);
    }
}

/// The paper's Idle-Waiting strategy (Fig 6) at a power-saving level.
#[derive(Debug, Clone, Copy)]
pub struct IdleWaiting {
    /// The power-saving level this strategy idles at.
    pub saving: PowerSaving,
}

impl IdleWaiting {
    /// Idle-Waiting at the baseline (no power-saving) level.
    pub fn baseline() -> IdleWaiting {
        IdleWaiting {
            saving: PowerSaving::BASELINE,
        }
    }

    /// Idle-Waiting + Method 1.
    pub fn method1() -> IdleWaiting {
        IdleWaiting {
            saving: PowerSaving::M1,
        }
    }

    /// Idle-Waiting + Methods 1+2.
    pub fn method12() -> IdleWaiting {
        IdleWaiting {
            saving: PowerSaving::M12,
        }
    }
}

impl Policy for IdleWaiting {
    fn kind(&self) -> PolicySpec {
        match (self.saving.method1, self.saving.method2) {
            (false, _) => PolicySpec::IdleWaiting,
            (true, false) => PolicySpec::IdleWaitingM1,
            (true, true) => PolicySpec::IdleWaitingM12,
        }
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        GapPlan::Idle(self.saving)
    }

    /// Stateless: one flat fill, no per-gap virtual dispatch.
    fn plan_gaps(&mut self, ctxs: &[GapContext], gaps: &[Duration], out: &mut GapBatch) {
        debug_assert_eq!(ctxs.len(), gaps.len());
        out.push_uniform(gaps, GapPlan::Idle(self.saving));
    }
}

/// Clairvoyant per-gap policy (formerly `Adaptive`): powers off for gaps
/// beyond the analytical crossover of its idle mode, idles otherwise.
/// The offline upper bound every online policy is measured against.
#[derive(Debug, Clone, Copy)]
pub struct Oracle {
    /// Idle mode used when idling wins.
    pub saving: PowerSaving,
    /// Break-even gap duration (precomputed from the analytical model).
    pub crossover: Duration,
}

impl Oracle {
    /// Build from the analytical model: the crossover is where the energy
    /// of idling for the gap equals the energy of a power cycle +
    /// reconfiguration.
    pub fn from_model(model: &Analytical, saving: PowerSaving) -> Oracle {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        Oracle {
            saving,
            crossover: crossover::asymptotic(model, p_idle),
        }
    }
}

impl OraclePolicy for Oracle {
    fn plan_for(&self, gap: Duration) -> GapPlan {
        if gap > self.crossover {
            GapPlan::PowerOff
        } else {
            GapPlan::Idle(self.saving)
        }
    }
}

impl Policy for Oracle {
    fn kind(&self) -> PolicySpec {
        PolicySpec::Oracle
    }

    /// Blind fallback for online contexts that cannot grant clairvoyance
    /// (e.g. the multi-accelerator DES): hold configuration.
    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        GapPlan::Idle(self.saving)
    }

    fn label(&self) -> String {
        format!(
            "oracle({}, crossover {:.2} ms)",
            self.saving.label(),
            self.crossover.millis()
        )
    }

    fn as_oracle(&self) -> Option<&dyn OraclePolicy> {
        Some(self)
    }
}

/// Ski-rental policy: idle up to the break-even timeout τ (idle energy
/// for τ equals one power cycle + reconfiguration), then power off. On
/// any gap sequence its gap energy is at most 2× the oracle's.
#[derive(Debug, Clone, Copy)]
pub struct Timeout {
    /// Idle mode used while renting.
    pub saving: PowerSaving,
    /// Idle window after which power is cut (the ski-rental "buy" point).
    pub timeout: Duration,
}

impl Timeout {
    /// τ from the analytical model: the idle duration whose energy equals
    /// the reconfiguration cost (= crossover minus the item latency).
    pub fn from_model(model: &Analytical, saving: PowerSaving) -> Timeout {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        Timeout {
            saving,
            timeout: crossover::ski_rental_timeout(model, p_idle),
        }
    }
}

impl Policy for Timeout {
    fn kind(&self) -> PolicySpec {
        PolicySpec::Timeout
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        GapPlan::IdleThenOff {
            saving: self.saving,
            timeout: self.timeout,
        }
    }

    /// Stateless (τ is fixed at build time): one flat fill.
    fn plan_gaps(&mut self, ctxs: &[GapContext], gaps: &[Duration], out: &mut GapBatch) {
        debug_assert_eq!(ctxs.len(), gaps.len());
        out.push_uniform(
            gaps,
            GapPlan::IdleThenOff {
                saving: self.saving,
                timeout: self.timeout,
            },
        );
    }

    fn label(&self) -> String {
        format!(
            "timeout({}, tau {:.2} ms)",
            self.saving.label(),
            self.timeout.millis()
        )
    }
}

/// Online predictor: an exponential moving average of observed gaps.
/// Idles iff the predicted gap is below the crossover, powers off
/// otherwise; before the first observation it hedges with the ski-rental
/// plan. On strictly periodic arrivals the prediction becomes exact after
/// one gap, so the policy degenerates to the winning static strategy.
#[derive(Debug, Clone, Copy)]
pub struct EmaPredictor {
    /// Idle mode used when the prediction says idle.
    pub saving: PowerSaving,
    /// Break-even gap duration of the idle mode.
    pub crossover: Duration,
    /// Ski-rental timeout used while no observation exists yet.
    pub timeout: Duration,
    /// EMA smoothing factor in (0, 1]: weight of the newest observation.
    pub alpha: f64,
    /// Predicted next gap in seconds (None until the first observation).
    predicted_secs: Option<f64>,
}

impl EmaPredictor {
    /// Default smoothing factor (mirrors `PolicyParams`).
    pub const DEFAULT_ALPHA: f64 = PolicyParams::DEFAULT_EMA_ALPHA;

    /// Build from the analytical model: crossover + tau for `saving`.
    pub fn from_model(model: &Analytical, saving: PowerSaving, alpha: f64) -> EmaPredictor {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        EmaPredictor {
            saving,
            crossover: crossover::asymptotic(model, p_idle),
            timeout: crossover::ski_rental_timeout(model, p_idle),
            alpha: alpha.clamp(f64::EPSILON, 1.0),
            predicted_secs: None,
        }
    }

    /// Current gap prediction, if any observation has arrived.
    pub fn predicted(&self) -> Option<Duration> {
        self.predicted_secs.map(Duration::from_secs)
    }
}

impl Policy for EmaPredictor {
    fn kind(&self) -> PolicySpec {
        PolicySpec::EmaPredictor
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        match self.predicted_secs {
            // cold start: no history → hedge with the 2-competitive plan
            None => GapPlan::IdleThenOff {
                saving: self.saving,
                timeout: self.timeout,
            },
            Some(p) if p < self.crossover.secs() => GapPlan::Idle(self.saving),
            Some(_) => GapPlan::PowerOff,
        }
    }

    fn observe(&mut self, actual_gap: Duration) {
        let g = actual_gap.secs();
        self.predicted_secs = Some(match self.predicted_secs {
            None => g,
            Some(p) => self.alpha * g + (1.0 - self.alpha) * p,
        });
    }

    fn label(&self) -> String {
        format!(
            "ema({}, alpha {:.2}, crossover {:.2} ms)",
            self.saving.label(),
            self.alpha,
            self.crossover.millis()
        )
    }
}

/// Online predictor over a sliding window: keeps the last `window`
/// observed gaps in a ring buffer and plans against their `quantile`-th
/// quantile. Where the EMA's single mean washes out under heavy-tailed
/// gap distributions (a few huge silences dragging the mean above the
/// crossover although most gaps are short — or vice versa), the quantile
/// asks the right question directly: "what fraction of recent gaps was
/// long enough that powering off would have won?" On strictly periodic
/// arrivals every windowed quantile equals the period exactly, so the
/// policy degenerates to the crossover decision after one observation.
#[derive(Debug, Clone)]
pub struct WindowedQuantile {
    /// Idle mode used when the quantile says idle.
    pub saving: PowerSaving,
    /// Break-even gap duration of the idle mode.
    pub crossover: Duration,
    /// Ski-rental timeout used while no observation exists yet.
    pub timeout: Duration,
    /// Planning quantile in (0, 1).
    pub quantile: f64,
    /// Ring-buffer capacity W ≥ 1.
    window: usize,
    /// Observed gaps in seconds, insertion order (up to `window` of them).
    buf: Vec<f64>,
    /// The same gaps kept sorted (binary-search insert/evict per
    /// observation), so `plan_gap` reads the quantile without re-sorting
    /// the window on the DES hot path.
    sorted: Vec<f64>,
    /// Next ring slot to overwrite once the buffer is full.
    next: usize,
}

impl WindowedQuantile {
    /// Build from the analytical model: crossover + tau for `saving`,
    /// with the given window length and planning quantile.
    pub fn from_model(
        model: &Analytical,
        saving: PowerSaving,
        window: usize,
        quantile: f64,
    ) -> WindowedQuantile {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        WindowedQuantile {
            saving,
            crossover: crossover::asymptotic(model, p_idle),
            timeout: crossover::ski_rental_timeout(model, p_idle),
            quantile: quantile.clamp(f64::EPSILON, 1.0 - f64::EPSILON),
            window: window.max(1),
            buf: Vec::new(),
            sorted: Vec::new(),
            next: 0,
        }
    }

    /// The ring-buffer capacity W.
    pub fn window(&self) -> usize {
        self.window
    }

    /// The current q-quantile of the windowed gaps (linear interpolation
    /// between order statistics); `None` until the first observation.
    pub fn predicted(&self) -> Option<Duration> {
        if self.sorted.is_empty() {
            return None;
        }
        let h = self.quantile * (self.sorted.len() - 1) as f64;
        let lo = h.floor() as usize;
        let hi = h.ceil() as usize;
        let frac = h - lo as f64;
        Some(Duration::from_secs(
            self.sorted[lo] + (self.sorted[hi] - self.sorted[lo]) * frac,
        ))
    }
}

impl Policy for WindowedQuantile {
    fn kind(&self) -> PolicySpec {
        PolicySpec::WindowedQuantile
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        match self.predicted() {
            // cold start: no history → hedge with the 2-competitive plan
            None => GapPlan::IdleThenOff {
                saving: self.saving,
                timeout: self.timeout,
            },
            Some(p) if p < self.crossover => GapPlan::Idle(self.saving),
            Some(_) => GapPlan::PowerOff,
        }
    }

    /// Table-driven: same plan/observe interleaving as the default, but
    /// with `plan_gap`/`observe` statically dispatched so the ring-buffer
    /// maintenance inlines into one tight loop over the batch.
    fn plan_gaps(&mut self, ctxs: &[GapContext], gaps: &[Duration], out: &mut GapBatch) {
        debug_assert_eq!(ctxs.len(), gaps.len());
        for (ctx, &gap) in ctxs.iter().zip(gaps) {
            let plan = self.plan_gap(ctx);
            out.push(gap, plan);
            self.observe(gap);
        }
    }

    fn observe(&mut self, actual_gap: Duration) {
        let g = actual_gap.secs();
        if self.buf.len() < self.window {
            self.buf.push(g);
        } else {
            // evict the oldest gap from the sorted view (an exact copy of
            // it is present, so partition_point lands on an equal element)
            let evicted = std::mem::replace(&mut self.buf[self.next], g);
            self.next = (self.next + 1) % self.window;
            let at = self.sorted.partition_point(|x| *x < evicted);
            debug_assert!(self.sorted[at] == evicted);
            self.sorted.remove(at);
        }
        let at = self.sorted.partition_point(|x| *x < g);
        self.sorted.insert(at, g);
    }

    fn label(&self) -> String {
        format!(
            "windowed-quantile({}, w {}, q {:.2}, crossover {:.2} ms)",
            self.saving.label(),
            self.window,
            self.quantile,
            self.crossover.millis()
        )
    }
}

/// Randomized ski-rental: like [`Timeout`], but the idle window is drawn
/// fresh for every gap from the classic exponential density
/// `p(t) = e^(t/τ) / (τ·(e−1))` on `[0, τ]`, which is
/// e/(e−1) ≈ 1.582-competitive in expectation against an oblivious
/// adversary — strictly better than the deterministic rule's 2. The draw
/// comes from the policy's own seeded [`Xoshiro256ss`] stream (in sweeps,
/// seeded per cell), so runs are byte-identical at any thread count.
#[derive(Debug, Clone)]
pub struct RandomizedSkiRental {
    /// Idle mode used while renting.
    pub saving: PowerSaving,
    /// The break-even scale τ (the deterministic rule's timeout).
    pub tau: Duration,
    rng: Xoshiro256ss,
}

impl RandomizedSkiRental {
    /// τ defaults to the analytical break-even; `timeout` overrides it.
    pub fn from_model(
        model: &Analytical,
        saving: PowerSaving,
        timeout: Option<Duration>,
        seed: u64,
    ) -> RandomizedSkiRental {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        RandomizedSkiRental {
            saving,
            tau: timeout.unwrap_or_else(|| crossover::ski_rental_timeout(model, p_idle)),
            rng: Xoshiro256ss::new(seed),
        }
    }

    /// Inverse-CDF sample of the e/(e−1)-competitive density:
    /// `F(t) = (e^(t/τ) − 1)/(e − 1)` ⟹ `t = τ·ln(1 + (e−1)·u)`,
    /// mapping u ∈ [0, 1) onto [0, τ).
    pub fn draw_timeout(&mut self) -> Duration {
        let u = self.rng.next_f64();
        let t = self.tau.secs() * (1.0 + (std::f64::consts::E - 1.0) * u).ln();
        Duration::from_secs(t)
    }
}

impl Policy for RandomizedSkiRental {
    fn kind(&self) -> PolicySpec {
        PolicySpec::RandomizedSkiRental
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        GapPlan::IdleThenOff {
            saving: self.saving,
            timeout: self.draw_timeout(),
        }
    }

    fn label(&self) -> String {
        format!(
            "randomized-ski-rental({}, tau {:.2} ms)",
            self.saving.label(),
            self.tau.millis()
        )
    }
}

/// Wrapper that holds configuration whenever requests are already queued
/// behind the item just served, delegating to the inner policy only for
/// genuinely empty gaps. The serving coordinator wraps its gap policy in
/// this: a queued burst is certain future work ([`GapContext::queued`]),
/// so powering off before it would pay a reconfiguration for nothing —
/// no clairvoyance involved, unlike [`Oracle`].
pub struct BurstHold {
    inner: Box<dyn Policy>,
    saving: PowerSaving,
}

impl BurstHold {
    /// Wrap `inner`, idling at `saving` while the queue is non-empty.
    pub fn new(inner: Box<dyn Policy>, saving: PowerSaving) -> BurstHold {
        BurstHold { inner, saving }
    }
}

impl Policy for BurstHold {
    fn kind(&self) -> PolicySpec {
        self.inner.kind()
    }

    fn plan_gap(&mut self, ctx: &GapContext) -> GapPlan {
        if ctx.queued > 0 {
            GapPlan::Idle(self.saving)
        } else {
            self.inner.plan_gap(ctx)
        }
    }

    fn observe(&mut self, actual_gap: Duration) {
        self.inner.observe(actual_gap);
    }

    fn label(&self) -> String {
        format!("burst-hold({})", self.inner.label())
    }
}

/// Construct the policy for a config-level [`PolicySpec`] with explicit
/// tunables. The named Idle-Waiting variants keep their fixed levels;
/// every advanced policy takes its idle mode (and any tunable it reads)
/// from `params`.
pub fn build_with(
    spec: PolicySpec,
    model: &Analytical,
    params: &PolicyParams,
) -> Box<dyn Policy> {
    let saving = params.saving;
    match spec {
        PolicySpec::OnOff => Box::new(OnOff),
        PolicySpec::IdleWaiting => Box::new(IdleWaiting::baseline()),
        PolicySpec::IdleWaitingM1 => Box::new(IdleWaiting::method1()),
        PolicySpec::IdleWaitingM12 => Box::new(IdleWaiting::method12()),
        PolicySpec::Oracle => Box::new(Oracle::from_model(model, saving)),
        PolicySpec::Timeout => {
            let mut t = Timeout::from_model(model, saving);
            if let Some(timeout) = params.timeout {
                t.timeout = timeout;
            }
            Box::new(t)
        }
        PolicySpec::EmaPredictor => {
            let mut e = EmaPredictor::from_model(model, saving, params.ema_alpha);
            if let Some(timeout) = params.timeout {
                e.timeout = timeout; // cold-start hedge
            }
            Box::new(e)
        }
        PolicySpec::WindowedQuantile => {
            let mut w = WindowedQuantile::from_model(model, saving, params.window, params.quantile);
            if let Some(timeout) = params.timeout {
                w.timeout = timeout; // cold-start hedge
            }
            Box::new(w)
        }
        PolicySpec::RandomizedSkiRental => Box::new(RandomizedSkiRental::from_model(
            model,
            saving,
            params.timeout,
            params.seed,
        )),
        PolicySpec::BayesMixture => {
            Box::new(crate::strategies::learned::bayes_from_params(model, params))
        }
        PolicySpec::BanditPolicy => {
            Box::new(crate::strategies::learned::bandit_from_params(model, params))
        }
    }
}

/// Construct the policy for a config-level [`PolicySpec`] with the
/// default tunables: the advanced policies idle at M1+2 (the paper's
/// best), matching the pre-rename `Adaptive` default.
pub fn build(spec: PolicySpec, model: &Analytical) -> Box<dyn Policy> {
    build_with(spec, model, &PolicyParams::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn model() -> Analytical {
        let cfg = paper_default();
        Analytical::new(&cfg.item, cfg.workload.energy_budget)
    }

    fn ctx() -> GapContext {
        GapContext {
            items_done: 0,
            now: Duration::ZERO,
            queued: 0,
        }
    }

    #[test]
    fn onoff_always_powers_off() {
        assert_eq!(OnOff.plan_gap(&ctx()), GapPlan::PowerOff);
        assert_eq!(OnOff.kind(), PolicySpec::OnOff);
    }

    #[test]
    fn idle_waiting_always_idles_at_its_level() {
        let mut p = IdleWaiting::method12();
        assert_eq!(p.plan_gap(&ctx()), GapPlan::Idle(PowerSaving::M12));
        assert_eq!(p.kind(), PolicySpec::IdleWaitingM12);
        assert_eq!(IdleWaiting::baseline().kind(), PolicySpec::IdleWaiting);
        assert_eq!(IdleWaiting::method1().kind(), PolicySpec::IdleWaitingM1);
    }

    #[test]
    fn oracle_switches_at_crossover() {
        let m = model();
        let o = Oracle::from_model(&m, PowerSaving::BASELINE);
        assert!((o.crossover.millis() - 89.21).abs() < 0.05);
        assert_eq!(
            o.plan_for(Duration::from_millis(50.0)),
            GapPlan::Idle(PowerSaving::BASELINE)
        );
        assert_eq!(o.plan_for(Duration::from_millis(200.0)), GapPlan::PowerOff);
    }

    #[test]
    fn oracle_m12_crossover_is_499ms() {
        let m = model();
        let o = Oracle::from_model(&m, PowerSaving::M12);
        assert!((o.crossover.millis() - 499.06).abs() < 0.15, "{}", o.crossover.millis());
    }

    #[test]
    fn decide_grants_the_oracle_clairvoyance_only() {
        let m = model();
        let mut oracle = Oracle::from_model(&m, PowerSaving::BASELINE);
        // blind path: the oracle cannot see the gap and holds configuration
        assert_eq!(
            oracle.plan_gap(&ctx()),
            GapPlan::Idle(PowerSaving::BASELINE)
        );
        // decide() routes through the escape hatch with the true gap
        assert_eq!(
            decide(&mut oracle, &ctx(), Duration::from_millis(200.0)),
            GapPlan::PowerOff
        );
        // an online policy never sees the gap, however long
        let mut onoff = OnOff;
        assert_eq!(
            decide(&mut onoff, &ctx(), Duration::from_secs(100.0)),
            GapPlan::PowerOff
        );
        let mut iw = IdleWaiting::baseline();
        assert_eq!(
            decide(&mut iw, &ctx(), Duration::from_secs(100.0)),
            GapPlan::Idle(PowerSaving::BASELINE)
        );
    }

    #[test]
    fn timeout_tau_is_crossover_minus_latency() {
        let m = model();
        let t = Timeout::from_model(&m, PowerSaving::BASELINE);
        let o = Oracle::from_model(&m, PowerSaving::BASELINE);
        let latency = m.item.latency_without_config;
        assert!(
            (t.timeout.millis() - (o.crossover - latency).millis()).abs() < 1e-9,
            "tau {} vs crossover {} - latency {}",
            t.timeout.millis(),
            o.crossover.millis(),
            latency.millis()
        );
        let mut planning = t;
        assert_eq!(
            planning.plan_gap(&ctx()),
            GapPlan::IdleThenOff {
                saving: PowerSaving::BASELINE,
                timeout: t.timeout
            }
        );
    }

    #[test]
    fn ema_learns_and_switches() {
        let m = model();
        let mut e = EmaPredictor::from_model(&m, PowerSaving::BASELINE, 1.0);
        // cold start hedges with the ski-rental plan
        assert!(matches!(e.plan_gap(&ctx()), GapPlan::IdleThenOff { .. }));
        // short observed gaps → idle
        e.observe(Duration::from_millis(40.0));
        assert_eq!(e.predicted().unwrap().millis(), 40.0);
        assert_eq!(e.plan_gap(&ctx()), GapPlan::Idle(PowerSaving::BASELINE));
        // long observed gaps → power off (alpha=1 tracks instantly)
        e.observe(Duration::from_millis(500.0));
        assert_eq!(e.plan_gap(&ctx()), GapPlan::PowerOff);
    }

    #[test]
    fn ema_smoothing_blends_history() {
        let m = model();
        let mut e = EmaPredictor::from_model(&m, PowerSaving::BASELINE, 0.5);
        e.observe(Duration::from_millis(100.0));
        e.observe(Duration::from_millis(200.0));
        assert!((e.predicted().unwrap().millis() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn build_covers_all_kinds() {
        let m = model();
        for spec in PolicySpec::ALL {
            let p = build(spec, &m);
            assert_eq!(p.kind(), spec);
            assert!(!p.label().is_empty());
        }
    }

    #[test]
    fn windowed_quantile_learns_and_switches() {
        let m = model();
        let mut w = WindowedQuantile::from_model(&m, PowerSaving::BASELINE, 4, 0.5);
        // cold start hedges with the ski-rental plan
        assert!(matches!(w.plan_gap(&ctx()), GapPlan::IdleThenOff { .. }));
        // short gaps dominate the window → idle
        for _ in 0..4 {
            w.observe(Duration::from_millis(40.0));
        }
        assert_eq!(w.predicted().unwrap().millis(), 40.0);
        assert_eq!(w.plan_gap(&ctx()), GapPlan::Idle(PowerSaving::BASELINE));
        // the ring evicts the old gaps; long gaps take over → power off
        for _ in 0..4 {
            w.observe(Duration::from_millis(500.0));
        }
        assert_eq!(w.predicted().unwrap().millis(), 500.0);
        assert_eq!(w.plan_gap(&ctx()), GapPlan::PowerOff);
    }

    #[test]
    fn windowed_quantile_interpolates_between_order_statistics() {
        let m = model();
        let mut w = WindowedQuantile::from_model(&m, PowerSaving::BASELINE, 8, 0.5);
        w.observe(Duration::from_millis(10.0));
        w.observe(Duration::from_millis(30.0));
        // median of {10, 30} interpolates to 20
        assert!((w.predicted().unwrap().millis() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn windowed_quantile_is_robust_where_the_mean_is_not() {
        // Heavy tail: 7 short gaps + 1 huge one per window. The mean (and
        // the EMA it feeds) is dragged far above the crossover; the median
        // still sees the typical 40 ms gap and keeps idling.
        let m = model();
        let mut wq = WindowedQuantile::from_model(&m, PowerSaving::BASELINE, 8, 0.5);
        let mut ema = EmaPredictor::from_model(&m, PowerSaving::BASELINE, 0.2);
        for i in 0..32 {
            let gap = if i % 8 == 7 {
                Duration::from_secs(10.0)
            } else {
                Duration::from_millis(40.0)
            };
            wq.observe(gap);
            ema.observe(gap);
        }
        assert_eq!(wq.plan_gap(&ctx()), GapPlan::Idle(PowerSaving::BASELINE));
        assert_eq!(ema.plan_gap(&ctx()), GapPlan::PowerOff);
    }

    #[test]
    fn windowed_quantile_high_q_reacts_to_the_tail() {
        // The q=0.95 planner asks whether the tail gaps are long — on the
        // same heavy-tailed stream it chooses to power off.
        let m = model();
        let mut wq = WindowedQuantile::from_model(&m, PowerSaving::BASELINE, 8, 0.95);
        for i in 0..16 {
            let gap = if i % 8 == 7 {
                Duration::from_secs(10.0)
            } else {
                Duration::from_millis(40.0)
            };
            wq.observe(gap);
        }
        assert_eq!(wq.plan_gap(&ctx()), GapPlan::PowerOff);
    }

    #[test]
    fn randomized_ski_rental_draws_within_tau_and_is_seed_deterministic() {
        let m = model();
        let mut a = RandomizedSkiRental::from_model(&m, PowerSaving::BASELINE, None, 7);
        let mut b = RandomizedSkiRental::from_model(&m, PowerSaving::BASELINE, None, 7);
        let tau = a.tau;
        let mut sum = 0.0;
        for _ in 0..2_000 {
            let ta = a.draw_timeout();
            assert_eq!(ta, b.draw_timeout(), "same seed, same stream");
            assert!(ta >= Duration::ZERO && ta < tau, "{ta:?} vs tau {tau:?}");
            sum += ta.secs();
        }
        // E[T] = τ/(e−1) ≈ 0.582τ for the e/(e−1)-competitive density
        let mean = sum / 2_000.0;
        let expect = tau.secs() / (std::f64::consts::E - 1.0);
        assert!((mean - expect).abs() < 0.02 * tau.secs(), "mean {mean} vs {expect}");
        // and every plan is the ski-rental shape
        assert!(matches!(a.plan_gap(&ctx()), GapPlan::IdleThenOff { .. }));
    }

    #[test]
    fn randomized_ski_rental_honours_timeout_override() {
        let m = model();
        let tau = Duration::from_millis(25.0);
        let mut p = RandomizedSkiRental::from_model(&m, PowerSaving::M12, Some(tau), 1);
        assert_eq!(p.tau, tau);
        for _ in 0..100 {
            assert!(p.draw_timeout() < tau);
        }
    }

    fn batch_ctxs(n: usize) -> Vec<GapContext> {
        (0..n)
            .map(|i| GapContext {
                items_done: i as u64 + 1,
                now: Duration::ZERO,
                queued: 0,
            })
            .collect()
    }

    /// The batched planner must emit exactly the plans of the scalar
    /// plan/observe loop, for every policy kind, including the stateful
    /// learners (identical observation order) and the seeded randomized
    /// policy (identical RNG draw order).
    #[test]
    fn plan_gaps_matches_the_scalar_sequence_for_every_policy() {
        let m = model();
        let gaps: Vec<Duration> = (0..48)
            .map(|i| {
                if i % 7 == 3 {
                    Duration::from_secs(2.0)
                } else {
                    Duration::from_millis(35.0 + i as f64)
                }
            })
            .collect();
        let ctxs = batch_ctxs(gaps.len());
        for spec in PolicySpec::ALL {
            let mut batched = build(spec, &m);
            let mut batch = GapBatch::default();
            decide_batch(batched.as_mut(), &ctxs, &gaps, &mut batch);
            assert_eq!(batch.len(), gaps.len(), "{spec}");
            let mut scalar = build(spec, &m);
            for (i, (&gap, ctx)) in gaps.iter().zip(&ctxs).enumerate() {
                let want = decide(scalar.as_mut(), ctx, gap);
                assert_eq!(batch.plan(i), want, "{spec} gap {i}");
                scalar.observe(gap);
            }
            // and the learned state agrees afterwards: the next scalar
            // plan is the same from both policies
            let next = GapContext {
                items_done: gaps.len() as u64 + 1,
                now: Duration::ZERO,
                queued: 0,
            };
            if spec != PolicySpec::RandomizedSkiRental {
                assert_eq!(
                    batched.plan_gap(&next),
                    scalar.plan_gap(&next),
                    "{spec} post-batch state"
                );
            }
        }
    }

    /// `decide_batch` grants the oracle clairvoyance per element, just as
    /// scalar `decide` does — the blind `plan_gaps` path must not be used.
    #[test]
    fn decide_batch_grants_the_oracle_clairvoyance() {
        let m = model();
        let mut oracle = Oracle::from_model(&m, PowerSaving::BASELINE);
        let gaps = [Duration::from_millis(50.0), Duration::from_millis(200.0)];
        let ctxs = batch_ctxs(gaps.len());
        let mut batch = GapBatch::default();
        decide_batch(&mut oracle, &ctxs, &gaps, &mut batch);
        assert_eq!(batch.plan(0), GapPlan::Idle(PowerSaving::BASELINE));
        assert_eq!(batch.plan(1), GapPlan::PowerOff);
    }

    /// The flat-fill overrides must agree with the default loop impl.
    #[test]
    fn push_uniform_overrides_match_the_default_loop() {
        let m = model();
        let gaps: Vec<Duration> = (0..9).map(|i| Duration::from_millis(10.0 * (i + 1) as f64)).collect();
        let ctxs = batch_ctxs(gaps.len());
        for spec in [
            PolicySpec::OnOff,
            PolicySpec::IdleWaiting,
            PolicySpec::IdleWaitingM1,
            PolicySpec::IdleWaitingM12,
            PolicySpec::Timeout,
        ] {
            let mut policy = build(spec, &m);
            let mut fast = GapBatch::default();
            policy.plan_gaps(&ctxs, &gaps, &mut fast);
            let mut policy = build(spec, &m);
            let mut slow = GapBatch::default();
            for (ctx, &gap) in ctxs.iter().zip(&gaps) {
                let plan = policy.plan_gap(ctx);
                slow.push(gap, plan);
                policy.observe(gap);
            }
            assert_eq!(fast.gaps(), slow.gaps(), "{spec}");
            assert_eq!(fast.kinds(), slow.kinds(), "{spec}");
            assert_eq!(fast.savings(), slow.savings(), "{spec}");
            assert_eq!(fast.timeouts(), slow.timeouts(), "{spec}");
        }
    }

    #[test]
    fn burst_hold_idles_while_the_queue_is_nonempty() {
        let mut p = BurstHold::new(Box::new(OnOff), PowerSaving::M12);
        let queued = GapContext { queued: 3, ..ctx() };
        // a queued burst holds configuration even over a power-off policy
        assert_eq!(p.plan_gap(&queued), GapPlan::Idle(PowerSaving::M12));
        // an empty queue delegates to the inner policy
        assert_eq!(p.plan_gap(&ctx()), GapPlan::PowerOff);
        assert_eq!(p.kind(), PolicySpec::OnOff);
        assert_eq!(p.label(), "burst-hold(on-off)");
    }

    #[test]
    fn build_with_applies_tunables() {
        let m = model();
        let params = PolicyParams {
            saving: PowerSaving::BASELINE,
            timeout: Some(Duration::from_millis(12.5)),
            ema_alpha: 0.7,
            window: 5,
            quantile: 0.25,
            seed: 3,
            ..PolicyParams::default()
        };
        let t = build_with(PolicySpec::Timeout, &m, &params);
        assert_eq!(
            t.label(),
            format!("timeout({}, tau 12.50 ms)", PowerSaving::BASELINE.label())
        );
        let w = build_with(PolicySpec::WindowedQuantile, &m, &params);
        assert!(w.label().contains("w 5, q 0.25"), "{}", w.label());
        let e = build_with(PolicySpec::EmaPredictor, &m, &params);
        assert!(e.label().contains("alpha 0.70"), "{}", e.label());
        let r = build_with(PolicySpec::RandomizedSkiRental, &m, &params);
        assert!(r.label().contains("tau 12.50 ms"), "{}", r.label());
    }
}
