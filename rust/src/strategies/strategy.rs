//! The gap-policy subsystem: what the platform does in the gap between
//! finishing a workload item and the next inference request.
//!
//! A [`Policy`] decides **at item-completion time, without seeing the
//! upcoming gap** — the deployable formulation of the paper's §7 future
//! work ("irregularly occurring inference requests"). It emits a
//! [`GapPlan`]:
//!
//! * **`Idle(saving)`** — stay configured at a Table 3 power-saving level
//!   (the paper's Idle-Waiting, Fig 6).
//! * **`PowerOff`** — cut the rails immediately; pay power-on transient +
//!   full reconfiguration at the next request (On-Off, Fig 5).
//! * **`IdleThenOff { saving, timeout }`** — the ski-rental shape: idle up
//!   to `timeout`, then cut power if no request arrived.
//!
//! After the gap resolves, the runtime calls [`Policy::observe`] with the
//! realized gap so policies can learn online. The clairvoyant per-gap
//! chooser that used to be called `Adaptive` survives as [`Oracle`] — it
//! is the offline upper bound, reachable only through the
//! [`OraclePolicy`] escape hatch ([`decide`]), never through the blind
//! [`Policy::plan_gap`] path.
//!
//! Built-in policies:
//!
//! | policy | information used | behaviour |
//! |---|---|---|
//! | [`OnOff`] | none | always `PowerOff` |
//! | [`IdleWaiting`] | none | always `Idle(saving)` |
//! | [`Oracle`] | the true upcoming gap | off iff gap > crossover |
//! | [`Timeout`] | none (τ from the model) | always `IdleThenOff` at the break-even τ — classically 2-competitive vs the oracle |
//! | [`EmaPredictor`] | observed gap history | idle iff EMA-predicted gap < crossover |

use crate::config::schema::PolicySpec;
use crate::device::rails::PowerSaving;
use crate::energy::analytical::Analytical;
use crate::energy::crossover;
use crate::util::units::Duration;

/// What to do during an inter-request gap, decided before the gap is
/// known. Executed by `ReplayCore::execute_plan` so every runtime shares
/// one energy-accounting path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GapPlan {
    /// Hold configuration at the given power-saving level.
    Idle(PowerSaving),
    /// Cut FPGA rails immediately; configuration is lost.
    PowerOff,
    /// Idle at `saving` for up to `timeout`, then cut power (ski-rental).
    IdleThenOff {
        saving: PowerSaving,
        timeout: Duration,
    },
}

/// What a policy may look at when planning a gap — everything known at
/// item-completion time, and nothing about the future.
#[derive(Debug, Clone, Copy)]
pub struct GapContext {
    /// Workload items completed so far in this run.
    pub items_done: u64,
    /// Simulated time at item completion.
    pub now: Duration,
}

/// Escape hatch for clairvoyant policies: sees the true upcoming gap.
/// Only the offline analyses (lifetime DES, serving loop) route through
/// it via [`decide`]; online contexts fall back to [`Policy::plan_gap`].
pub trait OraclePolicy {
    fn plan_for(&self, gap: Duration) -> GapPlan;
}

/// A stateful gap policy. Object-safe so the simulator and the serving
/// coordinator can hold `Box<dyn Policy>`.
pub trait Policy: Send {
    fn kind(&self) -> PolicySpec;

    /// Plan the upcoming gap from observed state only — the gap length is
    /// deliberately absent.
    fn plan_gap(&mut self, ctx: &GapContext) -> GapPlan;

    /// Feed back the realized gap once it has resolved (online learning).
    fn observe(&mut self, _actual_gap: Duration) {}

    /// Human-readable label for reports.
    fn label(&self) -> String {
        self.kind().name().to_string()
    }

    /// Clairvoyant view, if this policy is an offline upper bound.
    fn as_oracle(&self) -> Option<&dyn OraclePolicy> {
        None
    }
}

/// Resolve a policy's plan for a gap the runtime already knows: oracle
/// policies get the true gap (offline upper bound), online policies plan
/// blind from `ctx` alone.
pub fn decide(policy: &mut dyn Policy, ctx: &GapContext, actual_gap: Duration) -> GapPlan {
    if let Some(oracle) = policy.as_oracle() {
        return oracle.plan_for(actual_gap);
    }
    policy.plan_gap(ctx)
}

/// The paper's On-Off strategy (Fig 5).
#[derive(Debug, Clone, Copy, Default)]
pub struct OnOff;

impl Policy for OnOff {
    fn kind(&self) -> PolicySpec {
        PolicySpec::OnOff
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        GapPlan::PowerOff
    }
}

/// The paper's Idle-Waiting strategy (Fig 6) at a power-saving level.
#[derive(Debug, Clone, Copy)]
pub struct IdleWaiting {
    pub saving: PowerSaving,
}

impl IdleWaiting {
    pub fn baseline() -> IdleWaiting {
        IdleWaiting {
            saving: PowerSaving::BASELINE,
        }
    }

    pub fn method1() -> IdleWaiting {
        IdleWaiting {
            saving: PowerSaving::M1,
        }
    }

    pub fn method12() -> IdleWaiting {
        IdleWaiting {
            saving: PowerSaving::M12,
        }
    }
}

impl Policy for IdleWaiting {
    fn kind(&self) -> PolicySpec {
        match (self.saving.method1, self.saving.method2) {
            (false, _) => PolicySpec::IdleWaiting,
            (true, false) => PolicySpec::IdleWaitingM1,
            (true, true) => PolicySpec::IdleWaitingM12,
        }
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        GapPlan::Idle(self.saving)
    }
}

/// Clairvoyant per-gap policy (formerly `Adaptive`): powers off for gaps
/// beyond the analytical crossover of its idle mode, idles otherwise.
/// The offline upper bound every online policy is measured against.
#[derive(Debug, Clone, Copy)]
pub struct Oracle {
    pub saving: PowerSaving,
    /// Break-even gap duration (precomputed from the analytical model).
    pub crossover: Duration,
}

impl Oracle {
    /// Build from the analytical model: the crossover is where the energy
    /// of idling for the gap equals the energy of a power cycle +
    /// reconfiguration.
    pub fn from_model(model: &Analytical, saving: PowerSaving) -> Oracle {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        Oracle {
            saving,
            crossover: crossover::asymptotic(model, p_idle),
        }
    }
}

impl OraclePolicy for Oracle {
    fn plan_for(&self, gap: Duration) -> GapPlan {
        if gap > self.crossover {
            GapPlan::PowerOff
        } else {
            GapPlan::Idle(self.saving)
        }
    }
}

impl Policy for Oracle {
    fn kind(&self) -> PolicySpec {
        PolicySpec::Oracle
    }

    /// Blind fallback for online contexts that cannot grant clairvoyance
    /// (e.g. the multi-accelerator DES): hold configuration.
    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        GapPlan::Idle(self.saving)
    }

    fn label(&self) -> String {
        format!(
            "oracle({}, crossover {:.2} ms)",
            self.saving.label(),
            self.crossover.millis()
        )
    }

    fn as_oracle(&self) -> Option<&dyn OraclePolicy> {
        Some(self)
    }
}

/// Ski-rental policy: idle up to the break-even timeout τ (idle energy
/// for τ equals one power cycle + reconfiguration), then power off. On
/// any gap sequence its gap energy is at most 2× the oracle's.
#[derive(Debug, Clone, Copy)]
pub struct Timeout {
    pub saving: PowerSaving,
    /// Idle window after which power is cut (the ski-rental "buy" point).
    pub timeout: Duration,
}

impl Timeout {
    /// τ from the analytical model: the idle duration whose energy equals
    /// the reconfiguration cost (= crossover minus the item latency).
    pub fn from_model(model: &Analytical, saving: PowerSaving) -> Timeout {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        Timeout {
            saving,
            timeout: crossover::ski_rental_timeout(model, p_idle),
        }
    }
}

impl Policy for Timeout {
    fn kind(&self) -> PolicySpec {
        PolicySpec::Timeout
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        GapPlan::IdleThenOff {
            saving: self.saving,
            timeout: self.timeout,
        }
    }

    fn label(&self) -> String {
        format!(
            "timeout({}, tau {:.2} ms)",
            self.saving.label(),
            self.timeout.millis()
        )
    }
}

/// Online predictor: an exponential moving average of observed gaps.
/// Idles iff the predicted gap is below the crossover, powers off
/// otherwise; before the first observation it hedges with the ski-rental
/// plan. On strictly periodic arrivals the prediction becomes exact after
/// one gap, so the policy degenerates to the winning static strategy.
#[derive(Debug, Clone, Copy)]
pub struct EmaPredictor {
    pub saving: PowerSaving,
    /// Break-even gap duration of the idle mode.
    pub crossover: Duration,
    /// Ski-rental timeout used while no observation exists yet.
    pub timeout: Duration,
    /// EMA smoothing factor in (0, 1]: weight of the newest observation.
    pub alpha: f64,
    /// Predicted next gap in seconds (None until the first observation).
    predicted_secs: Option<f64>,
}

impl EmaPredictor {
    pub const DEFAULT_ALPHA: f64 = 0.2;

    pub fn from_model(model: &Analytical, saving: PowerSaving, alpha: f64) -> EmaPredictor {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        EmaPredictor {
            saving,
            crossover: crossover::asymptotic(model, p_idle),
            timeout: crossover::ski_rental_timeout(model, p_idle),
            alpha: alpha.clamp(f64::EPSILON, 1.0),
            predicted_secs: None,
        }
    }

    /// Current gap prediction, if any observation has arrived.
    pub fn predicted(&self) -> Option<Duration> {
        self.predicted_secs.map(Duration::from_secs)
    }
}

impl Policy for EmaPredictor {
    fn kind(&self) -> PolicySpec {
        PolicySpec::EmaPredictor
    }

    fn plan_gap(&mut self, _ctx: &GapContext) -> GapPlan {
        match self.predicted_secs {
            // cold start: no history → hedge with the 2-competitive plan
            None => GapPlan::IdleThenOff {
                saving: self.saving,
                timeout: self.timeout,
            },
            Some(p) if p < self.crossover.secs() => GapPlan::Idle(self.saving),
            Some(_) => GapPlan::PowerOff,
        }
    }

    fn observe(&mut self, actual_gap: Duration) {
        let g = actual_gap.secs();
        self.predicted_secs = Some(match self.predicted_secs {
            None => g,
            Some(p) => self.alpha * g + (1.0 - self.alpha) * p,
        });
    }

    fn label(&self) -> String {
        format!(
            "ema({}, alpha {:.2}, crossover {:.2} ms)",
            self.saving.label(),
            self.alpha,
            self.crossover.millis()
        )
    }
}

/// Construct the policy for a config-level [`PolicySpec`]. The advanced
/// policies default to the M1+2 idle mode (the paper's best), matching
/// the pre-rename `Adaptive` default.
pub fn build(spec: PolicySpec, model: &Analytical) -> Box<dyn Policy> {
    match spec {
        PolicySpec::OnOff => Box::new(OnOff),
        PolicySpec::IdleWaiting => Box::new(IdleWaiting::baseline()),
        PolicySpec::IdleWaitingM1 => Box::new(IdleWaiting::method1()),
        PolicySpec::IdleWaitingM12 => Box::new(IdleWaiting::method12()),
        PolicySpec::Oracle => Box::new(Oracle::from_model(model, PowerSaving::M12)),
        PolicySpec::Timeout => Box::new(Timeout::from_model(model, PowerSaving::M12)),
        PolicySpec::EmaPredictor => Box::new(EmaPredictor::from_model(
            model,
            PowerSaving::M12,
            EmaPredictor::DEFAULT_ALPHA,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn model() -> Analytical {
        let cfg = paper_default();
        Analytical::new(&cfg.item, cfg.workload.energy_budget)
    }

    fn ctx() -> GapContext {
        GapContext {
            items_done: 0,
            now: Duration::ZERO,
        }
    }

    #[test]
    fn onoff_always_powers_off() {
        assert_eq!(OnOff.plan_gap(&ctx()), GapPlan::PowerOff);
        assert_eq!(OnOff.kind(), PolicySpec::OnOff);
    }

    #[test]
    fn idle_waiting_always_idles_at_its_level() {
        let mut p = IdleWaiting::method12();
        assert_eq!(p.plan_gap(&ctx()), GapPlan::Idle(PowerSaving::M12));
        assert_eq!(p.kind(), PolicySpec::IdleWaitingM12);
        assert_eq!(IdleWaiting::baseline().kind(), PolicySpec::IdleWaiting);
        assert_eq!(IdleWaiting::method1().kind(), PolicySpec::IdleWaitingM1);
    }

    #[test]
    fn oracle_switches_at_crossover() {
        let m = model();
        let o = Oracle::from_model(&m, PowerSaving::BASELINE);
        assert!((o.crossover.millis() - 89.21).abs() < 0.05);
        assert_eq!(
            o.plan_for(Duration::from_millis(50.0)),
            GapPlan::Idle(PowerSaving::BASELINE)
        );
        assert_eq!(o.plan_for(Duration::from_millis(200.0)), GapPlan::PowerOff);
    }

    #[test]
    fn oracle_m12_crossover_is_499ms() {
        let m = model();
        let o = Oracle::from_model(&m, PowerSaving::M12);
        assert!((o.crossover.millis() - 499.06).abs() < 0.15, "{}", o.crossover.millis());
    }

    #[test]
    fn decide_grants_the_oracle_clairvoyance_only() {
        let m = model();
        let mut oracle = Oracle::from_model(&m, PowerSaving::BASELINE);
        // blind path: the oracle cannot see the gap and holds configuration
        assert_eq!(
            oracle.plan_gap(&ctx()),
            GapPlan::Idle(PowerSaving::BASELINE)
        );
        // decide() routes through the escape hatch with the true gap
        assert_eq!(
            decide(&mut oracle, &ctx(), Duration::from_millis(200.0)),
            GapPlan::PowerOff
        );
        // an online policy never sees the gap, however long
        let mut onoff = OnOff;
        assert_eq!(
            decide(&mut onoff, &ctx(), Duration::from_secs(100.0)),
            GapPlan::PowerOff
        );
        let mut iw = IdleWaiting::baseline();
        assert_eq!(
            decide(&mut iw, &ctx(), Duration::from_secs(100.0)),
            GapPlan::Idle(PowerSaving::BASELINE)
        );
    }

    #[test]
    fn timeout_tau_is_crossover_minus_latency() {
        let m = model();
        let t = Timeout::from_model(&m, PowerSaving::BASELINE);
        let o = Oracle::from_model(&m, PowerSaving::BASELINE);
        let latency = m.item.latency_without_config;
        assert!(
            (t.timeout.millis() - (o.crossover - latency).millis()).abs() < 1e-9,
            "tau {} vs crossover {} - latency {}",
            t.timeout.millis(),
            o.crossover.millis(),
            latency.millis()
        );
        let mut planning = t;
        assert_eq!(
            planning.plan_gap(&ctx()),
            GapPlan::IdleThenOff {
                saving: PowerSaving::BASELINE,
                timeout: t.timeout
            }
        );
    }

    #[test]
    fn ema_learns_and_switches() {
        let m = model();
        let mut e = EmaPredictor::from_model(&m, PowerSaving::BASELINE, 1.0);
        // cold start hedges with the ski-rental plan
        assert!(matches!(e.plan_gap(&ctx()), GapPlan::IdleThenOff { .. }));
        // short observed gaps → idle
        e.observe(Duration::from_millis(40.0));
        assert_eq!(e.predicted().unwrap().millis(), 40.0);
        assert_eq!(e.plan_gap(&ctx()), GapPlan::Idle(PowerSaving::BASELINE));
        // long observed gaps → power off (alpha=1 tracks instantly)
        e.observe(Duration::from_millis(500.0));
        assert_eq!(e.plan_gap(&ctx()), GapPlan::PowerOff);
    }

    #[test]
    fn ema_smoothing_blends_history() {
        let m = model();
        let mut e = EmaPredictor::from_model(&m, PowerSaving::BASELINE, 0.5);
        e.observe(Duration::from_millis(100.0));
        e.observe(Duration::from_millis(200.0));
        assert!((e.predicted().unwrap().millis() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn build_covers_all_kinds() {
        let m = model();
        for spec in PolicySpec::ALL {
            let p = build(spec, &m);
            assert_eq!(p.kind(), spec);
            assert!(!p.label().is_empty());
        }
    }
}
