//! The strategy abstraction: what the platform does in the gap between
//! finishing a workload item and the next inference request.
//!
//! The paper's two strategies (§4.2) plus our adaptive extension are all
//! expressible as a *gap policy*:
//!
//! * **On-Off** — power off; pay power-on transient + full reconfiguration
//!   at the next request.
//! * **Idle-Waiting** — stay configured; draw the Table 3 idle power of
//!   the selected power-saving mode.
//! * **Adaptive** (paper §7 future work) — choose per gap: power off when
//!   the gap is longer than the analytical crossover, idle otherwise.
//!   For periodic workloads this degenerates to whichever single strategy
//!   wins at T_req; its value shows with irregular arrivals.

use crate::config::schema::StrategyKind;
use crate::device::rails::PowerSaving;
use crate::energy::analytical::Analytical;
use crate::util::units::Duration;

/// What to do during an inter-request gap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GapAction {
    /// Cut FPGA rails; configuration is lost.
    PowerOff,
    /// Hold configuration at the given power-saving level.
    Idle(PowerSaving),
}

/// A gap policy. Object-safe so the simulator and the serving coordinator
/// can hold `Box<dyn Strategy>`.
pub trait Strategy: Send {
    fn kind(&self) -> StrategyKind;

    /// Decide the action for a gap of length `gap` (time from item
    /// completion to the next request arrival).
    fn gap_action(&self, gap: Duration) -> GapAction;

    /// Human-readable label for reports.
    fn label(&self) -> String {
        self.kind().name().to_string()
    }
}

/// The paper's On-Off strategy (Fig 5).
#[derive(Debug, Clone, Copy, Default)]
pub struct OnOff;

impl Strategy for OnOff {
    fn kind(&self) -> StrategyKind {
        StrategyKind::OnOff
    }

    fn gap_action(&self, _gap: Duration) -> GapAction {
        GapAction::PowerOff
    }
}

/// The paper's Idle-Waiting strategy (Fig 6) at a power-saving level.
#[derive(Debug, Clone, Copy)]
pub struct IdleWaiting {
    pub saving: PowerSaving,
}

impl IdleWaiting {
    pub fn baseline() -> IdleWaiting {
        IdleWaiting {
            saving: PowerSaving::BASELINE,
        }
    }

    pub fn method1() -> IdleWaiting {
        IdleWaiting {
            saving: PowerSaving::M1,
        }
    }

    pub fn method12() -> IdleWaiting {
        IdleWaiting {
            saving: PowerSaving::M12,
        }
    }
}

impl Strategy for IdleWaiting {
    fn kind(&self) -> StrategyKind {
        match (self.saving.method1, self.saving.method2) {
            (false, _) => StrategyKind::IdleWaiting,
            (true, false) => StrategyKind::IdleWaitingM1,
            (true, true) => StrategyKind::IdleWaitingM12,
        }
    }

    fn gap_action(&self, _gap: Duration) -> GapAction {
        GapAction::Idle(self.saving)
    }
}

/// Per-gap adaptive strategy: powers off for gaps beyond the analytical
/// crossover of its idle mode, idles otherwise.
#[derive(Debug, Clone, Copy)]
pub struct Adaptive {
    pub saving: PowerSaving,
    /// Break-even gap duration (precomputed from the analytical model).
    pub crossover: Duration,
}

impl Adaptive {
    /// Build from the analytical model: the crossover is where the energy
    /// of idling for the gap equals the energy of a power cycle +
    /// reconfiguration.
    pub fn from_model(model: &Analytical, saving: PowerSaving) -> Adaptive {
        let p_idle = crate::device::rails::RailSet::idle_power(saving);
        Adaptive {
            saving,
            crossover: crate::energy::crossover::asymptotic(model, p_idle),
        }
    }
}

impl Strategy for Adaptive {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Adaptive
    }

    fn gap_action(&self, gap: Duration) -> GapAction {
        if gap > self.crossover {
            GapAction::PowerOff
        } else {
            GapAction::Idle(self.saving)
        }
    }

    fn label(&self) -> String {
        format!(
            "adaptive({}, crossover {:.2} ms)",
            self.saving.label(),
            self.crossover.millis()
        )
    }
}

/// Construct the strategy for a config-level [`StrategyKind`].
pub fn build(kind: StrategyKind, model: &Analytical) -> Box<dyn Strategy> {
    match kind {
        StrategyKind::OnOff => Box::new(OnOff),
        StrategyKind::IdleWaiting => Box::new(IdleWaiting::baseline()),
        StrategyKind::IdleWaitingM1 => Box::new(IdleWaiting::method1()),
        StrategyKind::IdleWaitingM12 => Box::new(IdleWaiting::method12()),
        StrategyKind::Adaptive => Box::new(Adaptive::from_model(model, PowerSaving::M12)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn model() -> Analytical {
        let cfg = paper_default();
        Analytical::new(&cfg.item, cfg.workload.energy_budget)
    }

    #[test]
    fn onoff_always_powers_off() {
        assert_eq!(OnOff.gap_action(Duration::from_millis(1.0)), GapAction::PowerOff);
        assert_eq!(OnOff.gap_action(Duration::from_secs(100.0)), GapAction::PowerOff);
        assert_eq!(OnOff.kind(), StrategyKind::OnOff);
    }

    #[test]
    fn idle_waiting_always_idles_at_its_level() {
        let s = IdleWaiting::method12();
        assert_eq!(
            s.gap_action(Duration::from_secs(10.0)),
            GapAction::Idle(PowerSaving::M12)
        );
        assert_eq!(s.kind(), StrategyKind::IdleWaitingM12);
        assert_eq!(IdleWaiting::baseline().kind(), StrategyKind::IdleWaiting);
        assert_eq!(IdleWaiting::method1().kind(), StrategyKind::IdleWaitingM1);
    }

    #[test]
    fn adaptive_switches_at_crossover() {
        let m = model();
        let a = Adaptive::from_model(&m, PowerSaving::BASELINE);
        assert!((a.crossover.millis() - 89.21).abs() < 0.05);
        assert_eq!(
            a.gap_action(Duration::from_millis(50.0)),
            GapAction::Idle(PowerSaving::BASELINE)
        );
        assert_eq!(
            a.gap_action(Duration::from_millis(200.0)),
            GapAction::PowerOff
        );
    }

    #[test]
    fn adaptive_m12_crossover_is_499ms() {
        let m = model();
        let a = Adaptive::from_model(&m, PowerSaving::M12);
        assert!((a.crossover.millis() - 499.06).abs() < 0.15, "{}", a.crossover.millis());
    }

    #[test]
    fn build_covers_all_kinds() {
        let m = model();
        for kind in StrategyKind::ALL {
            let s = build(kind, &m);
            assert_eq!(s.kind(), kind);
            assert!(!s.label().is_empty());
        }
    }
}
