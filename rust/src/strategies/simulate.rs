//! The policy-level discrete-event simulation.
//!
//! Replays the duty-cycle workload (Fig 1) against the [`ReplayCore`]
//! under a [`Policy`]'s gap plans until the 4147 J battery budget is
//! exhausted (or an optional item cap is hit), reproducing the quantity
//! the paper's Python simulator computes: the maximum number of
//! executable workload items and the system lifetime. The PAC1934
//! monitor rides along, so the run also yields the "hardware-measured"
//! energy whose gap vs the exact integral mirrors the paper's §5.3
//! validation.
//!
//! Policies are *online*: they plan each gap at item-completion time
//! without seeing the upcoming inter-arrival gap, and receive the
//! realized gap via [`Policy::observe`] afterwards. Only a policy
//! exposing the `OraclePolicy` escape hatch (the offline upper bound) is
//! handed the true gap, through [`decide`].
//!
//! Since the runner/runtime unification this module contains no request
//! loop of its own: requests are [`LifetimeEvent`]s on the shared
//! [`sim::Engine`](crate::sim::Engine) — the same event-enum pattern the
//! multi-accelerator simulation uses — with the inter-arrival gaps drawn
//! from a pluggable [`ArrivalProcess`]. The engine clock tracks request
//! arrivals; the board's own ledger tracks busy/idle energy, exactly as
//! the pre-unification serial loop did, so reports are bit-identical.
//!
//! Consumers that materialize their gap stream up front (trace replays,
//! sweep cells, tuner evaluations) skip the event queue entirely and run
//! the **batched** kernel instead: gaps are planned [`GAP_BATCH`] at a
//! time into a structure-of-arrays [`GapBatch`] ([`decide_batch`]) and
//! executed by [`ReplayCore::execute_batch`] as tight loops over the
//! gap-cost table ([`SimWorker::run_batch`], [`simulate_batch`],
//! [`PrefixSim`]). The batched path is bit-identical to the scalar
//! event-driven path — same board-operation order, same f64 operation
//! order, same policy-visible plan/observe interleaving — pinned by
//! `tests/batch_equivalence.rs` against both the scalar fast path and the
//! golden `Board`-FSM reference.

use std::sync::Arc;

use crate::config::loader::SimConfig;
use crate::coordinator::requests::ArrivalProcess;
use crate::device::board::BoardError;
use crate::device::rails::PowerSaving;
use crate::sim::{Ctx, Engine, SimTime};
use crate::strategies::replay::{BatchRun, GapBatch, ReplayCore, SlotId};
use crate::strategies::strategy::{decide, decide_batch, GapContext, Policy};
use crate::util::stats::Welford;
use crate::util::units::{Duration, Energy};

pub use crate::strategies::replay::item_phases;

/// Per-run gap-decision counters: *why* a policy's energy total looks
/// the way it does, not just what it is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GapDecisions {
    /// Gaps spent fully configured (pure idle).
    pub idled: u64,
    /// Gaps that ended powered off (immediately or after a timeout).
    pub powered_off: u64,
    /// Subset of `powered_off` where an `IdleThenOff` timer expired.
    pub timeouts_expired: u64,
}

/// Outcome of one simulated lifetime.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Label of the policy that ran.
    pub policy: String,
    /// Label of the arrival process that drove it.
    pub arrival: String,
    /// Workload items fully executed within the budget (the paper's n_max).
    pub items: u64,
    /// Eq 4 lifetime: items × mean period for periodic workloads; for
    /// irregular arrivals, the elapsed simulated time at exhaustion.
    pub lifetime: Duration,
    /// Exact FPGA-side energy drawn from the budget.
    pub energy_exact: Energy,
    /// Energy as the PAC1934 monitor measured it.
    pub energy_measured: Energy,
    /// Relative instrument error (sampled vs exact).
    pub monitor_rel_error: f64,
    /// Number of FPGA configurations performed.
    pub configurations: u64,
    /// Number of power-on transients paid.
    pub power_ons: u64,
    /// Requests that arrived before the previous item finished (only
    /// possible with irregular arrivals) and were served late.
    pub late_requests: u64,
    /// Mean served latency (arrival → completion, including queueing
    /// behind a late-running predecessor and any reconfiguration).
    pub mean_latency: Duration,
    /// Per-gap decision counters (`items − 1` gaps in total).
    pub decisions: GapDecisions,
    /// Final engine clock: the arrival time of the last request
    /// processed (n−1 inter-arrival gaps for n items).
    pub sim_time: Duration,
    /// Faulted configuration/inference attempts that were retried (or
    /// given up on). Zero whenever fault injection is disabled.
    pub retries: u64,
    /// Energy destroyed by faulted attempts — partial configurations and
    /// interrupted inference runs. Recovery overhead drawn from the same
    /// battery budget, not productive spend; zero with faults disabled.
    pub recovery_energy: Energy,
    /// Requests shed after the retry policy exhausted its attempt cap
    /// ([`BoardError::RetriesExhausted`]): not served, not counted in
    /// `items`, the device powered off through the following gap.
    pub shed_requests: u64,
}

/// Events of the single-accelerator duty cycle: a request arrives. Each
/// request schedules its successor one inter-arrival gap later, so the
/// event chain is the workload.
#[derive(Debug)]
enum LifetimeEvent {
    Request,
}

/// The run-long counters and constants of one lifetime simulation — the
/// owned part of the simulation state, so a run can be paused at an item
/// boundary and resumed later ([`PrefixSim`]).
#[derive(Debug, Clone)]
struct RunLedger {
    /// Interned flash slot of the accelerator image.
    slot: SlotId,
    max_items: u64,
    items: u64,
    late_requests: u64,
    decisions: GapDecisions,
    /// Served-latency accounting: completion time of the previous item
    /// (absolute sim time), so a late-running predecessor queues us.
    prev_completion: Duration,
    latency: Welford,
    /// Configuration duration from the FSM (equals Table 2's 36.145 ms at
    /// the optimal SPI setting, but follows the mechanism when swept).
    config_time: Duration,
    item_latency: Duration,
    /// A board operation failed (budget exhausted): the run is over and
    /// cannot be resumed.
    exhausted: bool,
    /// Requests shed after the retry policy gave up (fault injection).
    shed_requests: u64,
    /// A request was just shed and its following gap has not been
    /// consumed yet: the batched driver must pass that gap powered off,
    /// without consulting the policy, before planning resumes.
    shed_pending: bool,
}

impl RunLedger {
    fn new(config: &SimConfig, slot: SlotId) -> RunLedger {
        RunLedger {
            slot,
            max_items: config.workload.max_items.unwrap_or(u64::MAX),
            items: 0,
            late_requests: 0,
            decisions: GapDecisions::default(),
            prev_completion: Duration::ZERO,
            latency: Welford::new(),
            config_time: config.item.configuration.time,
            item_latency: config.item.latency_without_config(),
            exhausted: false,
            shed_requests: 0,
            shed_pending: false,
        }
    }
}

/// Mutable simulation state threaded through the event handler: the
/// owned ledger plus the borrowed core/policy/arrival process.
struct LifetimeState<'a> {
    core: &'a mut ReplayCore,
    policy: &'a mut dyn Policy,
    arrivals: &'a mut dyn ArrivalProcess,
    ledger: &'a mut RunLedger,
}

impl LifetimeState<'_> {
    /// Serve one request: mechanics per the paper's Fig 1 duty cycle.
    ///
    /// 1. If the FPGA is unconfigured (first request, or the previous gap
    ///    powered it off), pay power-on transient + full configuration.
    /// 2. Run the three active phases (Table 2).
    /// 3. Ask the policy for a gap plan (blind, unless it is the oracle),
    ///    execute it on the shared core, feed the realized gap back, and
    ///    schedule the next request one inter-arrival gap out.
    ///
    /// Stops (without counting the in-flight item) as soon as any energy
    /// draw would exceed the remaining budget — Eq 3's `≤ E_Budget`
    /// criterion. With a fault stream installed the configure and phase
    /// steps route through the recovering wrappers (identical calls when
    /// no fault is drawn); a request whose retries are exhausted is
    /// *shed* instead of killing the run ([`shed_and_pass_gap`]).
    fn on_request(&mut self, ctx: &mut Ctx<LifetimeEvent>) {
        let ledger = &mut *self.ledger;
        if ledger.items >= ledger.max_items {
            ctx.stop();
            return;
        }
        let arrival = ctx.now().as_duration();
        // 1. ensure configured (interned slot: no per-item flash lookup)
        let mut reconfigured = false;
        let mut extra = Duration::ZERO;
        if !self.core.is_ready() {
            match self.core.configure_slot_recovering(ledger.slot) {
                Ok(rec) => {
                    ledger.config_time = rec.config_time;
                    reconfigured = true;
                    extra = extra + rec.recovery_time;
                }
                Err(BoardError::RetriesExhausted(_)) => {
                    shed_and_pass_gap(self.core, self.arrivals, ledger, ctx);
                    return;
                }
                Err(_) => {
                    ledger.exhausted = true;
                    ctx.stop();
                    return;
                }
            }
        }
        // 2. active phases (a supply brownout mid-item recovers in place)
        match self.core.run_phases_recovering(ledger.slot) {
            Ok(ph) => extra = extra + ph.recovery_time,
            Err(BoardError::RetriesExhausted(_)) => {
                shed_and_pass_gap(self.core, self.arrivals, ledger, ctx);
                return;
            }
            Err(_) => {
                ledger.exhausted = true;
                ctx.stop();
                return;
            }
        }
        // late/latency bookkeeping shared verbatim with the batched driver
        account_served_item(ledger, arrival, reconfigured, extra);
        if ledger.items >= ledger.max_items {
            // Eq 2 counts n−1 idle gaps: no gap after the final item.
            ctx.stop();
            return;
        }

        // 3. plan + execute the gap until the next arrival
        let gap = self.arrivals.next_gap();
        match plan_gap(self.core, self.policy, ledger, arrival, gap) {
            Ok(()) => ctx.schedule_in(gap, LifetimeEvent::Request),
            Err(()) => ctx.stop(),
        }
    }
}

/// Graceful degradation on the scalar event path: the retry policy gave
/// up on this request ([`BoardError::RetriesExhausted`]), so it is shed —
/// not served, not counted — and the device stays powered off through
/// the following inter-arrival gap. The policy is neither consulted nor
/// fed the gap: it plans at item completions, and no item completed.
fn shed_and_pass_gap(
    core: &mut ReplayCore,
    arrivals: &mut dyn ArrivalProcess,
    ledger: &mut RunLedger,
    ctx: &mut Ctx<LifetimeEvent>,
) {
    ledger.shed_requests += 1;
    let gap = arrivals.next_gap();
    // the fabric is off after a give-up, so this passes the gap in the
    // (paper-model, zero-energy) off state on both core flavours
    if core.elapse(PowerSaving::BASELINE, gap).is_err() {
        ledger.exhausted = true;
        ctx.stop();
        return;
    }
    ctx.schedule_in(gap, LifetimeEvent::Request);
}

/// The gap-planning tail of one served item: ask the policy, execute the
/// plan on the core, account the decision, feed the realized gap back.
/// Shared by the event handler and [`PrefixSim`]'s resume step (which
/// re-enters exactly here after a cap-stop). `Err(())` = the board
/// refused (budget exhausted); the caller must stop the run.
fn plan_gap(
    core: &mut ReplayCore,
    policy: &mut dyn Policy,
    ledger: &mut RunLedger,
    arrival: Duration,
    gap: Duration,
) -> Result<(), ()> {
    let gap_ctx = GapContext {
        items_done: ledger.items,
        now: arrival,
        queued: 0,
    };
    let plan = decide(policy, &gap_ctx, gap);
    match core.execute_plan(plan, gap, ledger.config_time, ledger.item_latency) {
        Ok(exec) => {
            if exec.powered_off {
                ledger.decisions.powered_off += 1;
            } else {
                ledger.decisions.idled += 1;
            }
            if exec.timeout_expired {
                ledger.decisions.timeouts_expired += 1;
            }
            // exec.late (the plan's busy window vs the local gap) is
            // deliberately NOT counted here: lateness is accounted at
            // the next arrival from the queue state, which also
            // catches cascades behind a late predecessor.
            policy.observe(gap);
            Ok(())
        }
        Err(_) => {
            ledger.exhausted = true;
            Err(())
        }
    }
}

/// Gaps planned and executed per batched chunk. Large enough to amortize
/// virtual dispatch and let the structure-of-arrays cost loops
/// auto-vectorize; small enough that the scratch arrays stay cache-hot.
pub const GAP_BATCH: usize = 256;

/// Reusable scratch for the batched driver — one allocation set per
/// worker, reused across chunks and runs.
#[derive(Default)]
struct BatchScratch {
    batch: GapBatch,
    run: BatchRun,
    ctxs: Vec<GapContext>,
    /// Absolute arrival times: `arrivals[0]` is the arrival of the last
    /// served item, `arrivals[k + 1]` the arrival after chunk gap `k`.
    /// Accumulated in [`SimTime`] so the clock quantizes per gap exactly
    /// as `Ctx::schedule_in` does on the event-driven path.
    arrivals: Vec<SimTime>,
}

/// The serve-side accounting of one request: item count, queueing,
/// served latency. Shared by the event handler and the batched driver so
/// both use the exact arithmetic (and f64 op order). `extra` is the
/// fault-recovery overhead (partial attempts, backoffs, brownout
/// reconfigurations) the request waited through on top of its nominal
/// busy window; it is exactly zero on the fault-free path, where adding
/// it to the strictly positive serve time cannot perturb a single bit.
fn account_served_item(
    ledger: &mut RunLedger,
    arrival: Duration,
    reconfigured: bool,
    extra: Duration,
) {
    ledger.items += 1;
    let base = if reconfigured {
        ledger.config_time + ledger.item_latency
    } else {
        ledger.item_latency
    };
    let serve = base + extra;
    let start = arrival.max(ledger.prev_completion);
    // late = arrived before the previous item finished. Counted here,
    // at arrival, from the same queue state the latency ledger uses —
    // so cascaded lateness (a request delayed by a predecessor that
    // was itself late) is counted, which the plan-local
    // `GapExecution::late` flag cannot see.
    if start > arrival {
        ledger.late_requests += 1;
    }
    let completion = start + serve;
    ledger.latency.push((completion - arrival).millis());
    ledger.prev_completion = completion;
}

/// Serve the first request (arrival t = 0) outside the batch loop: pay
/// power-on + configuration + the active phases, account the item. After
/// this every chunk element is one (gap, following item) pair. If the
/// retry policy gives up on the very first request it is shed and
/// `shed_pending` is raised, so [`drive_trace`] passes gap 0 powered off
/// before any planning happens — exactly like the scalar handler.
fn serve_first_item(core: &mut ReplayCore, ledger: &mut RunLedger) {
    if ledger.max_items == 0 {
        return;
    }
    let mut reconfigured = false;
    let mut extra = Duration::ZERO;
    if !core.is_ready() {
        match core.configure_slot_recovering(ledger.slot) {
            Ok(rec) => {
                ledger.config_time = rec.config_time;
                reconfigured = true;
                extra = extra + rec.recovery_time;
            }
            Err(BoardError::RetriesExhausted(_)) => {
                ledger.shed_requests += 1;
                ledger.shed_pending = true;
                return;
            }
            Err(_) => {
                ledger.exhausted = true;
                return;
            }
        }
    }
    match core.run_phases_recovering(ledger.slot) {
        Ok(ph) => extra = extra + ph.recovery_time,
        Err(BoardError::RetriesExhausted(_)) => {
            ledger.shed_requests += 1;
            ledger.shed_pending = true;
            return;
        }
        Err(_) => {
            ledger.exhausted = true;
            return;
        }
    }
    account_served_item(ledger, Duration::ZERO, reconfigured, extra);
}

/// The batched inner loop: drive the run through `gaps[..limit]` in
/// [`GAP_BATCH`]-sized chunks, stopping at the item cap, the end of the
/// trace, or budget exhaustion — whichever comes first.
///
/// Per chunk: build contexts and quantized arrival times, plan every gap
/// ([`decide_batch`] — flat fills for stateless policies, the faithful
/// plan/observe interleaving for learners), execute the whole chunk on
/// the core ([`ReplayCore::execute_batch`]), then fold the results into
/// the ledger. On exhaustion the clock and consumed-gap count land
/// exactly where the scalar event loop would have died: `execs.len() ==
/// reconfigured.len()` means gap `execs.len()` was drawn and refused
/// (clock stays at its planning arrival); one extra exec means the
/// following item's configure/phases refused (clock at that arrival, the
/// item not counted).
fn drive_trace(
    core: &mut ReplayCore,
    policy: &mut dyn Policy,
    ledger: &mut RunLedger,
    gaps: &[Duration],
    limit: usize,
    clock: &mut SimTime,
    consumed: &mut usize,
    scratch: &mut BatchScratch,
) {
    // With a fault stream installed, chunks shrink to one gap. A shed
    // request must stop planning immediately — its following gap passes
    // powered off without consulting the policy — and a multi-gap chunk
    // would already have planned (and let a learning policy observe)
    // gaps past the shed point, making chunk boundaries visible in the
    // results. One-gap chunks keep the policy-visible plan/observe
    // sequence identical to the scalar event path; fault-free runs keep
    // the full [`GAP_BATCH`] and are untouched.
    let span_cap = if core.fault_state().is_some() { 1 } else { GAP_BATCH };
    while !ledger.exhausted && ledger.items < ledger.max_items && *consumed < limit {
        if ledger.shed_pending {
            // tail of a shed request: its gap passes powered off,
            // unplanned and unobserved (mirrors `shed_and_pass_gap`)
            let gap = gaps[*consumed];
            if core.elapse(PowerSaving::BASELINE, gap).is_err() {
                ledger.exhausted = true;
                return;
            }
            *clock = *clock + gap;
            *consumed += 1;
            ledger.shed_pending = false;
            continue;
        }
        let span = span_cap
            .min(limit - *consumed)
            .min((ledger.max_items - ledger.items).min(span_cap as u64) as usize);
        let chunk = &gaps[*consumed..*consumed + span];
        scratch.ctxs.clear();
        scratch.arrivals.clear();
        scratch.arrivals.push(*clock);
        for (k, &gap) in chunk.iter().enumerate() {
            let at = scratch.arrivals[k];
            scratch.ctxs.push(GapContext {
                items_done: ledger.items + k as u64,
                now: at.as_duration(),
                queued: 0,
            });
            scratch.arrivals.push(at + gap);
        }
        decide_batch(policy, &scratch.ctxs, chunk, &mut scratch.batch);
        core.execute_batch(
            &scratch.batch,
            ledger.slot,
            &mut ledger.config_time,
            ledger.item_latency,
            &mut scratch.run,
        );
        let run = &scratch.run;
        for (k, exec) in run.execs.iter().enumerate() {
            if exec.powered_off {
                ledger.decisions.powered_off += 1;
            } else {
                ledger.decisions.idled += 1;
            }
            if exec.timeout_expired {
                ledger.decisions.timeouts_expired += 1;
            }
            if k < run.reconfigured.len() {
                // `extra` is empty on a fault-free core: zero overhead
                let extra = run.extra.get(k).copied().unwrap_or(Duration::ZERO);
                account_served_item(
                    ledger,
                    scratch.arrivals[k + 1].as_duration(),
                    run.reconfigured[k],
                    extra,
                );
            }
        }
        *clock = scratch.arrivals[run.execs.len()];
        if run.shed {
            // the item after the last executed gap exhausted its retry
            // cap: shed it (not served, not counted); its following gap
            // — the next in the trace — passes powered off through the
            // `shed_pending` arm on the next iteration
            ledger.shed_requests += 1;
            ledger.shed_pending = true;
            *consumed += run.execs.len();
        } else {
            *consumed += if run.exhausted {
                // the failed gap was drawn (consumed) before it was refused
                run.execs.len() + (run.execs.len() == run.reconfigured.len()) as usize
            } else {
                span
            };
        }
        if run.exhausted {
            ledger.exhausted = true;
        }
    }
}

/// Assemble the [`SimReport`] from a finished (or paused) run.
fn build_report(
    policy_label: String,
    arrival_label: String,
    arrival_mean: Duration,
    ledger: &RunLedger,
    core: &ReplayCore,
    end_time: SimTime,
) -> SimReport {
    let board = &core.board;
    let recovery = core.recovery();
    SimReport {
        policy: policy_label,
        arrival: arrival_label,
        items: ledger.items,
        lifetime: arrival_mean * ledger.items as f64, // Eq 4
        energy_exact: board.fpga_energy,
        energy_measured: board.monitor.measured(),
        monitor_rel_error: board.monitor.rel_error(),
        configurations: board.fpga.configurations,
        power_ons: board.fpga.power_ons,
        late_requests: ledger.late_requests,
        mean_latency: Duration::from_millis(if ledger.latency.count() > 0 {
            ledger.latency.mean()
        } else {
            0.0
        }),
        decisions: ledger.decisions,
        sim_time: end_time.as_duration(),
        retries: recovery.retries,
        recovery_energy: recovery.recovery_energy,
        shed_requests: ledger.shed_requests,
    }
}

/// A reusable lifetime-DES cell: one [`ReplayCore`] + one engine, reset
/// (not rebuilt) between runs.
///
/// `simulate()` used to construct the full platform — flash, bitstream,
/// monitor, event queue — per call; in a sweep that meant one platform
/// build per cell. A `SimWorker` is built once per worker thread
/// ([`SweepRunner::run_with_state`](crate::runner::SweepRunner::run_with_state))
/// and reused across cells through [`ReplayCore::reset_for`] and
/// [`Engine::reset`], which restore pristine state without reallocating.
/// Reports are bit-identical to fresh construction.
pub struct SimWorker {
    core: ReplayCore,
    engine: Engine<LifetimeEvent>,
    scratch: BatchScratch,
}

impl SimWorker {
    /// A worker on the fast gap-cost path (the default).
    pub fn new(config: &SimConfig) -> SimWorker {
        SimWorker {
            core: ReplayCore::from_config(config),
            engine: Engine::new(),
            scratch: BatchScratch::default(),
        }
    }

    /// A worker on the golden `Board`-FSM reference path.
    pub fn golden(config: &SimConfig) -> SimWorker {
        SimWorker {
            core: ReplayCore::golden_reference(config),
            engine: Engine::new(),
            scratch: BatchScratch::default(),
        }
    }

    /// Run one lifetime simulation of `config`'s workload under `policy`
    /// with `arrivals`. The worker's platform is reset to pristine state
    /// first, so consecutive runs are independent.
    pub fn run(
        &mut self,
        config: &SimConfig,
        policy: &mut dyn Policy,
        arrivals: &mut dyn ArrivalProcess,
    ) -> SimReport {
        self.core.reset_for(config);
        self.engine.reset();
        let slot = self
            .core
            .slot_id("lstm")
            .expect("the paper platform programs the lstm image");
        let mut ledger = RunLedger::new(config, slot);
        let mut state = LifetimeState {
            core: &mut self.core,
            policy,
            arrivals,
            ledger: &mut ledger,
        };
        self.engine.schedule_at(SimTime::ZERO, LifetimeEvent::Request);
        let stats = self.engine.run(&mut state, u64::MAX, |ctx, st, event| match event {
            LifetimeEvent::Request => st.on_request(ctx),
        });
        let policy_label = state.policy.label();
        let arrival_label = state.arrivals.label();
        let arrival_mean = state.arrivals.mean();
        build_report(
            policy_label,
            arrival_label,
            arrival_mean,
            &ledger,
            &self.core,
            stats.end_time,
        )
    }

    /// Run one lifetime simulation over a fully materialized gap trace on
    /// the batched kernel: no event queue, gaps planned and executed
    /// [`GAP_BATCH`] at a time. Bit-identical to [`SimWorker::run`] with a
    /// `TraceReplay` over the same gaps (and to the golden path when the
    /// worker is [`SimWorker::golden`]). `arrival_label`/`arrival_mean`
    /// name the process the trace was drawn from so reports match the
    /// generator-driven path field for field.
    pub fn run_batch(
        &mut self,
        config: &SimConfig,
        policy: &mut dyn Policy,
        gaps: &[Duration],
        arrival_label: &str,
        arrival_mean: Duration,
    ) -> SimReport {
        self.core.reset_for(config);
        let slot = self
            .core
            .slot_id("lstm")
            .expect("the paper platform programs the lstm image");
        let mut ledger = RunLedger::new(config, slot);
        let mut clock = SimTime::ZERO;
        serve_first_item(&mut self.core, &mut ledger);
        let mut consumed = 0usize;
        drive_trace(
            &mut self.core,
            policy,
            &mut ledger,
            gaps,
            gaps.len(),
            &mut clock,
            &mut consumed,
            &mut self.scratch,
        );
        build_report(
            policy.label(),
            arrival_label.to_string(),
            arrival_mean,
            &ledger,
            &self.core,
            clock,
        )
    }
}

/// Simulate `config`'s workload under `policy` with `arrivals` on the
/// shared discrete-event engine (fast gap-cost path).
pub fn simulate(
    config: &SimConfig,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalProcess,
) -> SimReport {
    SimWorker::new(config).run(config, policy, arrivals)
}

/// [`simulate`] on the golden `Board`-FSM reference path — every gap
/// walks the full device state machine as before the gap-cost kernel.
/// The equivalence suite pins `simulate` == `simulate_golden` on every
/// report field across the whole workload corpus.
pub fn simulate_golden(
    config: &SimConfig,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalProcess,
) -> SimReport {
    SimWorker::golden(config).run(config, policy, arrivals)
}

/// Simulate `config`'s workload under `policy` over a materialized gap
/// trace on the batched structure-of-arrays kernel. Labeled exactly like
/// a [`TraceReplay`](crate::coordinator::requests::TraceReplay) run, so
/// reports compare field for field against the scalar path.
pub fn simulate_batch(
    config: &SimConfig,
    policy: &mut dyn Policy,
    gaps: &[Duration],
) -> SimReport {
    SimWorker::new(config).run_batch(
        config,
        policy,
        gaps,
        &format!("trace({} gaps)", gaps.len()),
        crate::coordinator::requests::trace_mean(gaps),
    )
}

/// A pausable lifetime simulation over a shared gap trace: run the first
/// `p1` gaps, read the report, later *continue* to `p2 > p1` without
/// re-simulating the prefix.
///
/// This is the successive-halving hot path: each rung doubles the train
/// prefix for the surviving candidates, and re-simulating the shared
/// prefix made rung `k` cost the sum of all earlier rungs again. A
/// `PrefixSim` pauses at an item boundary (exactly where a `max_items`
/// cap stops the run) and resumes the batched driver from the next
/// unconsumed gap, so the state — board ledgers, policy history, queue,
/// clock — continues bit-for-bit as if the longer run had been simulated
/// from scratch. [`PrefixSim::advance_to`] returns the same `SimReport`,
/// bit-for-bit, as a fresh capped run over the prefix (pinned by the
/// tuner's equivalence tests). Since the batched kernel landed this runs
/// on [`ReplayCore::execute_batch`]; chunk boundaries (which differ
/// between resumed and from-scratch runs) affect only the grouping of
/// work, never a computed value.
pub struct PrefixSim {
    core: ReplayCore,
    policy: Box<dyn Policy>,
    gaps: Arc<[Duration]>,
    /// Gaps consumed so far.
    consumed: usize,
    /// The first request has been served.
    started: bool,
    /// The budget ran out (or another board refusal): no further progress
    /// is possible, reports stay frozen — exactly like a longer
    /// from-scratch run, which dies at the same event.
    dead: bool,
    /// Arrival time of the last request processed (the scalar engine
    /// clock), quantized per gap exactly as `Ctx::schedule_in` would.
    clock: SimTime,
    ledger: RunLedger,
    scratch: BatchScratch,
}

impl PrefixSim {
    /// A paused simulation of `config`'s workload under `policy` over
    /// `gaps`, positioned before the first request.
    pub fn new(config: &SimConfig, policy: Box<dyn Policy>, gaps: Arc<[Duration]>) -> PrefixSim {
        assert!(!gaps.is_empty(), "empty gap trace");
        let core = ReplayCore::from_config(config);
        let slot = core
            .slot_id("lstm")
            .expect("the paper platform programs the lstm image");
        let ledger = RunLedger::new(config, slot);
        PrefixSim {
            core,
            policy,
            gaps,
            consumed: 0,
            started: false,
            dead: false,
            clock: SimTime::ZERO,
            ledger,
            scratch: BatchScratch::default(),
        }
    }

    /// Gaps consumed so far.
    pub fn consumed(&self) -> usize {
        self.consumed
    }

    /// Run (or continue) the simulation through the first `prefix` gaps
    /// (`prefix + 1` items) and report. `prefix` must not shrink and must
    /// fit the trace; a repeated prefix just re-reports.
    pub fn advance_to(&mut self, prefix: usize) -> SimReport {
        assert!(
            prefix >= 1 && prefix <= self.gaps.len(),
            "prefix {prefix} outside 1..={}",
            self.gaps.len()
        );
        assert!(
            prefix >= self.consumed,
            "prefix {prefix} would rewind past {} consumed gaps",
            self.consumed
        );
        if (!self.dead && prefix > self.consumed) || !self.started {
            self.ledger.max_items = prefix as u64 + 1;
            if !self.started {
                self.started = true;
                serve_first_item(&mut self.core, &mut self.ledger);
            }
            if !self.ledger.exhausted {
                drive_trace(
                    &mut self.core,
                    self.policy.as_mut(),
                    &mut self.ledger,
                    &self.gaps[..],
                    prefix,
                    &mut self.clock,
                    &mut self.consumed,
                    &mut self.scratch,
                );
            }
            self.dead = self.ledger.exhausted;
        }
        self.report(prefix)
    }

    /// The report a fresh capped run over `gaps[..prefix]` would produce.
    fn report(&self, prefix: usize) -> SimReport {
        build_report(
            self.policy.label(),
            format!("trace({prefix} gaps)"),
            crate::coordinator::requests::trace_mean(&self.gaps[..prefix]),
            &self.ledger,
            &self.core,
            self.clock,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::config::schema::PolicySpec;
    use crate::coordinator::requests::{Periodic, Poisson};
    use crate::device::rails::PowerSaving;
    use crate::energy::analytical::Analytical;
    use crate::strategies::strategy::{build, IdleWaiting, OnOff, Oracle, Timeout};
    use crate::testing::assert_sim_reports_bit_identical as assert_reports_identical;

    fn capped_config(t_req_ms: f64, max_items: u64) -> SimConfig {
        let mut cfg = paper_default();
        cfg.workload.arrival = crate::config::schema::ArrivalSpec::Periodic {
            period: Duration::from_millis(t_req_ms),
        };
        cfg.workload.max_items = Some(max_items);
        cfg
    }

    fn periodic(ms: f64) -> Periodic {
        Periodic {
            period: Duration::from_millis(ms),
        }
    }

    #[test]
    fn onoff_pays_configuration_per_item() {
        let cfg = capped_config(40.0, 100);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &mut OnOff, &mut arr);
        assert_eq!(r.items, 100);
        assert_eq!(r.configurations, 100);
        assert_eq!(r.power_ons, 100);
        // per-item energy ≈ 11.983 mJ
        let per_item = r.energy_exact.millijoules() / 100.0;
        assert!((per_item - 11.983).abs() < 0.01, "{per_item}");
        // every gap was a power-off decision
        assert_eq!(r.decisions.powered_off, 99);
        assert_eq!(r.decisions.idled, 0);
        assert_eq!(r.decisions.timeouts_expired, 0);
    }

    #[test]
    fn idle_waiting_configures_once() {
        let cfg = capped_config(40.0, 100);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &mut IdleWaiting::baseline(), &mut arr);
        assert_eq!(r.items, 100);
        assert_eq!(r.configurations, 1);
        assert_eq!(r.power_ons, 1);
        assert_eq!(r.decisions.idled, 99);
        assert_eq!(r.decisions.powered_off, 0);
    }

    #[test]
    fn zero_item_cap_executes_nothing() {
        let cfg = capped_config(40.0, 0);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &mut IdleWaiting::baseline(), &mut arr);
        assert_eq!(r.items, 0);
        assert_eq!(r.configurations, 0);
        assert_eq!(r.energy_exact, Energy::ZERO);
        assert_eq!(r.mean_latency, Duration::ZERO);
    }

    #[test]
    fn des_matches_analytical_nmax_small_budget() {
        // shrink the budget so the full run is fast, then compare DES
        // item count against Eq 3 exactly
        let mut cfg = paper_default();
        cfg.workload.energy_budget = Energy::from_joules(5.0);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);

        // NOTE: Board uses the full 4147 J battery; rebuild with the small
        // budget by overriding platform battery through the simulate path:
        // simulate() uses Board::paper_setup which is fixed at 4147 J, so
        // instead cap items to the analytical n and check energy agreement.
        let expect_iw = model
            .n_max_idle_waiting(Duration::from_millis(40.0), model.item.idle_power_baseline)
            .unwrap();
        let mut capped = cfg.clone();
        capped.workload.max_items = Some(expect_iw);
        let mut arr = periodic(40.0);
        let r = simulate(&capped, &mut IdleWaiting::baseline(), &mut arr);
        assert_eq!(r.items, expect_iw);
        let predicted = model.e_sum_idle_waiting(
            expect_iw,
            Duration::from_millis(40.0),
            model.item.idle_power_baseline,
        );
        // DES config energy comes from the FSM mechanism (synthetic
        // bitstream), Eq 2 from Table 2 — they agree to ~1e-4 relative.
        let rel = (r.energy_exact.joules() - predicted.joules()).abs() / predicted.joules();
        assert!(rel < 5e-4, "DES vs Eq2 rel err {rel}");
    }

    #[test]
    fn onoff_energy_matches_eq1() {
        let cfg = capped_config(40.0, 500);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &mut OnOff, &mut arr);
        let predicted = model.e_sum_onoff(500);
        // Same FSM-vs-Table-2 tolerance as the Idle-Waiting check.
        let rel = (r.energy_exact.joules() - predicted.joules()).abs() / predicted.joules();
        assert!(rel < 5e-4, "DES vs Eq1 rel err {rel}");
    }

    #[test]
    fn monitor_error_is_small_but_nonzero() {
        let cfg = capped_config(40.0, 2_000);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &mut IdleWaiting::baseline(), &mut arr);
        assert!(r.monitor_rel_error < 0.03, "err={}", r.monitor_rel_error);
        assert!(r.monitor_rel_error > 0.0);
    }

    #[test]
    fn oracle_powers_off_on_long_gaps_only() {
        let cfg = capped_config(40.0, 50);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);

        // 40 ms gaps < 89.21 ms crossover → behaves like idle-waiting
        let mut oracle = Oracle::from_model(&model, PowerSaving::BASELINE);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &mut oracle, &mut arr);
        assert_eq!(r.configurations, 1);
        assert_eq!(r.decisions.idled, 49);

        // 200 ms gaps > crossover → behaves like on-off
        let cfg = capped_config(200.0, 50);
        let mut oracle = Oracle::from_model(&model, PowerSaving::BASELINE);
        let mut arr = periodic(200.0);
        let r = simulate(&cfg, &mut oracle, &mut arr);
        assert_eq!(r.configurations, 50);
        assert_eq!(r.decisions.powered_off, 49);
    }

    #[test]
    fn oracle_beats_both_on_bimodal_poisson() {
        // Irregular arrivals around the crossover: the oracle should do at
        // least as well (≤ energy) as each fixed policy per item.
        let cfg = capped_config(89.0, 2_000);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let run = |policy: &mut dyn crate::strategies::strategy::Policy| {
            let mut arr = Poisson::new(
                Duration::from_millis(89.0),
                Duration::from_millis(0.05),
                1234,
            );
            simulate(&cfg, policy, &mut arr).energy_exact.joules() / 2000.0
        };
        let e_oracle = run(&mut Oracle::from_model(&model, PowerSaving::BASELINE));
        let e_onoff = run(&mut OnOff);
        let e_iw = run(&mut IdleWaiting::baseline());
        assert!(
            e_oracle <= e_onoff * 1.001 && e_oracle <= e_iw * 1.001,
            "oracle {e_oracle} vs onoff {e_onoff} / iw {e_iw}"
        );
    }

    #[test]
    fn timeout_expiry_counted_on_long_periodic_gaps() {
        let cfg = capped_config(300.0, 20);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let mut policy = Timeout::from_model(&model, PowerSaving::BASELINE);
        let mut arr = periodic(300.0);
        let r = simulate(&cfg, &mut policy, &mut arr);
        // 300 ms gaps: idle window 299.96 ms > τ ≈ 89.17 ms → every gap
        // expires the timer and cuts power
        assert_eq!(r.decisions.timeouts_expired, 19);
        assert_eq!(r.decisions.powered_off, 19);
        assert_eq!(r.configurations, 20);
    }

    #[test]
    fn late_requests_counted_for_tight_poisson() {
        let cfg = capped_config(40.0, 500);
        // mean 1 ms gaps against a 36 ms On-Off item latency → many lates
        let mut arr = Poisson::new(Duration::from_millis(1.0), Duration::from_millis(0.05), 9);
        let r = simulate(&cfg, &mut OnOff, &mut arr);
        assert!(r.late_requests > 0);
        // queueing shows up in the served latency, not just the counter
        assert!(r.mean_latency > cfg.item.latency_with_config());
    }

    #[test]
    fn build_and_simulate_all_kinds() {
        let cfg = capped_config(40.0, 10);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        for spec in PolicySpec::ALL {
            let mut policy = build(spec, &model);
            let mut arr = periodic(40.0);
            let r = simulate(&cfg, policy.as_mut(), &mut arr);
            assert_eq!(r.items, 10, "{spec}");
            assert_eq!(r.decisions.idled + r.decisions.powered_off, 9, "{spec}");
        }
    }

    #[test]
    fn mean_latency_is_the_item_latency_when_never_late() {
        let cfg = capped_config(40.0, 100);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &mut IdleWaiting::baseline(), &mut arr);
        // every request is served immediately: latency = active phases
        assert!((r.mean_latency.millis() - 0.0401).abs() < 1e-9, "{}", r.mean_latency.millis());
        // on-off additionally pays the reconfiguration on every request
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &mut OnOff, &mut arr);
        assert!((r.mean_latency.millis() - 36.1851).abs() < 0.01, "{}", r.mean_latency.millis());
    }

    #[test]
    fn engine_clock_tracks_arrivals() {
        // 10 items at 40 ms: the event chain IS the workload, so the
        // engine's final clock must be the 10th request's arrival time,
        // nine inter-arrival gaps in (9 × 40 ms = 360 ms).
        let cfg = capped_config(40.0, 10);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &mut IdleWaiting::baseline(), &mut arr);
        assert_eq!(r.items, 10);
        assert!((r.sim_time.millis() - 360.0).abs() < 1e-9, "{}", r.sim_time.millis());
        // Eq 4 lifetime is derived from items, not the clock
        assert!((r.lifetime.millis() - 400.0).abs() < 1e-9);
    }

    #[test]
    fn reused_worker_reports_are_bit_identical_to_fresh_runs() {
        let cfg = capped_config(40.0, 200);
        let mut worker = SimWorker::new(&cfg);
        // run an unrelated policy first to dirty every ledger
        let mut arr = Poisson::new(Duration::from_millis(5.0), Duration::from_millis(0.05), 3);
        let _ = worker.run(&cfg, &mut OnOff, &mut arr);
        for seed in [1u64, 9, 42] {
            let poisson =
                || Poisson::new(Duration::from_millis(90.0), Duration::from_millis(0.05), seed);
            let mut arr = poisson();
            let reused = worker.run(&cfg, &mut IdleWaiting::baseline(), &mut arr);
            let mut arr = poisson();
            let fresh = simulate(&cfg, &mut IdleWaiting::baseline(), &mut arr);
            assert_reports_identical(&reused, &fresh, &format!("seed {seed}"));
        }
    }

    #[test]
    fn fast_path_matches_golden_reference() {
        let cfg = capped_config(40.0, 300);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        for spec in PolicySpec::ALL {
            let poisson =
                || Poisson::new(Duration::from_millis(80.0), Duration::from_millis(0.05), 7);
            let mut policy = build(spec, &model);
            let mut arr = poisson();
            let fast = simulate(&cfg, policy.as_mut(), &mut arr);
            let mut policy = build(spec, &model);
            let mut arr = poisson();
            let golden = simulate_golden(&cfg, policy.as_mut(), &mut arr);
            assert_reports_identical(&fast, &golden, spec.name());
        }
    }

    #[test]
    fn prefix_sim_resume_equals_from_scratch() {
        // heavy-tailed gaps so policies actually switch behaviour
        let gaps: Arc<[Duration]> = (0..96)
            .map(|i| Duration::from_millis(if i % 7 == 6 { 650.0 } else { 25.0 }))
            .collect::<Vec<_>>()
            .into();
        let cfg = paper_default();
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        for spec in [
            PolicySpec::OnOff,
            PolicySpec::Timeout,
            PolicySpec::WindowedQuantile,
            PolicySpec::EmaPredictor,
        ] {
            let mut prefix_sim = PrefixSim::new(&cfg, build(spec, &model), gaps.clone());
            for prefix in [12usize, 24, 48, 96] {
                let resumed = prefix_sim.advance_to(prefix);
                assert_eq!(prefix_sim.consumed(), prefix);
                // from scratch: a fresh capped run over the same prefix
                let mut capped = cfg.clone();
                capped.workload.max_items = Some(prefix as u64 + 1);
                let mut arr = crate::coordinator::requests::TraceReplay::new(
                    gaps[..prefix].to_vec(),
                );
                let mut policy = build(spec, &model);
                let scratch = simulate(&capped, policy.as_mut(), &mut arr);
                assert_reports_identical(&resumed, &scratch, &format!("{spec} prefix {prefix}"));
            }
            // repeated prefix re-reports without advancing
            let again = prefix_sim.advance_to(96);
            assert_eq!(again.items, 97);
        }
    }

    #[test]
    fn batched_trace_run_matches_the_scalar_path_across_chunks() {
        // more gaps than one GAP_BATCH chunk, heavy-tailed so policies
        // switch behaviour mid-chunk and across the chunk boundary
        let gaps: Vec<Duration> = (0..(GAP_BATCH + 40))
            .map(|i| Duration::from_millis(if i % 9 == 8 { 700.0 } else { 30.0 }))
            .collect();
        let cfg = paper_default();
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let mut capped = cfg.clone();
        capped.workload.max_items = Some(gaps.len() as u64 + 1);
        for spec in PolicySpec::ALL {
            let mut policy = build(spec, &model);
            let batched = simulate_batch(&capped, policy.as_mut(), &gaps);
            let mut policy = build(spec, &model);
            let mut arr = crate::coordinator::requests::TraceReplay::new(gaps.clone());
            let scalar = simulate(&capped, policy.as_mut(), &mut arr);
            assert_reports_identical(&batched, &scalar, &format!("{spec} batched vs scalar"));
        }
    }

    #[test]
    fn batched_golden_worker_matches_the_scalar_golden_path() {
        let gaps: Vec<Duration> = (0..60)
            .map(|i| Duration::from_millis(if i % 5 == 4 { 400.0 } else { 45.0 }))
            .collect();
        let cfg = paper_default();
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let mut capped = cfg.clone();
        capped.workload.max_items = Some(gaps.len() as u64 + 1);
        let label = format!("trace({} gaps)", gaps.len());
        let mean = crate::coordinator::requests::trace_mean(&gaps);
        for spec in [PolicySpec::OnOff, PolicySpec::Timeout, PolicySpec::Oracle] {
            let mut policy = build(spec, &model);
            let batched = SimWorker::golden(&capped).run_batch(
                &capped,
                policy.as_mut(),
                &gaps,
                &label,
                mean,
            );
            let mut policy = build(spec, &model);
            let mut arr = crate::coordinator::requests::TraceReplay::new(gaps.clone());
            let golden = simulate_golden(&capped, policy.as_mut(), &mut arr);
            assert_reports_identical(&batched, &golden, &format!("{spec} batched-golden"));
        }
    }

    #[test]
    fn batched_budget_death_matches_the_scalar_path() {
        // enormous idle gaps burn the 4147 J budget within a few gaps, so
        // the run dies mid-batch; death point, clock and ledgers must land
        // exactly where the event loop dies
        let gaps = vec![Duration::from_secs(5_000.0); 6];
        let cfg = paper_default();
        let mut capped = cfg.clone();
        capped.workload.max_items = Some(gaps.len() as u64 + 1);
        let mut iw = IdleWaiting::baseline();
        let batched = simulate_batch(&capped, &mut iw, &gaps);
        let mut iw = IdleWaiting::baseline();
        let mut arr = crate::coordinator::requests::TraceReplay::new(gaps.clone());
        let scalar = simulate(&capped, &mut iw, &mut arr);
        assert!(batched.items < gaps.len() as u64 + 1, "run must die early");
        assert_reports_identical(&batched, &scalar, "budget death");
    }

    #[test]
    fn batched_zero_item_cap_executes_nothing() {
        let cfg = capped_config(40.0, 0);
        let r = simulate_batch(&cfg, &mut IdleWaiting::baseline(), &[Duration::from_millis(40.0)]);
        assert_eq!(r.items, 0);
        assert_eq!(r.configurations, 0);
        assert_eq!(r.energy_exact, Energy::ZERO);
        assert_eq!(r.sim_time, Duration::ZERO);
    }

    #[test]
    fn engine_clock_follows_irregular_gaps() {
        // With Poisson arrivals the engine clock must equal the sum of
        // the n−1 drawn gaps — an engine-scheduling property a serial
        // loop could not fake.
        let cfg = capped_config(40.0, 50);
        let poisson = || Poisson::new(Duration::from_millis(40.0), Duration::from_millis(0.05), 3);
        let mut arr = poisson();
        let r = simulate(&cfg, &mut IdleWaiting::baseline(), &mut arr);
        let mut reference = poisson();
        let expected: f64 = (0..49).map(|_| reference.next_gap().millis()).sum();
        // engine time is nanosecond-quantized per gap
        let got = r.sim_time.millis();
        assert!((got - expected).abs() < 1e-3, "{got} vs {expected}");
    }
}
