//! The strategy-level discrete-event simulation.
//!
//! Replays the duty-cycle workload (Fig 1) against the [`Board`] under a
//! [`Strategy`]'s gap policy until the 4147 J battery budget is exhausted
//! (or an optional item cap is hit), reproducing the quantity the paper's
//! Python simulator computes: the maximum number of executable workload
//! items and the system lifetime. The PAC1934 monitor rides along, so the
//! run also yields the "hardware-measured" energy whose gap vs the exact
//! integral mirrors the paper's §5.3 validation.

use crate::config::loader::SimConfig;
use crate::config::schema::WorkloadItemSpec;
use crate::coordinator::requests::ArrivalProcess;
use crate::device::board::Board;
use crate::device::fpga::FpgaState;
use crate::strategies::strategy::{GapAction, Strategy};
use crate::util::units::{Duration, Energy, Power};

/// Outcome of one simulated lifetime.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub strategy: String,
    pub arrival: String,
    /// Workload items fully executed within the budget (the paper's n_max).
    pub items: u64,
    /// Eq 4 lifetime: items × mean period for periodic workloads; for
    /// irregular arrivals, the elapsed simulated time at exhaustion.
    pub lifetime: Duration,
    /// Exact FPGA-side energy drawn from the budget.
    pub energy_exact: Energy,
    /// Energy as the PAC1934 monitor measured it.
    pub energy_measured: Energy,
    /// Relative instrument error (sampled vs exact).
    pub monitor_rel_error: f64,
    /// Number of FPGA configurations performed.
    pub configurations: u64,
    /// Number of power-on transients paid.
    pub power_ons: u64,
    /// Requests that arrived before the previous item finished (only
    /// possible with irregular arrivals) and were served late.
    pub late_requests: u64,
}

/// Simulate `config`'s workload under `strategy` with `arrivals`.
///
/// Mechanics per request:
/// 1. If the FPGA is unconfigured (first request, or the previous gap
///    powered it off), pay power-on transient + full configuration.
/// 2. Run the three active phases (Table 2).
/// 3. Apply the strategy's gap action until the next arrival.
///
/// Stops (without counting the in-flight item) as soon as any energy draw
/// would exceed the remaining budget — Eq 3's `≤ E_Budget` criterion.
pub fn simulate(
    config: &SimConfig,
    strategy: &dyn Strategy,
    arrivals: &mut dyn ArrivalProcess,
) -> SimReport {
    let mut board = Board::paper_setup(config.platform.fpga, config.platform.spi.compressed);
    let item = &config.item;
    let phases = item_phases(item);
    let max_items = config.workload.max_items.unwrap_or(u64::MAX);

    let mut items = 0u64;
    let mut late_requests = 0u64;
    // Configuration duration from the FSM (equals Table 2's 36.145 ms at
    // the optimal SPI setting, but follows the mechanism when swept).
    let mut config_time = item.configuration.time;

    'run: while items < max_items {
        // 1. ensure configured
        if !matches!(board.fpga.state, FpgaState::Idle(_) | FpgaState::Busy) {
            match board.power_on_and_configure("lstm", config.platform.spi) {
                Ok(t) => config_time = t,
                Err(_) => break 'run,
            }
        }
        // 2. active phases
        if board.run_item_phases(&phases).is_err() {
            break 'run;
        }
        items += 1;
        if items >= max_items {
            // Eq 2 counts n−1 idle gaps: no gap after the final item.
            break 'run;
        }

        // 3. gap until next arrival
        let gap = arrivals.next_gap();
        let busy = if strategy.gap_action(gap) == GapAction::PowerOff {
            config_time + item.latency_without_config()
        } else {
            item.latency_without_config()
        };
        let idle_time = if gap.secs() > busy.secs() {
            gap - busy
        } else {
            late_requests += 1;
            Duration::ZERO
        };
        match strategy.gap_action(gap) {
            GapAction::PowerOff => {
                if board.off_for(idle_time, false).is_err() {
                    break 'run;
                }
            }
            GapAction::Idle(saving) => {
                if idle_time.secs() > 0.0 {
                    if board.idle_for(saving, idle_time).is_err() {
                        break 'run;
                    }
                } else if board.fpga.enter_idle(saving).is_err() {
                    break 'run;
                }
            }
        }
    }

    SimReport {
        strategy: strategy.label(),
        arrival: arrivals.label(),
        items,
        lifetime: arrivals.mean() * items as f64, // Eq 4
        energy_exact: board.fpga_energy,
        energy_measured: board.monitor.measured(),
        monitor_rel_error: board.monitor.rel_error(),
        configurations: board.fpga.configurations,
        power_ons: board.fpga.power_ons,
        late_requests,
    }
}

/// Table 2 active phases as (power, duration) tuples.
pub fn item_phases(item: &WorkloadItemSpec) -> [(Power, Duration); 3] {
    [
        (item.data_loading.power, item.data_loading.time),
        (item.inference.power, item.inference.time),
        (item.data_offloading.power, item.data_offloading.time),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::config::schema::StrategyKind;
    use crate::coordinator::requests::{Periodic, Poisson};
    use crate::energy::analytical::Analytical;
    use crate::strategies::strategy::{build, Adaptive, IdleWaiting, OnOff};
    use crate::device::rails::PowerSaving;

    fn capped_config(t_req_ms: f64, max_items: u64) -> SimConfig {
        let mut cfg = paper_default();
        cfg.workload.arrival = crate::config::schema::ArrivalSpec::Periodic {
            period: Duration::from_millis(t_req_ms),
        };
        cfg.workload.max_items = Some(max_items);
        cfg
    }

    fn periodic(ms: f64) -> Periodic {
        Periodic {
            period: Duration::from_millis(ms),
        }
    }

    #[test]
    fn onoff_pays_configuration_per_item() {
        let cfg = capped_config(40.0, 100);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &OnOff, &mut arr);
        assert_eq!(r.items, 100);
        assert_eq!(r.configurations, 100);
        assert_eq!(r.power_ons, 100);
        // per-item energy ≈ 11.983 mJ
        let per_item = r.energy_exact.millijoules() / 100.0;
        assert!((per_item - 11.983).abs() < 0.01, "{per_item}");
    }

    #[test]
    fn idle_waiting_configures_once() {
        let cfg = capped_config(40.0, 100);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &IdleWaiting::baseline(), &mut arr);
        assert_eq!(r.items, 100);
        assert_eq!(r.configurations, 1);
        assert_eq!(r.power_ons, 1);
    }

    #[test]
    fn des_matches_analytical_nmax_small_budget() {
        // shrink the budget so the full run is fast, then compare DES
        // item count against Eq 3 exactly
        let mut cfg = paper_default();
        cfg.workload.energy_budget = Energy::from_joules(5.0);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);

        // NOTE: Board uses the full 4147 J battery; rebuild with the small
        // budget by overriding platform battery through the simulate path:
        // simulate() uses Board::paper_setup which is fixed at 4147 J, so
        // instead cap items to the analytical n and check energy agreement.
        let expect_iw = model
            .n_max_idle_waiting(Duration::from_millis(40.0), model.item.idle_power_baseline)
            .unwrap();
        let mut capped = cfg.clone();
        capped.workload.max_items = Some(expect_iw);
        let mut arr = periodic(40.0);
        let r = simulate(&capped, &IdleWaiting::baseline(), &mut arr);
        assert_eq!(r.items, expect_iw);
        let predicted = model.e_sum_idle_waiting(
            expect_iw,
            Duration::from_millis(40.0),
            model.item.idle_power_baseline,
        );
        // DES config energy comes from the FSM mechanism (synthetic
        // bitstream), Eq 2 from Table 2 — they agree to ~1e-4 relative.
        let rel = (r.energy_exact.joules() - predicted.joules()).abs() / predicted.joules();
        assert!(rel < 5e-4, "DES vs Eq2 rel err {rel}");
    }

    #[test]
    fn onoff_energy_matches_eq1() {
        let cfg = capped_config(40.0, 500);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &OnOff, &mut arr);
        let predicted = model.e_sum_onoff(500);
        // Same FSM-vs-Table-2 tolerance as the Idle-Waiting check.
        let rel = (r.energy_exact.joules() - predicted.joules()).abs() / predicted.joules();
        assert!(rel < 5e-4, "DES vs Eq1 rel err {rel}");
    }

    #[test]
    fn monitor_error_is_small_but_nonzero() {
        let cfg = capped_config(40.0, 2_000);
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &IdleWaiting::baseline(), &mut arr);
        assert!(r.monitor_rel_error < 0.03, "err={}", r.monitor_rel_error);
        assert!(r.monitor_rel_error > 0.0);
    }

    #[test]
    fn adaptive_powers_off_on_long_gaps_only() {
        let cfg = capped_config(40.0, 50);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let adaptive = Adaptive::from_model(&model, PowerSaving::BASELINE);

        // 40 ms gaps < 89.21 ms crossover → behaves like idle-waiting
        let mut arr = periodic(40.0);
        let r = simulate(&cfg, &adaptive, &mut arr);
        assert_eq!(r.configurations, 1);

        // 200 ms gaps > crossover → behaves like on-off
        let cfg = capped_config(200.0, 50);
        let mut arr = periodic(200.0);
        let r = simulate(&cfg, &adaptive, &mut arr);
        assert_eq!(r.configurations, 50);
    }

    #[test]
    fn adaptive_beats_both_on_bimodal_poisson() {
        // Irregular arrivals around the crossover: adaptive should do at
        // least as well (≤ energy) as each fixed strategy per item.
        let cfg = capped_config(89.0, 2_000);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let adaptive = Adaptive::from_model(&model, PowerSaving::BASELINE);
        let run = |s: &dyn Strategy| {
            let mut arr = Poisson::new(
                Duration::from_millis(89.0),
                Duration::from_millis(0.05),
                1234,
            );
            simulate(&cfg, s, &mut arr).energy_exact.joules() / 2000.0
        };
        let e_adaptive = run(&adaptive);
        let e_onoff = run(&OnOff);
        let e_iw = run(&IdleWaiting::baseline());
        assert!(
            e_adaptive <= e_onoff * 1.001 && e_adaptive <= e_iw * 1.001,
            "adaptive {e_adaptive} vs onoff {e_onoff} / iw {e_iw}"
        );
    }

    #[test]
    fn late_requests_counted_for_tight_poisson() {
        let cfg = capped_config(40.0, 500);
        // mean 1 ms gaps against a 36 ms On-Off item latency → many lates
        let mut arr = Poisson::new(Duration::from_millis(1.0), Duration::from_millis(0.05), 9);
        let r = simulate(&cfg, &OnOff, &mut arr);
        assert!(r.late_requests > 0);
    }

    #[test]
    fn build_and_simulate_all_kinds() {
        let cfg = capped_config(40.0, 10);
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        for kind in StrategyKind::ALL {
            let s = build(kind, &model);
            let mut arr = periodic(40.0);
            let r = simulate(&cfg, s.as_ref(), &mut arr);
            assert_eq!(r.items, 10, "{kind}");
        }
    }
}
