//! The gap-policy subsystem (paper §4.2 + §7 future work) and the
//! policy-level discrete-event simulation that evaluates policies against
//! the energy budget.
//!
//! `strategy` defines [`Policy`]/[`GapPlan`] (stateful, observation-driven
//! gap decisions — the clairvoyant upper bound lives behind the
//! `OraclePolicy` escape hatch); `replay` holds [`ReplayCore`], the
//! phase-replay / gap-plan execution core shared by this module's
//! lifetime simulation, the multi-accelerator simulation in
//! `coordinator::multi_sim` and the serving loop in
//! `coordinator::server` — one energy-accounting code path for every
//! event-driven runtime.

pub mod learned;
pub mod replay;
pub mod simulate;
pub mod strategy;

pub use learned::{BanditPolicy, BayesMixture};
pub use replay::{
    item_phases, BatchRun, DeviceCosts, GapBatch, GapCostTable, GapExecution, ReplayCore, SlotId,
};
pub use simulate::{
    simulate, simulate_batch, simulate_golden, GapDecisions, PrefixSim, SimReport, SimWorker,
    GAP_BATCH,
};
pub use strategy::{
    build, decide, decide_batch, EmaPredictor, GapContext, GapPlan, IdleWaiting, OnOff, Oracle,
    OraclePolicy, Policy, Timeout,
};
