//! Power-management strategies (paper §4.2) and the strategy-level
//! discrete-event simulation that evaluates them against the budget.
//!
//! `replay` holds the phase-replay / gap-policy core shared by this
//! module's lifetime simulation and the multi-accelerator simulation in
//! `coordinator::multi_sim` — one energy-accounting code path for every
//! event-driven runtime.

pub mod replay;
pub mod simulate;
pub mod strategy;

pub use replay::{item_phases, ReplayCore};
pub use simulate::{simulate, SimReport};
pub use strategy::{build, Adaptive, GapAction, IdleWaiting, OnOff, Strategy};
