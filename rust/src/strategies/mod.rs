//! Power-management strategies (paper §4.2) and the strategy-level
//! discrete-event simulation that evaluates them against the budget.

pub mod simulate;
pub mod strategy;

pub use simulate::{simulate, SimReport};
pub use strategy::{build, Adaptive, GapAction, IdleWaiting, OnOff, Strategy};
