//! The shared phase-replay / gap-plan execution core.
//!
//! Every event-driven runtime — the single-accelerator lifetime run
//! ([`crate::strategies::simulate`]), the multi-accelerator scheduler run
//! ([`crate::coordinator::multi_sim`]) and the PJRT serving loop
//! ([`crate::coordinator::server`]) — drives a [`Board`] through the same
//! primitive moves: ensure the fabric is configured, replay the Table 2
//! active phases, and spend the inter-request gap per the policy's
//! [`GapPlan`]. [`ReplayCore`] owns that sequence so the runtimes cannot
//! drift apart on energy accounting; in particular [`execute_plan`] is
//! the *only* place the three plan shapes (idle, power-off, idle-then-off)
//! are translated into board time/energy.
//!
//! [`execute_plan`]: ReplayCore::execute_plan

use crate::config::loader::SimConfig;
use crate::config::schema::SpiConfig;
use crate::device::board::{Board, BoardError};
use crate::device::fpga::FpgaState;
use crate::device::rails::PowerSaving;
use crate::strategies::strategy::GapPlan;
use crate::util::units::{Duration, Power};

/// What actually happened while executing a [`GapPlan`] across one gap —
/// the feedback the runtimes use for decision counters and late-request
/// accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GapExecution {
    /// The fabric ended the gap powered off (configuration lost).
    pub powered_off: bool,
    /// An `IdleThenOff` timer expired mid-gap.
    pub timeout_expired: bool,
    /// The next request lands inside the busy window (served late).
    pub late: bool,
}

/// A board plus the workload-item phase profile, exposing the simulation
/// primitives every event-driven runtime shares.
#[derive(Debug, Clone)]
pub struct ReplayCore {
    /// The simulated platform (FPGA, flash, battery, monitor).
    pub board: Board,
    /// Table 2 active phases as (power, duration) tuples.
    pub phases: [(Power, Duration); 3],
    /// Configuration-port parameters used for reconfigurations.
    pub spi: SpiConfig,
}

impl ReplayCore {
    /// Build the paper platform for `config` with the LSTM image in flash.
    pub fn from_config(config: &SimConfig) -> ReplayCore {
        ReplayCore {
            board: Board::paper_setup(config.platform.fpga, config.platform.spi.compressed),
            phases: item_phases(&config.item),
            spi: config.platform.spi,
        }
    }

    /// True when the fabric holds a live configuration (no preamble due).
    pub fn is_ready(&self) -> bool {
        matches!(self.board.fpga.state, FpgaState::Idle(_) | FpgaState::Busy)
    }

    /// Power-on + configure `slot` from flash. Returns the configuration
    /// duration (the mechanism-derived T_config).
    pub fn configure(&mut self, slot: &str) -> Result<Duration, BoardError> {
        self.board.power_on_and_configure(slot, self.spi)
    }

    /// Switch images: power-cycle (losing the SRAM configuration) and load
    /// `slot` — the multi-accelerator reconfiguration path.
    pub fn power_cycle_configure(&mut self, slot: &str) -> Result<Duration, BoardError> {
        if self.board.fpga.is_configured() {
            self.board.fpga.power_off();
        }
        self.board.power_on_and_configure(slot, self.spi)
    }

    /// Cut the rails without advancing time (a policy's mid-gap decision;
    /// the elapsed off-time is accounted by the caller's next `elapse`).
    pub fn power_off(&mut self) {
        self.board.fpga.power_off();
    }

    /// Replay the three active phases; returns their total latency.
    pub fn run_phases(&mut self) -> Result<Duration, BoardError> {
        self.board.run_item_phases(&self.phases)
    }

    /// Execute a policy's [`GapPlan`] across one *inter-arrival* gap
    /// `gap` (request arrival → next request arrival; T_req on periodic
    /// workloads). The serving busy windows are carved out of it here —
    /// `item_latency` always, plus `config_time` when the plan cuts
    /// power — exactly as the paper's equations do
    /// (`E_Idle = P_idle · (T_req − T_latency)`). Callers must therefore
    /// pass the raw arrival-to-arrival gap, NOT a remaining-idle window.
    ///
    /// A zero idle window still switches the rails into the requested
    /// power-saving mode, so the next gap starts from the right state.
    pub fn execute_plan(
        &mut self,
        plan: GapPlan,
        gap: Duration,
        config_time: Duration,
        item_latency: Duration,
    ) -> Result<GapExecution, BoardError> {
        match plan {
            GapPlan::Idle(saving) => {
                if gap.secs() > item_latency.secs() {
                    self.board.idle_for(saving, gap - item_latency)?;
                    Ok(GapExecution::default())
                } else {
                    self.board.fpga.enter_idle(saving).map_err(BoardError::from)?;
                    Ok(GapExecution {
                        late: true,
                        ..Default::default()
                    })
                }
            }
            GapPlan::PowerOff => {
                let busy = config_time + item_latency;
                let (off, late) = if gap.secs() > busy.secs() {
                    (gap - busy, false)
                } else {
                    (Duration::ZERO, true)
                };
                self.board.off_for(off, false)?;
                Ok(GapExecution {
                    powered_off: true,
                    timeout_expired: false,
                    late,
                })
            }
            GapPlan::IdleThenOff { saving, timeout } => {
                let idle_window = gap - item_latency;
                if idle_window.secs() <= timeout.secs() {
                    // the next request (or its busy window) preempts the timer
                    if idle_window.secs() > 0.0 {
                        self.board.idle_for(saving, idle_window)?;
                        Ok(GapExecution::default())
                    } else {
                        self.board.fpga.enter_idle(saving).map_err(BoardError::from)?;
                        Ok(GapExecution {
                            late: true,
                            ..Default::default()
                        })
                    }
                } else {
                    // rent until τ, then buy: power off for the remainder
                    self.board.idle_for(saving, timeout)?;
                    let busy = timeout + config_time + item_latency;
                    let (off, late) = if gap.secs() > busy.secs() {
                        (gap - busy, false)
                    } else {
                        (Duration::ZERO, true)
                    };
                    self.board.off_for(off, false)?;
                    Ok(GapExecution {
                        powered_off: true,
                        timeout_expired: true,
                        late,
                    })
                }
            }
        }
    }

    /// Advance the energy ledger across `dur` of inactivity: idle at
    /// `saving` while configured, otherwise the (paper-model) off state.
    pub fn elapse(&mut self, saving: PowerSaving, dur: Duration) -> Result<(), BoardError> {
        if self.board.fpga.is_configured() {
            self.board.idle_for(saving, dur)
        } else {
            self.board.off_for(dur, false)
        }
    }
}

/// Table 2 active phases as (power, duration) tuples.
pub fn item_phases(item: &crate::config::schema::WorkloadItemSpec) -> [(Power, Duration); 3] {
    [
        (item.data_loading.power, item.data_loading.time),
        (item.inference.power, item.inference.time),
        (item.data_offloading.power, item.data_offloading.time),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn ready_core() -> (ReplayCore, Duration, Duration) {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        let config_time = core.configure("lstm").unwrap();
        core.run_phases().unwrap();
        (core, config_time, cfg.item.latency_without_config())
    }

    #[test]
    fn configure_then_phases_costs_the_calibrated_energy() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        assert!(!core.is_ready());
        let t = core.configure("lstm").unwrap();
        assert!((t.millis() - 36.145).abs() < 0.01);
        assert!(core.is_ready());
        core.run_phases().unwrap();
        // 11.85 (config) + 0.1244 (inrush) + 0.0065 (phases) ≈ 11.98 mJ
        assert!((core.board.fpga_energy.millijoules() - 11.983).abs() < 0.01);
    }

    #[test]
    fn zero_idle_window_still_switches_mode_and_reports_late() {
        let (mut core, config_time, latency) = ready_core();
        let before = core.board.fpga_energy;
        // gap shorter than the item latency: nothing to idle through
        let exec = core
            .execute_plan(
                GapPlan::Idle(PowerSaving::M12),
                Duration::from_micros(1.0),
                config_time,
                latency,
            )
            .unwrap();
        assert!(exec.late && !exec.powered_off);
        assert_eq!(core.board.fpga_energy, before);
        assert_eq!(core.board.fpga.state, FpgaState::Idle(PowerSaving::M12));
    }

    #[test]
    fn idle_plan_charges_table3_power_over_the_idle_window() {
        let (mut core, config_time, latency) = ready_core();
        let before = core.board.fpga_energy;
        let exec = core
            .execute_plan(
                GapPlan::Idle(PowerSaving::BASELINE),
                Duration::from_millis(40.0),
                config_time,
                latency,
            )
            .unwrap();
        assert_eq!(exec, GapExecution::default());
        // 134.3 mW × (40 − 0.0401) ms
        let drawn = (core.board.fpga_energy - before).millijoules();
        assert!((drawn - 0.1343 * (40.0 - 0.0401)).abs() < 1e-6, "{drawn}");
    }

    #[test]
    fn power_off_plan_loses_configuration_and_draws_nothing() {
        let (mut core, config_time, latency) = ready_core();
        let before = core.board.fpga_energy;
        let exec = core
            .execute_plan(
                GapPlan::PowerOff,
                Duration::from_millis(200.0),
                config_time,
                latency,
            )
            .unwrap();
        assert!(exec.powered_off && !exec.timeout_expired && !exec.late);
        assert!(!core.is_ready());
        // paper model: the off state draws nothing
        assert_eq!(core.board.fpga_energy, before);
    }

    #[test]
    fn power_off_plan_flags_late_when_gap_fits_no_reconfig() {
        let (mut core, config_time, latency) = ready_core();
        let exec = core
            .execute_plan(
                GapPlan::PowerOff,
                Duration::from_millis(3.8),
                config_time,
                latency,
            )
            .unwrap();
        assert!(exec.powered_off && exec.late);
    }

    #[test]
    fn idle_then_off_expires_and_pays_exactly_tau_of_idle() {
        let (mut core, config_time, latency) = ready_core();
        let before = core.board.fpga_energy;
        let timeout = Duration::from_millis(50.0);
        let exec = core
            .execute_plan(
                GapPlan::IdleThenOff {
                    saving: PowerSaving::BASELINE,
                    timeout,
                },
                Duration::from_millis(400.0),
                config_time,
                latency,
            )
            .unwrap();
        assert!(exec.powered_off && exec.timeout_expired && !exec.late);
        assert!(!core.is_ready());
        // the gap cost is exactly τ at the idle power; the off tail is free
        let drawn = (core.board.fpga_energy - before).millijoules();
        assert!((drawn - 0.1343 * 50.0).abs() < 1e-6, "{drawn}");
    }

    #[test]
    fn idle_then_off_short_gap_is_pure_idle() {
        let (mut core, config_time, latency) = ready_core();
        let before = core.board.fpga_energy;
        let exec = core
            .execute_plan(
                GapPlan::IdleThenOff {
                    saving: PowerSaving::BASELINE,
                    timeout: Duration::from_millis(50.0),
                },
                Duration::from_millis(40.0),
                config_time,
                latency,
            )
            .unwrap();
        assert!(!exec.powered_off && !exec.timeout_expired && !exec.late);
        assert!(core.is_ready());
        // identical to the pure-idle plan on the same gap
        let drawn = (core.board.fpga_energy - before).millijoules();
        assert!((drawn - 0.1343 * (40.0 - 0.0401)).abs() < 1e-6, "{drawn}");
    }

    #[test]
    fn elapse_while_configured_charges_idle_power() {
        let (mut core, _, _) = ready_core();
        let before = core.board.fpga_energy;
        core.elapse(PowerSaving::M12, Duration::from_secs(1.0)).unwrap();
        let drawn = core.board.fpga_energy - before;
        assert!((drawn.millijoules() - 24.0).abs() < 0.1, "{}", drawn.millijoules());
    }

    #[test]
    fn elapse_after_power_off_is_free() {
        let (mut core, _, _) = ready_core();
        core.power_off();
        let e = core.board.fpga_energy;
        core.elapse(PowerSaving::BASELINE, Duration::from_secs(1.0)).unwrap();
        assert_eq!(core.board.fpga_energy, e);
    }

    #[test]
    fn power_cycle_configure_counts_a_new_configuration() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        core.configure("lstm").unwrap();
        core.power_cycle_configure("lstm").unwrap();
        assert_eq!(core.board.fpga.configurations, 2);
        assert_eq!(core.board.fpga.power_ons, 2);
        assert!(core.is_ready());
    }
}
