//! The shared phase-replay / gap-plan execution core.
//!
//! Every event-driven runtime — the single-accelerator lifetime run
//! ([`crate::strategies::simulate`]), the multi-accelerator scheduler run
//! ([`crate::coordinator::multi_sim`]) and the PJRT serving loop
//! ([`crate::coordinator::server`]) — drives a [`Board`] through the same
//! primitive moves: ensure the fabric is configured, replay the Table 2
//! active phases, and spend the inter-request gap per the policy's
//! [`GapPlan`]. [`ReplayCore`] owns that sequence so the runtimes cannot
//! drift apart on energy accounting; in particular [`execute_plan`] is
//! the *only* place the three plan shapes (idle, power-off, idle-then-off)
//! are translated into board time/energy.
//!
//! Since the hot-path kernel work, [`ReplayCore`] carries a
//! [`GapCostTable`]: the idle power of every power-saving level and the
//! inrush/stage costs of every flash slot, precomputed once per core so
//! the per-gap path is pure arithmetic on cached constants. The original
//! `Board`-FSM accounting survives verbatim behind
//! [`ReplayCore::golden_reference`] as the golden path; the fast path is
//! proven bit-identical to it (every `SimReport` field, every energy
//! ledger) by `tests/fastpath_equivalence.rs`.
//!
//! [`execute_plan`]: ReplayCore::execute_plan

use std::sync::Arc;

use crate::config::loader::SimConfig;
use crate::config::schema::SpiConfig;
use crate::device::board::{Board, BoardError};
use crate::device::config_fsm::ConfigProfile;
use crate::device::faults::FaultState;
use crate::device::fpga::FpgaState;
use crate::device::rails::{PowerSaving, RailSet};
use crate::strategies::strategy::GapPlan;
use crate::util::units::{Duration, Energy, Power};

/// Interned handle for a flash slot: index into the core's
/// [`GapCostTable`], resolved once via [`ReplayCore::slot_id`] so the
/// per-item hot path never repeats the `&str` flash lookup.
///
/// The id carries the table *generation* it was interned from:
/// [`ReplayCore::rebuild_table`] renumbers slots (flash order can
/// change when slots are added), so using an id across a rebuild would
/// silently charge another slot's costs — the exact wrong-energy bug
/// class the device layer turns into hard errors. A stale id therefore
/// panics at [`configure_slot`](ReplayCore::configure_slot); re-intern
/// after rebuilding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotId {
    index: usize,
    generation: u64,
}

/// Precomputed per-slot configuration costs.
#[derive(Debug, Clone)]
struct SlotCosts {
    /// Slot name (shared with `Fpga::mark_configured`, so configuring
    /// through the table never allocates).
    name: Arc<str>,
    /// The configuration FSM stages as `(power, duration)`, in execution
    /// order — exactly the values `ConfigProfile::compute` emits.
    stages: [(Power, Duration); 3],
    /// The paper's T_config: the sum of the stage durations.
    total_time: Duration,
}

/// The precomputed gap-cost table: everything `execute_plan` and the
/// configuration preamble need, derived once per core from the same
/// device models the golden path queries per gap. Cached values are the
/// *outputs of the identical computations* (`RailSet::idle_power`,
/// `ConfigProfile::compute`), so arithmetic on them is bit-identical to
/// re-deriving them.
#[derive(Debug, Clone)]
pub struct GapCostTable {
    /// Table 3 idle power per power-saving combination, indexed by
    /// [`saving_index`].
    idle_power: [Power; 4],
    /// Per-slot configuration costs, in flash slot order.
    slots: Vec<SlotCosts>,
    /// Whether the board's SPI setting passed the flash limit check; when
    /// false the fast configure path defers to the golden path so the
    /// caller sees the identical error.
    spi_ok: bool,
    /// Rebuild counter: every [`SlotId`] is stamped with the generation
    /// it was interned from, and a mismatch at configure time is a
    /// programmer error (slots may have been renumbered).
    generation: u64,
}

/// Index of a [`PowerSaving`] combination in the idle-power table.
#[inline]
fn saving_index(saving: PowerSaving) -> usize {
    (saving.method1 as usize) | ((saving.method2 as usize) << 1)
}

impl GapCostTable {
    /// Build the table for `board`'s flash contents at `spi`.
    pub fn build(board: &Board, spi: SpiConfig) -> GapCostTable {
        let mut idle_power = [Power::ZERO; 4];
        for (i, slot) in idle_power.iter_mut().enumerate() {
            *slot = RailSet::idle_power(PowerSaving {
                method1: i & 1 != 0,
                method2: i & 2 != 0,
            });
        }
        let spi_ok = board.flash.check_spi(&spi).is_ok();
        let slots = board
            .flash
            .slots()
            .map(|name| {
                let image = board.flash.image(name).expect("listed slot has an image");
                let profile = ConfigProfile::compute(board.fpga.model, spi, image);
                let stage = |i: usize| (profile.stages[i].power, profile.stages[i].time);
                SlotCosts {
                    name: Arc::from(name),
                    stages: [stage(0), stage(1), stage(2)],
                    total_time: profile.total_time(),
                }
            })
            .collect();
        GapCostTable {
            idle_power,
            slots,
            spi_ok,
            generation: 0,
        }
    }

    /// Cached Table 3 idle power for a power-saving level (the value
    /// `RailSet::idle_power` computes, without rebuilding a rail tree per
    /// gap).
    #[inline]
    pub fn idle_power(&self, saving: PowerSaving) -> Power {
        self.idle_power[saving_index(saving)]
    }

    /// Find a slot's interned id by name, stamped with the current table
    /// generation.
    pub fn slot_id(&self, name: &str) -> Option<SlotId> {
        self.slots
            .iter()
            .position(|s| &*s.name == name)
            .map(|index| SlotId {
                index,
                generation: self.generation,
            })
    }
}

/// What actually happened while executing a [`GapPlan`] across one gap —
/// the feedback the runtimes use for decision counters and late-request
/// accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GapExecution {
    /// The fabric ended the gap powered off (configuration lost).
    pub powered_off: bool,
    /// An `IdleThenOff` timer expired mid-gap.
    pub timeout_expired: bool,
    /// The next request lands inside the busy window (served late).
    pub late: bool,
}

/// Plan-kind tag of one [`GapBatch`] element: idle through the gap.
pub const KIND_IDLE: u8 = 0;
/// Plan-kind tag of one [`GapBatch`] element: cut power immediately.
pub const KIND_OFF: u8 = 1;
/// Plan-kind tag of one [`GapBatch`] element: idle until τ, then cut.
pub const KIND_IDLE_THEN_OFF: u8 = 2;

/// A batch of planned gaps in structure-of-arrays layout: gap lengths,
/// plan kinds, power-saving combo indices and timeout cutoffs as
/// parallel flat arrays. This is the input format of
/// [`ReplayCore::execute_batch`] — planning fills it once per chunk
/// (`Policy::plan_gaps` / `decide_batch`), and the kernel then streams
/// the Table-3 arithmetic over the arrays instead of re-matching a
/// `GapPlan` enum per gap.
///
/// Uniform-plan policies (On-Off, Idle-Waiting, Timeout) fill it with
/// [`push_uniform`](GapBatch::push_uniform): three `resize` fills plus
/// one slice copy, which the compiler can vectorize.
#[derive(Debug, Clone, Default)]
pub struct GapBatch {
    /// Gap lengths, arrival to arrival.
    gaps: Vec<Duration>,
    /// Plan kind per gap (`KIND_IDLE` / `KIND_OFF` / `KIND_IDLE_THEN_OFF`).
    kinds: Vec<u8>,
    /// Power-saving combo index per gap ([`saving_index`] encoding).
    savings: Vec<u8>,
    /// `IdleThenOff` cutoff per gap (`Duration::ZERO` for other kinds).
    timeouts: Vec<Duration>,
}

impl GapBatch {
    /// Number of planned gaps in the batch.
    pub fn len(&self) -> usize {
        self.gaps.len()
    }

    /// True when the batch holds no gaps.
    pub fn is_empty(&self) -> bool {
        self.gaps.is_empty()
    }

    /// Drop every element, keeping the backing allocations.
    pub fn clear(&mut self) {
        self.gaps.clear();
        self.kinds.clear();
        self.savings.clear();
        self.timeouts.clear();
    }

    /// Append one planned gap.
    pub fn push(&mut self, gap: Duration, plan: GapPlan) {
        let (kind, saving, timeout) = match plan {
            GapPlan::Idle(saving) => (KIND_IDLE, saving_index(saving) as u8, Duration::ZERO),
            GapPlan::PowerOff => (KIND_OFF, 0, Duration::ZERO),
            GapPlan::IdleThenOff { saving, timeout } => {
                (KIND_IDLE_THEN_OFF, saving_index(saving) as u8, timeout)
            }
        };
        self.gaps.push(gap);
        self.kinds.push(kind);
        self.savings.push(saving);
        self.timeouts.push(timeout);
    }

    /// Append every gap of `gaps` under the same `plan` — the batched
    /// fill for plan-constant policies. One memcpy plus three constant
    /// fills; no per-gap branching.
    pub fn push_uniform(&mut self, gaps: &[Duration], plan: GapPlan) {
        let (kind, saving, timeout) = match plan {
            GapPlan::Idle(saving) => (KIND_IDLE, saving_index(saving) as u8, Duration::ZERO),
            GapPlan::PowerOff => (KIND_OFF, 0, Duration::ZERO),
            GapPlan::IdleThenOff { saving, timeout } => {
                (KIND_IDLE_THEN_OFF, saving_index(saving) as u8, timeout)
            }
        };
        self.gaps.extend_from_slice(gaps);
        let n = self.gaps.len();
        self.kinds.resize(n, kind);
        self.savings.resize(n, saving);
        self.timeouts.resize(n, timeout);
    }

    /// Decode element `i` back into its [`GapPlan`] (the golden path
    /// replays batches through `execute_plan_via_board`, which wants the
    /// enum form).
    pub fn plan(&self, i: usize) -> GapPlan {
        let saving = PowerSaving {
            method1: self.savings[i] & 1 != 0,
            method2: self.savings[i] & 2 != 0,
        };
        match self.kinds[i] {
            KIND_IDLE => GapPlan::Idle(saving),
            KIND_OFF => GapPlan::PowerOff,
            _ => GapPlan::IdleThenOff {
                saving,
                timeout: self.timeouts[i],
            },
        }
    }

    /// The gap-length array.
    pub fn gaps(&self) -> &[Duration] {
        &self.gaps
    }

    /// The plan-kind array (0 = idle, 1 = power off, 2 = idle-then-off).
    pub fn kinds(&self) -> &[u8] {
        &self.kinds
    }

    /// The power-saving combo index array ([`GapCostTable`] row per gap).
    pub fn savings(&self) -> &[u8] {
        &self.savings
    }

    /// The timeout-cutoff array (`ZERO` except for idle-then-off gaps).
    pub fn timeouts(&self) -> &[Duration] {
        &self.timeouts
    }
}

/// What one [`ReplayCore::execute_batch`] call did: per-gap executions,
/// per-item reconfiguration flags, and whether the battery died mid-run.
///
/// Invariants after a call over `n` planned gaps:
/// * `execs.len() == reconfigured.len()` and both `== n` when the batch
///   completed (`!exhausted`);
/// * on exhaustion, `execs.len() == reconfigured.len()` means the budget
///   died executing gap `execs.len()` (its follow-up item never served),
///   while `execs.len() == reconfigured.len() + 1` means it died serving
///   the item after gap `execs.len() - 1`.
#[derive(Debug, Clone, Default)]
pub struct BatchRun {
    /// Execution feedback for each gap that completed.
    pub execs: Vec<GapExecution>,
    /// For each item served after its gap: did serving it reconfigure?
    pub reconfigured: Vec<bool>,
    /// The energy budget ran out mid-batch.
    pub exhausted: bool,
    /// Per-served-item extra busy time from fault recovery (partial
    /// attempts, backoffs, brownout reconfigurations), parallel to
    /// `reconfigured`. Left empty on a core without a fault stream, so
    /// the fault-free hot path never touches it.
    pub extra: Vec<Duration>,
    /// The retry policy gave up serving the item after the last executed
    /// gap ([`BoardError::RetriesExhausted`]); the batch stopped there
    /// with `execs.len() == reconfigured.len() + 1` and the fabric off.
    /// Unlike `exhausted` this is recoverable: the driver sheds that one
    /// request and resumes from the next.
    pub shed: bool,
}

impl BatchRun {
    /// Drop the per-gap records, keeping the backing allocations.
    pub fn clear(&mut self) {
        self.execs.clear();
        self.reconfigured.clear();
        self.exhausted = false;
        self.extra.clear();
        self.shed = false;
    }

    /// Gaps whose plan fully executed.
    pub fn gaps_executed(&self) -> usize {
        self.execs.len()
    }

    /// Items served after their gap (≤ [`gaps_executed`](BatchRun::gaps_executed)).
    pub fn items_served(&self) -> usize {
        self.reconfigured.len()
    }
}

/// A board plus the workload-item phase profile, exposing the simulation
/// primitives every event-driven runtime shares.
#[derive(Debug, Clone)]
pub struct ReplayCore {
    /// The simulated platform (FPGA, flash, battery, monitor).
    pub board: Board,
    /// Table 2 active phases as (power, duration) tuples.
    pub phases: [(Power, Duration); 3],
    /// Configuration-port parameters used for reconfigurations. Private
    /// so it cannot drift from the cached table: change it via
    /// [`set_spi`](ReplayCore::set_spi), which rebuilds the table.
    spi: SpiConfig,
    /// Precomputed gap costs (idle powers, per-slot configuration
    /// stages) — the fast path's constants.
    table: GapCostTable,
    /// When true, every operation routes through the original `Board`
    /// FSM accounting (the golden reference path).
    golden: bool,
    /// Seeded fault stream; `None` when the config's [`FaultSpec`] has
    /// every rate at zero, in which case the `*_recovering` wrappers
    /// delegate straight to the plain calls — zero behavioural delta.
    ///
    /// [`FaultSpec`]: crate::config::schema::FaultSpec
    faults: Option<FaultState>,
    /// Cumulative recovery ledger (always zero with faults disabled).
    recovery: RecoveryLedger,
}

/// Cumulative fault-recovery ledger of one [`ReplayCore`], reset with the
/// board. Unlike the per-call [`Recovery`] return values, the ledger also
/// captures attempts whose call ultimately gave up
/// ([`BoardError::RetriesExhausted`]) — their partial energy is already
/// charged to the battery, so a report built from the ledger conserves
/// energy exactly.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RecoveryLedger {
    /// Faulted configuration attempts plus inference brownouts.
    pub retries: u64,
    /// Energy destroyed by faults: partial configuration attempts
    /// (inrush + truncated stage walk) and partial phase runs. Productive
    /// spends (the eventual successful configuration) are not counted —
    /// battery drawn = productive spends + this, exactly.
    pub recovery_energy: Energy,
    /// Sim time lost to faults: partial attempts, backoffs, and forced
    /// recovery reconfigurations after an inference brownout.
    pub recovery_time: Duration,
}

/// What one fault-aware configuration call did: the nominal configuration
/// time of the successful attempt plus the retry ledger accumulated on
/// the way there. With no fault injected this is
/// [`Recovery::clean`]`(config_time)` — all retry fields zero.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Recovery {
    /// T_config of the **successful** attempt (what the busy-window math
    /// keys on, exactly the plain `configure_slot` return value).
    pub config_time: Duration,
    /// Total wall time of the call: failed partial attempts + backoffs +
    /// the successful configuration.
    pub total_time: Duration,
    /// Faulted attempts that preceded the success.
    pub retries: u32,
    /// Energy charged to the battery for the failed partial attempts
    /// (inrush + partial stage walk per attempt) — what Eq 2 would not
    /// have spent on a fault-free device.
    pub recovery_energy: Energy,
    /// Wall time of the failed attempts + backoffs (excludes the
    /// successful configuration itself).
    pub recovery_time: Duration,
}

impl Recovery {
    /// The fault-free outcome: one clean configuration of `config_time`.
    pub fn clean(config_time: Duration) -> Recovery {
        Recovery {
            config_time,
            total_time: config_time,
            retries: 0,
            recovery_energy: Energy::ZERO,
            recovery_time: Duration::ZERO,
        }
    }
}

/// What one fault-aware phase replay did: the total busy latency (equal
/// to the plain `run_phases` latency when no brownout struck) plus the
/// recovery ledger of any mid-inference brownout.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseRecovery {
    /// Total busy time serving the item: partial phases + backoffs +
    /// recovery reconfiguration + the clean re-run (just the three active
    /// phases when no fault struck).
    pub latency: Duration,
    /// The brownout itself plus any faulted configuration attempts during
    /// its recovery.
    pub retries: u32,
    /// Energy destroyed by the fault: the wasted partial phases plus any
    /// partial configuration attempts during recovery (the successful
    /// reconfiguration is productive spend and is not counted).
    pub recovery_energy: Energy,
    /// `latency` minus the final clean phase run.
    pub recovery_time: Duration,
    /// A supply brownout interrupted the phases (at most one per item).
    pub browned_out: bool,
}

impl PhaseRecovery {
    /// The fault-free outcome: one clean phase replay of `latency`.
    pub fn clean(latency: Duration) -> PhaseRecovery {
        PhaseRecovery {
            latency,
            retries: 0,
            recovery_energy: Energy::ZERO,
            recovery_time: Duration::ZERO,
            browned_out: false,
        }
    }
}

impl ReplayCore {
    /// Build the paper platform for `config` with the LSTM image in flash.
    pub fn from_config(config: &SimConfig) -> ReplayCore {
        let board = Board::paper_setup(config.platform.fpga, config.platform.spi.compressed);
        let spi = config.platform.spi;
        let table = GapCostTable::build(&board, spi);
        ReplayCore {
            board,
            phases: item_phases(&config.item),
            spi,
            table,
            golden: false,
            faults: config.faults.enabled().then(|| FaultState::new(&config.faults)),
            recovery: RecoveryLedger::default(),
        }
    }

    /// Build the platform with the fast path disabled: every gap walks
    /// the full `Board` device FSM exactly as before the gap-cost
    /// kernel. This is the golden reference the fast path is proven
    /// bit-identical against.
    pub fn golden_reference(config: &SimConfig) -> ReplayCore {
        ReplayCore {
            golden: true,
            ..ReplayCore::from_config(config)
        }
    }

    /// True when this core routes through the golden `Board` FSM path.
    pub fn is_golden(&self) -> bool {
        self.golden
    }

    /// The precomputed gap-cost table.
    pub fn table(&self) -> &GapCostTable {
        &self.table
    }

    /// Intern a flash slot name for the allocation-free configure path.
    pub fn slot_id(&self, name: &str) -> Option<SlotId> {
        self.table.slot_id(name)
    }

    /// The SPI setting reconfigurations run at.
    pub fn spi(&self) -> SpiConfig {
        self.spi
    }

    /// Change the SPI setting. Rebuilds the cached gap-cost table in the
    /// same step, so the fast path can never charge costs computed at a
    /// previous setting; previously interned [`SlotId`]s become stale
    /// (the rebuild bumps the table generation).
    pub fn set_spi(&mut self, spi: SpiConfig) {
        if self.spi != spi {
            self.spi = spi;
            self.rebuild_table();
        }
    }

    /// Recompute the gap-cost table from the current flash contents and
    /// SPI setting. Call after programming additional slots (e.g. the
    /// multi-accelerator setup) or changing `spi`. Rebuilding bumps the
    /// table generation: previously interned [`SlotId`]s become stale
    /// (slots may be renumbered) and must be re-interned — a stale id
    /// panics at [`configure_slot`](ReplayCore::configure_slot) instead
    /// of silently charging another slot's costs.
    pub fn rebuild_table(&mut self) {
        let generation = self.table.generation + 1;
        self.table = GapCostTable::build(&self.board, self.spi);
        self.table.generation = generation;
    }

    /// Return the platform to its pristine state (full battery, cold
    /// FPGA, zeroed ledgers) and point it at `config`'s workload item and
    /// SPI setting — the sweep-cell reuse path. The flash (and its
    /// shared bitstream images) is kept; a reset core behaves
    /// state-for-state like a fresh [`ReplayCore::from_config`] of the
    /// same platform.
    pub fn reset_for(&mut self, config: &SimConfig) {
        self.phases = item_phases(&config.item);
        // fresh fault stream + ledger per run, exactly as from_config
        self.faults = config.faults.enabled().then(|| FaultState::new(&config.faults));
        self.recovery = RecoveryLedger::default();
        let spi = config.platform.spi;
        if config.platform.fpga != self.board.fpga.model || spi.compressed != self.spi.compressed {
            // different device or on-flash encoding: the stored image
            // itself changes, so rebuild the platform (still cheap — the
            // image comes from the shared cache)
            self.board = Board::paper_setup(config.platform.fpga, spi.compressed);
            self.spi = spi;
            self.rebuild_table();
            return;
        }
        if self.spi != spi {
            self.spi = spi;
            self.rebuild_table();
        }
        self.board.reset();
    }

    /// True when the fabric holds a live configuration (no preamble due).
    pub fn is_ready(&self) -> bool {
        matches!(self.board.fpga.state, FpgaState::Idle(_) | FpgaState::Busy)
    }

    /// Power-on + configure `slot` from flash. Returns the configuration
    /// duration (the mechanism-derived T_config).
    pub fn configure(&mut self, slot: &str) -> Result<Duration, BoardError> {
        self.board.power_on_and_configure(slot, self.spi)
    }

    /// Power-on + configure an interned slot on precomputed stage costs:
    /// the same inrush transient and the same three stage spends, in the
    /// same order, as [`configure`](ReplayCore::configure) — but without
    /// re-running the flash lookup, the profile computation or the slot
    /// name allocation. Bit-identical to the golden path on every ledger
    /// (counters included), error cases too.
    pub fn configure_slot(&mut self, slot: SlotId) -> Result<Duration, BoardError> {
        assert_eq!(
            slot.generation, self.table.generation,
            "stale SlotId: the gap-cost table was rebuilt since this slot \
             was interned — re-intern via slot_id() after rebuild_table()"
        );
        if self.golden || !self.table.spi_ok {
            // golden mode, or an SPI setting the flash rejects: walk the
            // full path so the caller sees the identical behaviour/error
            let name = self.table.slots[slot.index].name.clone();
            return self.configure(&name);
        }
        let inrush = self.board.fpga.power_on();
        self.board.spend_transient(inrush)?;
        let costs = &self.table.slots[slot.index];
        self.board.fpga.mark_configured(costs.name.clone());
        let (stages, total_time) = (costs.stages, costs.total_time);
        for (power, time) in stages {
            self.board.spend(power, time)?;
        }
        Ok(total_time)
    }

    /// Switch images: power-cycle (losing the SRAM configuration) and load
    /// `slot` — the multi-accelerator reconfiguration path.
    pub fn power_cycle_configure(&mut self, slot: &str) -> Result<Duration, BoardError> {
        if self.board.fpga.is_configured() {
            self.board.fpga.power_off();
        }
        self.board.power_on_and_configure(slot, self.spi)
    }

    /// Replace the fault stream (fleet devices install a
    /// `derive_seed`-split stream per device; `None` disables injection).
    pub fn set_fault_state(&mut self, faults: Option<FaultState>) {
        self.faults = faults;
    }

    /// The fault stream, if injection is enabled (counters live here).
    pub fn fault_state(&self) -> Option<&FaultState> {
        self.faults.as_ref()
    }

    /// The cumulative fault-recovery ledger (all-zero with faults off).
    pub fn recovery(&self) -> RecoveryLedger {
        self.recovery
    }

    /// Fault-aware [`configure_slot`](ReplayCore::configure_slot): before
    /// each attempt the fault stream is consulted; a faulted attempt
    /// charges the *partial* configuration energy actually spent (inrush
    /// + stage walk up to the fault's fraction), powers back off, waits
    /// the capped-exponential backoff in sim time, and retries — up to
    /// the spec's `retry_max` attempts, after which
    /// [`BoardError::RetriesExhausted`] is returned with the fabric off.
    /// Every retry re-draws from the battery, so Eq-2 accounting stays
    /// honest; a battery death mid-retry surfaces as `Exhausted` as
    /// everywhere else. With faults disabled this *is* `configure_slot`
    /// (same single call, zero extra arithmetic).
    pub fn configure_slot_recovering(&mut self, slot: SlotId) -> Result<Recovery, BoardError> {
        if self.faults.is_none() {
            return Ok(Recovery::clean(self.configure_slot(slot)?));
        }
        self.recover_configure(slot.index, |core| core.configure_slot(slot))
    }

    /// Fault-aware [`configure`](ReplayCore::configure) (by slot name).
    pub fn configure_recovering(&mut self, name: &str) -> Result<Recovery, BoardError> {
        if self.faults.is_none() {
            return Ok(Recovery::clean(self.configure(name)?));
        }
        match self.table.slot_id(name) {
            Some(slot) => self.recover_configure(slot.index, move |core| {
                let name = core.table.slots[slot.index].name.clone();
                core.configure(&name)
            }),
            // unknown slot: the plain path produces the right error
            None => Ok(Recovery::clean(self.configure(name)?)),
        }
    }

    /// Fault-aware [`power_cycle_configure`](ReplayCore::power_cycle_configure).
    pub fn power_cycle_configure_recovering(&mut self, name: &str) -> Result<Recovery, BoardError> {
        if self.board.fpga.is_configured() {
            self.board.fpga.power_off();
        }
        self.configure_recovering(name)
    }

    /// The shared retry loop: consult the stream, charge partials, back
    /// off, and run `success` (one of the plain configure calls) on a
    /// clean draw. `slot_index` names the table row whose stage costs a
    /// partial attempt charges.
    fn recover_configure(
        &mut self,
        slot_index: usize,
        mut success: impl FnMut(&mut Self) -> Result<Duration, BoardError>,
    ) -> Result<Recovery, BoardError> {
        let mut retries = 0u32;
        let mut recovery_energy = Energy::ZERO;
        let mut recovery_time = Duration::ZERO;
        loop {
            let fault = self
                .faults
                .as_mut()
                .expect("recover_configure requires an installed fault stream")
                .next_config_fault();
            match fault {
                None => {
                    let config_time = success(self)?;
                    return Ok(Recovery {
                        config_time,
                        total_time: recovery_time + config_time,
                        retries,
                        recovery_energy,
                        recovery_time,
                    });
                }
                Some(f) => {
                    let before = self.board.fpga_energy;
                    let partial = self.charge_partial_attempt(slot_index, f.fraction)?;
                    let destroyed = self.board.fpga_energy - before;
                    recovery_energy += destroyed;
                    recovery_time += partial;
                    retries += 1;
                    self.recovery.retries += 1;
                    self.recovery.recovery_energy += destroyed;
                    self.recovery.recovery_time += partial;
                    let faults = self.faults.as_ref().expect("stream installed");
                    if retries >= faults.retry_max() {
                        return Err(BoardError::RetriesExhausted(retries));
                    }
                    let backoff = faults.backoff_after(retries);
                    self.pass_off_time(backoff);
                    recovery_time += backoff;
                    self.recovery.recovery_time += backoff;
                }
            }
        }
    }

    /// Charge one *failed* configuration attempt: the inrush transient
    /// plus the stage walk truncated at `fraction` of the slot's nominal
    /// T_config, then power back off. `configurations` does not advance
    /// (the image never became live); `power_ons` does, one per attempt.
    /// Returns the partial wall time spent.
    fn charge_partial_attempt(
        &mut self,
        slot_index: usize,
        fraction: f64,
    ) -> Result<Duration, BoardError> {
        let (stages, total_time) = {
            let costs = &self.table.slots[slot_index];
            (costs.stages, costs.total_time)
        };
        let inrush = self.board.fpga.power_on();
        self.board.spend_transient(inrush)?;
        let cutoff = total_time * fraction;
        let mut elapsed = Duration::ZERO;
        for (power, time) in stages {
            if elapsed >= cutoff {
                break;
            }
            let span = time.min(cutoff - elapsed);
            self.board.spend(power, span)?;
            elapsed += span;
        }
        self.board.fpga.power_off();
        Ok(elapsed)
    }

    /// Fault-aware [`run_phases`](ReplayCore::run_phases): at most one
    /// supply brownout may interrupt the item's active phases, wasting
    /// the partial phase energy, clearing the configuration, and forcing
    /// a full (itself fault-prone) recovering reconfiguration of `slot`
    /// before the phases re-run cleanly. Propagates
    /// [`BoardError::RetriesExhausted`] when that recovery gives up. With
    /// faults disabled this *is* `run_phases`.
    pub fn run_phases_recovering(&mut self, slot: SlotId) -> Result<PhaseRecovery, BoardError> {
        let fault = match self.faults.as_mut() {
            None => None,
            Some(f) => f.next_infer_fault(),
        };
        let Some(fraction) = fault else {
            return Ok(PhaseRecovery::clean(self.run_phases()?));
        };
        let before = self.board.fpga_energy;
        // partial phase walk up to the brownout instant, then rails drop
        self.board.fpga.begin_work()?;
        let phases = self.phases;
        let total = phases
            .iter()
            .fold(Duration::ZERO, |acc, &(_, t)| acc + t);
        let cutoff = total * fraction;
        let mut elapsed = Duration::ZERO;
        for (power, time) in phases {
            if elapsed >= cutoff {
                break;
            }
            let span = time.min(cutoff - elapsed);
            self.board.spend(power, span)?;
            elapsed += span;
        }
        self.board.fpga.power_off();
        let destroyed = self.board.fpga_energy - before;
        self.recovery.retries += 1;
        self.recovery.recovery_energy += destroyed;
        self.recovery.recovery_time += elapsed;
        // full recovery reconfiguration (may itself fault and retry; its
        // own partial attempts land on the ledger through the inner call)
        let rec = self.configure_slot_recovering(slot)?;
        self.recovery.recovery_time += rec.config_time;
        let clean = self.run_phases()?;
        let recovery_time = elapsed + rec.total_time;
        Ok(PhaseRecovery {
            latency: recovery_time + clean,
            retries: rec.retries + 1,
            recovery_energy: destroyed + rec.recovery_energy,
            recovery_time,
            browned_out: true,
        })
    }

    /// Cut the rails without advancing time (a policy's mid-gap decision;
    /// the elapsed off-time is accounted by the caller's next `elapse`).
    pub fn power_off(&mut self) {
        self.board.fpga.power_off();
    }

    /// Replay the three active phases; returns their total latency.
    pub fn run_phases(&mut self) -> Result<Duration, BoardError> {
        self.board.run_item_phases(&self.phases)
    }

    /// Execute a policy's [`GapPlan`] across one *inter-arrival* gap
    /// `gap` (request arrival → next request arrival; T_req on periodic
    /// workloads). The serving busy windows are carved out of it here —
    /// `item_latency` always, plus `config_time` when the plan cuts
    /// power — exactly as the paper's equations do
    /// (`E_Idle = P_idle · (T_req − T_latency)`). Callers must therefore
    /// pass the raw arrival-to-arrival gap, NOT a remaining-idle window.
    ///
    /// A zero idle window still switches the rails into the requested
    /// power-saving mode, so the next gap starts from the right state.
    ///
    /// On a fast-path core this is pure arithmetic on the cached
    /// [`GapCostTable`] constants; a [`golden_reference`] core walks the
    /// original `Board` FSM accounting instead. The two are bit-identical
    /// on every reported quantity (`tests/fastpath_equivalence.rs`).
    ///
    /// [`golden_reference`]: ReplayCore::golden_reference
    pub fn execute_plan(
        &mut self,
        plan: GapPlan,
        gap: Duration,
        config_time: Duration,
        item_latency: Duration,
    ) -> Result<GapExecution, BoardError> {
        if self.golden {
            return self.execute_plan_via_board(plan, gap, config_time, item_latency);
        }
        match plan {
            GapPlan::Idle(saving) => {
                self.board.fpga.enter_idle(saving).map_err(BoardError::from)?;
                if gap.secs() > item_latency.secs() {
                    self.board
                        .spend(self.table.idle_power(saving), gap - item_latency)?;
                    Ok(GapExecution::default())
                } else {
                    Ok(GapExecution {
                        late: true,
                        ..Default::default()
                    })
                }
            }
            GapPlan::PowerOff => {
                let busy = config_time + item_latency;
                let (off, late) = if gap.secs() > busy.secs() {
                    (gap - busy, false)
                } else {
                    (Duration::ZERO, true)
                };
                self.pass_off_time(off);
                Ok(GapExecution {
                    powered_off: true,
                    timeout_expired: false,
                    late,
                })
            }
            GapPlan::IdleThenOff { saving, timeout } => {
                let idle_window = gap - item_latency;
                self.board.fpga.enter_idle(saving).map_err(BoardError::from)?;
                if idle_window.secs() <= timeout.secs() {
                    // the next request (or its busy window) preempts the timer
                    if idle_window.secs() > 0.0 {
                        self.board
                            .spend(self.table.idle_power(saving), idle_window)?;
                        Ok(GapExecution::default())
                    } else {
                        Ok(GapExecution {
                            late: true,
                            ..Default::default()
                        })
                    }
                } else {
                    // rent until τ, then buy: power off for the remainder
                    self.board.spend(self.table.idle_power(saving), timeout)?;
                    let busy = timeout + config_time + item_latency;
                    let (off, late) = if gap.secs() > busy.secs() {
                        (gap - busy, false)
                    } else {
                        (Duration::ZERO, true)
                    };
                    self.pass_off_time(off);
                    Ok(GapExecution {
                        powered_off: true,
                        timeout_expired: true,
                        late,
                    })
                }
            }
        }
    }

    /// Execute a whole planned batch — gap, then the follow-up item's
    /// configure-if-needed + phases, per element — appending the outcome
    /// to `out` (cleared first). This is the trace-driven kernel: the
    /// per-gap arithmetic reads the [`GapBatch`] flat arrays and the
    /// [`GapCostTable`] constants directly ([`execute_soa_fast`]) instead
    /// of matching a `GapPlan` per gap, and the board-op order per
    /// element is exactly the scalar DES's
    /// (`execute_plan` → `configure_slot`? → `run_phases`), so every
    /// ledger and the monitor's absolute tick grid land on identical
    /// bits. The caller accounts served items from `out.reconfigured`
    /// and stops on `out.exhausted`.
    ///
    /// `config_time` is read for power-off busy windows and updated when
    /// an element reconfigures, mirroring the scalar driver's ledger.
    /// On a [`golden_reference`](ReplayCore::golden_reference) core every
    /// element routes through the `Board`-FSM path instead.
    ///
    /// [`execute_soa_fast`]: GapBatch
    pub fn execute_batch(
        &mut self,
        batch: &GapBatch,
        slot: SlotId,
        config_time: &mut Duration,
        item_latency: Duration,
        out: &mut BatchRun,
    ) {
        out.clear();
        for i in 0..batch.len() {
            let exec = if self.golden {
                self.execute_plan_via_board(batch.plan(i), batch.gaps[i], *config_time, item_latency)
            } else {
                self.execute_soa_fast(
                    batch.kinds[i],
                    batch.gaps[i],
                    batch.savings[i],
                    batch.timeouts[i],
                    *config_time,
                    item_latency,
                )
            };
            match exec {
                Ok(exec) => out.execs.push(exec),
                Err(_) => {
                    out.exhausted = true;
                    return;
                }
            }
            // the request ending this gap: reconfigure if the plan cut
            // power, then replay the active phases — same order, same
            // spends as the scalar event handler. With a fault stream
            // installed both steps route through the recovering wrappers
            // (identical calls when no fault is drawn).
            let mut reconfigured = false;
            let mut extra = Duration::ZERO;
            if !self.is_ready() {
                if self.faults.is_some() {
                    match self.configure_slot_recovering(slot) {
                        Ok(rec) => {
                            *config_time = rec.config_time;
                            reconfigured = true;
                            extra += rec.recovery_time;
                        }
                        Err(BoardError::RetriesExhausted(_)) => {
                            out.shed = true;
                            return;
                        }
                        Err(_) => {
                            out.exhausted = true;
                            return;
                        }
                    }
                } else {
                    match self.configure_slot(slot) {
                        Ok(t) => {
                            *config_time = t;
                            reconfigured = true;
                        }
                        Err(_) => {
                            out.exhausted = true;
                            return;
                        }
                    }
                }
            }
            if self.faults.is_some() {
                match self.run_phases_recovering(slot) {
                    Ok(ph) => extra += ph.recovery_time,
                    Err(BoardError::RetriesExhausted(_)) => {
                        out.shed = true;
                        return;
                    }
                    Err(_) => {
                        out.exhausted = true;
                        return;
                    }
                }
                out.extra.push(extra);
            } else if self.run_phases().is_err() {
                out.exhausted = true;
                return;
            }
            out.reconfigured.push(reconfigured);
        }
    }

    /// One gap of the SoA kernel: the [`execute_plan`] fast arms,
    /// dispatched on the batch's kind byte with the idle power read
    /// straight from the cached table row. Identical spends in identical
    /// order — the enum decode exists only for `enter_idle`'s mode
    /// switch.
    ///
    /// [`execute_plan`]: ReplayCore::execute_plan
    #[inline]
    fn execute_soa_fast(
        &mut self,
        kind: u8,
        gap: Duration,
        saving_bits: u8,
        timeout: Duration,
        config_time: Duration,
        item_latency: Duration,
    ) -> Result<GapExecution, BoardError> {
        let saving = PowerSaving {
            method1: saving_bits & 1 != 0,
            method2: saving_bits & 2 != 0,
        };
        match kind {
            KIND_IDLE => {
                self.board.fpga.enter_idle(saving).map_err(BoardError::from)?;
                if gap.secs() > item_latency.secs() {
                    self.board
                        .spend(self.table.idle_power[saving_bits as usize], gap - item_latency)?;
                    Ok(GapExecution::default())
                } else {
                    Ok(GapExecution {
                        late: true,
                        ..Default::default()
                    })
                }
            }
            KIND_OFF => {
                let busy = config_time + item_latency;
                let (off, late) = if gap.secs() > busy.secs() {
                    (gap - busy, false)
                } else {
                    (Duration::ZERO, true)
                };
                self.pass_off_time(off);
                Ok(GapExecution {
                    powered_off: true,
                    timeout_expired: false,
                    late,
                })
            }
            _ => {
                let idle_window = gap - item_latency;
                self.board.fpga.enter_idle(saving).map_err(BoardError::from)?;
                if idle_window.secs() <= timeout.secs() {
                    // the next request (or its busy window) preempts the timer
                    if idle_window.secs() > 0.0 {
                        self.board
                            .spend(self.table.idle_power[saving_bits as usize], idle_window)?;
                        Ok(GapExecution::default())
                    } else {
                        Ok(GapExecution {
                            late: true,
                            ..Default::default()
                        })
                    }
                } else {
                    // rent until τ, then buy: power off for the remainder
                    self.board
                        .spend(self.table.idle_power[saving_bits as usize], timeout)?;
                    let busy = timeout + config_time + item_latency;
                    let (off, late) = if gap.secs() > busy.secs() {
                        (gap - busy, false)
                    } else {
                        (Duration::ZERO, true)
                    };
                    self.pass_off_time(off);
                    Ok(GapExecution {
                        powered_off: true,
                        timeout_expired: true,
                        late,
                    })
                }
            }
        }
    }

    /// Cut the rails and let `off` pass. The paper's off state draws
    /// nothing, so where the golden path feeds a zero-power segment
    /// through the ledger (a no-op draw, a zero-energy monitor segment),
    /// the fast path just advances the board clock: bit-identical on
    /// every `SimReport` quantity — the monitor's tick grid is absolute,
    /// so its deferred gap-skip lands on the same sample tick either
    /// way, leaving `measured()`/`exact()`/`rel_error()` untouched. The
    /// one observable that legitimately differs is `Pac1934::samples()`:
    /// the golden path counts zero-power ticks inside off windows, the
    /// fast path never takes them (they contribute no energy). No report
    /// reads the sample count; anything that starts to must use the
    /// golden path.
    fn pass_off_time(&mut self, off: Duration) {
        self.board.fpga.power_off();
        self.board.now = self.board.now + off;
    }

    /// The original `Board`-FSM implementation of
    /// [`execute_plan`](ReplayCore::execute_plan) — the golden reference
    /// the fast path is validated against, and the path every
    /// [`golden_reference`](ReplayCore::golden_reference) core takes.
    pub fn execute_plan_via_board(
        &mut self,
        plan: GapPlan,
        gap: Duration,
        config_time: Duration,
        item_latency: Duration,
    ) -> Result<GapExecution, BoardError> {
        match plan {
            GapPlan::Idle(saving) => {
                if gap.secs() > item_latency.secs() {
                    self.board.idle_for(saving, gap - item_latency)?;
                    Ok(GapExecution::default())
                } else {
                    self.board.fpga.enter_idle(saving).map_err(BoardError::from)?;
                    Ok(GapExecution {
                        late: true,
                        ..Default::default()
                    })
                }
            }
            GapPlan::PowerOff => {
                let busy = config_time + item_latency;
                let (off, late) = if gap.secs() > busy.secs() {
                    (gap - busy, false)
                } else {
                    (Duration::ZERO, true)
                };
                self.board.off_for(off, false)?;
                Ok(GapExecution {
                    powered_off: true,
                    timeout_expired: false,
                    late,
                })
            }
            GapPlan::IdleThenOff { saving, timeout } => {
                let idle_window = gap - item_latency;
                if idle_window.secs() <= timeout.secs() {
                    // the next request (or its busy window) preempts the timer
                    if idle_window.secs() > 0.0 {
                        self.board.idle_for(saving, idle_window)?;
                        Ok(GapExecution::default())
                    } else {
                        self.board.fpga.enter_idle(saving).map_err(BoardError::from)?;
                        Ok(GapExecution {
                            late: true,
                            ..Default::default()
                        })
                    }
                } else {
                    // rent until τ, then buy: power off for the remainder
                    self.board.idle_for(saving, timeout)?;
                    let busy = timeout + config_time + item_latency;
                    let (off, late) = if gap.secs() > busy.secs() {
                        (gap - busy, false)
                    } else {
                        (Duration::ZERO, true)
                    };
                    self.board.off_for(off, false)?;
                    Ok(GapExecution {
                        powered_off: true,
                        timeout_expired: true,
                        late,
                    })
                }
            }
        }
    }

    /// Advance the energy ledger across `dur` of inactivity: idle at
    /// `saving` while configured, otherwise the (paper-model) off state.
    pub fn elapse(&mut self, saving: PowerSaving, dur: Duration) -> Result<(), BoardError> {
        if self.golden {
            return if self.board.fpga.is_configured() {
                self.board.idle_for(saving, dur)
            } else {
                self.board.off_for(dur, false)
            };
        }
        if self.board.fpga.is_configured() {
            self.board.fpga.enter_idle(saving).map_err(BoardError::from)?;
            self.board.spend(self.table.idle_power(saving), dur)
        } else {
            self.pass_off_time(dur);
            Ok(())
        }
    }
}

/// Precomputed per-device arithmetic constants for the fleet DES: the
/// Table 3 idle powers, the cost of one power-on + configure of the
/// device's slot (inrush transient included) and the serve cost of one
/// workload item, extracted once from a scratch [`ReplayCore`]. A fleet
/// device accounts a gap + serve step with a handful of multiplies on
/// this `Copy` struct — no `Board`, no event queue, O(bytes) of state
/// per device — which is what lets `repro fleet` hold 100k+ devices in
/// one process.
///
/// The constants are *measured* off the same `configure_slot` /
/// `run_phases` path every event-driven runtime uses (battery-ledger
/// deltas across one configure and one item), so fleet-level energy
/// arithmetic agrees with the per-device simulators by construction.
#[derive(Debug, Clone, Copy)]
pub struct DeviceCosts {
    /// Table 3 idle power per power-saving combination ([`saving_index`]
    /// encoding, same layout as [`GapCostTable`]).
    idle_power: [Power; 4],
    /// The slot's T_config (the paper's configuration time).
    pub config_time: Duration,
    /// Energy of one power-on + configure: inrush + the three stages.
    pub config_energy: Energy,
    /// Latency of the three active phases (T_latency without config).
    pub item_latency: Duration,
    /// Energy of the three active phases.
    pub item_energy: Energy,
}

impl DeviceCosts {
    /// Measure the constants for `config`'s platform by driving a scratch
    /// fast-path core through one configure + one item and reading the
    /// energy-ledger deltas.
    pub fn measure(config: &SimConfig) -> DeviceCosts {
        let mut core = ReplayCore::from_config(config);
        let before = core.board.fpga_energy;
        let config_time = core
            .configure("lstm")
            .expect("a fresh battery covers one configuration");
        let after_config = core.board.fpga_energy;
        let item_latency = core
            .run_phases()
            .expect("a fresh battery covers one workload item");
        let after_item = core.board.fpga_energy;
        DeviceCosts {
            idle_power: core.table.idle_power,
            config_time,
            config_energy: after_config - before,
            item_latency,
            item_energy: after_item - after_config,
        }
    }

    /// Cached Table 3 idle power for a power-saving level.
    #[inline]
    pub fn idle_power(&self, saving: PowerSaving) -> Power {
        self.idle_power[saving_index(saving)]
    }
}

/// Table 2 active phases as (power, duration) tuples.
pub fn item_phases(item: &crate::config::schema::WorkloadItemSpec) -> [(Power, Duration); 3] {
    [
        (item.data_loading.power, item.data_loading.time),
        (item.inference.power, item.inference.time),
        (item.data_offloading.power, item.data_offloading.time),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn ready_core() -> (ReplayCore, Duration, Duration) {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        let config_time = core.configure("lstm").unwrap();
        core.run_phases().unwrap();
        (core, config_time, cfg.item.latency_without_config())
    }

    #[test]
    fn configure_then_phases_costs_the_calibrated_energy() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        assert!(!core.is_ready());
        let t = core.configure("lstm").unwrap();
        assert!((t.millis() - 36.145).abs() < 0.01);
        assert!(core.is_ready());
        core.run_phases().unwrap();
        // 11.85 (config) + 0.1244 (inrush) + 0.0065 (phases) ≈ 11.98 mJ
        assert!((core.board.fpga_energy.millijoules() - 11.983).abs() < 0.01);
    }

    #[test]
    fn zero_idle_window_still_switches_mode_and_reports_late() {
        let (mut core, config_time, latency) = ready_core();
        let before = core.board.fpga_energy;
        // gap shorter than the item latency: nothing to idle through
        let exec = core
            .execute_plan(
                GapPlan::Idle(PowerSaving::M12),
                Duration::from_micros(1.0),
                config_time,
                latency,
            )
            .unwrap();
        assert!(exec.late && !exec.powered_off);
        assert_eq!(core.board.fpga_energy, before);
        assert_eq!(core.board.fpga.state, FpgaState::Idle(PowerSaving::M12));
    }

    #[test]
    fn idle_plan_charges_table3_power_over_the_idle_window() {
        let (mut core, config_time, latency) = ready_core();
        let before = core.board.fpga_energy;
        let exec = core
            .execute_plan(
                GapPlan::Idle(PowerSaving::BASELINE),
                Duration::from_millis(40.0),
                config_time,
                latency,
            )
            .unwrap();
        assert_eq!(exec, GapExecution::default());
        // 134.3 mW × (40 − 0.0401) ms
        let drawn = (core.board.fpga_energy - before).millijoules();
        assert!((drawn - 0.1343 * (40.0 - 0.0401)).abs() < 1e-6, "{drawn}");
    }

    #[test]
    fn power_off_plan_loses_configuration_and_draws_nothing() {
        let (mut core, config_time, latency) = ready_core();
        let before = core.board.fpga_energy;
        let exec = core
            .execute_plan(
                GapPlan::PowerOff,
                Duration::from_millis(200.0),
                config_time,
                latency,
            )
            .unwrap();
        assert!(exec.powered_off && !exec.timeout_expired && !exec.late);
        assert!(!core.is_ready());
        // paper model: the off state draws nothing
        assert_eq!(core.board.fpga_energy, before);
    }

    #[test]
    fn power_off_plan_flags_late_when_gap_fits_no_reconfig() {
        let (mut core, config_time, latency) = ready_core();
        let exec = core
            .execute_plan(
                GapPlan::PowerOff,
                Duration::from_millis(3.8),
                config_time,
                latency,
            )
            .unwrap();
        assert!(exec.powered_off && exec.late);
    }

    #[test]
    fn idle_then_off_expires_and_pays_exactly_tau_of_idle() {
        let (mut core, config_time, latency) = ready_core();
        let before = core.board.fpga_energy;
        let timeout = Duration::from_millis(50.0);
        let exec = core
            .execute_plan(
                GapPlan::IdleThenOff {
                    saving: PowerSaving::BASELINE,
                    timeout,
                },
                Duration::from_millis(400.0),
                config_time,
                latency,
            )
            .unwrap();
        assert!(exec.powered_off && exec.timeout_expired && !exec.late);
        assert!(!core.is_ready());
        // the gap cost is exactly τ at the idle power; the off tail is free
        let drawn = (core.board.fpga_energy - before).millijoules();
        assert!((drawn - 0.1343 * 50.0).abs() < 1e-6, "{drawn}");
    }

    #[test]
    fn idle_then_off_short_gap_is_pure_idle() {
        let (mut core, config_time, latency) = ready_core();
        let before = core.board.fpga_energy;
        let exec = core
            .execute_plan(
                GapPlan::IdleThenOff {
                    saving: PowerSaving::BASELINE,
                    timeout: Duration::from_millis(50.0),
                },
                Duration::from_millis(40.0),
                config_time,
                latency,
            )
            .unwrap();
        assert!(!exec.powered_off && !exec.timeout_expired && !exec.late);
        assert!(core.is_ready());
        // identical to the pure-idle plan on the same gap
        let drawn = (core.board.fpga_energy - before).millijoules();
        assert!((drawn - 0.1343 * (40.0 - 0.0401)).abs() < 1e-6, "{drawn}");
    }

    #[test]
    fn elapse_while_configured_charges_idle_power() {
        let (mut core, _, _) = ready_core();
        let before = core.board.fpga_energy;
        core.elapse(PowerSaving::M12, Duration::from_secs(1.0)).unwrap();
        let drawn = core.board.fpga_energy - before;
        assert!((drawn.millijoules() - 24.0).abs() < 0.1, "{}", drawn.millijoules());
    }

    #[test]
    fn elapse_after_power_off_is_free() {
        let (mut core, _, _) = ready_core();
        core.power_off();
        let e = core.board.fpga_energy;
        core.elapse(PowerSaving::BASELINE, Duration::from_secs(1.0)).unwrap();
        assert_eq!(core.board.fpga_energy, e);
    }

    /// Every ledger a `SimReport` is built from, as one comparable tuple.
    fn ledger(core: &ReplayCore) -> (f64, f64, f64, f64, u64, u64, u64, FpgaState) {
        (
            core.board.fpga_energy.joules(),
            core.board.battery.drawn().joules(),
            core.board.monitor.measured().joules(),
            core.board.monitor.exact().joules(),
            core.board.now.nanos(),
            core.board.fpga.configurations,
            core.board.fpga.power_ons,
            core.board.fpga.state,
        )
    }

    #[test]
    fn interned_configure_matches_golden_bit_for_bit() {
        let cfg = paper_default();
        let mut fast = ReplayCore::from_config(&cfg);
        let mut golden = ReplayCore::golden_reference(&cfg);
        assert!(!fast.is_golden() && golden.is_golden());
        let slot = fast.slot_id("lstm").expect("lstm slot interned");
        let t_fast = fast.configure_slot(slot).unwrap();
        let t_golden = golden.configure("lstm").unwrap();
        assert_eq!(t_fast.secs().to_bits(), t_golden.secs().to_bits());
        assert_eq!(ledger(&fast), ledger(&golden));
        assert_eq!(fast.board.fpga.configured_with(), Some("lstm"));
    }

    #[test]
    fn fast_plans_match_golden_on_every_ledger() {
        let cfg = paper_default();
        let plans = [
            GapPlan::Idle(PowerSaving::BASELINE),
            GapPlan::Idle(PowerSaving::M12),
            GapPlan::PowerOff,
            GapPlan::IdleThenOff {
                saving: PowerSaving::M1,
                timeout: Duration::from_millis(50.0),
            },
        ];
        let gaps = [0.01, 3.8, 40.0, 120.0, 700.0];
        for plan in plans {
            for gap_ms in gaps {
                let run = |mut core: ReplayCore| {
                    let slot = core.slot_id("lstm").unwrap();
                    let config_time = core.configure_slot(slot).unwrap();
                    core.run_phases().unwrap();
                    let latency = cfg.item.latency_without_config();
                    let exec = core
                        .execute_plan(plan, Duration::from_millis(gap_ms), config_time, latency)
                        .unwrap();
                    // a second serving after the gap exercises the
                    // post-gap reconfigure path too
                    if !core.is_ready() {
                        core.configure_slot(slot).unwrap();
                    }
                    core.run_phases().unwrap();
                    (exec, ledger(&core))
                };
                let fast = run(ReplayCore::from_config(&cfg));
                let golden = run(ReplayCore::golden_reference(&cfg));
                assert_eq!(fast, golden, "{plan:?} at {gap_ms} ms");
            }
        }
    }

    #[test]
    fn fast_elapse_matches_golden() {
        let cfg = paper_default();
        let run = |mut core: ReplayCore| {
            core.configure("lstm").unwrap();
            core.run_phases().unwrap();
            core.elapse(PowerSaving::M12, Duration::from_millis(300.0)).unwrap();
            core.power_off();
            core.elapse(PowerSaving::BASELINE, Duration::from_secs(2.0)).unwrap();
            ledger(&core)
        };
        assert_eq!(
            run(ReplayCore::from_config(&cfg)),
            run(ReplayCore::golden_reference(&cfg))
        );
    }

    #[test]
    fn table_caches_the_exact_idle_powers() {
        let cfg = paper_default();
        let core = ReplayCore::from_config(&cfg);
        for saving in [PowerSaving::BASELINE, PowerSaving::M1, PowerSaving::M12] {
            assert_eq!(
                core.table().idle_power(saving).milliwatts().to_bits(),
                crate::device::rails::RailSet::idle_power(saving)
                    .milliwatts()
                    .to_bits(),
                "{saving:?}"
            );
        }
        assert!(core.slot_id("nonexistent").is_none());
    }

    #[test]
    fn set_spi_rebuilds_the_cached_costs_in_the_same_step() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        core.set_spi(crate::config::schema::SpiConfig::worst());
        assert_eq!(core.spi(), crate::config::schema::SpiConfig::worst());
        let slot = core.slot_id("lstm").unwrap();
        let t_fast = core.configure_slot(slot).unwrap();
        // ~1496.6 ms at the worst setting — nothing like the old 36 ms
        assert!((t_fast.millis() - 1496.6).abs() < 1.5, "{}", t_fast.millis());
        // and bit-equal to the golden path at the same setting
        let mut reference = ReplayCore::golden_reference(&cfg);
        reference.set_spi(crate::config::schema::SpiConfig::worst());
        let t_golden = reference.configure("lstm").unwrap();
        assert_eq!(t_fast.secs().to_bits(), t_golden.secs().to_bits());
    }

    #[test]
    #[should_panic(expected = "stale SlotId")]
    fn slot_id_from_before_a_rebuild_is_rejected() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        let slot = core.slot_id("lstm").unwrap();
        // rebuilding may renumber slots (flash order can change), so the
        // old id must be a loud error, never another slot's costs
        core.rebuild_table();
        let _ = core.configure_slot(slot);
    }

    #[test]
    fn reset_for_restores_a_pristine_core() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        let slot = core.slot_id("lstm").unwrap();
        core.configure_slot(slot).unwrap();
        core.run_phases().unwrap();
        core.reset_for(&cfg);
        let fresh = ReplayCore::from_config(&cfg);
        assert_eq!(ledger(&core), ledger(&fresh));
        // interning survives the reset, and the reset core still runs
        assert_eq!(core.slot_id("lstm"), Some(slot));
        core.configure_slot(slot).unwrap();
        core.run_phases().unwrap();
        assert_eq!(core.board.fpga.configurations, 1);
    }

    #[test]
    fn gap_batch_round_trips_every_plan_shape() {
        let plans = [
            GapPlan::Idle(PowerSaving::BASELINE),
            GapPlan::Idle(PowerSaving::M1),
            GapPlan::Idle(PowerSaving::M12),
            GapPlan::PowerOff,
            GapPlan::IdleThenOff {
                saving: PowerSaving::M12,
                timeout: Duration::from_millis(50.0),
            },
        ];
        let mut batch = GapBatch::default();
        assert!(batch.is_empty());
        for (i, plan) in plans.iter().enumerate() {
            batch.push(Duration::from_millis(10.0 * (i + 1) as f64), *plan);
        }
        assert_eq!(batch.len(), plans.len());
        for (i, plan) in plans.iter().enumerate() {
            assert_eq!(batch.plan(i), *plan, "element {i}");
        }
        // uniform fill appends and agrees with element-wise pushes
        let gaps = vec![Duration::from_millis(40.0); 7];
        batch.push_uniform(
            &gaps,
            GapPlan::IdleThenOff {
                saving: PowerSaving::M1,
                timeout: Duration::from_millis(9.0),
            },
        );
        assert_eq!(batch.len(), plans.len() + 7);
        assert_eq!(
            batch.plan(plans.len() + 3),
            GapPlan::IdleThenOff {
                saving: PowerSaving::M1,
                timeout: Duration::from_millis(9.0),
            }
        );
        batch.clear();
        assert!(batch.is_empty() && batch.gaps().is_empty());
    }

    #[test]
    fn execute_batch_matches_the_scalar_loop_bit_for_bit() {
        let cfg = paper_default();
        let latency = cfg.item.latency_without_config();
        let mut batch = GapBatch::default();
        for (i, gap_ms) in [40.0, 700.0, 3.8, 120.0, 0.01, 55.0].iter().enumerate() {
            let plan = match i % 3 {
                0 => GapPlan::Idle(PowerSaving::M12),
                1 => GapPlan::PowerOff,
                _ => GapPlan::IdleThenOff {
                    saving: PowerSaving::M1,
                    timeout: Duration::from_millis(50.0),
                },
            };
            batch.push(Duration::from_millis(*gap_ms), plan);
        }
        for golden in [false, true] {
            let make = |cfg: &SimConfig| {
                if golden {
                    ReplayCore::golden_reference(cfg)
                } else {
                    ReplayCore::from_config(cfg)
                }
            };
            // batched execution
            let mut core = make(&cfg);
            let slot = core.slot_id("lstm").unwrap();
            let mut config_time = core.configure_slot(slot).unwrap();
            core.run_phases().unwrap();
            let mut run = BatchRun::default();
            core.execute_batch(&batch, slot, &mut config_time, latency, &mut run);
            assert!(!run.exhausted);
            assert_eq!(run.gaps_executed(), batch.len());
            assert_eq!(run.items_served(), batch.len());

            // the scalar gap-by-gap loop over the same plans
            let mut scalar = make(&cfg);
            let slot_s = scalar.slot_id("lstm").unwrap();
            let mut ct = scalar.configure_slot(slot_s).unwrap();
            scalar.run_phases().unwrap();
            let mut execs = Vec::new();
            let mut reconf = Vec::new();
            for i in 0..batch.len() {
                execs.push(
                    scalar
                        .execute_plan(batch.plan(i), batch.gaps()[i], ct, latency)
                        .unwrap(),
                );
                let mut r = false;
                if !scalar.is_ready() {
                    ct = scalar.configure_slot(slot_s).unwrap();
                    r = true;
                }
                scalar.run_phases().unwrap();
                reconf.push(r);
            }
            assert_eq!(run.execs, execs, "golden={golden}");
            assert_eq!(run.reconfigured, reconf, "golden={golden}");
            assert_eq!(config_time.secs().to_bits(), ct.secs().to_bits());
            assert_eq!(ledger(&core), ledger(&scalar), "golden={golden}");
        }
    }

    #[test]
    fn device_costs_match_the_calibrated_energies() {
        let cfg = paper_default();
        let costs = DeviceCosts::measure(&cfg);
        // Table 2 / DESIGN.md §6 constants
        assert!((costs.config_time.millis() - 36.145).abs() < 0.01);
        // 11.852 mJ config stages + 0.1244 mJ inrush
        assert!(
            (costs.config_energy.millijoules() - 11.976).abs() < 0.01,
            "{}",
            costs.config_energy.millijoules()
        );
        assert!((costs.item_latency.millis() - 0.0401).abs() < 1e-9);
        assert!((costs.item_energy.millijoules() - 0.0065).abs() < 1e-4);
        // the idle rows are the GapCostTable's, bit for bit
        let core = ReplayCore::from_config(&cfg);
        for saving in [PowerSaving::BASELINE, PowerSaving::M1, PowerSaving::M12] {
            assert_eq!(
                costs.idle_power(saving).milliwatts().to_bits(),
                core.table().idle_power(saving).milliwatts().to_bits()
            );
        }
    }

    #[test]
    fn power_cycle_configure_counts_a_new_configuration() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        core.configure("lstm").unwrap();
        core.power_cycle_configure("lstm").unwrap();
        assert_eq!(core.board.fpga.configurations, 2);
        assert_eq!(core.board.fpga.power_ons, 2);
        assert!(core.is_ready());
    }
}
