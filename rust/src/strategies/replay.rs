//! The shared phase-replay / gap-policy core.
//!
//! Both event-driven simulations — the single-accelerator lifetime run
//! ([`crate::strategies::simulate`]) and the multi-accelerator scheduler
//! run ([`crate::coordinator::multi_sim`]) — drive a [`Board`] through
//! the same primitive moves: ensure the fabric is configured, replay the
//! Table 2 active phases, and spend the inter-request gap per the
//! strategy's [`GapAction`]. [`ReplayCore`] owns that sequence so the two
//! runtimes cannot drift apart on energy accounting.

use crate::config::loader::SimConfig;
use crate::config::schema::SpiConfig;
use crate::device::board::{Board, BoardError};
use crate::device::fpga::FpgaState;
use crate::device::rails::PowerSaving;
use crate::strategies::strategy::GapAction;
use crate::util::units::{Duration, Power};

/// A board plus the workload-item phase profile, exposing the simulation
/// primitives every event-driven runtime shares.
#[derive(Debug, Clone)]
pub struct ReplayCore {
    pub board: Board,
    /// Table 2 active phases as (power, duration) tuples.
    pub phases: [(Power, Duration); 3],
    pub spi: SpiConfig,
}

impl ReplayCore {
    /// Build the paper platform for `config` with the LSTM image in flash.
    pub fn from_config(config: &SimConfig) -> ReplayCore {
        ReplayCore {
            board: Board::paper_setup(config.platform.fpga, config.platform.spi.compressed),
            phases: item_phases(&config.item),
            spi: config.platform.spi,
        }
    }

    /// True when the fabric holds a live configuration (no preamble due).
    pub fn is_ready(&self) -> bool {
        matches!(self.board.fpga.state, FpgaState::Idle(_) | FpgaState::Busy)
    }

    /// Power-on + configure `slot` from flash. Returns the configuration
    /// duration (the mechanism-derived T_config).
    pub fn configure(&mut self, slot: &str) -> Result<Duration, BoardError> {
        self.board.power_on_and_configure(slot, self.spi)
    }

    /// Switch images: power-cycle (losing the SRAM configuration) and load
    /// `slot` — the multi-accelerator reconfiguration path.
    pub fn power_cycle_configure(&mut self, slot: &str) -> Result<Duration, BoardError> {
        if self.board.fpga.is_configured() {
            self.board.fpga.power_off();
        }
        self.board.power_on_and_configure(slot, self.spi)
    }

    /// Replay the three active phases; returns their total latency.
    pub fn run_phases(&mut self) -> Result<Duration, BoardError> {
        self.board.run_item_phases(&self.phases)
    }

    /// Spend an inter-request gap per the strategy's decision. A zero
    /// idle window still switches the rails into the requested
    /// power-saving mode (so the next gap starts from the right state).
    pub fn apply_gap(&mut self, action: GapAction, idle: Duration) -> Result<(), BoardError> {
        match action {
            GapAction::PowerOff => self.board.off_for(idle, false),
            GapAction::Idle(saving) => {
                if idle.secs() > 0.0 {
                    self.board.idle_for(saving, idle)
                } else {
                    self.board.fpga.enter_idle(saving).map_err(BoardError::from)
                }
            }
        }
    }

    /// Advance the energy ledger across `dur` of inactivity: idle at
    /// `saving` while configured, otherwise the (paper-model) off state.
    pub fn elapse(&mut self, saving: PowerSaving, dur: Duration) -> Result<(), BoardError> {
        if self.board.fpga.is_configured() {
            self.board.idle_for(saving, dur)
        } else {
            self.board.off_for(dur, false)
        }
    }
}

/// Table 2 active phases as (power, duration) tuples.
pub fn item_phases(item: &crate::config::schema::WorkloadItemSpec) -> [(Power, Duration); 3] {
    [
        (item.data_loading.power, item.data_loading.time),
        (item.inference.power, item.inference.time),
        (item.data_offloading.power, item.data_offloading.time),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    #[test]
    fn configure_then_phases_costs_the_calibrated_energy() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        assert!(!core.is_ready());
        let t = core.configure("lstm").unwrap();
        assert!((t.millis() - 36.145).abs() < 0.01);
        assert!(core.is_ready());
        core.run_phases().unwrap();
        // 11.85 (config) + 0.1244 (inrush) + 0.0065 (phases) ≈ 11.98 mJ
        assert!((core.board.fpga_energy.millijoules() - 11.983).abs() < 0.01);
    }

    #[test]
    fn apply_gap_zero_idle_still_switches_mode() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        core.configure("lstm").unwrap();
        core.run_phases().unwrap();
        let before = core.board.fpga_energy;
        core.apply_gap(GapAction::Idle(PowerSaving::M12), Duration::ZERO)
            .unwrap();
        assert_eq!(core.board.fpga_energy, before);
        assert_eq!(core.board.fpga.state, FpgaState::Idle(PowerSaving::M12));
    }

    #[test]
    fn power_off_gap_loses_configuration() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        core.configure("lstm").unwrap();
        core.run_phases().unwrap();
        core.apply_gap(GapAction::PowerOff, Duration::from_millis(3.8))
            .unwrap();
        assert!(!core.is_ready());
        // paper model: the off state draws nothing
        let e = core.board.fpga_energy;
        core.elapse(PowerSaving::BASELINE, Duration::from_secs(1.0)).unwrap();
        assert_eq!(core.board.fpga_energy, e);
    }

    #[test]
    fn elapse_while_configured_charges_idle_power() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        core.configure("lstm").unwrap();
        core.run_phases().unwrap();
        let before = core.board.fpga_energy;
        core.elapse(PowerSaving::M12, Duration::from_secs(1.0)).unwrap();
        let drawn = core.board.fpga_energy - before;
        assert!((drawn.millijoules() - 24.0).abs() < 0.1, "{}", drawn.millijoules());
    }

    #[test]
    fn power_cycle_configure_counts_a_new_configuration() {
        let cfg = paper_default();
        let mut core = ReplayCore::from_config(&cfg);
        core.configure("lstm").unwrap();
        core.power_cycle_configure("lstm").unwrap();
        assert_eq!(core.board.fpga.configurations, 2);
        assert_eq!(core.board.fpga.power_ons, 2);
        assert!(core.is_ready());
    }
}
