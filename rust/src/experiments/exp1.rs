//! Experiment 1 (paper §5.2, Fig 7): configuration-phase optimization.
//!
//! Sweeps the three bitstream-loading knobs of Table 1 — SPI buswidth
//! {1,2,4} × clock {3..66 MHz, 11 values} × compression {off,on} — on the
//! synthetic-bitstream device model and reports, per setting, the
//! time/power/energy of the configuration phase and of its Setup and
//! Bitstream-Loading stages: exactly Fig 7's 3×3 grid of series, plus the
//! paper's XC7S25 spot-check.

use crate::config::schema::{FpgaModel, SpiConfig};
use crate::device::bitstream::Bitstream;
use crate::device::config_fsm::ConfigProfile;
use crate::device::flash::StoredImage;
use crate::experiments::paper;
use crate::runner::{Grid, SweepRunner};
use crate::util::csv::Csv;
use crate::util::table::{fnum, Table};

/// One sweep point of Fig 7.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// The SPI setting of this sweep point.
    pub spi: SpiConfig,
    /// The configuration profile the FSM produced for it.
    pub profile: ConfigProfile,
}

impl SweepPoint {
    /// Configuration time in ms.
    pub fn config_time_ms(&self) -> f64 {
        self.profile.total_time().millis()
    }

    /// Configuration energy in mJ.
    pub fn config_energy_mj(&self) -> f64 {
        self.profile.total_energy().millijoules()
    }

    /// Average configuration power in mW.
    pub fn config_power_mw(&self) -> f64 {
        self.profile.avg_power().milliwatts()
    }
}

/// Full Experiment 1 results.
#[derive(Debug, Clone)]
pub struct Exp1Result {
    /// FPGA model swept.
    pub model: FpgaModel,
    /// All 66 sweep points (Table 1 grid).
    pub points: Vec<SweepPoint>,
}

/// Run the 66-point sweep for `model`. Single-threaded; see
/// [`run_threaded`] for the parallel path.
pub fn run(model: FpgaModel) -> Exp1Result {
    run_threaded(model, &SweepRunner::single())
}

/// The Table 1 configuration-setting sweep as a grid declaration on the
/// sweep engine.
pub fn run_threaded(model: FpgaModel, runner: &SweepRunner) -> Exp1Result {
    let bitstream = Bitstream::lstm_accelerator(model);
    // the stored image depends on compression only, not on the SPI grid
    // point: synthesize (and compress) it once per variant instead of
    // once per cell — 66 cells share two images
    let plain = StoredImage::new(bitstream.clone(), false);
    let compressed = StoredImage::new(bitstream, true);
    let grid = Grid::new(SpiConfig::sweep());
    let points = runner.run(&grid, |cell| {
        let spi = *cell.params;
        let image = if spi.compressed { &compressed } else { &plain };
        SweepPoint {
            spi,
            profile: ConfigProfile::compute(model, spi, image),
        }
    });
    Exp1Result { model, points }
}

impl Exp1Result {
    /// The sweep point for an exact SPI setting.
    pub fn point(&self, spi: SpiConfig) -> &SweepPoint {
        self.points
            .iter()
            .find(|p| p.spi == spi)
            .expect("sweep covers all settings")
    }

    /// The paper's optimal setting's point.
    pub fn optimal(&self) -> &SweepPoint {
        self.point(SpiConfig::optimal())
    }

    /// The paper's worst setting's point.
    pub fn worst(&self) -> &SweepPoint {
        self.point(SpiConfig::worst())
    }

    /// The headline 40.13× energy reduction.
    pub fn energy_improvement(&self) -> f64 {
        self.worst().config_energy_mj() / self.optimal().config_energy_mj()
    }

    /// The headline 41.4× time reduction.
    pub fn time_improvement(&self) -> f64 {
        self.worst().config_time_ms() / self.optimal().config_time_ms()
    }

    /// Fig 7's selected frequencies (3, 33, 66 MHz) as a printed table —
    /// the same data points the paper plots "due to space constraints".
    pub fn render_fig7(&self) -> String {
        let mut out = String::new();
        for (metric, extract) in [
            (
                "time (ms)",
                Box::new(|p: &SweepPoint, stage: &str| match stage {
                    "config" => p.config_time_ms(),
                    "setup" => p.profile.setup().time.millis(),
                    _ => p.profile.loading().time.millis(),
                }) as Box<dyn Fn(&SweepPoint, &str) -> f64>,
            ),
            (
                "power (mW)",
                Box::new(|p: &SweepPoint, stage: &str| match stage {
                    "config" => p.config_power_mw(),
                    "setup" => p.profile.setup().power.milliwatts(),
                    _ => p.profile.loading().power.milliwatts(),
                }),
            ),
            (
                "energy (mJ)",
                Box::new(|p: &SweepPoint, stage: &str| match stage {
                    "config" => p.config_energy_mj(),
                    "setup" => p.profile.setup().energy().millijoules(),
                    _ => p.profile.loading().energy().millijoules(),
                }),
            ),
        ] {
            for stage in ["config", "setup", "loading"] {
                let mut t = Table::new(&["buswidth", "compressed", "3 MHz", "33 MHz", "66 MHz"])
                    .with_title(format!(
                        "Fig 7 [{}] — {} stage ({})",
                        metric, stage, self.model
                    ));
                for &compressed in &[false, true] {
                    for &buswidth in &SpiConfig::BUSWIDTHS {
                        let cells: Vec<String> = [3.0, 33.0, 66.0]
                            .iter()
                            .map(|&freq_mhz| {
                                let p = self.point(SpiConfig {
                                    buswidth,
                                    freq_mhz,
                                    compressed,
                                });
                                fnum(extract(p, stage), 3)
                            })
                            .collect();
                        t.row(&[
                            buswidth.to_string(),
                            compressed.to_string(),
                            cells[0].clone(),
                            cells[1].clone(),
                            cells[2].clone(),
                        ]);
                    }
                }
                out.push_str(&t.render());
                out.push('\n');
            }
        }
        out
    }

    /// Headline summary with paper comparison.
    pub fn render_summary(&self) -> String {
        let mut t = Table::new(&["metric", "paper", "measured"])
            .with_title(format!("Experiment 1 summary ({})", self.model));
        let opt = self.optimal();
        let worst = self.worst();
        t.row(&[
            "optimal config time (ms)".into(),
            fnum(paper::exp1::OPT_TIME_MS, 3),
            fnum(opt.config_time_ms(), 3),
        ]);
        t.row(&[
            "optimal config energy (mJ)".into(),
            fnum(paper::exp1::OPT_ENERGY_MJ, 2),
            fnum(opt.config_energy_mj(), 2),
        ]);
        t.row(&[
            "optimal config power (mW)".into(),
            fnum(paper::exp1::OPT_POWER_MW, 1),
            fnum(opt.config_power_mw(), 1),
        ]);
        t.row(&[
            "worst config energy (mJ)".into(),
            fnum(paper::exp1::WORST_ENERGY_MJ, 2),
            fnum(worst.config_energy_mj(), 2),
        ]);
        t.row(&[
            "energy improvement (×)".into(),
            fnum(paper::exp1::ENERGY_IMPROVEMENT, 2),
            fnum(self.energy_improvement(), 2),
        ]);
        t.row(&[
            "time improvement (×)".into(),
            fnum(paper::exp1::TIME_IMPROVEMENT, 1),
            fnum(self.time_improvement(), 1),
        ]);
        t.render()
    }

    /// Full-sweep CSV (all 66 points × all stages).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "buswidth",
            "freq_mhz",
            "compressed",
            "config_time_ms",
            "config_power_mw",
            "config_energy_mj",
            "setup_time_ms",
            "setup_power_mw",
            "setup_energy_mj",
            "loading_time_ms",
            "loading_power_mw",
            "loading_energy_mj",
        ]);
        for p in &self.points {
            csv.row_f64(&[
                p.spi.buswidth as f64,
                p.spi.freq_mhz,
                p.spi.compressed as u8 as f64,
                p.config_time_ms(),
                p.config_power_mw(),
                p.config_energy_mj(),
                p.profile.setup().time.millis(),
                p.profile.setup().power.milliwatts(),
                p.profile.setup().energy().millijoules(),
                p.profile.loading().time.millis(),
                p.profile.loading().power.milliwatts(),
                p.profile.loading().energy().millijoules(),
            ]);
        }
        csv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_numbers_reproduce() {
        let r = run(FpgaModel::Xc7s15);
        assert_eq!(r.points.len(), 66);
        assert!((r.optimal().config_time_ms() - 36.145).abs() < 0.01);
        assert!((r.optimal().config_energy_mj() - 11.85).abs() < 0.02);
        assert!((r.energy_improvement() - 40.13).abs() < 0.15);
        assert!((r.time_improvement() - 41.4).abs() < 0.1);
    }

    #[test]
    fn xc7s25_matches_paper_spotcheck() {
        let r = run(FpgaModel::Xc7s25);
        assert!((r.optimal().config_time_ms() - 38.09).abs() < 0.05);
        assert!((r.optimal().config_energy_mj() - 13.75).abs() < 0.05);
    }

    #[test]
    fn energy_monotone_decreasing_in_freq_at_fixed_width() {
        // the paper's key trend: higher frequency → lower config energy
        let r = run(FpgaModel::Xc7s15);
        for &buswidth in &SpiConfig::BUSWIDTHS {
            for &compressed in &[false, true] {
                let mut last = f64::INFINITY;
                for &freq_mhz in &SpiConfig::FREQS_MHZ {
                    let e = r
                        .point(SpiConfig {
                            buswidth,
                            freq_mhz,
                            compressed,
                        })
                        .config_energy_mj();
                    assert!(e < last, "w={buswidth} c={compressed} f={freq_mhz}");
                    last = e;
                }
            }
        }
    }

    #[test]
    fn compression_always_helps_energy() {
        let r = run(FpgaModel::Xc7s15);
        for &buswidth in &SpiConfig::BUSWIDTHS {
            for &freq_mhz in &SpiConfig::FREQS_MHZ {
                let on = r.point(SpiConfig { buswidth, freq_mhz, compressed: true });
                let off = r.point(SpiConfig { buswidth, freq_mhz, compressed: false });
                assert!(on.config_energy_mj() < off.config_energy_mj());
            }
        }
    }

    #[test]
    fn renders_and_csv() {
        let r = run(FpgaModel::Xc7s15);
        let fig7 = r.render_fig7();
        assert!(fig7.contains("Fig 7 [time (ms)] — config stage"));
        assert!(fig7.contains("Fig 7 [energy (mJ)] — loading stage"));
        let summary = r.render_summary();
        assert!(summary.contains("40.13"));
        assert_eq!(r.to_csv().n_rows(), 66);
    }
}
