//! Validation experiment (paper §5.3): model vs "measurement".
//!
//! The paper validates its simulator against direct hardware measurement
//! at a 40 ms request period (2.8% gap in items, 2.7% in lifetime). We
//! have no hardware, so the validation chain becomes:
//!
//! * **analytical model** (Eqs 1–4, what the paper's simulator computes)
//!   vs the **discrete-event simulation** of the full device substrate —
//!   these must agree almost exactly on item counts (same physics,
//!   mechanism vs closed form), and
//! * **exact energy integral** vs the **PAC1934-sampled energy** the DES
//!   monitor records — the instrument-side gap, which is the physical
//!   origin of the paper's few-percent hardware-vs-simulator discrepancy.

use crate::config::loader::SimConfig;
use crate::config::schema::PolicySpec;
use crate::coordinator::requests::Periodic;
use crate::energy::analytical::Analytical;
use crate::experiments::paper;
use crate::runner::{Grid, SweepRunner};
use crate::strategies::simulate::{SimReport, SimWorker};
use crate::strategies::strategy::build;
use crate::util::table::{fcount, fnum, Table};
use crate::util::units::Duration;

/// One policy's validation row.
#[derive(Debug, Clone)]
pub struct Row {
    /// Policy validated.
    pub policy: PolicySpec,
    /// Eq 3 items from the analytical model.
    pub analytical_items: u64,
    /// Items from the discrete-event simulation.
    pub des_items: u64,
    /// Relative items gap (DES vs analytical).
    pub items_gap: f64,
    /// Analytical lifetime (hours).
    pub analytical_lifetime_h: f64,
    /// DES lifetime (hours).
    pub des_lifetime_h: f64,
    /// Relative lifetime gap.
    pub lifetime_gap: f64,
    /// PAC1934 instrument error in the DES run.
    pub monitor_rel_error: f64,
}

/// Full validation results at one request period.
#[derive(Debug, Clone)]
pub struct ValidationResult {
    /// Request period validated at (ms).
    pub t_req_ms: f64,
    /// One row per validated policy.
    pub rows: Vec<Row>,
}

/// Run the validation at `t_req_ms` (paper uses 40 ms). Single-threaded;
/// see [`run_threaded`] for the parallel path.
pub fn run(config: &SimConfig, t_req_ms: f64) -> ValidationResult {
    run_threaded(config, t_req_ms, &SweepRunner::single())
}

/// The per-policy validation as a grid on the sweep engine — each cell
/// is a full DES lifetime run, so the two policies validate in
/// parallel when the runner has ≥ 2 threads.
pub fn run_threaded(config: &SimConfig, t_req_ms: f64, runner: &SweepRunner) -> ValidationResult {
    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let t_req = Duration::from_millis(t_req_ms);
    let grid = Grid::new(vec![PolicySpec::OnOff, PolicySpec::IdleWaiting]);
    let rows = runner.run_with_state(
        &grid,
        || SimWorker::new(config),
        |worker, cell| {
            let kind = *cell.params;
            let prediction = model.predict(kind, t_req);
            let analytical_items = prediction.n_max.expect("feasible period");
            let mut policy = build(kind, &model);
            let mut arrivals = Periodic { period: t_req };
            let report: SimReport = worker.run(config, policy.as_mut(), &mut arrivals);
            let des_lifetime_h = report.lifetime.hours();
            let analytical_lifetime_h = prediction.lifetime.hours();
            Row {
                policy: kind,
                analytical_items,
                des_items: report.items,
                items_gap: (report.items as f64 - analytical_items as f64).abs()
                    / analytical_items as f64,
                analytical_lifetime_h,
                des_lifetime_h,
                lifetime_gap: (des_lifetime_h - analytical_lifetime_h).abs()
                    / analytical_lifetime_h,
                monitor_rel_error: report.monitor_rel_error,
            }
        },
    );
    ValidationResult { t_req_ms, rows }
}

impl ValidationResult {
    /// The row for a policy.
    pub fn row(&self, kind: PolicySpec) -> &Row {
        self.rows
            .iter()
            .find(|r| r.policy == kind)
            .expect("policy present")
    }

    /// Render the validation table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "policy",
            "items (Eq 3)",
            "items (DES)",
            "gap (%)",
            "lifetime (Eq 4, h)",
            "lifetime (DES, h)",
            "monitor err (%)",
        ])
        .with_title(format!(
            "Validation at {} ms (paper §5.3: hw-vs-sim gaps were {:.1}% / {:.1}%)",
            self.t_req_ms,
            paper::exp2::HW_ITEMS_GAP * 100.0,
            paper::exp2::HW_LIFETIME_GAP * 100.0
        ));
        for r in &self.rows {
            t.row(&[
                r.policy.name().into(),
                fcount(r.analytical_items),
                fcount(r.des_items),
                fnum(r.items_gap * 100.0, 4),
                fnum(r.analytical_lifetime_h, 3),
                fnum(r.des_lifetime_h, 3),
                fnum(r.monitor_rel_error * 100.0, 3),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    #[test]
    fn des_agrees_with_analytical_at_40ms() {
        let result = run(&paper_default(), 40.0);
        for row in &result.rows {
            // mechanism vs closed form: far tighter than the paper's
            // hardware-vs-simulator 2.8%
            assert!(
                row.items_gap < 0.002,
                "{}: items {} vs {}",
                row.policy,
                row.des_items,
                row.analytical_items
            );
            assert!(row.lifetime_gap < 0.002, "{}", row.policy);
            // the instrument gap is nonzero but bounded (paper-level few %)
            assert!(row.monitor_rel_error < 0.03, "{}", row.monitor_rel_error);
        }
    }

    #[test]
    fn onoff_des_item_count_matches_paper() {
        let result = run(&paper_default(), 40.0);
        let onoff = result.row(PolicySpec::OnOff);
        assert!(onoff.des_items.abs_diff(paper::exp2::ONOFF_ITEMS) < 300, "{}", onoff.des_items);
    }

    #[test]
    fn render_mentions_paper_gaps() {
        let s = run(&paper_default(), 40.0).render();
        assert!(s.contains("2.8%"));
        assert!(s.contains("on-off"));
    }
}
