//! Experiment 5 (beyond the paper): scheduling policy × offered load on
//! the multi-client serving coordinator.
//!
//! The paper serves one periodic client. This grid asks what happens
//! when several clients share the board: every scheduling policy
//! (FIFO and same-slot batching at three window sizes) runs against
//! four offered-load levels (0.5× to 4× the nominal per-board rate),
//! with Poisson sources alternating between the two accelerator slots.
//! Each cell runs the full [`serve_multi`] coordinator — admission
//! bound, batching window, gap policy and energy ledger on one clock —
//! and reports served/dropped counts, reconfigurations, energy and the
//! sojourn-time SLA percentiles.
//!
//! Determinism: every policy row of a load column replays the *same*
//! materialized source columns (drawn once per load from seeds derived
//! off the experiment seed, Arc-shared across rows), and cells are pure
//! functions of their grid point — so the CSV is byte-identical at any
//! `--threads N`.

use crate::config::loader::SimConfig;
use crate::config::schema::{PolicyParams, PolicySpec};
use crate::coordinator::scheduler::Policy as SchedPolicy;
use crate::coordinator::serving::{poisson_sources, serve_multi, MultiServeOptions, ServeSource};
use crate::runner::grid::{cross, derive_seed};
use crate::runner::SweepRunner;
use crate::util::csv::Csv;
use crate::util::table::{fcount, fnum, Table};
use crate::util::units::Duration;

/// The scheduling-policy axis, in output order.
pub const POLICIES: [(&str, SchedPolicy); 4] = [
    ("fifo", SchedPolicy::Fifo),
    ("batch-4", SchedPolicy::BatchBySlot { window: 4 }),
    ("batch-8", SchedPolicy::BatchBySlot { window: 8 }),
    ("batch-16", SchedPolicy::BatchBySlot { window: 16 }),
];

/// The offered-load axis: multiples of the nominal per-board rate
/// (1.0× = one request per `period_ms` across all sources combined).
pub const LOADS: [(&str, f64); 4] = [
    ("0.5x", 0.5),
    ("1.0x", 1.0),
    ("2.0x", 2.0),
    ("4.0x", 4.0),
];

/// Admission bound every cell runs with.
const MAX_QUEUE: usize = 64;

/// Per-run parameters.
#[derive(Debug, Clone)]
pub struct Exp5Config {
    /// Requests generated per source.
    pub requests: usize,
    /// Concurrent client sources (alternating accelerator slots).
    pub sources: usize,
    /// Nominal per-board mean inter-arrival time at 1.0× load (ms).
    pub period_ms: f64,
    /// Experiment seed; source streams derive from it per load column.
    pub seed: u64,
}

impl Default for Exp5Config {
    fn default() -> Self {
        Exp5Config {
            requests: 250,
            sources: 4,
            period_ms: 40.0,
            seed: 5,
        }
    }
}

/// One grid cell's outcome.
#[derive(Debug, Clone)]
pub struct Exp5Row {
    /// Scheduling-policy label (`fifo`, `batch-8`, …).
    pub policy: &'static str,
    /// Offered-load label (`0.5x`, …).
    pub load: &'static str,
    /// Requests served to completion.
    pub served: u64,
    /// Requests dropped at admission.
    pub dropped: u64,
    /// FPGA configurations performed.
    pub reconfigurations: u64,
    /// Exact FPGA-side energy drawn (mJ).
    pub energy_mj: f64,
    /// Sojourn-time percentiles (ms); zero when nothing was served.
    pub sojourn_p50_ms: f64,
    /// 95th-percentile sojourn (ms).
    pub sojourn_p95_ms: f64,
    /// 99th-percentile sojourn (ms).
    pub sojourn_p99_ms: f64,
    /// Deadline-miss rate over served requests.
    pub miss_rate: f64,
    /// Drop rate over offered requests.
    pub drop_rate: f64,
}

/// Full Experiment 5 results (row-major: policy outer, load inner).
#[derive(Debug, Clone)]
pub struct Exp5Result {
    /// All grid cells in row-major order.
    pub rows: Vec<Exp5Row>,
    /// Requests per source.
    pub requests: usize,
    /// Concurrent sources.
    pub sources: usize,
}

/// Run the grid single-threaded; see [`run_threaded`] for the parallel
/// path.
pub fn run(config: &SimConfig, e5: &Exp5Config) -> Exp5Result {
    run_threaded(config, e5, &SweepRunner::single())
}

/// The scheduling-policy × offered-load grid on the sweep engine.
pub fn run_threaded(config: &SimConfig, e5: &Exp5Config, runner: &SweepRunner) -> Exp5Result {
    let sources = e5.sources.max(1);
    // One materialized source set per load column, Arc-shared by every
    // policy row: same arrivals, different scheduling. The deadline
    // slack tracks the per-source mean gap, so "equal miss pressure"
    // holds across load levels.
    let columns: Vec<Vec<ServeSource>> = LOADS
        .iter()
        .enumerate()
        .map(|(load_idx, (_, factor))| {
            let mean_gap = Duration::from_millis(e5.period_ms * sources as f64 / factor);
            poisson_sources(
                sources,
                e5.requests,
                mean_gap,
                mean_gap,
                derive_seed(e5.seed, 0x200 + load_idx as u64),
            )
        })
        .collect();

    let load_axis: Vec<usize> = (0..LOADS.len()).collect();
    let grid = cross(&POLICIES, &load_axis);
    let rows = runner.run(&grid, |cell| {
        let ((policy_name, sched), load_idx) = cell.params;
        let (load_name, _) = LOADS[*load_idx];
        let opts = MultiServeOptions {
            sched: *sched,
            max_queue: MAX_QUEUE,
            gap_policy: PolicySpec::IdleWaitingM12,
            params: PolicyParams::default(),
        };
        let r = serve_multi(config, &opts, &columns[*load_idx]);
        let sojourn = r.metrics.sojourn_summary();
        Exp5Row {
            policy: *policy_name,
            load: load_name,
            served: r.served,
            dropped: r.metrics.dropped,
            reconfigurations: r.reconfigurations,
            energy_mj: r.metrics.sim_energy.millijoules(),
            sojourn_p50_ms: sojourn.as_ref().map_or(0.0, |s| s.p50),
            sojourn_p95_ms: sojourn.as_ref().map_or(0.0, |s| s.p95),
            sojourn_p99_ms: sojourn.as_ref().map_or(0.0, |s| s.p99),
            miss_rate: r.metrics.miss_rate(),
            drop_rate: r.metrics.drop_rate(),
        }
    });
    Exp5Result {
        rows,
        requests: e5.requests,
        sources,
    }
}

impl Exp5Result {
    /// The row for a (policy label, load label) cell.
    pub fn row(&self, policy: &str, load: &str) -> &Exp5Row {
        self.rows
            .iter()
            .find(|r| r.policy == policy && r.load == load)
            .expect("cell present")
    }

    /// Render the ASCII results table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "policy",
            "load",
            "served",
            "dropped",
            "reconfigs",
            "energy (mJ)",
            "sojourn p95 (ms)",
            "miss rate",
            "drop rate",
        ])
        .with_title(format!(
            "Experiment 5: scheduling x load ({} sources x {} requests)",
            self.sources, self.requests
        ));
        for r in &self.rows {
            t.row(&[
                r.policy.into(),
                r.load.into(),
                fcount(r.served),
                fcount(r.dropped),
                fcount(r.reconfigurations),
                fnum(r.energy_mj, 2),
                fnum(r.sojourn_p95_ms, 3),
                fnum(r.miss_rate, 4),
                fnum(r.drop_rate, 4),
            ]);
        }
        t.render()
    }

    /// The grid as CSV (the published `repro exp5 --csv` schema).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "policy",
            "load",
            "served",
            "dropped",
            "reconfigs",
            "energy_mj",
            "sojourn_p50_ms",
            "sojourn_p95_ms",
            "sojourn_p99_ms",
            "miss_rate",
            "drop_rate",
        ]);
        for r in &self.rows {
            csv.row(&[
                r.policy.to_string(),
                r.load.to_string(),
                r.served.to_string(),
                r.dropped.to_string(),
                r.reconfigurations.to_string(),
                format!("{}", r.energy_mj),
                format!("{}", r.sojourn_p50_ms),
                format!("{}", r.sojourn_p95_ms),
                format!("{}", r.sojourn_p99_ms),
                format!("{}", r.miss_rate),
                format!("{}", r.drop_rate),
            ]);
        }
        csv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn small() -> Exp5Config {
        Exp5Config {
            requests: 60,
            sources: 4,
            period_ms: 40.0,
            seed: 5,
        }
    }

    #[test]
    fn grid_covers_every_policy_and_load() {
        let r = run(&paper_default(), &small());
        assert_eq!(r.rows.len(), POLICIES.len() * LOADS.len());
        for (policy, _) in POLICIES {
            for (load, _) in LOADS {
                let row = r.row(policy, load);
                assert_eq!(row.served + row.dropped, 4 * 60, "{policy}/{load}");
            }
        }
    }

    #[test]
    fn rows_of_a_load_column_see_the_same_arrivals() {
        // the offered total is a property of the column, not the policy
        let r = run(&paper_default(), &small());
        for (load, _) in LOADS {
            let offered: Vec<u64> = POLICIES
                .iter()
                .map(|(p, _)| {
                    let row = r.row(p, load);
                    row.served + row.dropped
                })
                .collect();
            assert!(offered.windows(2).all(|w| w[0] == w[1]), "{load}: {offered:?}");
        }
    }

    #[test]
    fn batching_amortizes_switches_under_pressure() {
        // at 4x load the queue backs up, which is exactly where the
        // batching window pays: fewer switches than FIFO, less energy
        let r = run(&paper_default(), &small());
        let fifo = r.row("fifo", "4.0x");
        let batched = r.row("batch-16", "4.0x");
        assert!(
            batched.reconfigurations < fifo.reconfigurations,
            "batched {} vs fifo {}",
            batched.reconfigurations,
            fifo.reconfigurations
        );
        assert!(batched.energy_mj < fifo.energy_mj);
    }

    #[test]
    fn renders_and_csv() {
        let r = run(&paper_default(), &small());
        assert!(r.render().contains("Experiment 5"));
        let csv = r.to_csv();
        assert_eq!(csv.n_rows(), r.rows.len());
        assert!(csv.render().starts_with("policy,load,served"));
    }

    // Thread-count invariance (threads=1 vs N byte-identical CSV) is
    // covered by tests/serve_determinism.rs.
}
