//! Experiment 2 (paper §5.3, Table 2, Figs 8–9): Idle-Waiting vs On-Off.
//!
//! Sweeps the request period 10–120 ms at the paper's 0.01 ms resolution
//! through the analytical model (which is what the paper's simulator
//! implements), producing the Fig 8 executable-item series and the Fig 9
//! lifetime series, the 89.21 ms crossover, and the 40 ms case study.

use crate::config::loader::SimConfig;
use crate::energy::analytical::Analytical;
use crate::energy::crossover;
use crate::experiments::paper;
use crate::runner::{Grid, SweepRunner};
use crate::util::csv::Csv;
use crate::util::table::{fcount, fnum, Table};
use crate::util::units::Duration;

/// One sweep sample.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// Request period of the sample (ms).
    pub t_req_ms: f64,
    /// None = infeasible (On-Off below the configuration time).
    pub onoff_items: Option<u64>,
    /// Idle-Waiting items (Eq 3).
    pub iw_items: u64,
    /// On-Off lifetime in hours (None where infeasible).
    pub onoff_lifetime_h: Option<f64>,
    /// Idle-Waiting lifetime in hours.
    pub iw_lifetime_h: f64,
}

/// Full Experiment 2 results.
#[derive(Debug, Clone)]
pub struct Exp2Result {
    /// The swept samples, in period order.
    pub samples: Vec<Sample>,
    /// Measured efficiency crossover (ms).
    pub crossover_ms: f64,
    /// Sweep step used (ms).
    pub step_ms: f64,
}

/// Run the sweep with the paper's parameters (or a coarser step for quick
/// runs — pass `step_ms = 0.01` for full fidelity). Single-threaded; see
/// [`run_threaded`] for the parallel path.
pub fn run(config: &SimConfig, step_ms: f64) -> Exp2Result {
    run_threaded(config, step_ms, &SweepRunner::single())
}

/// The T_req sweep as a grid declaration on the sweep engine. Output is
/// byte-identical at any thread count (each cell is a pure function of
/// its grid point).
pub fn run_threaded(config: &SimConfig, step_ms: f64, runner: &SweepRunner) -> Exp2Result {
    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let p_idle = model.item.idle_power_baseline;
    let grid = Grid::stepped(paper::exp2::T_REQ_MIN_MS, paper::exp2::T_REQ_MAX_MS, step_ms);
    let samples = runner.run(&grid, |cell| {
        let t = *cell.params;
        let t_req = Duration::from_millis(t);
        let onoff_items = model.n_max_onoff(t_req);
        let iw_items = model.n_max_idle_waiting(t_req, p_idle).unwrap_or(0);
        Sample {
            t_req_ms: t,
            onoff_items,
            iw_items,
            onoff_lifetime_h: onoff_items.map(|n| (t_req * n as f64).hours()),
            iw_lifetime_h: (t_req * iw_items as f64).hours(),
        }
    });
    Exp2Result {
        samples,
        crossover_ms: crossover::asymptotic(&model, p_idle).millis(),
        step_ms,
    }
}

impl Exp2Result {
    /// The sample at an exact period (ms).
    pub fn at(&self, t_req_ms: f64) -> &Sample {
        self.samples
            .iter()
            .min_by(|a, b| {
                (a.t_req_ms - t_req_ms)
                    .abs()
                    .partial_cmp(&(b.t_req_ms - t_req_ms).abs())
                    .unwrap()
            })
            .expect("non-empty sweep")
    }

    /// The paper's 40 ms case-study ratio.
    pub fn ratio_at_40ms(&self) -> f64 {
        let s = self.at(40.0);
        s.iw_items as f64 / s.onoff_items.expect("40 ms is feasible") as f64
    }

    /// Average Idle-Waiting lifetime across the sweep (paper: ≈8.58 h).
    pub fn iw_avg_lifetime_h(&self) -> f64 {
        self.samples.iter().map(|s| s.iw_lifetime_h).sum::<f64>() / self.samples.len() as f64
    }

    /// Fig 8 + Fig 9 at the paper's displayed 10 ms intervals.
    pub fn render_figs(&self) -> String {
        let mut t = Table::new(&[
            "T_req (ms)",
            "On-Off items",
            "Idle-Waiting items",
            "On-Off lifetime (h)",
            "Idle-Waiting lifetime (h)",
        ])
        .with_title("Fig 8 (items) + Fig 9 (lifetime): Idle-Waiting vs On-Off");
        let mut ms = 10.0;
        while ms <= 120.0 + 1e-9 {
            let s = self.at(ms);
            t.row(&[
                fnum(ms, 0),
                s.onoff_items.map(fcount).unwrap_or_else(|| "—".into()),
                fcount(s.iw_items),
                s.onoff_lifetime_h
                    .map(|h| fnum(h, 2))
                    .unwrap_or_else(|| "—".into()),
                fnum(s.iw_lifetime_h, 2),
            ]);
            ms += 10.0;
        }
        t.render()
    }

    /// Table 2 echo + headline summary with paper comparison.
    pub fn render_summary(&self, config: &SimConfig) -> String {
        let mut out = String::new();
        let mut t2 = Table::new(&["phase", "power (mW)", "time (ms)"])
            .with_title("Table 2: workload-item characterization");
        for (name, p, ms) in [
            ("configuration", config.item.configuration.power, config.item.configuration.time),
            ("data loading", config.item.data_loading.power, config.item.data_loading.time),
            ("inference", config.item.inference.power, config.item.inference.time),
            ("data offloading", config.item.data_offloading.power, config.item.data_offloading.time),
        ] {
            t2.row(&[name.into(), fnum(p.milliwatts(), 1), fnum(ms.millis(), 4)]);
        }
        t2.row(&[
            "idle-waiting".into(),
            fnum(config.item.idle_power.milliwatts(), 1),
            "varying".into(),
        ]);
        out.push_str(&t2.render());
        out.push('\n');

        let s40 = self.at(40.0);
        let mut t = Table::new(&["metric", "paper", "measured"])
            .with_title("Experiment 2 summary");
        t.row(&[
            "On-Off items".into(),
            fcount(paper::exp2::ONOFF_ITEMS),
            s40.onoff_items.map(fcount).unwrap_or_default(),
        ]);
        t.row(&[
            "Idle-Waiting items @10 ms".into(),
            fcount(paper::exp2::IW_ITEMS_MAX),
            fcount(self.at(10.0).iw_items),
        ]);
        t.row(&[
            "Idle-Waiting items @120 ms".into(),
            fcount(paper::exp2::IW_ITEMS_MIN),
            fcount(self.at(120.0).iw_items),
        ]);
        t.row(&[
            "ratio @40 ms (×)".into(),
            fnum(paper::exp2::RATIO_AT_40MS, 2),
            fnum(self.ratio_at_40ms(), 2),
        ]);
        t.row(&[
            "crossover (ms)".into(),
            fnum(paper::exp2::CROSSOVER_MS, 2),
            fnum(self.crossover_ms, 2),
        ]);
        t.row(&[
            "Idle-Waiting avg lifetime (h)".into(),
            fnum(paper::exp2::IW_AVG_LIFETIME_H, 2),
            fnum(self.iw_avg_lifetime_h(), 2),
        ]);
        out.push_str(&t.render());
        out
    }

    /// The sweep series as CSV (the published `--csv` schema).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "t_req_ms",
            "onoff_items",
            "iw_items",
            "onoff_lifetime_h",
            "iw_lifetime_h",
        ]);
        for s in &self.samples {
            csv.row(&[
                format!("{}", s.t_req_ms),
                s.onoff_items.map(|n| n.to_string()).unwrap_or_default(),
                s.iw_items.to_string(),
                s.onoff_lifetime_h.map(|h| format!("{h}")).unwrap_or_default(),
                format!("{}", s.iw_lifetime_h),
            ]);
        }
        csv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn result() -> Exp2Result {
        run(&paper_default(), 1.0) // coarse step for unit tests
    }

    #[test]
    fn reproduces_fig8_endpoints() {
        let r = result();
        assert!(r.at(10.0).iw_items.abs_diff(paper::exp2::IW_ITEMS_MAX) < 600);
        assert!(r.at(120.0).iw_items.abs_diff(paper::exp2::IW_ITEMS_MIN) < 60);
        assert!(r
            .at(40.0)
            .onoff_items
            .unwrap()
            .abs_diff(paper::exp2::ONOFF_ITEMS)
            < 150);
    }

    #[test]
    fn onoff_gap_below_36_15ms() {
        let r = result();
        assert!(r.at(36.0).onoff_items.is_none());
        assert!(r.at(37.0).onoff_items.is_some());
    }

    #[test]
    fn crossover_and_ratio() {
        let r = result();
        assert!((r.crossover_ms - 89.21).abs() < 0.05, "{}", r.crossover_ms);
        assert!((r.ratio_at_40ms() - 2.23).abs() < 0.01);
    }

    #[test]
    fn iw_lifetime_flat_onoff_linear() {
        let r = result();
        // IW ≈ flat around 8.58 h
        assert!((r.iw_avg_lifetime_h() - 8.58).abs() < 0.03);
        // On-Off linear: lifetime(120)/lifetime(40) = 3
        let l40 = r.at(40.0).onoff_lifetime_h.unwrap();
        let l120 = r.at(120.0).onoff_lifetime_h.unwrap();
        assert!((l120 / l40 - 3.0).abs() < 1e-6);
    }

    #[test]
    fn renders() {
        let cfg = paper_default();
        let r = result();
        let figs = r.render_figs();
        assert!(figs.contains("Fig 8"));
        assert!(figs.contains("—")); // infeasible markers below 36.15 ms
        let summary = r.render_summary(&cfg);
        assert!(summary.contains("Table 2"));
        assert!(summary.contains("89.21"));
        assert!(r.to_csv().n_rows() > 100);
    }

    #[test]
    fn full_resolution_sweep_matches_paper_grid() {
        let r = run(&paper_default(), paper::exp2::T_REQ_STEP_MS);
        // 10..120 ms at 0.01 ms = 11,001 samples
        assert_eq!(r.samples.len(), 11_001);
    }

    // Thread-count invariance (threads=1 vs N byte-identical CSV) is
    // covered by tests/sweep_determinism.rs.
}
