//! Experiment 4 (beyond the paper; its §7 future work made concrete):
//! online gap policies × arrival processes.
//!
//! The paper's evaluation is strictly periodic, where the best policy is
//! a compile-time choice. This grid measures what happens when arrivals
//! are *not* periodic and the policy must decide online: every
//! [`PolicySpec`] runs against four arrival processes — periodic,
//! jittered, Poisson and a bursty trace replay — on the shared
//! [`SweepRunner`], and each cell reports energy, lifetime, mean served
//! latency and the gap-decision counters that explain *why* a policy
//! wins (gaps idled / powered off / timers expired), per the
//! [`SimReport`] ledger.
//!
//! Determinism: every policy row sees the *same* arrival stream per
//! arrival column (seeds derive from the experiment seed and the arrival
//! column only), and cells are pure functions of their grid point, so
//! the CSV is byte-identical at any `--threads N`.

use crate::config::loader::SimConfig;
use crate::config::schema::{ArrivalSpec, PolicySpec};
use crate::coordinator::requests::{
    ArrivalProcess, Jittered, Periodic, Poisson, TraceReplay,
};
use crate::energy::analytical::Analytical;
use crate::runner::grid::{cross, derive_seed};
use crate::runner::SweepRunner;
use crate::strategies::simulate::{simulate, GapDecisions};
use crate::strategies::strategy::build;
use crate::util::csv::Csv;
use crate::util::rng::Xoshiro256ss;
use crate::util::table::{fcount, fnum, Table};
use crate::util::units::Duration;

/// The four arrival-process columns of the grid, in output order.
pub const ARRIVALS: [&str; 4] = ["periodic", "jittered", "poisson", "trace"];

/// Per-run parameters.
#[derive(Debug, Clone)]
pub struct Exp4Config {
    /// Items simulated per cell (the budget still applies).
    pub items: u64,
    /// Nominal mean inter-arrival time for every process (ms).
    pub period_ms: f64,
    /// Experiment seed; arrival streams derive from it per column.
    pub seed: u64,
}

impl Default for Exp4Config {
    fn default() -> Self {
        Exp4Config {
            items: 2_000,
            period_ms: 40.0,
            seed: 4,
        }
    }
}

/// One grid cell's outcome.
#[derive(Debug, Clone)]
pub struct Exp4Row {
    pub policy: PolicySpec,
    pub arrival: &'static str,
    pub items: u64,
    pub energy_mj: f64,
    pub lifetime_h: f64,
    pub mean_latency_ms: f64,
    pub decisions: GapDecisions,
    pub late_requests: u64,
}

/// Full Experiment 4 results (row-major: policy outer, arrival inner).
#[derive(Debug, Clone)]
pub struct Exp4Result {
    pub rows: Vec<Exp4Row>,
    pub items: u64,
    pub period_ms: f64,
}

/// Run the grid single-threaded; see [`run_threaded`] for the parallel
/// path.
pub fn run(config: &SimConfig, e4: &Exp4Config) -> std::io::Result<Exp4Result> {
    run_threaded(config, e4, &SweepRunner::single())
}

/// The policy × arrival grid on the sweep engine.
///
/// The "trace" column replays the config's own `ArrivalSpec::Trace` file
/// when one is configured (trace-driven arrivals from config, not just
/// code); otherwise it synthesizes a deterministic bursty trace from the
/// experiment seed. A configured trace that fails to load is an error —
/// never silently swapped for the synthetic one.
pub fn run_threaded(
    config: &SimConfig,
    e4: &Exp4Config,
    runner: &SweepRunner,
) -> std::io::Result<Exp4Result> {
    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let period = Duration::from_millis(e4.period_ms);
    let trace_gaps: Vec<Duration> = match &config.workload.arrival {
        ArrivalSpec::Trace { path, .. } => {
            let mut t = TraceReplay::from_file(path)?;
            // materialize one cycle so every cell replays the same gaps
            (0..t.len()).map(|_| t.next_gap()).collect()
        }
        _ => bursty_trace(period, derive_seed(e4.seed, 3)),
    };

    let arrival_axis: Vec<(usize, &'static str)> =
        ARRIVALS.iter().copied().enumerate().collect();
    let grid = cross(&PolicySpec::ALL, &arrival_axis);
    let rows = runner.run(&grid, |cell| {
        let (spec, (arrival_idx, arrival_name)) = *cell.params;
        // one stream per arrival column, shared by every policy row
        let stream_seed = derive_seed(e4.seed, arrival_idx as u64);
        let mut arrivals: Box<dyn ArrivalProcess> = match arrival_name {
            "periodic" => Box::new(Periodic { period }),
            "jittered" => Box::new(Jittered::new(
                period,
                period * 0.25,
                Duration::from_millis(0.1),
                stream_seed,
            )),
            "poisson" => Box::new(Poisson::new(
                period,
                Duration::from_millis(ArrivalSpec::DEFAULT_POISSON_MIN_GAP_MS),
                stream_seed,
            )),
            _ => Box::new(TraceReplay::new(trace_gaps.clone())),
        };
        let mut policy = build(spec, &model);
        let mut capped = config.clone();
        capped.workload.max_items = Some(e4.items);
        let report = simulate(&capped, policy.as_mut(), arrivals.as_mut());
        Exp4Row {
            policy: spec,
            arrival: arrival_name,
            items: report.items,
            energy_mj: report.energy_exact.millijoules(),
            lifetime_h: report.lifetime.hours(),
            mean_latency_ms: report.mean_latency.millis(),
            decisions: report.decisions,
            late_requests: report.late_requests,
        }
    });
    Ok(Exp4Result {
        rows,
        items: e4.items,
        period_ms: e4.period_ms,
    })
}

/// Deterministic bursty inter-arrival trace: short intra-burst gaps
/// followed by long silences — the workload shape where online policies
/// separate (bursts reward idling, silences reward powering off).
fn bursty_trace(period: Duration, seed: u64) -> Vec<Duration> {
    let mut rng = Xoshiro256ss::new(seed);
    let mut gaps = Vec::new();
    for _ in 0..32 {
        for _ in 0..rng.range_inclusive(2, 6) {
            gaps.push(period * rng.uniform(0.2, 0.6));
        }
        // silences sit beyond every idle mode's crossover (≤ 499 ms at
        // the 40 ms nominal), so power-off decisions genuinely pay off
        gaps.push(period * rng.uniform(13.0, 20.0));
    }
    gaps
}

impl Exp4Result {
    pub fn row(&self, policy: PolicySpec, arrival: &str) -> &Exp4Row {
        self.rows
            .iter()
            .find(|r| r.policy == policy && r.arrival == arrival)
            .expect("cell present")
    }

    /// Mean per-item gap+item energy for a cell, in mJ.
    pub fn energy_per_item_mj(&self, policy: PolicySpec, arrival: &str) -> f64 {
        let r = self.row(policy, arrival);
        r.energy_mj / r.items.max(1) as f64
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "policy",
            "arrival",
            "items",
            "mJ/item",
            "lifetime (h)",
            "mean lat (ms)",
            "idled",
            "off",
            "timeouts",
            "late",
        ])
        .with_title(format!(
            "Experiment 4: gap policies x arrival processes ({} items, mean {} ms)",
            self.items, self.period_ms
        ));
        for r in &self.rows {
            t.row(&[
                r.policy.name().into(),
                r.arrival.into(),
                fcount(r.items),
                fnum(r.energy_mj / r.items.max(1) as f64, 4),
                fnum(r.lifetime_h, 2),
                fnum(r.mean_latency_ms, 3),
                fcount(r.decisions.idled),
                fcount(r.decisions.powered_off),
                fcount(r.decisions.timeouts_expired),
                fcount(r.late_requests),
            ]);
        }
        t.render()
    }

    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "policy",
            "arrival",
            "items",
            "energy_mj",
            "lifetime_h",
            "mean_latency_ms",
            "gaps_idled",
            "gaps_powered_off",
            "timeouts_expired",
            "late_requests",
        ]);
        for r in &self.rows {
            csv.row(&[
                r.policy.name().to_string(),
                r.arrival.to_string(),
                r.items.to_string(),
                format!("{}", r.energy_mj),
                format!("{}", r.lifetime_h),
                format!("{}", r.mean_latency_ms),
                r.decisions.idled.to_string(),
                r.decisions.powered_off.to_string(),
                r.decisions.timeouts_expired.to_string(),
                r.late_requests.to_string(),
            ]);
        }
        csv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn small() -> Exp4Config {
        Exp4Config {
            items: 300,
            period_ms: 40.0,
            seed: 4,
        }
    }

    #[test]
    fn grid_covers_every_policy_and_arrival() {
        let r = run(&paper_default(), &small()).unwrap();
        assert_eq!(r.rows.len(), PolicySpec::ALL.len() * ARRIVALS.len());
        for spec in PolicySpec::ALL {
            for arrival in ARRIVALS {
                assert_eq!(r.row(spec, arrival).items, 300, "{spec}/{arrival}");
            }
        }
    }

    #[test]
    fn periodic_column_reproduces_the_paper_ordering() {
        // at 40 ms (below every crossover) Idle-Waiting M1+2 must beat
        // On-Off by the paper's margin, and the oracle must match the
        // winning static policy exactly
        let r = run(&paper_default(), &small()).unwrap();
        let onoff = r.energy_per_item_mj(PolicySpec::OnOff, "periodic");
        let m12 = r.energy_per_item_mj(PolicySpec::IdleWaitingM12, "periodic");
        assert!(onoff / m12 > 5.0, "onoff {onoff} vs m12 {m12}");
        let oracle = r.row(PolicySpec::Oracle, "periodic");
        let m12_row = r.row(PolicySpec::IdleWaitingM12, "periodic");
        assert_eq!(oracle.decisions, m12_row.decisions);
        assert!((oracle.energy_mj - m12_row.energy_mj).abs() < 1e-9);
    }

    #[test]
    fn policies_see_identical_streams_per_arrival_column() {
        // the static policies never react to the stream, so their item
        // counts must match across rows; and the jittered/poisson columns
        // must differ from periodic for at least one late/decision field
        let r = run(&paper_default(), &small()).unwrap();
        for arrival in ARRIVALS {
            assert_eq!(
                r.row(PolicySpec::OnOff, arrival).items,
                r.row(PolicySpec::IdleWaiting, arrival).items
            );
        }
    }

    #[test]
    fn bursty_trace_separates_online_policies_from_statics() {
        // on the bursty trace the timeout policy must expire some timers
        // (long silences) and still idle through bursts
        let r = run(&paper_default(), &small()).unwrap();
        let t = r.row(PolicySpec::Timeout, "trace");
        assert!(t.decisions.timeouts_expired > 0, "{:?}", t.decisions);
        assert!(t.decisions.idled > 0, "{:?}", t.decisions);
        // and it must beat at least one static policy on energy
        let onoff = r.energy_per_item_mj(PolicySpec::OnOff, "trace");
        let iw = r.energy_per_item_mj(PolicySpec::IdleWaiting, "trace");
        let timeout = r.energy_per_item_mj(PolicySpec::Timeout, "trace");
        assert!(
            timeout <= onoff.max(iw),
            "timeout {timeout} vs onoff {onoff} / iw {iw}"
        );
    }

    #[test]
    fn renders_and_csv() {
        let r = run(&paper_default(), &small()).unwrap();
        assert!(r.render().contains("Experiment 4"));
        let csv = r.to_csv();
        assert_eq!(csv.n_rows(), r.rows.len());
        assert!(csv.render().starts_with("policy,arrival,items"));
    }

    // Thread-count invariance (threads=1 vs N byte-identical CSV) is
    // covered by tests/sweep_determinism.rs.
}
