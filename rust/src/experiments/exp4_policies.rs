//! Experiment 4 (beyond the paper; its §7 future work made concrete):
//! online gap policies × per-policy tunables × arrival processes.
//!
//! The paper's evaluation is strictly periodic, where the best policy is
//! a compile-time choice. This grid measures what happens when arrivals
//! are *not* periodic and the policy must decide online: every policy
//! **variant** — a [`PolicySpec`] plus a [`PolicyParams`] tunable point
//! (extra quantiles, windows, EMA alphas, timeouts beyond the defaults,
//! plus one `tuned` row whose point the [`tuner`] auto-searched on the
//! bursty-IoT corpus) — runs against six arrival processes: periodic,
//! jittered, Poisson and the three `workloads/` corpus shapes (bursty
//! IoT, diurnal Poisson, on/off MMPP, synthesized deterministically by
//! [`tracegen`](crate::coordinator::tracegen)). Cells run on the shared
//! [`SweepRunner`]; each reports energy, lifetime, mean served latency
//! and the gap-decision counters that explain *why* a variant wins, per
//! the [`SimReport`](crate::strategies::simulate::SimReport) ledger.
//!
//! Determinism: every variant row sees the *same* arrival stream per
//! arrival column — materialized once per (arrival, seed) pair and
//! Arc-shared across rows, then replayed on the batched
//! [`SimWorker::run_batch`] kernel (stream seeds derive from the
//! experiment seed and the arrival column only) — randomized policies
//! draw from a per-cell stream derived from the experiment seed and the
//! cell index, and cells are pure functions of their grid point — so the
//! CSV is byte-identical at any `--threads N`.

use std::sync::Arc;

use crate::config::loader::SimConfig;
use crate::config::schema::{ArrivalSpec, PolicyParams, PolicySpec};
use crate::coordinator::requests::{
    ArrivalProcess, Jittered, Periodic, Poisson, TraceReplay,
};
use crate::coordinator::tracegen::{self, TraceKind};
use crate::energy::analytical::Analytical;
use crate::runner::grid::{cross, derive_seed};
use crate::runner::SweepRunner;
use crate::strategies::simulate::{GapDecisions, SimWorker};
use crate::strategies::strategy::build_with;
use crate::tuner::{self, SearchStrategy, TuneConfig};
use crate::util::csv::Csv;
use crate::util::table::{fcount, fnum, Table};
use crate::util::units::Duration;

/// The fixed arrival-process columns of the grid, in output order. A
/// seventh column, `trace`, is appended when the loaded config itself
/// specifies `ArrivalSpec::Trace` (replaying the configured file).
pub const ARRIVALS: [&str; 6] = [
    "periodic",
    "jittered",
    "poisson",
    "bursty-iot",
    "diurnal",
    "mmpp",
];

/// Gaps synthesized per corpus column (cycled by the replayer).
const CORPUS_GAPS: usize = 256;

/// Candidate budget of the embedded tuner behind the `tuned` row.
const TUNED_BUDGET: usize = 16;

/// One policy variant: a spec plus a tunable point. `tunable` labels the
/// point in tables/CSV (`default` = the paper-faithful [`PolicyParams`],
/// `tuned` = the point the embedded auto-search found).
#[derive(Debug, Clone)]
pub struct PolicyVariant {
    /// The policy.
    pub spec: PolicySpec,
    /// Label of the tunable point (`default`, `w=16 q=0.5`, `tuned`, …).
    pub tunable: &'static str,
    /// The tunable point itself.
    pub params: PolicyParams,
}

/// The grid's policy axis: every [`PolicySpec`] at its default tunables,
/// plus the tunable points where the knob plausibly changes the winner —
/// a sharper quantile window, a sluggish EMA, a short explicit timeout.
pub fn variants() -> Vec<PolicyVariant> {
    let d = PolicyParams::default();
    let mut out: Vec<PolicyVariant> = PolicySpec::ALL
        .iter()
        .map(|&spec| PolicyVariant {
            spec,
            tunable: "default",
            params: d,
        })
        .collect();
    out.push(PolicyVariant {
        spec: PolicySpec::EmaPredictor,
        tunable: "alpha=0.05",
        params: PolicyParams { ema_alpha: 0.05, ..d },
    });
    out.push(PolicyVariant {
        spec: PolicySpec::WindowedQuantile,
        tunable: "w=16 q=0.5",
        params: PolicyParams {
            window: 16,
            quantile: 0.5,
            ..d
        },
    });
    out.push(PolicyVariant {
        spec: PolicySpec::WindowedQuantile,
        tunable: "w=128 q=0.99",
        params: PolicyParams {
            window: 128,
            quantile: 0.99,
            ..d
        },
    });
    out.push(PolicyVariant {
        spec: PolicySpec::Timeout,
        tunable: "tau=100ms",
        params: PolicyParams {
            timeout: Some(Duration::from_millis(100.0)),
            ..d
        },
    });
    out.push(PolicyVariant {
        spec: PolicySpec::RandomizedSkiRental,
        tunable: "tau=100ms",
        params: PolicyParams {
            timeout: Some(Duration::from_millis(100.0)),
            ..d
        },
    });
    out
}

/// One materialized arrival column: the gap stream every variant row of
/// the column replays (drawn once, Arc-shared), plus the generating
/// process's label and nominal mean captured before the draw.
struct ArrivalColumn {
    label: String,
    mean: Duration,
    gaps: Arc<[Duration]>,
}

/// Per-run parameters.
#[derive(Debug, Clone)]
pub struct Exp4Config {
    /// Items simulated per cell (the budget still applies).
    pub items: u64,
    /// Nominal mean inter-arrival time for every process (ms).
    pub period_ms: f64,
    /// Experiment seed; arrival streams derive from it per column,
    /// randomized-policy streams per cell.
    pub seed: u64,
}

impl Default for Exp4Config {
    fn default() -> Self {
        Exp4Config {
            items: 2_000,
            period_ms: 40.0,
            seed: 4,
        }
    }
}

/// One grid cell's outcome.
#[derive(Debug, Clone)]
pub struct Exp4Row {
    /// The policy of the cell's variant.
    pub policy: PolicySpec,
    /// Tunable-point label of the cell's variant.
    pub tunable: &'static str,
    /// Arrival column name.
    pub arrival: &'static str,
    /// Items served.
    pub items: u64,
    /// Exact FPGA-side energy drawn (mJ).
    pub energy_mj: f64,
    /// Eq 4 lifetime (hours).
    pub lifetime_h: f64,
    /// Mean served latency (ms), queueing included.
    pub mean_latency_ms: f64,
    /// Per-gap decision counters.
    pub decisions: GapDecisions,
    /// Requests that arrived before their predecessor finished.
    pub late_requests: u64,
}

/// Full Experiment 4 results (row-major: variant outer, arrival inner).
#[derive(Debug, Clone)]
pub struct Exp4Result {
    /// All grid cells in row-major order.
    pub rows: Vec<Exp4Row>,
    /// Item cap per cell.
    pub items: u64,
    /// Nominal mean inter-arrival time (ms).
    pub period_ms: f64,
}

/// Run the grid single-threaded; see [`run_threaded`] for the parallel
/// path.
pub fn run(config: &SimConfig, e4: &Exp4Config) -> std::io::Result<Exp4Result> {
    run_threaded(config, e4, &SweepRunner::single())
}

/// The `tuned` grid row: run the [`tuner`] (successive halving, small
/// budget) for the windowed-quantile policy on the bursty-IoT corpus
/// trace — the shape where hand-picked tunables hurt most — and enter
/// the winning point as one more variant. Deterministic: the tuner
/// derives its candidate stream from the experiment seed and evaluates
/// on the shared runner, so the row (and the whole CSV) stays
/// byte-identical at any `--threads N`.
pub fn tuned_variant(
    config: &SimConfig,
    e4: &Exp4Config,
    bursty_gaps: &Arc<[Duration]>,
    runner: &SweepRunner,
) -> Result<PolicyVariant, tuner::TuneError> {
    let tc = TuneConfig {
        search: SearchStrategy::Halving,
        budget: TUNED_BUDGET,
        seed: derive_seed(e4.seed, 0x7EED),
        ..TuneConfig::for_spec(PolicySpec::WindowedQuantile)
    };
    let outcome = tuner::tune(config, &tc, bursty_gaps, runner)?;
    Ok(PolicyVariant {
        spec: PolicySpec::WindowedQuantile,
        tunable: "tuned",
        params: outcome.best,
    })
}

/// The policy-variant × arrival grid on the sweep engine.
///
/// The three corpus columns synthesize their gap sequences from the
/// experiment seed via [`tracegen`], so they need no files on disk; when
/// the config's own arrival is `ArrivalSpec::Trace`, an extra `trace`
/// column replays that file for every variant (trace-driven arrivals
/// from config, not just code). A configured trace that fails to load is
/// an error — never silently swapped for a synthetic one.
pub fn run_threaded(
    config: &SimConfig,
    e4: &Exp4Config,
    runner: &SweepRunner,
) -> std::io::Result<Exp4Result> {
    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let period = Duration::from_millis(e4.period_ms);

    // one gap sequence per corpus column, Arc-shared by every variant row
    // (cells clone a refcount, not the trace)
    let corpus: Vec<(&'static str, Arc<[Duration]>)> = [
        ("bursty-iot", TraceKind::BurstyIot),
        ("diurnal", TraceKind::DiurnalPoisson),
        ("mmpp", TraceKind::OnOffMmpp),
    ]
    .into_iter()
    .enumerate()
    .map(|(i, (name, kind))| {
        (
            name,
            tracegen::generate_durations(
                kind,
                CORPUS_GAPS,
                e4.period_ms,
                derive_seed(e4.seed, 0x100 + i as u64),
            )
            .into(),
        )
    })
    .collect();

    // the config's own trace file, if any, becomes a seventh column
    let config_trace: Option<Arc<[Duration]>> = match &config.workload.arrival {
        ArrivalSpec::Trace { path, .. } => Some(TraceReplay::from_file(path)?.shared_gaps()),
        _ => None,
    };

    let mut arrival_axis: Vec<(usize, &'static str)> =
        ARRIVALS.iter().copied().enumerate().collect();
    if config_trace.is_some() {
        arrival_axis.push((ARRIVALS.len(), "trace"));
    }

    // One *materialized* stream per (arrival, seed) column, Arc-shared by
    // every variant row: the generator runs once per column instead of
    // once per cell, and cells replay the shared gaps on the batched
    // kernel. Label and nominal mean are captured from the process
    // *before* drawing, so reports (and the Eq 4 lifetime) match the
    // generator-driven path field for field.
    let n_gaps = e4.items.saturating_sub(1) as usize;
    let columns: Vec<ArrivalColumn> = arrival_axis
        .iter()
        .map(|(arrival_idx, arrival_name)| {
            let stream_seed = derive_seed(e4.seed, *arrival_idx as u64);
            let mut process: Box<dyn ArrivalProcess> = match *arrival_name {
                "periodic" => Box::new(Periodic { period }),
                "jittered" => Box::new(Jittered::new(
                    period,
                    period * 0.25,
                    Duration::from_millis(0.1),
                    stream_seed,
                )),
                "poisson" => Box::new(Poisson::new(
                    period,
                    Duration::from_millis(ArrivalSpec::DEFAULT_POISSON_MIN_GAP_MS),
                    stream_seed,
                )),
                "trace" => Box::new(TraceReplay::shared(
                    config_trace.clone().expect("trace column requires a config trace"),
                )),
                corpus_name => Box::new(TraceReplay::shared(
                    corpus
                        .iter()
                        .find(|(name, _)| *name == corpus_name)
                        .expect("corpus column present")
                        .1
                        .clone(),
                )),
            };
            let label = process.label();
            let mean = process.mean();
            let gaps: Arc<[Duration]> = (0..n_gaps)
                .map(|_| process.next_gap())
                .collect::<Vec<_>>()
                .into();
            ArrivalColumn { label, mean, gaps }
        })
        .collect();

    // the hand-picked variants plus the auto-searched `tuned` row
    let bursty = &corpus
        .iter()
        .find(|(name, _)| *name == "bursty-iot")
        .expect("bursty-iot corpus column present")
        .1;
    let mut vs = variants();
    vs.push(
        tuned_variant(config, e4, bursty, runner)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?,
    );

    let grid = cross(&vs, &arrival_axis);
    // one capped config for every cell (hoisted: cells used to clone it),
    // and one reusable DES worker per thread (platform + event queue
    // built once per worker instead of once per cell)
    let mut capped = config.clone();
    capped.workload.max_items = Some(e4.items);
    let capped = &capped;
    let rows = runner.run_with_state(
        &grid,
        || SimWorker::new(capped),
        |worker, cell| {
            let (variant, (arrival_idx, arrival_name)) = cell.params;
            // the materialized column stream, shared by every variant row
            let column = &columns[*arrival_idx];
            // randomized policies draw from a per-cell stream that depends on
            // the experiment seed and the cell index only — thread-invariant
            let params = PolicyParams {
                seed: derive_seed(e4.seed, 0x9000 + cell.index as u64),
                ..variant.params
            };
            let mut policy = build_with(variant.spec, &model, &params);
            let report = worker.run_batch(
                capped,
                policy.as_mut(),
                &column.gaps,
                &column.label,
                column.mean,
            );
            Exp4Row {
                policy: variant.spec,
                tunable: variant.tunable,
                arrival: *arrival_name,
                items: report.items,
                energy_mj: report.energy_exact.millijoules(),
                lifetime_h: report.lifetime.hours(),
                mean_latency_ms: report.mean_latency.millis(),
                decisions: report.decisions,
                late_requests: report.late_requests,
            }
        },
    );
    Ok(Exp4Result {
        rows,
        items: e4.items,
        period_ms: e4.period_ms,
    })
}

impl Exp4Result {
    /// The default-tunable row for a (policy, arrival) cell.
    pub fn row(&self, policy: PolicySpec, arrival: &str) -> &Exp4Row {
        self.row_variant(policy, "default", arrival)
    }

    /// The row for an exact (policy, tunable label, arrival) cell.
    pub fn row_variant(
        &self,
        policy: PolicySpec,
        tunable: &str,
        arrival: &str,
    ) -> &Exp4Row {
        self.rows
            .iter()
            .find(|r| r.policy == policy && r.tunable == tunable && r.arrival == arrival)
            .expect("cell present")
    }

    /// Mean per-item gap+item energy for a default-tunable cell, in mJ.
    pub fn energy_per_item_mj(&self, policy: PolicySpec, arrival: &str) -> f64 {
        let r = self.row(policy, arrival);
        r.energy_mj / r.items.max(1) as f64
    }

    /// Render the ASCII results table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "policy",
            "params",
            "arrival",
            "items",
            "mJ/item",
            "lifetime (h)",
            "mean lat (ms)",
            "idled",
            "off",
            "timeouts",
            "late",
        ])
        .with_title(format!(
            "Experiment 4: gap policies x tunables x arrivals ({} items, mean {} ms)",
            self.items, self.period_ms
        ));
        for r in &self.rows {
            t.row(&[
                r.policy.name().into(),
                r.tunable.into(),
                r.arrival.into(),
                fcount(r.items),
                fnum(r.energy_mj / r.items.max(1) as f64, 4),
                fnum(r.lifetime_h, 2),
                fnum(r.mean_latency_ms, 3),
                fcount(r.decisions.idled),
                fcount(r.decisions.powered_off),
                fcount(r.decisions.timeouts_expired),
                fcount(r.late_requests),
            ]);
        }
        t.render()
    }

    /// The grid as CSV (the published `repro exp4 --csv` schema).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "policy",
            "params",
            "arrival",
            "items",
            "energy_mj",
            "lifetime_h",
            "mean_latency_ms",
            "gaps_idled",
            "gaps_powered_off",
            "timeouts_expired",
            "late_requests",
        ]);
        for r in &self.rows {
            csv.row(&[
                r.policy.name().to_string(),
                r.tunable.to_string(),
                r.arrival.to_string(),
                r.items.to_string(),
                format!("{}", r.energy_mj),
                format!("{}", r.lifetime_h),
                format!("{}", r.mean_latency_ms),
                r.decisions.idled.to_string(),
                r.decisions.powered_off.to_string(),
                r.decisions.timeouts_expired.to_string(),
                r.late_requests.to_string(),
            ]);
        }
        csv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn small() -> Exp4Config {
        Exp4Config {
            items: 300,
            period_ms: 40.0,
            seed: 4,
        }
    }

    #[test]
    fn grid_covers_every_variant_and_arrival() {
        let r = run(&paper_default(), &small()).unwrap();
        let vs = variants();
        // the hand-picked variants plus the auto-searched `tuned` row
        assert_eq!(r.rows.len(), (vs.len() + 1) * ARRIVALS.len());
        for v in &vs {
            for arrival in ARRIVALS {
                assert_eq!(
                    r.row_variant(v.spec, v.tunable, arrival).items,
                    300,
                    "{}/{}/{arrival}",
                    v.spec,
                    v.tunable
                );
            }
        }
        // every spec appears at its default tunables
        for spec in PolicySpec::ALL {
            assert_eq!(r.row(spec, "periodic").tunable, "default");
        }
        // the tuned row covers every arrival column too
        for arrival in ARRIVALS {
            assert_eq!(
                r.row_variant(PolicySpec::WindowedQuantile, "tuned", arrival).items,
                300
            );
        }
    }

    #[test]
    fn tuned_row_beats_the_default_point_on_the_trace_it_tuned_for() {
        // the embedded tuner searched windowed-quantile on the bursty-IoT
        // corpus; its row must not lose to the hand-default point there
        let r = run(&paper_default(), &small()).unwrap();
        let tuned = r.row_variant(PolicySpec::WindowedQuantile, "tuned", "bursty-iot");
        let dflt = r.row_variant(PolicySpec::WindowedQuantile, "default", "bursty-iot");
        let per_item = |row: &Exp4Row| row.energy_mj / row.items.max(1) as f64;
        assert!(
            per_item(tuned) <= per_item(dflt) * 1.001,
            "tuned {} vs default {}",
            per_item(tuned),
            per_item(dflt)
        );
    }

    #[test]
    fn periodic_column_reproduces_the_paper_ordering() {
        // at 40 ms (below every crossover) Idle-Waiting M1+2 must beat
        // On-Off by the paper's margin, and the oracle must match the
        // winning static policy exactly
        let r = run(&paper_default(), &small()).unwrap();
        let onoff = r.energy_per_item_mj(PolicySpec::OnOff, "periodic");
        let m12 = r.energy_per_item_mj(PolicySpec::IdleWaitingM12, "periodic");
        assert!(onoff / m12 > 5.0, "onoff {onoff} vs m12 {m12}");
        let oracle = r.row(PolicySpec::Oracle, "periodic");
        let m12_row = r.row(PolicySpec::IdleWaitingM12, "periodic");
        assert_eq!(oracle.decisions, m12_row.decisions);
        assert!((oracle.energy_mj - m12_row.energy_mj).abs() < 1e-9);
        // the windowed-quantile predictor degenerates to the same winner
        let wq = r.row(PolicySpec::WindowedQuantile, "periodic");
        assert_eq!(wq.decisions.powered_off, 0);
        assert_eq!(wq.decisions.idled, 299);
    }

    #[test]
    fn policies_see_identical_streams_per_arrival_column() {
        // the static policies never react to the stream, so their item
        // counts must match across rows
        let r = run(&paper_default(), &small()).unwrap();
        for arrival in ARRIVALS {
            assert_eq!(
                r.row(PolicySpec::OnOff, arrival).items,
                r.row(PolicySpec::IdleWaiting, arrival).items
            );
        }
    }

    #[test]
    fn bursty_trace_separates_online_policies_from_statics() {
        // on the bursty corpus the timeout policy must expire some timers
        // (long silences) and still idle through bursts
        let r = run(&paper_default(), &small()).unwrap();
        let t = r.row(PolicySpec::Timeout, "bursty-iot");
        assert!(t.decisions.timeouts_expired > 0, "{:?}", t.decisions);
        assert!(t.decisions.idled > 0, "{:?}", t.decisions);
        // and it must beat at least one static policy on energy
        let onoff = r.energy_per_item_mj(PolicySpec::OnOff, "bursty-iot");
        let iw = r.energy_per_item_mj(PolicySpec::IdleWaiting, "bursty-iot");
        let timeout = r.energy_per_item_mj(PolicySpec::Timeout, "bursty-iot");
        assert!(
            timeout <= onoff.max(iw),
            "timeout {timeout} vs onoff {onoff} / iw {iw}"
        );
    }

    #[test]
    fn tunables_change_behaviour_on_heavy_tails() {
        // on the bursty corpus the sharp w=16 q=0.5 quantile point and
        // the default q=0.9 point must make genuinely different per-gap
        // decisions — the tunable axis is not decorative
        let r = run(&paper_default(), &small()).unwrap();
        let dflt = r.row_variant(PolicySpec::WindowedQuantile, "default", "bursty-iot");
        let sharp = r.row_variant(PolicySpec::WindowedQuantile, "w=16 q=0.5", "bursty-iot");
        assert_ne!(dflt.decisions, sharp.decisions, "{:?}", dflt.decisions);
    }

    #[test]
    fn config_trace_adds_a_seventh_column() {
        let dir = std::env::temp_dir().join("idlewait_exp4_cfg_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("gaps.csv");
        std::fs::write(&path, "30\n50\n700\n").unwrap();
        let mut cfg = paper_default();
        cfg.workload.arrival = ArrivalSpec::Trace {
            path: path.to_str().unwrap().to_string(),
            nominal: Duration::from_millis(40.0),
        };
        let r = run(&cfg, &small()).unwrap();
        assert_eq!(r.rows.len(), (variants().len() + 1) * (ARRIVALS.len() + 1));
        let row = r.row(PolicySpec::Oracle, "trace");
        assert_eq!(row.items, 300);
        // the 700 ms silences (beyond every crossover) force power-offs
        assert!(row.decisions.powered_off > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_config_trace_is_an_error() {
        let mut cfg = paper_default();
        cfg.workload.arrival = ArrivalSpec::Trace {
            path: "/nonexistent/exp4.csv".into(),
            nominal: Duration::from_millis(40.0),
        };
        assert!(run(&cfg, &small()).is_err());
    }

    #[test]
    fn renders_and_csv() {
        let r = run(&paper_default(), &small()).unwrap();
        assert!(r.render().contains("Experiment 4"));
        let csv = r.to_csv();
        assert_eq!(csv.n_rows(), r.rows.len());
        assert!(csv.render().starts_with("policy,params,arrival,items"));
    }

    // Thread-count invariance (threads=1 vs N byte-identical CSV) is
    // covered by tests/sweep_determinism.rs.
}
