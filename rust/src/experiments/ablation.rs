//! Ablation studies for the design choices DESIGN.md calls out.
//!
//! 1. **Flash-floor ablation** — the paper's closing §5.4 observation:
//!    the 15.2 mW flash standby draw is the hardware constraint limiting
//!    its methods; "Addressing this could extend the advantageous period
//!    by up to 5.57×". We rerun Experiment 3 with the floor removed.
//! 2. **Power-on-transient sensitivity** — the single calibrated
//!    constant (0.1244 mJ, DESIGN.md §6) that pins the paper's On-Off
//!    item count and both crossovers: sweep it and show how the headline
//!    numbers move (i.e. how sensitive the reproduction is to it).
//! 3. **Multi-accelerator switching** — the §4.2 out-of-scope case:
//!    sweep the fraction of requests targeting a second accelerator and
//!    compare FIFO vs batch-by-slot scheduling on reconfiguration
//!    energy.

use crate::config::loader::SimConfig;
use crate::config::schema::PolicySpec;
use crate::coordinator::scheduler::{MultiAccelScheduler, Policy, SlotRequest};
use crate::device::calib::FLASH_STANDBY_POWER;
use crate::energy::analytical::Analytical;
use crate::energy::crossover;
use crate::runner::{Grid, SweepRunner};
use crate::util::rng::Xoshiro256ss;
use crate::util::table::{fnum, Table};
use crate::util::units::{Duration, Energy, Power};

// ---------------------------------------------------------------------------
// 1. flash-floor ablation
// ---------------------------------------------------------------------------

/// Lifetime sensitivity to the flash-standby floor (§5.4).
#[derive(Debug, Clone)]
pub struct FlashFloorAblation {
    /// (label, idle power with floor, idle power without, crossover with,
    /// crossover without)
    pub rows: Vec<(&'static str, Power, Power, Duration, Duration)>,
}

/// Run the flash-floor ablation serially.
pub fn flash_floor(config: &SimConfig) -> FlashFloorAblation {
    flash_floor_threaded(config, &SweepRunner::single())
}

/// The idle-mode grid on the sweep engine.
pub fn flash_floor_threaded(config: &SimConfig, runner: &SweepRunner) -> FlashFloorAblation {
    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let grid = Grid::new(vec![
        ("baseline", PolicySpec::IdleWaiting),
        ("method 1", PolicySpec::IdleWaitingM1),
        ("method 1+2", PolicySpec::IdleWaitingM12),
    ]);
    let rows = runner.run(&grid, |cell| {
        let (label, kind) = *cell.params;
        let with = model.item.idle_power(kind);
        let without = with - FLASH_STANDBY_POWER;
        (
            label,
            with,
            without,
            crossover::asymptotic(&model, with),
            crossover::asymptotic(&model, without),
        )
    });
    FlashFloorAblation { rows }
}

impl FlashFloorAblation {
    /// The paper's "up to 5.57×" claim target: crossover extension factor
    /// for the best method once the flash floor is gone.
    pub fn best_extension(&self) -> f64 {
        let (_, _, _, with, without) = self.rows.last().expect("rows");
        *without / *with
    }

    /// Render the ablation table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "idle mode",
            "P_idle (mW)",
            "P_idle w/o flash (mW)",
            "crossover (ms)",
            "crossover w/o flash (ms)",
            "extension (x)",
        ])
        .with_title("ablation: remove the 15.2 mW flash standby floor (paper §5.4 closing)");
        for (label, with_p, without_p, with_t, without_t) in &self.rows {
            t.row(&[
                (*label).into(),
                fnum(with_p.milliwatts(), 1),
                fnum(without_p.milliwatts(), 1),
                fnum(with_t.millis(), 2),
                fnum(without_t.millis(), 2),
                fnum(*without_t / *with_t, 2),
            ]);
        }
        t.render()
    }
}

// ---------------------------------------------------------------------------
// 2. power-on-transient sensitivity
// ---------------------------------------------------------------------------

/// Lifetime sensitivity to the power-on transient constant.
#[derive(Debug, Clone)]
pub struct TransientSensitivity {
    /// (transient mJ, on-off items, baseline crossover ms)
    pub rows: Vec<(f64, u64, f64)>,
}

/// Run the transient ablation serially.
pub fn transient_sensitivity(config: &SimConfig) -> TransientSensitivity {
    transient_sensitivity_threaded(config, &SweepRunner::single())
}

/// The transient-energy grid on the sweep engine.
pub fn transient_sensitivity_threaded(
    config: &SimConfig,
    runner: &SweepRunner,
) -> TransientSensitivity {
    let grid = Grid::new(vec![0.0, 0.05, 0.1244, 0.2, 0.4]);
    let rows = runner.run(&grid, |cell| {
        let mj = *cell.params;
        let mut item = config.item.clone();
        item.power_on_transient = Energy::from_millijoules(mj);
        let model = Analytical::new(&item, config.workload.energy_budget);
        let items = model
            .n_max_onoff(Duration::from_millis(40.0))
            .expect("feasible");
        let cross = crossover::asymptotic(&model, model.item.idle_power_baseline);
        (mj, items, cross.millis())
    });
    TransientSensitivity { rows }
}

impl TransientSensitivity {
    /// Render the ablation table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "transient (mJ)",
            "On-Off items",
            "baseline crossover (ms)",
        ])
        .with_title(
            "ablation: power-on transient (calibrated 0.1244 mJ reproduces the paper; see DESIGN.md §6)",
        );
        for (mj, items, cross) in &self.rows {
            t.row(&[
                fnum(*mj, 4),
                crate::util::table::fcount(*items),
                fnum(*cross, 2),
            ]);
        }
        t.render()
    }
}

// ---------------------------------------------------------------------------
// 3. multi-accelerator switching
// ---------------------------------------------------------------------------

/// Closed-form multi-accelerator reconfiguration ablation.
#[derive(Debug, Clone)]
pub struct MultiAccelAblation {
    /// (mix fraction, fifo reconfigs, batched reconfigs, fifo energy mJ,
    /// batched energy mJ, batched deadline violations)
    pub rows: Vec<(f64, u64, u64, f64, f64, u64)>,
    /// Requests simulated per mix point.
    pub requests: u64,
}

/// Run the multi-accel ablation serially.
pub fn multi_accel(config: &SimConfig, requests: u64, seed: u64) -> MultiAccelAblation {
    multi_accel_threaded(config, requests, seed, &SweepRunner::single())
}

/// The accelerator-mix grid on the sweep engine. Each cell reuses the
/// caller's `seed` (not the per-cell stream) so the request sequence per
/// mix matches the historical serial output exactly.
pub fn multi_accel_threaded(
    config: &SimConfig,
    requests: u64,
    seed: u64,
    runner: &SweepRunner,
) -> MultiAccelAblation {
    let e_config = config.item.configuration.energy() + config.item.power_on_transient;
    let config_time = config.item.configuration.time;
    let item_latency = config.item.latency_without_config();
    let period = config.workload.arrival.mean_period();

    let grid = Grid::new(vec![0.0, 0.1, 0.25, 0.5]);
    let rows = runner.run(&grid, |cell| {
        let mix = *cell.params;
        let run = |policy: Policy| {
            let mut sched = MultiAccelScheduler::new(policy, config_time, item_latency);
            let mut rng = Xoshiro256ss::new(seed);
            for i in 0..requests {
                let slot = if rng.bernoulli(mix) { 1 } else { 0 };
                sched.submit(SlotRequest {
                    id: i,
                    slot,
                    arrival: period * i as f64,
                    // deadline: next-period completion (paper premise)
                    deadline: period * (i + 1) as f64,
                });
            }
            while sched.next().is_some() {}
            sched
        };
        let fifo = run(Policy::Fifo);
        let batched = run(Policy::BatchBySlot { window: 8 });
        (
            mix,
            fifo.stats.reconfigurations,
            batched.stats.reconfigurations,
            fifo.reconfiguration_energy(e_config).millijoules(),
            batched.reconfiguration_energy(e_config).millijoules(),
            batched.stats.deadline_violations,
        )
    });
    MultiAccelAblation { rows, requests }
}

impl MultiAccelAblation {
    /// Render the ablation table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "mix (frac to accel B)",
            "fifo reconfigs",
            "batched reconfigs",
            "fifo E_cfg (mJ)",
            "batched E_cfg (mJ)",
            "batched deadline misses",
        ])
        .with_title(format!(
            "ablation: multi-accelerator switching over {} requests (paper §4.2 out-of-scope case)",
            self.requests
        ));
        for (mix, fr, br, fe, be, viol) in &self.rows {
            t.row(&[
                fnum(*mix, 2),
                fr.to_string(),
                br.to_string(),
                fnum(*fe, 1),
                fnum(*be, 1),
                viol.to_string(),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    #[test]
    fn flash_floor_extends_crossovers() {
        let a = flash_floor(&paper_default());
        for (label, with_p, without_p, with_t, without_t) in &a.rows {
            assert!(
                (with_p.milliwatts() - without_p.milliwatts() - 15.2).abs() < 1e-9,
                "{label}"
            );
            assert!(without_t > with_t, "{label}");
        }
        // m1+2 without flash: 8.8 mW → crossover ≈ 1361 ms (2.7× of 499)
        let ext = a.best_extension();
        assert!(ext > 2.5 && ext < 3.0, "extension {ext}");
    }

    #[test]
    fn calibrated_transient_reproduces_paper_row() {
        let s = transient_sensitivity(&paper_default());
        let row = s.rows.iter().find(|(mj, _, _)| (*mj - 0.1244).abs() < 1e-9).unwrap();
        assert!(row.1.abs_diff(346_073) < 150);
        assert!((row.2 - 89.21).abs() < 0.05);
        // zero transient → more items, earlier crossover
        let zero = &s.rows[0];
        assert!(zero.1 > row.1);
        assert!(zero.2 < row.2);
    }

    #[test]
    fn transient_monotonicity() {
        let s = transient_sensitivity(&paper_default());
        for pair in s.rows.windows(2) {
            assert!(pair[1].1 <= pair[0].1, "items decrease with transient");
            assert!(pair[1].2 >= pair[0].2, "crossover grows with transient");
        }
    }

    #[test]
    fn batching_never_worse_on_reconfig_energy() {
        let a = multi_accel(&paper_default(), 2_000, 7);
        for (mix, fifo, batched, fe, be, _) in &a.rows {
            assert!(batched <= fifo, "mix {mix}");
            assert!(be <= fe, "mix {mix}");
        }
        // pure single-accelerator mix: exactly one configuration
        assert_eq!(a.rows[0].1, 1);
        assert_eq!(a.rows[0].2, 1);
    }

    #[test]
    fn renders() {
        let cfg = paper_default();
        assert!(flash_floor(&cfg).render().contains("flash standby floor"));
        assert!(transient_sensitivity(&cfg).render().contains("0.1244"));
        assert!(multi_accel(&cfg, 500, 1).render().contains("multi-accelerator"));
    }
}
