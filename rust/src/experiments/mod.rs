//! Experiment harness: regenerates every table and figure in the paper's
//! evaluation (§5) and validates the models against each other.
//!
//! | Paper artifact | Module |
//! |---|---|
//! | Fig 2 energy breakdown | [`fig2`] |
//! | Fig 7 + §5.2 headline numbers | [`exp1`] |
//! | Table 2, Figs 8–9, 89.21 ms crossover | [`exp2`] |
//! | Table 3, Figs 10–11, 499.06 ms, 12.39× | [`exp3`] |
//! | §5.3 validation (2.8%/2.7%) | [`validation`] |
//! | §7 future work: online policies × irregular arrivals | [`exp4_policies`] |
//! | §4.2 extension: multi-client scheduling × offered load | [`exp5_serving`] |
//! | Robustness study: fault rate × policy | [`faults`] |
//! | Published values | [`paper`] |

pub mod ablation;
pub mod exp1;
pub mod exp2;
pub mod exp3;
pub mod exp4_policies;
pub mod exp5_serving;
pub mod faults;
pub mod fig2;
pub mod paper;
pub mod validation;
