//! Fault-rate sweep (robustness study, beyond the paper): configuration
//! fault rate × gap policy under the deterministic fault injector.
//!
//! The paper's §5 evaluation assumes every configuration succeeds. Real
//! flash-to-fabric loads fail — CRC mismatches, corrupted SPI transfers,
//! supply brownouts, transient flash read errors — and every retry
//! re-draws the partial configuration energy from the same Eq-2 battery
//! budget. That failure tax is proportional to how often a policy
//! *configures*: On-Off pays it on every item, Idle-Waiting only on its
//! first. This grid quantifies the asymmetry: it sweeps a composite
//! configuration fault rate across [`RATES`] for each policy in
//! [`POLICIES`] and answers **at what fault rate does Idle-Waiting's
//! energy advantage over On-Off widen beyond its fault-free baseline?**
//!
//! Determinism: every cell replays the *same* materialized periodic
//! arrival stream; the cell's fault stream is seeded
//! `derive_seed(seed, 0xFA00 + cell_index)` — a pure function of the
//! experiment seed and the grid point — so the CSV is byte-identical at
//! any `--threads N` (pinned by `tests/fault_determinism.rs`).

use std::sync::Arc;

use crate::config::loader::SimConfig;
use crate::config::schema::{FaultSpec, PolicySpec};
use crate::coordinator::requests::{ArrivalProcess, Periodic};
use crate::energy::analytical::Analytical;
use crate::runner::grid::{cross, derive_seed};
use crate::runner::SweepRunner;
use crate::strategies::simulate::SimWorker;
use crate::strategies::strategy::build_with;
use crate::util::csv::Csv;
use crate::util::table::{fcount, fnum, Table};
use crate::util::units::Duration;

/// The swept composite configuration fault rates (probability that one
/// configuration attempt faults), from the fault-free control upward.
pub const RATES: [f64; 6] = [0.0, 0.001, 0.01, 0.05, 0.1, 0.2];

/// The policy axis: the paper's two static baselines, the headline
/// Idle-Waiting M1+2 variant, and the online timeout policy.
pub const POLICIES: [PolicySpec; 4] = [
    PolicySpec::OnOff,
    PolicySpec::IdleWaiting,
    PolicySpec::IdleWaitingM12,
    PolicySpec::Timeout,
];

/// Split one composite rate across the four configuration-fault
/// scenarios (no inference brownouts — the sweep isolates the
/// configuration tax) with the given retry policy knobs.
pub fn spec_for_rate(rate: f64, seed: u64, retry_max: u32, backoff: Duration) -> FaultSpec {
    FaultSpec {
        config_crc_rate: 0.4 * rate,
        spi_corrupt_rate: 0.3 * rate,
        brownout_config_rate: 0.2 * rate,
        flash_read_rate: 0.1 * rate,
        brownout_infer_rate: 0.0,
        seed,
        retry_max,
        backoff,
        ..FaultSpec::none()
    }
}

/// Per-run parameters.
#[derive(Debug, Clone)]
pub struct FaultsConfig {
    /// Items simulated per cell.
    pub items: u64,
    /// Inter-arrival period of the shared periodic stream (ms).
    pub period_ms: f64,
    /// Experiment seed; per-cell fault streams derive from it.
    pub seed: u64,
    /// Attempt cap of the retry policy in every cell.
    pub retry_max: u32,
    /// Base backoff of the retry policy in every cell (ms).
    pub backoff_ms: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            items: 2_000,
            period_ms: 40.0,
            seed: 0xFA,
            retry_max: 3,
            backoff_ms: 10.0,
        }
    }
}

/// One grid cell's outcome.
#[derive(Debug, Clone)]
pub struct FaultsRow {
    /// Composite configuration fault rate of the cell.
    pub rate: f64,
    /// Gap policy of the cell.
    pub policy: PolicySpec,
    /// Items served (shed requests are not counted).
    pub items: u64,
    /// Exact FPGA-side energy drawn (mJ), recovery overhead included.
    pub energy_mj: f64,
    /// Faulted attempts that were retried (or given up on).
    pub retries: u64,
    /// Energy destroyed by faulted attempts (mJ).
    pub recovery_energy_mj: f64,
    /// Requests shed after the retry cap was exhausted.
    pub shed: u64,
    /// Successful FPGA configurations.
    pub configurations: u64,
    /// Power-on transients paid (faulted attempts included).
    pub power_ons: u64,
}

/// Full fault-sweep results, row-major (rate outer, policy inner).
#[derive(Debug, Clone)]
pub struct FaultsResult {
    /// All grid cells in row-major order.
    pub rows: Vec<FaultsRow>,
    /// Item cap per cell.
    pub items: u64,
    /// Inter-arrival period (ms).
    pub period_ms: f64,
}

/// Run the grid single-threaded; see [`run_threaded`] for the parallel
/// path.
pub fn run(config: &SimConfig, fc: &FaultsConfig) -> FaultsResult {
    run_threaded(config, fc, &SweepRunner::single())
}

/// The fault-rate × policy grid on the sweep engine. Every cell replays
/// one shared periodic stream through the batched kernel with a
/// per-cell seeded fault stream spliced into its config.
pub fn run_threaded(config: &SimConfig, fc: &FaultsConfig, runner: &SweepRunner) -> FaultsResult {
    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let mut process = Periodic {
        period: Duration::from_millis(fc.period_ms),
    };
    let label = process.label();
    let mean = process.mean();
    let n_gaps = fc.items.saturating_sub(1) as usize;
    let gaps: Arc<[Duration]> = (0..n_gaps)
        .map(|_| process.next_gap())
        .collect::<Vec<_>>()
        .into();
    let backoff = Duration::from_millis(fc.backoff_ms);

    let mut base = config.clone();
    base.workload.max_items = Some(fc.items);
    let base = &base;
    let grid = cross(&RATES, &POLICIES);
    let rows = runner.run_with_state(
        &grid,
        || SimWorker::new(base),
        |worker, cell| {
            let (rate, policy_spec) = cell.params;
            // the fault stream is a pure function of the experiment seed
            // and the grid point — thread-invariant by construction
            let mut cfg = base.clone();
            cfg.faults = spec_for_rate(
                *rate,
                derive_seed(fc.seed, 0xFA00 + cell.index as u64),
                fc.retry_max,
                backoff,
            );
            let mut policy = build_with(*policy_spec, &model, &cfg.workload.params);
            let report = worker.run_batch(&cfg, policy.as_mut(), &gaps, &label, mean);
            FaultsRow {
                rate: *rate,
                policy: *policy_spec,
                items: report.items,
                energy_mj: report.energy_exact.millijoules(),
                retries: report.retries,
                recovery_energy_mj: report.recovery_energy.millijoules(),
                shed: report.shed_requests,
                configurations: report.configurations,
                power_ons: report.power_ons,
            }
        },
    );
    FaultsResult {
        rows,
        items: fc.items,
        period_ms: fc.period_ms,
    }
}

impl FaultsResult {
    /// The row for an exact (rate, policy) cell.
    pub fn row(&self, rate: f64, policy: PolicySpec) -> &FaultsRow {
        self.rows
            .iter()
            .find(|r| r.rate == rate && r.policy == policy)
            .expect("cell present")
    }

    /// Mean energy per served item for a cell, in mJ.
    pub fn energy_per_item_mj(&self, rate: f64, policy: PolicySpec) -> f64 {
        let r = self.row(rate, policy);
        r.energy_mj / r.items.max(1) as f64
    }

    /// Idle-Waiting's energy advantage over On-Off at `rate`: the ratio
    /// of their per-item energies (>1 means Idle-Waiting wins).
    pub fn advantage(&self, rate: f64) -> f64 {
        self.energy_per_item_mj(rate, PolicySpec::OnOff)
            / self.energy_per_item_mj(rate, PolicySpec::IdleWaiting)
    }

    /// The first swept rate (if any) where Idle-Waiting's advantage over
    /// On-Off exceeds its fault-free baseline by more than 5%.
    pub fn widening_rate(&self) -> Option<f64> {
        let baseline = self.advantage(RATES[0]);
        RATES
            .into_iter()
            .skip(1)
            .find(|&rate| self.advantage(rate) > baseline * 1.05)
    }

    /// Render the ASCII results table plus the headline answer.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "rate",
            "policy",
            "items",
            "mJ/item",
            "retries",
            "recovery mJ",
            "shed",
            "configs",
            "power-ons",
        ])
        .with_title(format!(
            "Fault sweep: config fault rate x policy ({} items, {} ms period)",
            self.items, self.period_ms
        ));
        for r in &self.rows {
            t.row(&[
                fnum(r.rate, 3),
                r.policy.name().into(),
                fcount(r.items),
                fnum(r.energy_mj / r.items.max(1) as f64, 4),
                fcount(r.retries),
                fnum(r.recovery_energy_mj, 3),
                fcount(r.shed),
                fcount(r.configurations),
                fcount(r.power_ons),
            ]);
        }
        let mut out = t.render();
        out.push_str("\nIdle-Waiting vs On-Off per-item energy advantage by fault rate:\n");
        for rate in RATES {
            out.push_str(&format!("  rate {:>5.3}: {:.2}x\n", rate, self.advantage(rate)));
        }
        match self.widening_rate() {
            Some(rate) => out.push_str(&format!(
                "the advantage widens beyond its fault-free baseline (+5%) from rate {rate}\n"
            )),
            None => out.push_str(
                "the advantage never widens beyond its fault-free baseline (+5%) in this sweep\n",
            ),
        }
        out
    }

    /// The grid as CSV (the published `repro faults --csv` schema).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "rate",
            "policy",
            "items",
            "energy_mj",
            "retries",
            "recovery_energy_mj",
            "shed",
            "configurations",
            "power_ons",
        ]);
        for r in &self.rows {
            csv.row(&[
                format!("{}", r.rate),
                r.policy.name().to_string(),
                r.items.to_string(),
                format!("{}", r.energy_mj),
                r.retries.to_string(),
                format!("{}", r.recovery_energy_mj),
                r.shed.to_string(),
                r.configurations.to_string(),
                r.power_ons.to_string(),
            ]);
        }
        csv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::strategies::simulate::simulate_batch;

    fn small() -> FaultsConfig {
        FaultsConfig {
            items: 300,
            ..FaultsConfig::default()
        }
    }

    #[test]
    fn grid_covers_every_rate_and_policy() {
        let r = run(&paper_default(), &small());
        assert_eq!(r.rows.len(), RATES.len() * POLICIES.len());
        for rate in RATES {
            for policy in POLICIES {
                let row = r.row(rate, policy);
                assert!(row.items > 0, "{rate}/{policy}");
                if rate == 0.0 {
                    assert_eq!(row.retries, 0, "{policy}");
                    assert_eq!(row.shed, 0, "{policy}");
                    assert_eq!(row.recovery_energy_mj, 0.0, "{policy}");
                }
            }
        }
    }

    #[test]
    fn zero_rate_column_is_bit_identical_to_a_fault_free_run() {
        // the rate-0 cells must take the exact fault-free code path: the
        // energy bits match an independent simulate_batch with no fault
        // machinery configured at all
        let cfg = paper_default();
        let fc = small();
        let r = run(&cfg, &fc);
        let mut capped = cfg.clone();
        capped.workload.max_items = Some(fc.items);
        let gaps: Vec<Duration> = (0..fc.items - 1)
            .map(|_| Duration::from_millis(fc.period_ms))
            .collect();
        let model = Analytical::new(&capped.item, capped.workload.energy_budget);
        for policy in POLICIES {
            let mut p = build_with(policy, &model, &capped.workload.params);
            let solo = simulate_batch(&capped, p.as_mut(), &gaps);
            let cell = r.row(0.0, policy);
            assert_eq!(
                cell.energy_mj.to_bits(),
                solo.energy_exact.millijoules().to_bits(),
                "{policy}: {} vs {}",
                cell.energy_mj,
                solo.energy_exact.millijoules()
            );
            assert_eq!(cell.items, solo.items, "{policy}");
        }
    }

    #[test]
    fn onoff_pays_the_fault_tax_and_the_advantage_widens() {
        let r = run(&paper_default(), &small());
        let top = RATES[RATES.len() - 1];
        // On-Off configures ~every item: at a 20% attempt fault rate its
        // retries dwarf Idle-Waiting's (which configures once)
        let onoff = r.row(top, PolicySpec::OnOff);
        let iw = r.row(top, PolicySpec::IdleWaiting);
        assert!(onoff.retries > iw.retries, "{} vs {}", onoff.retries, iw.retries);
        assert!(onoff.recovery_energy_mj > iw.recovery_energy_mj);
        // and the headline: the fault tax widens Idle-Waiting's per-item
        // energy advantage beyond its fault-free baseline
        assert!(
            r.advantage(top) > r.advantage(0.0),
            "{} vs {}",
            r.advantage(top),
            r.advantage(0.0)
        );
    }

    #[test]
    fn renders_and_csv() {
        let r = run(&paper_default(), &small());
        assert!(r.render().contains("Fault sweep"));
        assert!(r.render().contains("advantage"));
        let csv = r.to_csv();
        assert_eq!(csv.n_rows(), r.rows.len());
        assert!(csv.render().starts_with("rate,policy,items,energy_mj"));
    }
}
