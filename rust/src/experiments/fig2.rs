//! Fig 2 (paper §1/§3): energy breakdown of a workload item.
//!
//! The 87.15%-configuration pie comes from the authors' *prior* study
//! (Cichiwskyj et al. [5]): single-SPI configuration before the
//! Experiment-1 optimization, and a heavier data-transmission workload
//! than the Table 2 LSTM. We reconstruct that regime from the same device
//! mechanism (single SPI @ 26 MHz, uncompressed) plus a documented
//! prior-study phase profile, and show the fraction emerges.

use crate::config::schema::{FpgaModel, SpiConfig};
use crate::device::bitstream::Bitstream;
use crate::device::config_fsm::ConfigProfile;
use crate::device::flash::StoredImage;
use crate::experiments::paper;
use crate::runner::{Grid, SweepRunner};
use crate::util::table::{fnum, Table};
use crate::util::units::{Duration, Energy, Power};

/// The reconstructed prior-study ([5]) workload item.
#[derive(Debug, Clone)]
pub struct Fig2Profile {
    /// The configuration profile at optimal settings.
    pub config: ConfigProfile,
    /// The non-configuration phases as (name, power, time).
    pub phases: Vec<(&'static str, Power, Duration)>,
}

/// The assumed prior-study SPI clock (the [5] platform used single SPI
/// at a mid-range frequency; 26 MHz reproduces the published 87.15%).
pub const PRIOR_STUDY_FREQ_MHZ: f64 = 26.0;

/// Build the pre-optimization profile at the documented 26 MHz.
pub fn run() -> Fig2Profile {
    profile_at(PRIOR_STUDY_FREQ_MHZ)
}

/// Build the prior-study profile assuming a given single-SPI clock.
pub fn profile_at(freq_mhz: f64) -> Fig2Profile {
    // Prior-study configuration path: single SPI (the [5] platform did
    // not use multi-bit configuration), no compression.
    let spi = SpiConfig {
        buswidth: 1,
        freq_mhz,
        compressed: false,
    };
    let image = StoredImage::new(Bitstream::lstm_accelerator(FpgaModel::Xc7s15), false);
    let config = ConfigProfile::compute(FpgaModel::Xc7s15, spi, &image);
    // Prior-study active phases (heavier data movement than Table 2's
    // LSTM: [5] streamed full sensor batches per inference).
    let phases = vec![
        (
            "data_loading",
            Power::from_milliwatts(138.7),
            Duration::from_millis(60.0),
        ),
        (
            "inference",
            Power::from_milliwatts(171.4),
            Duration::from_millis(5.0),
        ),
        (
            "data_offloading",
            Power::from_milliwatts(144.1),
            Duration::from_millis(1.2),
        ),
    ];
    Fig2Profile { config, phases }
}

/// Reconstruction sensitivity on the sweep engine: the configuration
/// share of a prior-study item as a function of the assumed single-SPI
/// clock — how robust the 87.15% headline is to the one free parameter
/// of the Fig 2 reconstruction. Returns (freq_mhz, config_fraction).
pub fn share_series(runner: &SweepRunner) -> Vec<(f64, f64)> {
    let grid = Grid::new(SpiConfig::FREQS_MHZ.to_vec());
    runner.run(&grid, |cell| {
        let freq = *cell.params;
        (freq, profile_at(freq).config_fraction())
    })
}

impl Fig2Profile {
    /// Configuration-phase energy.
    pub fn config_energy(&self) -> Energy {
        self.config.total_energy()
    }

    /// Energy of everything except configuration.
    pub fn other_energy(&self) -> Energy {
        self.phases.iter().map(|(_, p, t)| *p * *t).sum()
    }

    /// Total item energy.
    pub fn total_energy(&self) -> Energy {
        self.config_energy() + self.other_energy()
    }

    /// The Fig 2 headline: configuration share of the item.
    pub fn config_fraction(&self) -> f64 {
        self.config_energy() / self.total_energy()
    }

    /// §3's thought experiment: items executable if configuration energy
    /// were eliminated, as a multiple of the status quo.
    pub fn items_multiplier_without_config(&self) -> f64 {
        self.total_energy() / self.other_energy()
    }

    /// Render the Fig 2 breakdown table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["phase", "energy (mJ)", "share (%)"])
            .with_title("Fig 2: energy breakdown of a workload item (prior-study regime)");
        let total = self.total_energy();
        t.row(&[
            "configuration".into(),
            fnum(self.config_energy().millijoules(), 2),
            fnum(self.config_fraction() * 100.0, 2),
        ]);
        for (name, p, dur) in &self.phases {
            let e = *p * *dur;
            t.row(&[
                (*name).into(),
                fnum(e.millijoules(), 2),
                fnum(e / total * 100.0, 2),
            ]);
        }
        t.row(&[
            "TOTAL".into(),
            fnum(total.millijoules(), 2),
            "100.00".into(),
        ]);
        let mut out = t.render();
        out.push_str(&format!(
            "\npaper config share: {:.2}% | measured: {:.2}%\n\
             eliminating configuration would allow {:.2}x the workload items (paper: 'up to 6x more')\n",
            paper::fig2::CONFIG_FRACTION * 100.0,
            self.config_fraction() * 100.0,
            self.items_multiplier_without_config()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_share_matches_fig2() {
        let f = run();
        assert!(
            (f.config_fraction() - paper::fig2::CONFIG_FRACTION).abs() < 0.002,
            "share={}",
            f.config_fraction()
        );
    }

    #[test]
    fn zeroing_other_phases_changes_little() {
        // §3: "Reducing the energy consumption of these phases to zero
        // would only lead to a 12.85% decrease"
        let f = run();
        let decrease = f.other_energy() / f.total_energy();
        assert!((decrease - 0.1285).abs() < 0.002, "{decrease}");
    }

    #[test]
    fn eliminating_config_allows_6x_more_items() {
        // 1 / 0.1285 ≈ 7.8× the items ⇒ ~6–7 additional per one — the
        // paper says "up to 6 additional inference requests"
        let f = run();
        let x = f.items_multiplier_without_config();
        assert!(x > 6.5 && x < 8.5, "{x}");
    }

    #[test]
    fn render_contains_breakdown() {
        let s = run().render();
        assert!(s.contains("configuration"));
        assert!(s.contains("87."));
    }

    #[test]
    fn share_series_decreases_with_frequency() {
        let series = share_series(&SweepRunner::single());
        assert_eq!(series.len(), SpiConfig::FREQS_MHZ.len());
        // faster loading → cheaper configuration → smaller share
        for pair in series.windows(2) {
            assert!(pair[1].1 < pair[0].1, "{pair:?}");
        }
        // the documented 26 MHz point is the headline reconstruction
        let at26 = series
            .iter()
            .find(|(f, _)| *f == PRIOR_STUDY_FREQ_MHZ)
            .unwrap();
        assert!((at26.1 - run().config_fraction()).abs() < 1e-12);
    }

    #[test]
    fn share_series_thread_invariant() {
        let serial = share_series(&SweepRunner::single());
        let parallel = share_series(&SweepRunner::new(4));
        assert_eq!(serial, parallel);
    }
}
