//! Experiment 3 (paper §5.4, Table 3, Figs 10–11): idle-power saving.
//!
//! Evaluates the Idle-Waiting strategy with Method 1 (gate IOs + clock
//! reference) and Methods 1+2 (+ retention undervolting) against the
//! baseline: Table 3's idle powers (reproduced by the rail model, not
//! hardcoded), the Fig 10/11 item and lifetime series, the sweep-average
//! multipliers (3.92× / 5.57×), the extended 499.06 ms crossover and the
//! combined 12.39× headline vs On-Off at 40 ms.

use crate::config::loader::SimConfig;
use crate::config::schema::PolicySpec;
use crate::device::fpga::Fpga;
use crate::device::rails::PowerSaving;
use crate::energy::analytical::Analytical;
use crate::energy::crossover;
use crate::experiments::paper;
use crate::runner::{Grid, SweepRunner};
use crate::util::csv::Csv;
use crate::util::table::{fcount, fnum, Table};
use crate::util::units::Duration;

/// One sweep sample across the three idle modes.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// Request period of the sample (ms).
    pub t_req_ms: f64,
    /// Items at baseline idle power.
    pub baseline_items: u64,
    /// Items with Method 1.
    pub m1_items: u64,
    /// Items with Methods 1+2.
    pub m12_items: u64,
}

/// Full Experiment 3 results.
#[derive(Debug, Clone)]
pub struct Exp3Result {
    /// The swept samples, in period order.
    pub samples: Vec<Sample>,
    /// Baseline idle power (mW).
    pub idle_baseline_mw: f64,
    /// Method 1 idle power (mW).
    pub idle_m1_mw: f64,
    /// Methods 1+2 idle power (mW).
    pub idle_m12_mw: f64,
    /// Measured M1+2-vs-On-Off crossover (ms).
    pub m12_crossover_ms: f64,
    /// M1+2 items over On-Off items at the 40 ms case study.
    pub m12_vs_onoff_at_40ms: f64,
}

/// Run the sweep (paper range 10–120 ms for the multipliers; the
/// crossover analysis extends to 600 ms internally). Single-threaded;
/// see [`run_threaded`] for the parallel path.
pub fn run(config: &SimConfig, step_ms: f64) -> Exp3Result {
    run_threaded(config, step_ms, &SweepRunner::single())
}

/// The idle-mode sweep as a grid declaration on the sweep engine.
pub fn run_threaded(config: &SimConfig, step_ms: f64, runner: &SweepRunner) -> Exp3Result {
    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let p_base = model.item.idle_power(PolicySpec::IdleWaiting);
    let p_m1 = model.item.idle_power(PolicySpec::IdleWaitingM1);
    let p_m12 = model.item.idle_power(PolicySpec::IdleWaitingM12);

    let grid = Grid::stepped(paper::exp2::T_REQ_MIN_MS, paper::exp2::T_REQ_MAX_MS, step_ms);
    let samples = runner.run(&grid, |cell| {
        let t = *cell.params;
        let t_req = Duration::from_millis(t);
        Sample {
            t_req_ms: t,
            baseline_items: model.n_max_idle_waiting(t_req, p_base).unwrap_or(0),
            m1_items: model.n_max_idle_waiting(t_req, p_m1).unwrap_or(0),
            m12_items: model.n_max_idle_waiting(t_req, p_m12).unwrap_or(0),
        }
    });

    let onoff_40 = model
        .n_max_onoff(Duration::from_millis(40.0))
        .expect("40 ms feasible") as f64;
    let m12_40 = model
        .n_max_idle_waiting(Duration::from_millis(40.0), p_m12)
        .unwrap() as f64;

    Exp3Result {
        samples,
        idle_baseline_mw: p_base.milliwatts(),
        idle_m1_mw: p_m1.milliwatts(),
        idle_m12_mw: p_m12.milliwatts(),
        m12_crossover_ms: crossover::asymptotic(&model, p_m12).millis(),
        m12_vs_onoff_at_40ms: m12_40 / onoff_40,
    }
}

impl Exp3Result {
    /// Sweep-average item multiplier vs baseline for Method 1.
    pub fn m1_items_x(&self) -> f64 {
        self.avg_ratio(|s| s.m1_items as f64 / s.baseline_items as f64)
    }

    /// Sweep-average item multiplier vs baseline for Methods 1+2.
    pub fn m12_items_x(&self) -> f64 {
        self.avg_ratio(|s| s.m12_items as f64 / s.baseline_items as f64)
    }

    fn avg_ratio(&self, f: impl Fn(&Sample) -> f64) -> f64 {
        self.samples.iter().map(&f).sum::<f64>() / self.samples.len() as f64
    }

    /// Average lifetime in hours for a mode across the sweep.
    pub fn avg_lifetime_h(&self, mode: PowerSaving) -> f64 {
        self.samples
            .iter()
            .map(|s| {
                let items = match mode {
                    PowerSaving { method1: false, .. } => s.baseline_items,
                    PowerSaving { method1: true, method2: false } => s.m1_items,
                    PowerSaving { method1: true, method2: true } => s.m12_items,
                };
                Duration::from_millis(s.t_req_ms).hours() * items as f64
            })
            .sum::<f64>()
            / self.samples.len() as f64
    }

    /// Table 3 with paper comparison (powers come from the rail model).
    pub fn render_table3(&self) -> String {
        let mut t = Table::new(&["metric", "baseline", "method 1", "method 1+2"])
            .with_title("Table 3: idle power");
        t.row(&[
            "idle power (mW), paper".into(),
            fnum(paper::exp3::BASELINE_IDLE_MW, 1),
            fnum(paper::exp3::M1_IDLE_MW, 1),
            fnum(paper::exp3::M12_IDLE_MW, 1),
        ]);
        t.row(&[
            "idle power (mW), rail model".into(),
            fnum(self.idle_baseline_mw, 1),
            fnum(self.idle_m1_mw, 1),
            fnum(self.idle_m12_mw, 1),
        ]);
        t.row(&[
            "saved power (%)".into(),
            "-".into(),
            fnum((1.0 - self.idle_m1_mw / self.idle_baseline_mw) * 100.0, 2),
            fnum((1.0 - self.idle_m12_mw / self.idle_baseline_mw) * 100.0, 2),
        ]);
        t.render()
    }

    /// Figs 10–11 at 10 ms intervals.
    pub fn render_figs(&self) -> String {
        let mut t = Table::new(&[
            "T_req (ms)",
            "baseline items",
            "m1 items",
            "m1+2 items",
            "baseline life (h)",
            "m1 life (h)",
            "m1+2 life (h)",
        ])
        .with_title("Fig 10 (items) + Fig 11 (lifetime): power-saving methods");
        for s in self.samples.iter().filter(|s| (s.t_req_ms % 10.0).abs() < 1e-9) {
            let h = |items: u64| fnum(Duration::from_millis(s.t_req_ms).hours() * items as f64, 2);
            t.row(&[
                fnum(s.t_req_ms, 0),
                fcount(s.baseline_items),
                fcount(s.m1_items),
                fcount(s.m12_items),
                h(s.baseline_items),
                h(s.m1_items),
                h(s.m12_items),
            ]);
        }
        t.render()
    }

    /// Headline summary with paper comparison.
    pub fn render_summary(&self) -> String {
        let mut t = Table::new(&["metric", "paper", "measured"])
            .with_title("Experiment 3 summary");
        t.row(&[
            "method 1 items (× baseline)".into(),
            fnum(paper::exp3::M1_ITEMS_X, 2),
            fnum(self.m1_items_x(), 2),
        ]);
        t.row(&[
            "method 1+2 items (× baseline)".into(),
            fnum(paper::exp3::M12_ITEMS_X, 2),
            fnum(self.m12_items_x(), 2),
        ]);
        t.row(&[
            "method 1 avg lifetime (h)".into(),
            fnum(paper::exp3::M1_AVG_LIFETIME_H, 2),
            fnum(self.avg_lifetime_h(PowerSaving::M1), 2),
        ]);
        t.row(&[
            "method 1+2 avg lifetime (h)".into(),
            fnum(paper::exp3::M12_AVG_LIFETIME_H, 2),
            fnum(self.avg_lifetime_h(PowerSaving::M12), 2),
        ]);
        t.row(&[
            "m1+2 crossover (ms)".into(),
            fnum(paper::exp3::M12_CROSSOVER_MS, 2),
            fnum(self.m12_crossover_ms, 2),
        ]);
        t.row(&[
            "m1+2 vs On-Off @40 ms (×)".into(),
            fnum(paper::exp3::M12_VS_ONOFF_AT_40MS, 2),
            fnum(self.m12_vs_onoff_at_40ms, 2),
        ]);
        t.render()
    }

    /// The sweep series as CSV (the published `--csv` schema).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&["t_req_ms", "baseline_items", "m1_items", "m12_items"]);
        for s in &self.samples {
            csv.row_f64(&[
                s.t_req_ms,
                s.baseline_items as f64,
                s.m1_items as f64,
                s.m12_items as f64,
            ]);
        }
        csv
    }
}

/// Cross-check: the Table 3 idle powers must also be exactly what the
/// FPGA state machine reports when driven into each idle mode.
pub fn table3_from_device() -> [f64; 3] {
    [
        Fpga::idle_power(PowerSaving::BASELINE).milliwatts(),
        Fpga::idle_power(PowerSaving::M1).milliwatts(),
        Fpga::idle_power(PowerSaving::M12).milliwatts(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn result() -> Exp3Result {
        run(&paper_default(), 1.0)
    }

    #[test]
    fn table3_powers_from_rail_model() {
        let r = result();
        assert!((r.idle_baseline_mw - 134.3).abs() < 1e-9);
        assert!((r.idle_m1_mw - 34.2).abs() < 1e-9);
        assert!((r.idle_m12_mw - 24.0).abs() < 0.05);
        let dev = table3_from_device();
        assert!((dev[0] - 134.3).abs() < 1e-9);
        assert!((dev[1] - 34.2).abs() < 1e-9);
        assert!((dev[2] - 24.0).abs() < 0.05);
    }

    #[test]
    fn multipliers_match_paper() {
        let r = result();
        assert!((r.m1_items_x() - 3.92).abs() < 0.03, "{}", r.m1_items_x());
        assert!((r.m12_items_x() - 5.57).abs() < 0.04, "{}", r.m12_items_x());
    }

    #[test]
    fn lifetimes_match_paper() {
        let r = result();
        assert!(
            (r.avg_lifetime_h(PowerSaving::M1) - 33.64).abs() < 0.3,
            "{}",
            r.avg_lifetime_h(PowerSaving::M1)
        );
        assert!(
            (r.avg_lifetime_h(PowerSaving::M12) - 47.80).abs() < 0.4,
            "{}",
            r.avg_lifetime_h(PowerSaving::M12)
        );
    }

    #[test]
    fn extended_crossover_and_combined_headline() {
        let r = result();
        assert!((r.m12_crossover_ms - 499.06).abs() < 0.2, "{}", r.m12_crossover_ms);
        assert!((r.m12_vs_onoff_at_40ms - 12.39).abs() < 0.05, "{}", r.m12_vs_onoff_at_40ms);
    }

    #[test]
    fn ordering_invariant_m12_ge_m1_ge_baseline() {
        let r = result();
        for s in &r.samples {
            assert!(s.m12_items >= s.m1_items);
            assert!(s.m1_items >= s.baseline_items);
        }
    }

    #[test]
    fn renders() {
        let r = result();
        assert!(r.render_table3().contains("Table 3"));
        assert!(r.render_figs().contains("Fig 10"));
        assert!(r.render_summary().contains("499.06"));
        assert!(r.to_csv().n_rows() > 100);
    }

    // Thread-count invariance (threads=1 vs N byte-identical CSV) is
    // covered by tests/sweep_determinism.rs.
}
