//! The paper's published numbers, as constants.
//!
//! Every experiment report prints "paper vs measured" using these values;
//! the integration tests assert agreement within stated tolerances. Keep
//! this file the *only* place paper numbers live, so a failed
//! reproduction points at exactly one diff.

/// §5.2 / Fig 7 — Experiment 1 (configuration-phase optimization).
pub mod exp1 {
    /// Optimal setting: Quad SPI, 66 MHz, compressed.
    pub const OPT_TIME_MS: f64 = 36.145;
    /// Optimal-setting configuration energy (mJ).
    pub const OPT_ENERGY_MJ: f64 = 11.85;
    /// Optimal-setting configuration power (mW).
    pub const OPT_POWER_MW: f64 = 327.9;
    /// Worst setting: Single SPI, 3 MHz, uncompressed.
    pub const WORST_ENERGY_MJ: f64 = 475.56;
    /// Headline ratios.
    pub const TIME_IMPROVEMENT: f64 = 41.4;
    /// Headline energy ratio (worst / optimal).
    pub const ENERGY_IMPROVEMENT: f64 = 40.13;
    /// Setup stage (§5.2): constant across settings.
    pub const SETUP_POWER_MW: f64 = 288.0;
    /// Setup stage duration (ms).
    pub const SETUP_TIME_MS: f64 = 27.0;
    /// XC7S25 at optimal settings (§5.2).
    pub const XC7S25_TIME_MS: f64 = 38.09;
    /// XC7S25 configuration energy at optimal settings (mJ).
    pub const XC7S25_ENERGY_MJ: f64 = 13.75;
}

/// Table 2 — workload-item characterization on hardware.
pub mod table2 {
    /// Configuration power (mW).
    pub const CONFIG_POWER_MW: f64 = 327.9;
    /// Configuration time (ms).
    pub const CONFIG_TIME_MS: f64 = 36.145;
    /// Data-loading power (mW).
    pub const LOAD_POWER_MW: f64 = 138.7;
    /// Data-loading time (ms).
    pub const LOAD_TIME_MS: f64 = 0.0100;
    /// Inference power (mW).
    pub const INFER_POWER_MW: f64 = 171.4;
    /// Inference time (ms).
    pub const INFER_TIME_MS: f64 = 0.0281;
    /// Data-offloading power (mW).
    pub const OFFLOAD_POWER_MW: f64 = 144.1;
    /// Data-offloading time (ms).
    pub const OFFLOAD_TIME_MS: f64 = 0.0020;
    /// Idle power (mW).
    pub const IDLE_POWER_MW: f64 = 134.3;
}

/// §5.3 / Figs 8–9 — Experiment 2 (Idle-Waiting vs On-Off).
pub mod exp2 {
    /// Battery energy budget (J).
    pub const BUDGET_J: f64 = 4147.0;
    /// Sweep range and step used by the paper.
    pub const T_REQ_MIN_MS: f64 = 10.0;
    /// Sweep upper bound (ms).
    pub const T_REQ_MAX_MS: f64 = 120.0;
    /// Sweep step (ms).
    pub const T_REQ_STEP_MS: f64 = 0.01;
    /// On-Off items (constant over feasible periods).
    pub const ONOFF_ITEMS: u64 = 346_073;
    /// Idle-Waiting items at the sweep extremes.
    pub const IW_ITEMS_MAX: u64 = 3_085_319; // at 10 ms
    /// Idle-Waiting items at the slowest swept period.
    pub const IW_ITEMS_MIN: u64 = 257_305; // at 120 ms
    /// Ratio at the paper's 40 ms case study.
    pub const RATIO_AT_40MS: f64 = 2.23;
    /// Efficiency cross point.
    pub const CROSSOVER_MS: f64 = 89.21;
    /// On-Off infeasible below the configuration time.
    pub const ONOFF_MIN_PERIOD_MS: f64 = 36.15;
    /// Idle-Waiting average lifetime.
    pub const IW_AVG_LIFETIME_H: f64 = 8.58;
    /// Hardware-vs-simulator validation gaps at 40 ms (§5.3).
    pub const HW_ITEMS_GAP: f64 = 0.028;
    /// Hardware-vs-simulator lifetime gap (§5.3).
    pub const HW_LIFETIME_GAP: f64 = 0.027;
}

/// Table 3 + §5.4 / Figs 10–11 — Experiment 3 (idle power-saving).
pub mod exp3 {
    /// Baseline idle power (mW).
    pub const BASELINE_IDLE_MW: f64 = 134.3;
    /// Method 1 idle power (mW).
    pub const M1_IDLE_MW: f64 = 34.2;
    /// Methods 1+2 idle power (mW).
    pub const M12_IDLE_MW: f64 = 24.0;
    /// Paper's quoted savings (computed from unrounded measurements; the
    /// rounded Table 3 powers give 74.53% / 82.13%).
    pub const M1_SAVED_PCT: f64 = 74.38;
    /// Idle-power saving of M1+2 vs baseline (%).
    pub const M12_SAVED_PCT: f64 = 81.98;
    /// Item-count multipliers vs baseline Idle-Waiting (sweep averages).
    pub const M1_ITEMS_X: f64 = 3.92;
    /// M1+2 items over On-Off items at 40 ms.
    pub const M12_ITEMS_X: f64 = 5.57;
    /// Average lifetimes.
    pub const M1_AVG_LIFETIME_H: f64 = 33.64;
    /// M1+2 average lifetime (hours).
    pub const M12_AVG_LIFETIME_H: f64 = 47.80;
    /// Extended advantageous request period.
    pub const M12_CROSSOVER_MS: f64 = 499.06;
    /// Combined headline: vs On-Off at 40 ms.
    pub const M12_VS_ONOFF_AT_40MS: f64 = 12.39;
}

/// Fig 2 — energy breakdown of a workload item (from prior study [5],
/// pre-optimization configuration settings).
pub mod fig2 {
    /// Configuration phase share of total item energy.
    pub const CONFIG_FRACTION: f64 = 0.8715;
    /// Everything else (data transmission + inference).
    pub const REST_FRACTION: f64 = 0.1285;
}

#[cfg(test)]
mod tests {
    #[test]
    fn fractions_sum_to_one() {
        assert!((super::fig2::CONFIG_FRACTION + super::fig2::REST_FRACTION - 1.0).abs() < 1e-12);
    }

    #[test]
    fn table2_config_energy_is_optimal_exp1_energy() {
        let e = super::table2::CONFIG_POWER_MW * super::table2::CONFIG_TIME_MS / 1000.0;
        assert!((e - super::exp1::OPT_ENERGY_MJ).abs() < 0.01);
    }

    #[test]
    fn paper_internal_consistency_of_ratios() {
        // 475.56 / 11.85 ≈ 40.13
        let r = super::exp1::WORST_ENERGY_MJ / super::exp1::OPT_ENERGY_MJ;
        assert!((r - super::exp1::ENERGY_IMPROVEMENT).abs() < 0.01);
    }
}
