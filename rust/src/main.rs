//! `repro` — CLI entrypoint for the "Idle is the New Sleep" reproduction.

use idlewait::cli;
use idlewait::util::logging;

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(err) = cli::run(&argv) {
        eprintln!("error: {err:#}");
        std::process::exit(1);
    }
}
