//! ASCII table rendering for experiment reports.
//!
//! The benches and the `repro` CLI print the paper's tables/figure series
//! as plain-text tables; this module owns alignment, headers and separators
//! so every report looks the same.

use std::fmt::Write as _;

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    /// Left-aligned column (text).
    Left,
    /// Right-aligned column (numbers).
    Right,
}

/// A simple ASCII table builder.
#[derive(Debug, Clone)]
pub struct Table {
    title: Option<String>,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table {
            title: None,
            aligns: vec![Align::Right; headers.len()],
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Set a title line rendered above the table.
    pub fn with_title(mut self, title: impl Into<String>) -> Table {
        self.title = Some(title.into());
        self
    }

    /// Set per-column alignment (defaults to right-aligned).
    pub fn with_aligns(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from displayable items.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Table {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// True when no rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a string with box-drawing separators.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }

        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };

        let fmt_row = |cells: &[String], aligns: &[Align]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                let cell = &cells[i];
                match aligns[i] {
                    Align::Left => {
                        let _ = write!(line, " {:<width$} |", cell, width = widths[i]);
                    }
                    Align::Right => {
                        let _ = write!(line, " {:>width$} |", cell, width = widths[i]);
                    }
                }
            }
            line
        };

        let mut out = String::new();
        if let Some(title) = &self.title {
            let _ = writeln!(out, "{title}");
        }
        let _ = writeln!(out, "{sep}");
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &vec![Align::Left; ncols]));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &self.aligns));
        }
        let _ = writeln!(out, "{sep}");
        out
    }
}

/// Format a float with a fixed number of decimals (report helper).
pub fn fnum(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Format a large count with thousands separators (e.g. 3,085,319).
pub fn fcount(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*b as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new(&["strategy", "items"]).with_title("Fig 8");
        t.row(&["On-Off".into(), "346,073".into()]);
        t.row(&["Idle-Waiting".into(), "771,781".into()]);
        let s = t.render();
        assert!(s.contains("Fig 8"));
        assert!(s.contains("| strategy     | items   |"));
        assert!(s.contains("|       On-Off | 346,073 |"));
        // all data lines same width
        let widths: Vec<usize> = s.lines().skip(1).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_mismatched_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn left_alignment() {
        let mut t = Table::new(&["k", "v"]).with_aligns(&[Align::Left, Align::Right]);
        t.row(&["x".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("| x      |  1 |"));
    }

    #[test]
    fn fcount_groups_thousands() {
        assert_eq!(fcount(0), "0");
        assert_eq!(fcount(999), "999");
        assert_eq!(fcount(1000), "1,000");
        assert_eq!(fcount(3_085_319), "3,085,319");
        assert_eq!(fcount(346_073), "346,073");
    }

    #[test]
    fn fnum_decimals() {
        assert_eq!(fnum(11.8523, 2), "11.85");
        assert_eq!(fnum(40.131, 2), "40.13");
    }

    #[test]
    fn empty_table_renders_headers_only() {
        let t = Table::new(&["h1", "h2"]);
        assert!(t.is_empty());
        let s = t.render();
        assert!(s.contains("h1"));
        assert_eq!(s.lines().count(), 4); // sep, header, sep, sep
    }
}
