//! Minimal JSON value type with parser and serializer.
//!
//! Used for two things: reading `artifacts/manifest.json` (written by the
//! python AOT pipeline, describes each HLO artifact's entry shapes) and
//! writing machine-readable experiment reports. `serde` is unavailable in
//! the offline vendor set, so this is a small, strict RFC-8259 subset
//! implementation: no comments, no trailing commas, UTF-8 strings with the
//! standard escapes, f64 numbers.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON/YAML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null` / absent.
    Null,
    /// Boolean.
    Bool(bool),
    /// Number (f64, as in JSON).
    Num(f64),
    /// String.
    Str(String),
    /// Sequence.
    Arr(Vec<Json>),
    /// Mapping (sorted keys for deterministic rendering).
    Obj(BTreeMap<String, Json>),
}

/// A JSON parse error with its byte offset.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset of the offending input.
    pub pos: usize,
    /// Parser diagnostics.
    pub msg: String,
}

impl Json {
    // ---- accessors ----

    /// The boolean value, if this is a Bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The numeric value, if this is a Num.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if exactly representable.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// The string value, if this is a Str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an Arr.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The mapping, if this is an Obj.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- construction helpers ----

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a number.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Build a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build an array.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // ---- parsing ----

    /// Parse JSON text.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ---- serialization ----

    /// Compact single-line rendering.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn render_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(width * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|b| (b as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        // BMP only (sufficient for manifest/report content)
                        s.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // re-assemble UTF-8 multibyte sequences
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number '{text}'")))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "artifacts": [
                {"name": "lstm_step", "file": "lstm_step.hlo.txt",
                 "inputs": [[1, 6], [1, 20], [1, 20]], "dtype": "f32"}
            ],
            "hidden_size": 20,
            "compressed": true,
            "note": null
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("hidden_size").unwrap().as_u64(), Some(20));
        assert_eq!(v.get("compressed").unwrap().as_bool(), Some(true));
        assert_eq!(*v.get("note").unwrap(), Json::Null);
        let art = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(art.get("name").unwrap().as_str(), Some("lstm_step"));
        let shape0 = art.get("inputs").unwrap().as_arr().unwrap()[0]
            .as_arr()
            .unwrap();
        assert_eq!(shape0[1].as_u64(), Some(6));
    }

    #[test]
    fn round_trips_compact() {
        let v = Json::obj(vec![
            ("a", Json::num(1.5)),
            ("b", Json::arr(vec![Json::Bool(false), Json::Null])),
            ("c", Json::str("x\"y\n")),
        ]);
        let text = v.render();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn round_trips_pretty() {
        let v = Json::obj(vec![(
            "nested",
            Json::obj(vec![("k", Json::arr(vec![Json::num(1.0), Json::num(2.0)]))]),
        )]);
        let text = v.render_pretty();
        assert!(text.contains("\n  "));
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::num(20.0).render(), "20");
        assert_eq!(Json::num(1.25).render(), "1.25");
        assert_eq!(Json::num(-3.0).render(), "-3");
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
        assert_eq!(Json::parse("1e-2").unwrap().as_f64(), Some(0.01));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_and_escape_handling() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Json::num(1.5).as_u64(), None);
        assert_eq!(Json::num(-1.0).as_u64(), None);
        assert_eq!(Json::num(7.0).as_u64(), Some(7));
    }
}
