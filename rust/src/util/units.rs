//! Physical-unit newtypes used across the energy model.
//!
//! The paper's quantities live on very different scales (180 µA MCU sleep
//! current vs 4147 J battery budget vs 2 µs data-offload phases), so raw
//! `f64`s invite unit mistakes. These newtypes make the units explicit and
//! give the arithmetic the obvious physical identities:
//!
//! * `Power * Duration = Energy`
//! * `Energy / Duration = Power`
//! * `Voltage * Current = Power`
//!
//! Internal representations: watts, joules, seconds, volts, amperes (SI
//! base), with milli-scaled constructors/accessors because the paper's
//! tables are in mW / mJ / ms.

use std::cmp::Ordering;
use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub, SubAssign};

macro_rules! unit_newtype {
    ($(#[$doc:meta])* $name:ident, $sym:expr) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, Default)]
        pub struct $name(pub(crate) f64);

        impl $name {
            /// The zero value.
            pub const ZERO: $name = $name(0.0);

            /// Raw SI-base value (W, J, s, V, A respectively).
            #[inline]
            pub fn raw(self) -> f64 {
                self.0
            }

            /// True if the value is finite (neither NaN nor ±inf).
            #[inline]
            pub fn is_finite(self) -> bool {
                self.0.is_finite()
            }

            /// Absolute value.
            #[inline]
            pub fn abs(self) -> $name {
                $name(self.0.abs())
            }

            /// Elementwise max.
            #[inline]
            pub fn max(self, other: $name) -> $name {
                $name(self.0.max(other.0))
            }

            /// Elementwise min.
            #[inline]
            pub fn min(self, other: $name) -> $name {
                $name(self.0.min(other.0))
            }

            /// Relative difference |a-b| / max(|a|,|b|,eps); 0 for two zeros.
            pub fn rel_diff(self, other: $name) -> f64 {
                let denom = self.0.abs().max(other.0.abs());
                if denom == 0.0 {
                    0.0
                } else {
                    (self.0 - other.0).abs() / denom
                }
            }
        }

        impl Add for $name {
            type Output = $name;
            #[inline]
            fn add(self, rhs: $name) -> $name {
                $name(self.0 + rhs.0)
            }
        }

        impl AddAssign for $name {
            #[inline]
            fn add_assign(&mut self, rhs: $name) {
                self.0 += rhs.0;
            }
        }

        impl Sub for $name {
            type Output = $name;
            #[inline]
            fn sub(self, rhs: $name) -> $name {
                $name(self.0 - rhs.0)
            }
        }

        impl SubAssign for $name {
            #[inline]
            fn sub_assign(&mut self, rhs: $name) {
                self.0 -= rhs.0;
            }
        }

        impl Neg for $name {
            type Output = $name;
            #[inline]
            fn neg(self) -> $name {
                $name(-self.0)
            }
        }

        impl Mul<f64> for $name {
            type Output = $name;
            #[inline]
            fn mul(self, rhs: f64) -> $name {
                $name(self.0 * rhs)
            }
        }

        impl Mul<$name> for f64 {
            type Output = $name;
            #[inline]
            fn mul(self, rhs: $name) -> $name {
                $name(self * rhs.0)
            }
        }

        impl Div<f64> for $name {
            type Output = $name;
            #[inline]
            fn div(self, rhs: f64) -> $name {
                $name(self.0 / rhs)
            }
        }

        impl Div<$name> for $name {
            /// Ratio of two same-unit quantities is dimensionless.
            type Output = f64;
            #[inline]
            fn div(self, rhs: $name) -> f64 {
                self.0 / rhs.0
            }
        }

        impl PartialOrd for $name {
            #[inline]
            fn partial_cmp(&self, other: &$name) -> Option<Ordering> {
                self.0.partial_cmp(&other.0)
            }
        }

        impl Sum for $name {
            fn sum<I: Iterator<Item = $name>>(iter: I) -> $name {
                $name(iter.map(|v| v.0).sum())
            }
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                if let Some(prec) = f.precision() {
                    write!(f, "{:.*} {}", prec, self.0, $sym)
                } else {
                    write!(f, "{} {}", self.0, $sym)
                }
            }
        }
    };
}

unit_newtype!(
    /// Electrical power. SI base: watts.
    Power,
    "W"
);
unit_newtype!(
    /// Energy. SI base: joules.
    Energy,
    "J"
);
unit_newtype!(
    /// Time duration. SI base: seconds.
    Duration,
    "s"
);
unit_newtype!(
    /// Electrical potential. SI base: volts.
    Voltage,
    "V"
);
unit_newtype!(
    /// Electrical current. SI base: amperes.
    Current,
    "A"
);

impl Power {
    /// Construct from watts.
    #[inline]
    pub fn from_watts(w: f64) -> Power {
        Power(w)
    }
    /// Construct from milliwatts.
    #[inline]
    pub fn from_milliwatts(mw: f64) -> Power {
        Power(mw * 1e-3)
    }
    /// Construct from microwatts.
    #[inline]
    pub fn from_microwatts(uw: f64) -> Power {
        Power(uw * 1e-6)
    }
    /// Value in watts.
    #[inline]
    pub fn watts(self) -> f64 {
        self.0
    }
    /// Value in milliwatts.
    #[inline]
    pub fn milliwatts(self) -> f64 {
        self.0 * 1e3
    }
    /// Value in microwatts.
    #[inline]
    pub fn microwatts(self) -> f64 {
        self.0 * 1e6
    }
}

impl Energy {
    /// Construct from joules.
    #[inline]
    pub fn from_joules(j: f64) -> Energy {
        Energy(j)
    }
    /// Construct from millijoules.
    #[inline]
    pub fn from_millijoules(mj: f64) -> Energy {
        Energy(mj * 1e-3)
    }
    /// Construct from microjoules.
    #[inline]
    pub fn from_microjoules(uj: f64) -> Energy {
        Energy(uj * 1e-6)
    }
    /// Value in joules.
    #[inline]
    pub fn joules(self) -> f64 {
        self.0
    }
    /// Value in millijoules.
    #[inline]
    pub fn millijoules(self) -> f64 {
        self.0 * 1e3
    }
    /// Value in microjoules.
    #[inline]
    pub fn microjoules(self) -> f64 {
        self.0 * 1e6
    }
}

impl Duration {
    /// Construct from seconds.
    #[inline]
    pub fn from_secs(s: f64) -> Duration {
        Duration(s)
    }
    /// Construct from milliseconds.
    #[inline]
    pub fn from_millis(ms: f64) -> Duration {
        Duration(ms * 1e-3)
    }
    /// Construct from microseconds.
    #[inline]
    pub fn from_micros(us: f64) -> Duration {
        Duration(us * 1e-6)
    }
    /// Construct from nanoseconds.
    #[inline]
    pub fn from_nanos(ns: f64) -> Duration {
        Duration(ns * 1e-9)
    }
    /// Construct from hours.
    #[inline]
    pub fn from_hours(h: f64) -> Duration {
        Duration(h * 3600.0)
    }
    /// Value in seconds.
    #[inline]
    pub fn secs(self) -> f64 {
        self.0
    }
    /// Value in milliseconds.
    #[inline]
    pub fn millis(self) -> f64 {
        self.0 * 1e3
    }
    /// Value in microseconds.
    #[inline]
    pub fn micros(self) -> f64 {
        self.0 * 1e6
    }
    /// Value in hours.
    #[inline]
    pub fn hours(self) -> f64 {
        self.0 / 3600.0
    }
}

impl Voltage {
    /// Construct from volts.
    #[inline]
    pub fn from_volts(v: f64) -> Voltage {
        Voltage(v)
    }
    /// Value in volts.
    #[inline]
    pub fn volts(self) -> f64 {
        self.0
    }
    /// Value in millivolts.
    #[inline]
    pub fn millivolts(self) -> f64 {
        self.0 * 1e3
    }
}

impl Current {
    /// Construct from amperes.
    #[inline]
    pub fn from_amps(a: f64) -> Current {
        Current(a)
    }
    /// Construct from milliamperes.
    #[inline]
    pub fn from_milliamps(ma: f64) -> Current {
        Current(ma * 1e-3)
    }
    /// Construct from microamperes.
    #[inline]
    pub fn from_microamps(ua: f64) -> Current {
        Current(ua * 1e-6)
    }
    /// Value in amperes.
    #[inline]
    pub fn amps(self) -> f64 {
        self.0
    }
    /// Value in milliamperes.
    #[inline]
    pub fn milliamps(self) -> f64 {
        self.0 * 1e3
    }
}

// ---- cross-unit physics ----

impl Mul<Duration> for Power {
    type Output = Energy;
    #[inline]
    fn mul(self, rhs: Duration) -> Energy {
        Energy(self.0 * rhs.0)
    }
}

impl Mul<Power> for Duration {
    type Output = Energy;
    #[inline]
    fn mul(self, rhs: Power) -> Energy {
        Energy(self.0 * rhs.0)
    }
}

impl Div<Duration> for Energy {
    type Output = Power;
    #[inline]
    fn div(self, rhs: Duration) -> Power {
        Power(self.0 / rhs.0)
    }
}

impl Div<Power> for Energy {
    type Output = Duration;
    #[inline]
    fn div(self, rhs: Power) -> Duration {
        Duration(self.0 / rhs.0)
    }
}

impl Mul<Current> for Voltage {
    type Output = Power;
    #[inline]
    fn mul(self, rhs: Current) -> Power {
        Power(self.0 * rhs.0)
    }
}

impl Mul<Voltage> for Current {
    type Output = Power;
    #[inline]
    fn mul(self, rhs: Voltage) -> Power {
        Power(self.0 * rhs.0)
    }
}

impl Div<Voltage> for Power {
    type Output = Current;
    #[inline]
    fn div(self, rhs: Voltage) -> Current {
        Current(self.0 / rhs.0)
    }
}

/// Battery capacity helper: charge (mAh) at a nominal voltage → energy.
///
/// The paper's 320 mAh LiPo at a 3.6 V nominal ≈ 4147 J energy budget.
pub fn battery_energy(capacity_mah: f64, nominal: Voltage) -> Energy {
    // mAh → coulombs: 1 mAh = 3.6 C
    let coulombs = capacity_mah * 3.6;
    Energy(coulombs * nominal.volts())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_times_duration_is_energy() {
        let e = Power::from_milliwatts(327.9) * Duration::from_millis(36.145);
        assert!((e.millijoules() - 11.8520).abs() < 1e-3);
    }

    #[test]
    fn energy_div_duration_is_power() {
        let p = Energy::from_millijoules(10.0) / Duration::from_millis(5.0);
        assert!((p.milliwatts() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn energy_div_power_is_duration() {
        let t = Energy::from_joules(4147.0) / Power::from_milliwatts(134.3);
        assert!((t.hours() - 4147.0 / 0.1343 / 3600.0).abs() < 1e-9);
    }

    #[test]
    fn voltage_times_current_is_power() {
        // RP2040 sleep: 180 µA at 3.3 V
        let p = Voltage::from_volts(3.3) * Current::from_microamps(180.0);
        assert!((p.milliwatts() - 0.594).abs() < 1e-9);
    }

    #[test]
    fn milli_round_trips() {
        assert!((Power::from_milliwatts(134.3).milliwatts() - 134.3).abs() < 1e-12);
        assert!((Energy::from_millijoules(11.85).millijoules() - 11.85).abs() < 1e-12);
        assert!((Duration::from_millis(36.15).millis() - 36.15).abs() < 1e-12);
    }

    #[test]
    fn micro_round_trips() {
        assert!((Power::from_microwatts(594.0).microwatts() - 594.0).abs() < 1e-9);
        assert!((Energy::from_microjoules(4.816).microjoules() - 4.816).abs() < 1e-12);
        assert!((Duration::from_micros(28.1).micros() - 28.1).abs() < 1e-12);
    }

    #[test]
    fn battery_energy_matches_paper_budget() {
        // 320 mAh LiPo ≈ 4147 J (paper §2) at 3.6 V nominal
        let e = battery_energy(320.0, Voltage::from_volts(3.6));
        assert!((e.joules() - 4147.2).abs() < 0.5, "{}", e.joules());
    }

    #[test]
    fn sum_of_energies() {
        let phases = [
            Energy::from_millijoules(11.852),
            Energy::from_microjoules(1.387),
            Energy::from_microjoules(4.816),
            Energy::from_microjoules(0.2882),
        ];
        let total: Energy = phases.iter().copied().sum();
        assert!((total.millijoules() - 11.8585).abs() < 1e-3);
    }

    #[test]
    fn ordering_and_ratio() {
        let a = Duration::from_millis(89.21);
        let b = Duration::from_millis(499.06);
        assert!(a < b);
        assert!((b / a - 499.06 / 89.21).abs() < 1e-12);
    }

    #[test]
    fn rel_diff_zero_and_nonzero() {
        assert_eq!(Energy::ZERO.rel_diff(Energy::ZERO), 0.0);
        let d = Energy::from_joules(1.0).rel_diff(Energy::from_joules(1.028));
        assert!((d - 0.028 / 1.028).abs() < 1e-12);
    }

    #[test]
    fn display_precision() {
        let p = Power::from_milliwatts(134.3);
        assert_eq!(format!("{:.4}", p), "0.1343 W");
    }

    #[test]
    fn negation_and_sub_assign() {
        let mut e = Energy::from_joules(5.0);
        e -= Energy::from_joules(2.0);
        assert_eq!(e, Energy::from_joules(3.0));
        assert_eq!(-e, Energy::from_joules(-3.0));
    }
}
