//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set ships `rand_core` (traits) but no PRNG
//! implementation crate, so we implement two small, well-known generators:
//!
//! * [`SplitMix64`] — Steele/Lea/Flood splittable generator; used to seed.
//! * [`Xoshiro256ss`] — Blackman/Vigna xoshiro256**, the general-purpose
//!   generator used by the simulator, the property-test framework and the
//!   workload generators.
//!
//! Determinism matters here: every experiment and property test takes an
//! explicit seed so runs are reproducible bit-for-bit.

use rand_core::{impls, Error, RngCore, SeedableRng};

/// SplitMix64 — tiny, passes BigCrush, ideal for seeding other generators.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// A generator starting from `seed`.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// The next 64-bit output.
    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the simulator's general-purpose PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256ss {
    s: [u64; 4],
}

impl Xoshiro256ss {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Xoshiro256ss {
            s: [sm.next(), sm.next(), sm.next(), sm.next()],
        }
    }

    /// The next raw 64-bit output.
    #[inline]
    pub fn next_u64_raw(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits → [0,1) with full double precision
        (self.next_u64_raw() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64_raw();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Exponentially-distributed sample with the given mean (for Poisson
    /// arrival processes in the irregular-request extension).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        // inverse CDF; guard against ln(0)
        let u = 1.0 - self.next_f64();
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (for jittered request periods).
    pub fn normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std_dev * z
    }

    /// Random boolean with probability `p` of true.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

impl RngCore for Xoshiro256ss {
    fn next_u32(&mut self) -> u32 {
        (self.next_u64_raw() >> 32) as u32
    }
    fn next_u64(&mut self) -> u64 {
        self.next_u64_raw()
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        impls::fill_bytes_via_next(self, dest)
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

impl SeedableRng for Xoshiro256ss {
    type Seed = [u8; 8];
    fn from_seed(seed: Self::Seed) -> Self {
        Xoshiro256ss::new(u64::from_le_bytes(seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed=0 from the public-domain splitmix64.c
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(sm.next(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Xoshiro256ss::new(42);
        let mut b = Xoshiro256ss::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64_raw(), b.next_u64_raw());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256ss::new(1);
        let mut b = Xoshiro256ss::new(2);
        let same = (0..64).filter(|_| a.next_u64_raw() == b.next_u64_raw()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256ss::new(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_ish_and_in_range() {
        let mut rng = Xoshiro256ss::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            // each bucket ≈ 10_000; allow 10% tolerance
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn exponential_mean_converges() {
        let mut rng = Xoshiro256ss::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(40.0)).sum::<f64>() / n as f64;
        assert!((mean - 40.0).abs() < 0.7, "mean={mean}");
    }

    #[test]
    fn normal_moments_converge() {
        let mut rng = Xoshiro256ss::new(13);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.normal(10.0, 2.0)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Xoshiro256ss::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn range_inclusive_covers_endpoints() {
        let mut rng = Xoshiro256ss::new(17);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            match rng.range_inclusive(3, 6) {
                3 => saw_lo = true,
                6 => saw_hi = true,
                4 | 5 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn rand_core_trait_impl_works() {
        let mut rng = Xoshiro256ss::new(23);
        let mut buf = [0u8; 17];
        rng.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
