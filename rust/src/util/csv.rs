//! Minimal CSV writer for experiment series (Fig 7/8/9/10/11 data dumps).
//!
//! We only *write* CSV (the figures are regenerated from these files), so
//! this is a small escaping-correct serializer, not a parser.

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// An in-memory CSV document with a fixed header.
#[derive(Debug, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    /// An empty document with the given header.
    pub fn new(header: &[&str]) -> Csv {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: &[String]) -> &mut Csv {
        assert_eq!(cells.len(), self.header.len(), "csv row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a numeric row, formatted with shortest round-trip.
    pub fn row_f64(&mut self, cells: &[f64]) -> &mut Csv {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells)
    }

    /// Rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// RFC-4180 escaping: quote fields containing comma/quote/newline.
    fn escape(field: &str) -> String {
        if field.contains([',', '"', '\n', '\r']) {
            format!("\"{}\"", field.replace('"', "\"\""))
        } else {
            field.to_string()
        }
    }

    /// Render the document as RFC-4180 CSV text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let write_row = |out: &mut String, cells: &[String]| {
            let escaped: Vec<String> = cells.iter().map(|c| Self::escape(c)).collect();
            let _ = writeln!(out, "{}", escaped.join(","));
        };
        write_row(&mut out, &self.header);
        for row in &self.rows {
            write_row(&mut out, row);
        }
        out
    }

    /// Write to disk, creating parent directories as needed.
    pub fn write_to(&self, path: impl AsRef<Path>) -> io::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut c = Csv::new(&["t_req_ms", "items"]);
        c.row_f64(&[40.0, 771781.0]);
        let s = c.render();
        assert_eq!(s, "t_req_ms,items\n40,771781\n");
    }

    #[test]
    fn escapes_specials() {
        let mut c = Csv::new(&["name", "note"]);
        c.row(&["a,b".into(), "say \"hi\"".into()]);
        let s = c.render();
        assert!(s.contains("\"a,b\",\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn rejects_bad_width() {
        let mut c = Csv::new(&["a"]);
        c.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn writes_to_disk() {
        let dir = std::env::temp_dir().join("idlewait_csv_test");
        let path = dir.join("sub/out.csv");
        let mut c = Csv::new(&["x"]);
        c.row_f64(&[1.5]);
        c.write_to(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "x\n1.5\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
