//! Streaming and batch statistics used by the bench harness, the PAC1934
//! monitor model and the experiment reports.

use crate::util::rng::Xoshiro256ss;

/// Welford's online algorithm: numerically-stable streaming mean/variance.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// An empty accumulator.
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (`NaN` before any observation).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator into this one (parallel Welford).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + delta * delta * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Batch summary with exact percentiles (sorts a copy).
#[derive(Debug, Clone)]
pub struct Summary {
    /// Number of samples summarized.
    pub count: usize,
    /// Mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std_dev: f64,
    /// Minimum.
    pub min: f64,
    /// Median.
    pub p50: f64,
    /// 90th percentile.
    pub p90: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// The defined zero-observation summary: `count == 0` and every
    /// statistic exactly `0.0`. Report rows built from an empty sample
    /// set render these zeros instead of `NaN` (which would break CSV
    /// byte-comparison across runs) or being skipped (which would make
    /// the CSV schema depend on the data).
    pub fn empty() -> Summary {
        Summary {
            count: 0,
            mean: 0.0,
            std_dev: 0.0,
            min: 0.0,
            p50: 0.0,
            p90: 0.0,
            p95: 0.0,
            p99: 0.0,
            max: 0.0,
        }
    }

    /// Summarize a sample set. Returns `None` on an empty slice.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let mut w = Welford::new();
        for &x in samples {
            w.push(x);
        }
        Some(Summary {
            count: samples.len(),
            mean: w.mean(),
            std_dev: w.std_dev(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: *sorted.last().unwrap(),
        })
    }
}

/// Bounded streaming quantile estimator: a fixed-capacity uniform sample
/// (Vitter's Algorithm R, deterministically seeded) plus an embedded
/// [`Welford`] accumulator, so `count`/`mean`/`std_dev`/`min`/`max` stay
/// **exact** at any stream length while percentiles come from the
/// reservoir. Memory is O(capacity) forever — this is the estimator
/// behind `Metrics::latency_summary` and the fleet aggregates, replacing
/// the old grow-without-bound latency vector. Percentiles are exact while
/// the stream is no longer than the capacity, and an unbiased uniform
/// subsample beyond it. Everything is a pure function of
/// `(capacity, seed, pushed values, merge order)`.
#[derive(Debug, Clone)]
pub struct ReservoirQuantiles {
    cap: usize,
    samples: Vec<f64>,
    rng: Xoshiro256ss,
    moments: Welford,
}

impl ReservoirQuantiles {
    /// An empty reservoir holding at most `cap` samples (`cap > 0`),
    /// with replacement decisions driven by `seed`.
    pub fn new(cap: usize, seed: u64) -> ReservoirQuantiles {
        assert!(cap > 0, "reservoir capacity must be positive");
        ReservoirQuantiles {
            cap,
            samples: Vec::new(),
            rng: Xoshiro256ss::new(seed),
            moments: Welford::new(),
        }
    }

    /// Add one observation (Algorithm R: kept with probability cap/seen).
    pub fn push(&mut self, x: f64) {
        self.moments.push(x);
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            let j = self.rng.below(self.moments.count());
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    /// Observations pushed so far (the full stream, not the reservoir).
    pub fn count(&self) -> u64 {
        self.moments.count()
    }

    /// Exact running mean (`NaN` before any observation).
    pub fn mean(&self) -> f64 {
        self.moments.mean()
    }

    /// True while every observation is still retained, i.e. percentiles
    /// are exact rather than sampled.
    pub fn is_exact(&self) -> bool {
        self.moments.count() <= self.cap as u64
    }

    /// Percentile summary. Moments (`count`, `mean`, `std_dev`, `min`,
    /// `max`) are exact over the whole stream; percentiles interpolate
    /// over the reservoir. `None` before any observation.
    pub fn summary(&self) -> Option<Summary> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in reservoir"));
        Some(Summary {
            count: self.moments.count() as usize,
            mean: self.moments.mean(),
            std_dev: self.moments.std_dev(),
            min: self.moments.min(),
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: self.moments.max(),
        })
    }

    /// Total-function variant of [`summary`](Self::summary): returns
    /// [`Summary::empty`] before any observation, so callers that
    /// render a fixed report shape never have to special-case the
    /// zero-observation reservoir.
    pub fn summary_or_empty(&self) -> Summary {
        self.summary().unwrap_or_else(Summary::empty)
    }

    /// Fold another reservoir into this one. Moments merge exactly
    /// (parallel Welford); samples are re-drawn by weighted sampling
    /// without replacement (Efraimidis–Spirakis keys, each retained
    /// sample weighted by the stream length it represents), with all
    /// randomness from `self`'s generator — so the result is a pure
    /// function of the two inputs and merges applied in a fixed order
    /// (the fleet's shard order) are reproducible bit-for-bit.
    pub fn merge(&mut self, other: &ReservoirQuantiles) {
        if other.moments.count() == 0 {
            return;
        }
        let self_w = if self.samples.is_empty() {
            0.0
        } else {
            self.moments.count() as f64 / self.samples.len() as f64
        };
        let other_w = other.moments.count() as f64 / other.samples.len() as f64;
        self.moments.merge(&other.moments);
        let mut pool: Vec<(f64, f64)> =
            Vec::with_capacity(self.samples.len() + other.samples.len());
        pool.extend(self.samples.iter().map(|&x| (x, self_w)));
        pool.extend(other.samples.iter().map(|&x| (x, other_w)));
        if pool.len() <= self.cap {
            self.samples = pool.into_iter().map(|(x, _)| x).collect();
            return;
        }
        let mut keyed: Vec<(f64, usize, f64)> = pool
            .iter()
            .enumerate()
            .map(|(i, &(x, w))| {
                let u = self.rng.next_f64().max(f64::MIN_POSITIVE);
                (u.powf(1.0 / w), i, x)
            })
            .collect();
        keyed.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .expect("NaN merge key")
                .then(a.1.cmp(&b.1))
        });
        keyed.truncate(self.cap);
        // restore stream order so later merges see a stable layout
        keyed.sort_by_key(|e| e.1);
        self.samples = keyed.into_iter().map(|(_, _, x)| x).collect();
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Relative error |measured - expected| / |expected| (expected ≠ 0).
pub fn rel_error(measured: f64, expected: f64) -> f64 {
    debug_assert!(expected != 0.0);
    (measured - expected).abs() / expected.abs()
}

/// Assert two floats agree within a relative tolerance; for tests/validation.
pub fn within_rel(measured: f64, expected: f64, tol: f64) -> bool {
    if expected == 0.0 {
        measured.abs() <= tol
    } else {
        rel_error(measured, expected) <= tol
    }
}

/// Simple ordinary-least-squares fit y = a + b·x; returns (a, b, r²).
/// Used by experiment reports to characterize linear trends (e.g. On-Off
/// lifetime vs request period).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 8);
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 4.0).abs() < 1e-12);
        assert!((w.std_dev() - 2.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_empty_is_nan() {
        let w = Welford::new();
        assert!(w.mean().is_nan());
        assert!(w.variance().is_nan());
    }

    #[test]
    fn welford_merge_equals_single_pass() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..317] {
            a.push(x);
        }
        for &x in &xs[317..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&sorted, 0.0), 10.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 40.0);
        assert!((percentile_sorted(&sorted, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn summary_of_known_data() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn empty_summary_is_defined_zeros() {
        let s = Summary::empty();
        assert_eq!(s.count, 0);
        for v in [s.mean, s.std_dev, s.min, s.p50, s.p90, s.p95, s.p99, s.max] {
            assert_eq!(v.to_bits(), 0.0f64.to_bits(), "empty stat must be +0.0, not NaN");
        }
    }

    #[test]
    fn reservoir_summary_or_empty_is_total() {
        let r = ReservoirQuantiles::new(16, 7);
        assert!(r.summary().is_none());
        let s = r.summary_or_empty();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99.to_bits(), 0.0f64.to_bits());
        let mut r = r;
        r.push(3.5);
        let s = r.summary_or_empty();
        assert_eq!(s.count, 1);
        assert_eq!(s.p50, 3.5);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.5 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.5).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_exact_under_capacity() {
        let mut r = ReservoirQuantiles::new(4096, 9);
        let xs: Vec<f64> = (0..100).map(|i| 0.5 + i as f64 * 0.01).collect();
        for &x in &xs {
            r.push(x);
        }
        assert!(r.is_exact());
        let got = r.summary().unwrap();
        let want = Summary::of(&xs).unwrap();
        assert_eq!(got.count, want.count);
        assert_eq!(got.p50.to_bits(), want.p50.to_bits());
        assert_eq!(got.p99.to_bits(), want.p99.to_bits());
        assert_eq!(got.min.to_bits(), want.min.to_bits());
        assert_eq!(got.max.to_bits(), want.max.to_bits());
    }

    #[test]
    fn reservoir_bounded_with_exact_moments() {
        let mut r = ReservoirQuantiles::new(512, 1);
        for i in 0..100_000u64 {
            r.push(i as f64);
        }
        assert!(!r.is_exact());
        assert_eq!(r.count(), 100_000);
        assert_eq!(r.samples.len(), 512);
        let s = r.summary().unwrap();
        assert_eq!(s.count, 100_000);
        assert!((s.mean - 49_999.5).abs() < 1e-6); // exact, via Welford
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 99_999.0);
        // sampled percentile of a uniform ramp: loose statistical bound
        assert!((s.p50 - 50_000.0).abs() < 10_000.0, "p50={}", s.p50);
    }

    #[test]
    fn reservoir_is_deterministic() {
        let mut a = ReservoirQuantiles::new(64, 42);
        let mut b = ReservoirQuantiles::new(64, 42);
        for i in 0..10_000u64 {
            let x = (i as f64).sin() * 5.0;
            a.push(x);
            b.push(x);
        }
        assert_eq!(a.samples, b.samples);
        let (sa, sb) = (a.summary().unwrap(), b.summary().unwrap());
        assert_eq!(sa.p50.to_bits(), sb.p50.to_bits());
        assert_eq!(sa.p95.to_bits(), sb.p95.to_bits());
    }

    #[test]
    fn reservoir_merge_keeps_exact_moments_and_bound() {
        let xs: Vec<f64> = (0..5_000).map(|i| (i as f64).cos() * 3.0 + 7.0).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = ReservoirQuantiles::new(256, 5);
        let mut b = ReservoirQuantiles::new(256, 6);
        for &x in &xs[..1_700] {
            a.push(x);
        }
        for &x in &xs[1_700..] {
            b.push(x);
        }
        let mut a2 = a.clone();
        a.merge(&b);
        a2.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!(a.samples.len() <= 256);
        // merge is deterministic: same inputs, same result
        assert_eq!(a.samples, a2.samples);
    }

    #[test]
    fn reservoir_merge_into_empty() {
        let mut a = ReservoirQuantiles::new(32, 1);
        let mut b = ReservoirQuantiles::new(32, 2);
        for i in 0..10u64 {
            b.push(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 10);
        assert_eq!(a.samples.len(), 10);
        a.merge(&ReservoirQuantiles::new(32, 3)); // empty other: no-op
        assert_eq!(a.count(), 10);
    }

    #[test]
    fn within_rel_tolerances() {
        assert!(within_rel(102.8, 100.0, 0.03)); // paper's 2.8% validation gap
        assert!(!within_rel(110.0, 100.0, 0.05));
        assert!(within_rel(0.0, 0.0, 1e-9));
    }
}
