//! Shared utilities: unit-safe numerics, deterministic PRNGs, statistics,
//! and report serialization (ASCII tables, CSV, JSON).
//!
//! These are the substrate pieces the offline environment could not supply
//! as crates (serde/csv/env_logger are absent from the vendor set); each is
//! a small, fully-tested implementation scoped to what this project needs.

pub mod csv;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;

pub use units::{battery_energy, Current, Duration, Energy, Power, Voltage};
