//! Tiny `log` facade backend: timestamped stderr logger with env filtering.
//!
//! `env_logger` is not in the offline vendor set; this logger covers what
//! the coordinator and experiments need: level filtering via
//! `IDLEWAIT_LOG` (error|warn|info|debug|trace, default info) and
//! monotonic-elapsed timestamps so serving-loop logs can be correlated with
//! simulated time.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};
use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    level: LevelFilter,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record<'_>) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let elapsed = START.elapsed();
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>9.3}s {} {}] {}",
            elapsed.as_secs_f64(),
            tag,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Parse a level name, defaulting to Info.
fn parse_level(s: &str) -> LevelFilter {
    match s.to_ascii_lowercase().as_str() {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => LevelFilter::Info,
    }
}

/// Install the logger (idempotent). Reads `IDLEWAIT_LOG` for the level.
pub fn init() {
    init_with_level(
        std::env::var("IDLEWAIT_LOG")
            .map(|v| parse_level(&v))
            .unwrap_or(LevelFilter::Info),
    );
}

/// Install with an explicit level (idempotent; first call wins).
pub fn init_with_level(level: LevelFilter) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    Lazy::force(&START);
    let logger = Box::leak(Box::new(StderrLogger { level }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(parse_level("error"), LevelFilter::Error);
        assert_eq!(parse_level("WARN"), LevelFilter::Warn);
        assert_eq!(parse_level("debug"), LevelFilter::Debug);
        assert_eq!(parse_level("trace"), LevelFilter::Trace);
        assert_eq!(parse_level("off"), LevelFilter::Off);
        assert_eq!(parse_level("bogus"), LevelFilter::Info);
    }

    #[test]
    fn init_is_idempotent() {
        init_with_level(LevelFilter::Warn);
        init_with_level(LevelFilter::Trace); // ignored
        log::info!("this should not panic");
    }
}
