//! Config loading: file → [`Json`] value → typed specs, with format
//! auto-detection (`.yaml`/`.yml` vs `.json`) and defaults that reproduce
//! the paper's experimental setup when no file is given.

use std::path::Path;

use crate::config::schema::{
    ConfigError, FaultSpec, FleetSpec, PlatformSpec, ServeSpec, WorkloadItemSpec, WorkloadSpec,
};
use crate::config::{validate, yaml};
use crate::util::json::Json;

/// A fully-loaded simulation configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    /// The workload description (budget, arrivals, policy).
    pub workload: WorkloadSpec,
    /// The workload-item description (Table 2).
    pub item: WorkloadItemSpec,
    /// The platform description (FPGA, SPI, battery).
    pub platform: PlatformSpec,
    /// The fleet description (`repro fleet`; defaults when absent).
    pub fleet: FleetSpec,
    /// The serving description (`repro serve`; defaults when absent).
    pub serve: ServeSpec,
    /// The fault-injection description (all rates zero when absent, which
    /// keeps every simulation path bit-identical to the fault-free build).
    pub faults: FaultSpec,
}

/// Why a config failed to load.
#[derive(Debug, thiserror::Error)]
pub enum LoadError {
    /// The file could not be read.
    #[error("io error reading {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
    /// YAML syntax error.
    #[error(transparent)]
    Yaml(#[from] yaml::YamlError),
    /// JSON syntax error.
    #[error("json: {0}")]
    Json(#[from] crate::util::json::JsonError),
    /// The document decoded but a field is missing/mistyped.
    #[error(transparent)]
    Config(#[from] ConfigError),
    /// The config decoded but fails semantic validation.
    #[error("validation: {0}")]
    Invalid(String),
}

/// Parse a config document (YAML or JSON detected by leading `{`).
pub fn parse_str(text: &str) -> Result<Json, LoadError> {
    let trimmed = text.trim_start();
    if trimmed.starts_with('{') || trimmed.starts_with('[') {
        Ok(Json::parse(text)?)
    } else {
        Ok(yaml::parse(text)?)
    }
}

/// Load and validate a [`SimConfig`] from a file.
pub fn load_file(path: impl AsRef<Path>) -> Result<SimConfig, LoadError> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path).map_err(|source| LoadError::Io {
        path: path.display().to_string(),
        source,
    })?;
    load_str(&text)
}

/// Load and validate a [`SimConfig`] from a string.
pub fn load_str(text: &str) -> Result<SimConfig, LoadError> {
    let root = parse_str(text)?;
    let config = SimConfig {
        workload: WorkloadSpec::from_json(&root)?,
        item: WorkloadItemSpec::from_json(&root)?,
        platform: PlatformSpec::from_json(&root)?,
        fleet: FleetSpec::from_json(&root)?,
        serve: ServeSpec::from_json(&root)?,
        faults: FaultSpec::from_json(&root)?,
    };
    validate::validate(&config).map_err(LoadError::Invalid)?;
    Ok(config)
}

/// The paper's experimental setup as an embedded config document
/// (Table 2 + 4147 J budget + 40 ms period). This is the default config
/// used by the CLI and examples when no file is supplied; the
/// power-on-transient constant is derived in DESIGN.md §6.
pub const PAPER_DEFAULT_YAML: &str = "\
# Default configuration — the paper's experimental setup (Table 2, §5).
workload:
  energy_budget_j: 4147
  request_period_ms: 40.0
  strategy: idle-waiting
workload_item:
  phases:
    - name: configuration
      power_mw: 327.9
      time_ms: 36.145
    - name: data_loading
      power_mw: 138.7
      time_ms: 0.0100
    - name: inference
      power_mw: 171.4          # includes 114 mW clock reference + flash
      time_ms: 0.0281
    - name: data_offloading
      power_mw: 144.1
      time_ms: 0.0020
  idle_power_mw: 134.3
  power_on_transient_mj: 0.1244
platform:
  fpga:
    model: XC7S15
  spi:
    buswidth: 4
    freq_mhz: 66
    compressed: true
  battery_budget_j: 4147
  flash_standby_mw: 15.2
";

/// Load the paper-default configuration.
pub fn paper_default() -> SimConfig {
    load_str(PAPER_DEFAULT_YAML).expect("embedded default config must be valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::PolicySpec;

    #[test]
    fn paper_default_loads_and_matches_table2() {
        let cfg = paper_default();
        assert_eq!(cfg.workload.policy, PolicySpec::IdleWaiting);
        assert!((cfg.workload.energy_budget.joules() - 4147.0).abs() < 1e-9);
        assert!((cfg.item.configuration.power.milliwatts() - 327.9).abs() < 1e-9);
        assert!((cfg.item.configuration.time.millis() - 36.145).abs() < 1e-9);
        assert!((cfg.item.idle_power.milliwatts() - 134.3).abs() < 1e-9);
        assert!((cfg.platform.flash_standby.milliwatts() - 15.2).abs() < 1e-9);
    }

    #[test]
    fn json_config_also_loads() {
        let doc = r#"{
            "workload": {"energy_budget_j": 100, "request_period_ms": 50, "strategy": "on-off"},
            "workload_item": {
                "phases": [
                    {"name": "configuration", "power_mw": 327.9, "time_ms": 36.145},
                    {"name": "data_loading", "power_mw": 138.7, "time_ms": 0.01},
                    {"name": "inference", "power_mw": 171.4, "time_ms": 0.0281},
                    {"name": "data_offloading", "power_mw": 144.1, "time_ms": 0.002}
                ],
                "idle_power_mw": 134.3
            }
        }"#;
        let cfg = load_str(doc).unwrap();
        assert_eq!(cfg.workload.policy, PolicySpec::OnOff);
        assert_eq!(cfg.item.power_on_transient.millijoules(), 0.0);
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("idlewait_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.yaml");
        std::fs::write(&path, PAPER_DEFAULT_YAML).unwrap();
        let cfg = load_file(&path).unwrap();
        assert_eq!(cfg, paper_default());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_is_io_error() {
        let e = load_file("/nonexistent/nope.yaml").unwrap_err();
        assert!(matches!(e, LoadError::Io { .. }));
    }

    #[test]
    fn invalid_config_rejected_by_validation() {
        // On-Off with T_req shorter than configuration time is infeasible
        let doc = PAPER_DEFAULT_YAML
            .replace("request_period_ms: 40.0", "request_period_ms: 10.0")
            .replace("strategy: idle-waiting", "strategy: on-off");
        let e = load_str(&doc).unwrap_err();
        assert!(matches!(e, LoadError::Invalid(_)), "{e:?}");
    }
}
