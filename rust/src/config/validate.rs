//! Semantic validation of loaded configurations.
//!
//! Syntactic decoding lives in `schema`; this module enforces the physical
//! and paper-specific feasibility constraints, e.g. the paper's observation
//! that On-Off cannot serve request periods shorter than the configuration
//! time (Fig 8 omits On-Off below 36.15 ms).

use crate::config::loader::SimConfig;
use crate::config::schema::{PolicySpec, SpiConfig};
use crate::device::bitstream::Bitstream;
use crate::device::config_fsm::ConfigProfile;
use crate::device::flash::StoredImage;

/// Validate a full configuration; returns a human-readable reason on error.
pub fn validate(cfg: &SimConfig) -> Result<(), String> {
    validate_spi(&cfg.platform.spi)?;
    validate_item(cfg)?;
    validate_workload(cfg)?;
    cfg.fleet.validate()?;
    cfg.serve.validate()?;
    cfg.faults.validate()?;
    validate_profile(cfg)?;
    Ok(())
}

/// The configuration FSM must produce every stage the experiment layer
/// reads (setup / bitstream_loading / startup). Today `compute()` emits
/// exactly these three, so this is a regression tripwire, not a
/// user-input check: if a future FSM refactor renames or drops a stage,
/// config loading fails with `ConfigProfile::stage`'s `UnknownStage`
/// error here — at validation time — instead of panicking deep inside a
/// sweep. Runs once per config load (not on any hot path).
fn validate_profile(cfg: &SimConfig) -> Result<(), String> {
    let image = StoredImage::new(
        Bitstream::lstm_accelerator(cfg.platform.fpga),
        cfg.platform.spi.compressed,
    );
    let profile = ConfigProfile::compute(cfg.platform.fpga, cfg.platform.spi, &image);
    for name in ConfigProfile::STAGE_NAMES {
        profile.stage(name).map_err(|e| e.to_string())?;
    }
    Ok(())
}

fn validate_spi(spi: &SpiConfig) -> Result<(), String> {
    if !SpiConfig::BUSWIDTHS.contains(&spi.buswidth) {
        return Err(format!(
            "spi.buswidth must be 1, 2 or 4 (got {})",
            spi.buswidth
        ));
    }
    if !(3.0..=66.0).contains(&spi.freq_mhz) {
        return Err(format!(
            "spi.freq_mhz must be within the config port's 3..=66 MHz (got {})",
            spi.freq_mhz
        ));
    }
    Ok(())
}

fn validate_item(cfg: &SimConfig) -> Result<(), String> {
    let item = &cfg.item;
    for (name, phase) in [
        ("configuration", &item.configuration),
        ("data_loading", &item.data_loading),
        ("inference", &item.inference),
        ("data_offloading", &item.data_offloading),
    ] {
        if !(phase.power.watts().is_finite() && phase.power.watts() > 0.0) {
            return Err(format!("phase '{name}': power must be positive and finite"));
        }
        if !(phase.time.secs().is_finite() && phase.time.secs() > 0.0) {
            return Err(format!("phase '{name}': time must be positive and finite"));
        }
    }
    if item.idle_power.watts() <= 0.0 || !item.idle_power.watts().is_finite() {
        return Err("idle_power_mw must be positive and finite".into());
    }
    if item.power_on_transient.joules() < 0.0 {
        return Err("power_on_transient_mj must be non-negative".into());
    }
    // Idle power below the flash standby floor is physically impossible on
    // this board (§5.4: the flash draws ~15.2 mW whenever rails are up).
    if item.idle_power < cfg.platform.flash_standby {
        return Err(format!(
            "idle power {:.4} is below the flash standby floor {:.4}",
            item.idle_power, cfg.platform.flash_standby
        ));
    }
    Ok(())
}

fn validate_workload(cfg: &SimConfig) -> Result<(), String> {
    let w = &cfg.workload;
    if w.energy_budget.joules() <= 0.0 || !w.energy_budget.joules().is_finite() {
        return Err("energy_budget_j must be positive and finite".into());
    }
    // Per-policy tunables: reject out-of-range values (quantile ∉ (0,1),
    // window = 0, negative timeout, …) at load time with an actionable
    // message instead of propagating NaN or a panic into the sweep.
    w.params.validate()?;
    let period = w.arrival.mean_period();
    if period.secs() <= 0.0 || !period.secs().is_finite() {
        return Err("request_period_ms must be positive and finite".into());
    }
    // Feasibility (paper §5.3): under On-Off the FPGA must finish
    // configuration + the workload item within one period, otherwise it
    // "can not be prepared to process an incoming workload".
    if w.policy == PolicySpec::OnOff && period < cfg.item.latency_with_config() {
        return Err(format!(
            "on-off infeasible: request period {:.3} < workload-item latency {:.3} \
             (the paper omits On-Off below 36.15 ms for this reason)",
            period, cfg.item.latency_with_config()
        ));
    }
    // Idle-Waiting needs the non-config latency to fit in the period.
    // (The online policies are allowed anywhere: on too-short periods
    // they degrade to late serving, which the simulator reports.)
    if matches!(
        w.policy,
        PolicySpec::IdleWaiting | PolicySpec::IdleWaitingM1 | PolicySpec::IdleWaitingM12
    ) && period < cfg.item.latency_without_config()
    {
        return Err(format!(
            "idle-waiting infeasible: request period {:.5} < item latency {:.5}",
            period, cfg.item.latency_without_config()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::loader::{load_str, paper_default, PAPER_DEFAULT_YAML};

    fn mutate(from: &str, to: &str) -> Result<SimConfig, String> {
        let doc = PAPER_DEFAULT_YAML.replace(from, to);
        match load_str(&doc) {
            Ok(cfg) => Ok(cfg),
            Err(crate::config::loader::LoadError::Invalid(msg)) => Err(msg),
            Err(other) => panic!("unexpected load error: {other}"),
        }
    }

    #[test]
    fn paper_default_is_valid() {
        assert!(validate(&paper_default()).is_ok());
    }

    #[test]
    fn onoff_below_config_time_rejected() {
        let e = mutate("strategy: idle-waiting", "strategy: on-off")
            .map(|_| ())
            .and(mutate_onoff_short())
            .unwrap_err();
        assert!(e.contains("on-off infeasible"));
    }

    fn mutate_onoff_short() -> Result<(), String> {
        let doc = PAPER_DEFAULT_YAML
            .replace("request_period_ms: 40.0", "request_period_ms: 20.0")
            .replace("strategy: idle-waiting", "strategy: on-off");
        match load_str(&doc) {
            Ok(_) => Ok(()),
            Err(crate::config::loader::LoadError::Invalid(msg)) => Err(msg),
            Err(other) => panic!("unexpected: {other}"),
        }
    }

    #[test]
    fn onoff_at_40ms_is_feasible() {
        let cfg = mutate("strategy: idle-waiting", "strategy: on-off").unwrap();
        assert!(validate(&cfg).is_ok());
    }

    #[test]
    fn bad_buswidth_rejected() {
        let e = mutate("buswidth: 4", "buswidth: 3").unwrap_err();
        assert!(e.contains("buswidth"));
    }

    #[test]
    fn bad_freq_rejected() {
        let e = mutate("freq_mhz: 66", "freq_mhz: 100").unwrap_err();
        assert!(e.contains("freq_mhz"));
    }

    #[test]
    fn negative_budget_rejected() {
        let e = mutate("energy_budget_j: 4147", "energy_budget_j: -1").unwrap_err();
        assert!(e.contains("energy_budget"));
    }

    #[test]
    fn idle_below_flash_floor_rejected() {
        let e = mutate("idle_power_mw: 134.3", "idle_power_mw: 10.0").unwrap_err();
        assert!(e.contains("flash standby floor"));
    }

    #[test]
    fn zero_phase_time_rejected() {
        let e = mutate("time_ms: 0.0281", "time_ms: 0").unwrap_err();
        assert!(e.contains("inference"));
    }

    /// Out-of-range `serving` knobs must fail at load time, same as the
    /// policy tunables below.
    #[test]
    fn out_of_range_serving_block_rejected() {
        let with_serving = |serving_yaml: &str| -> Result<SimConfig, String> {
            let doc = format!("{PAPER_DEFAULT_YAML}serving:\n{serving_yaml}");
            match load_str(&doc) {
                Ok(cfg) => Ok(cfg),
                Err(crate::config::loader::LoadError::Invalid(msg)) => Err(msg),
                Err(other) => panic!("unexpected load error: {other}"),
            }
        };
        let e = with_serving("  sources: 0\n").unwrap_err();
        assert!(e.contains("serving.sources"), "{e}");
        let e = with_serving("  window: 0\n").unwrap_err();
        assert!(e.contains("serving.window"), "{e}");
        let e = with_serving("  max_queue: 0\n").unwrap_err();
        assert!(e.contains("serving.max_queue"), "{e}");
        let e = with_serving("  deadline_slack_ms: -10\n").unwrap_err();
        assert!(e.contains("serving.deadline_slack_ms"), "{e}");
        // in-range block loads fine
        let cfg = with_serving("  sources: 4\n  max_queue: 16\n").unwrap();
        assert_eq!(cfg.serve.sources, 4);
        assert_eq!(cfg.serve.max_queue, 16);
    }

    /// Out-of-range `faults` knobs must fail at load time with the same
    /// actionable-message contract as the other blocks.
    #[test]
    fn out_of_range_faults_block_rejected() {
        let with_faults = |faults_yaml: &str| -> Result<SimConfig, String> {
            let doc = format!("{PAPER_DEFAULT_YAML}faults:\n{faults_yaml}");
            match load_str(&doc) {
                Ok(cfg) => Ok(cfg),
                Err(crate::config::loader::LoadError::Invalid(msg)) => Err(msg),
                Err(other) => panic!("unexpected load error: {other}"),
            }
        };
        let e = with_faults("  config_crc_rate: 2\n").unwrap_err();
        assert!(e.contains("faults.config_crc_rate"), "{e}");
        let e = with_faults("  brownout_infer_rate: -0.5\n").unwrap_err();
        assert!(e.contains("faults.brownout_infer_rate"), "{e}");
        let e = with_faults("  config_crc_rate: 0.6\n  spi_corrupt_rate: 0.6\n").unwrap_err();
        assert!(e.contains("sum to at most 1"), "{e}");
        let e = with_faults("  retry_max: 0\n").unwrap_err();
        assert!(e.contains("faults.retry_max"), "{e}");
        let e = with_faults("  backoff_ms: 100\n  backoff_cap_ms: 10\n").unwrap_err();
        assert!(e.contains("faults.backoff_cap_ms"), "{e}");
        // in-range block loads fine and reports enabled
        let cfg = with_faults("  config_crc_rate: 0.05\n  retry_max: 4\n").unwrap();
        assert!(cfg.faults.enabled());
        assert_eq!(cfg.faults.retry_max, 4);
    }

    /// Out-of-range per-policy tunables must be rejected at load time
    /// with an actionable message, not propagated as NaN/panic into a
    /// sweep.
    #[test]
    fn out_of_range_policy_params_rejected() {
        let with_params = |params_yaml: &str| -> Result<SimConfig, String> {
            let doc = PAPER_DEFAULT_YAML.replace(
                "  strategy: idle-waiting",
                &format!("  strategy: windowed-quantile\n  policy_params:\n{params_yaml}"),
            );
            match load_str(&doc) {
                Ok(cfg) => Ok(cfg),
                Err(crate::config::loader::LoadError::Invalid(msg)) => Err(msg),
                Err(other) => panic!("unexpected load error: {other}"),
            }
        };
        let e = with_params("    quantile: 1.5\n").unwrap_err();
        assert!(e.contains("quantile") && e.contains("(0, 1)"), "{e}");
        let e = with_params("    quantile: 0\n").unwrap_err();
        assert!(e.contains("quantile"), "{e}");
        let e = with_params("    window: 0\n").unwrap_err();
        assert!(e.contains("window") && e.contains("at least 1"), "{e}");
        let e = with_params("    timeout_ms: -3\n").unwrap_err();
        assert!(e.contains("timeout_ms") && e.contains("positive"), "{e}");
        let e = with_params("    ema_alpha: 2\n").unwrap_err();
        assert!(e.contains("ema_alpha"), "{e}");
        // in-range tunables load fine
        assert!(with_params("    quantile: 0.75\n    window: 8\n").is_ok());
    }
}
