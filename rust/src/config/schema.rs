//! Typed configuration schema mirroring the paper's simulator inputs
//! (§5.1): a *workload* description and a *workload item* description,
//! plus our platform description that parameterizes the device substrate.
//!
//! All types decode from the [`Json`] value produced by either the YAML or
//! JSON parser, so configs can be written in both formats.

use std::fmt;

use crate::device::rails::PowerSaving;
use crate::util::json::Json;
use crate::util::units::{Duration, Energy, Power};

/// A config decoding error, locating the offending key.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("config error at {path}: {msg}")]
pub struct ConfigError {
    /// Dotted path of the offending key (e.g. `workload.policy_params.quantile`).
    pub path: String,
    /// What is wrong and what was expected.
    pub msg: String,
}

fn cerr(path: &str, msg: impl Into<String>) -> ConfigError {
    ConfigError {
        path: path.to_string(),
        msg: msg.into(),
    }
}

fn req<'a>(v: &'a Json, path: &str, key: &str) -> Result<&'a Json, ConfigError> {
    v.get(key)
        .ok_or_else(|| cerr(&format!("{path}.{key}"), "missing required field"))
}

fn req_f64(v: &Json, path: &str, key: &str) -> Result<f64, ConfigError> {
    req(v, path, key)?
        .as_f64()
        .ok_or_else(|| cerr(&format!("{path}.{key}"), "expected a number"))
}

fn req_str<'a>(v: &'a Json, path: &str, key: &str) -> Result<&'a str, ConfigError> {
    req(v, path, key)?
        .as_str()
        .ok_or_else(|| cerr(&format!("{path}.{key}"), "expected a string"))
}

fn opt_f64(v: &Json, path: &str, key: &str) -> Result<Option<f64>, ConfigError> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => x
            .as_f64()
            .map(Some)
            .ok_or_else(|| cerr(&format!("{path}.{key}"), "expected a number")),
    }
}

fn opt_bool(v: &Json, path: &str, key: &str, default: bool) -> Result<bool, ConfigError> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(x) => x
            .as_bool()
            .ok_or_else(|| cerr(&format!("{path}.{key}"), "expected a boolean")),
    }
}

fn opt_u64(v: &Json, path: &str, key: &str) -> Result<Option<u64>, ConfigError> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => x.as_u64().map(Some).ok_or_else(|| {
            cerr(&format!("{path}.{key}"), "expected a non-negative integer")
        }),
    }
}

// ---------------------------------------------------------------------------
// Gap-policy selection
// ---------------------------------------------------------------------------

/// Config-level selector for the gap policy: the paper's strategies
/// (§4.2) plus the idle-power-saving methods of §5.4 and the online
/// policies addressing its §7 future work (irregular requests).
///
/// Static policies (`OnOff`, `IdleWaiting*`) need no gap knowledge;
/// `Oracle` is the clairvoyant offline upper bound (sees the true
/// upcoming gap); `Timeout` and `EmaPredictor` are deployable online
/// policies that decide from observed history only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicySpec {
    /// Power off between requests; reconfigure every request (Fig 5).
    OnOff,
    /// Configure once, idle between requests (Fig 6), at baseline idle power.
    IdleWaiting,
    /// Idle-Waiting + Method 1 (gate IOs + clock reference).
    IdleWaitingM1,
    /// Idle-Waiting + Methods 1+2 (also undervolt VCCINT/VCCAUX).
    IdleWaitingM12,
    /// Clairvoyant per-gap choice at the analytical crossover (offline
    /// upper bound; formerly named `Adaptive`).
    Oracle,
    /// Ski-rental: idle up to the break-even timeout, then power off
    /// (classically 2-competitive vs the oracle).
    Timeout,
    /// EMA of observed gaps; idle iff the predicted gap is below the
    /// crossover, power off otherwise.
    EmaPredictor,
    /// Quantile of a sliding window of observed gaps vs the crossover —
    /// robust on heavy-tailed gap distributions where the EMA washes out.
    WindowedQuantile,
    /// Ski-rental with the timeout drawn per gap from the
    /// e/(e−1)-competitive density over [0, τ].
    RandomizedSkiRental,
    /// Online Bayesian mixture-of-exponentials gap model (2–4
    /// components); plans Idle/Off/IdleThenOff by posterior expected
    /// cost against the analytical crossover constants.
    BayesMixture,
    /// Contextual bandit / tabular-Q over discretized [`GapContext`]
    /// features (recent-gap EMA and variance buckets, diurnal phase,
    /// queue depth), optionally seeded from an offline-trained
    /// [`PolicyTable`] (`repro train --emit`).
    ///
    /// [`GapContext`]: crate::strategies::strategy::GapContext
    BanditPolicy,
}

impl PolicySpec {
    /// Parse a config/CLI policy name (case-insensitive, `_`/`-`
    /// agnostic, legacy aliases like `adaptive` included).
    pub fn parse(s: &str) -> Option<PolicySpec> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "on-off" | "onoff" => Some(PolicySpec::OnOff),
            "idle-waiting" | "idlewaiting" | "idle-waiting-baseline" => {
                Some(PolicySpec::IdleWaiting)
            }
            "idle-waiting-m1" | "method1" => Some(PolicySpec::IdleWaitingM1),
            "idle-waiting-m12" | "method1+2" | "method12" => Some(PolicySpec::IdleWaitingM12),
            // "adaptive" is the legacy name for the clairvoyant policy
            "oracle" | "adaptive" => Some(PolicySpec::Oracle),
            "timeout" | "ski-rental" | "idle-then-off" => Some(PolicySpec::Timeout),
            "ema" | "ema-predictor" => Some(PolicySpec::EmaPredictor),
            "windowed-quantile" | "quantile" => Some(PolicySpec::WindowedQuantile),
            "randomized-ski-rental" | "randomized-timeout" | "rand-ski-rental" => {
                Some(PolicySpec::RandomizedSkiRental)
            }
            "bayes-mixture" | "bayes" | "mixture" => Some(PolicySpec::BayesMixture),
            "bandit" | "contextual-bandit" | "tabular-q" => Some(PolicySpec::BanditPolicy),
            _ => None,
        }
    }

    /// Canonical name (the one `parse` round-trips and reports use).
    pub fn name(&self) -> &'static str {
        match self {
            PolicySpec::OnOff => "on-off",
            PolicySpec::IdleWaiting => "idle-waiting",
            PolicySpec::IdleWaitingM1 => "idle-waiting-m1",
            PolicySpec::IdleWaitingM12 => "idle-waiting-m12",
            PolicySpec::Oracle => "oracle",
            PolicySpec::Timeout => "timeout",
            PolicySpec::EmaPredictor => "ema-predictor",
            PolicySpec::WindowedQuantile => "windowed-quantile",
            PolicySpec::RandomizedSkiRental => "randomized-ski-rental",
            PolicySpec::BayesMixture => "bayes-mixture",
            PolicySpec::BanditPolicy => "bandit",
        }
    }

    /// Every policy, in the order tables and sweeps enumerate them.
    pub const ALL: [PolicySpec; 11] = [
        PolicySpec::OnOff,
        PolicySpec::IdleWaiting,
        PolicySpec::IdleWaitingM1,
        PolicySpec::IdleWaitingM12,
        PolicySpec::Oracle,
        PolicySpec::Timeout,
        PolicySpec::EmaPredictor,
        PolicySpec::WindowedQuantile,
        PolicySpec::RandomizedSkiRental,
        PolicySpec::BayesMixture,
        PolicySpec::BanditPolicy,
    ];
}

impl fmt::Display for PolicySpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------------
// Per-policy tunables
// ---------------------------------------------------------------------------

/// An offline-trained action table for the contextual bandit policy:
/// one action letter per discretized context cell, `i` = idle, `o` =
/// power off, `t` = idle-then-off at the break-even timeout.
///
/// The canonical text form is a 64-character string of those letters
/// (cell 0 first), which is what `repro train --emit` writes and the
/// `policy_params.table` config key parses. Letters were chosen over
/// digits so the mini-YAML scalar always decodes as a string.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PolicyTable(pub [u8; 64]);

impl PolicyTable {
    /// Number of context cells (4 EMA buckets × 2 variance buckets ×
    /// 4 diurnal-phase buckets × 2 queue-depth buckets).
    pub const CELLS: usize = 64;

    /// Parse the 64-letter text form; `None` on wrong length or any
    /// character outside `{i, o, t}`.
    pub fn parse(s: &str) -> Option<PolicyTable> {
        let bytes = s.as_bytes();
        if bytes.len() != Self::CELLS {
            return None;
        }
        let mut cells = [b't'; 64];
        for (cell, &b) in cells.iter_mut().zip(bytes) {
            if !matches!(b, b'i' | b'o' | b't') {
                return None;
            }
            *cell = b;
        }
        Some(PolicyTable(cells))
    }

    /// The canonical 64-letter text form (`parse` round-trips it).
    pub fn render(&self) -> String {
        self.0.iter().map(|&b| b as char).collect()
    }

    /// A table that hedges every cell with idle-then-off at τ — the
    /// same cold-start behaviour the untrained policy uses.
    pub fn hedge() -> PolicyTable {
        PolicyTable([b't'; 64])
    }
}

impl fmt::Display for PolicyTable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

/// The per-policy tunable table (config key `policy_params`). Every field
/// has a paper-faithful default, so the block is entirely optional; each
/// policy reads only the tunables it understands:
///
/// | tunable | used by | meaning |
/// |---|---|---|
/// | `saving` | all advanced policies | idle power-saving level (`baseline`/`m1`/`m12`) |
/// | `timeout_ms` | `timeout`, `randomized-ski-rental`, cold-start hedges | idle window before cutting power (default: the analytical break-even τ) |
/// | `ema_alpha` | `ema-predictor`, `bandit` | EMA smoothing factor in (0, 1] |
/// | `window` | `windowed-quantile` | ring-buffer length W ≥ 1 of observed gaps |
/// | `quantile` | `windowed-quantile` | planning quantile in (0, 1) |
/// | `seed` | `randomized-ski-rental`, `bayes-mixture` | RNG stream for randomized draws / init jitter |
/// | `components` | `bayes-mixture` | mixture components K in 2..=4 |
/// | `table` | `bandit` | 64-letter offline-trained action table (see [`PolicyTable`]) |
///
/// Range checks live in [`PolicyParams::validate`], called from
/// `config::validate` on load and from the CLI when flags override the
/// file, so out-of-range tunables fail with an actionable message before
/// any sweep starts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolicyParams {
    /// Idle power-saving level the advanced policies idle at.
    pub saving: PowerSaving,
    /// Explicit ski-rental timeout; `None` = the analytical break-even τ.
    pub timeout: Option<Duration>,
    /// EMA smoothing factor in (0, 1].
    pub ema_alpha: f64,
    /// Sliding-window length for the windowed-quantile predictor.
    pub window: usize,
    /// Planning quantile in (0, 1) for the windowed-quantile predictor.
    pub quantile: f64,
    /// Seed for randomized policies (the per-gap timeout draw).
    pub seed: u64,
    /// Mixture components for the Bayesian gap model (2..=4).
    pub components: usize,
    /// Offline-trained action table for the contextual bandit;
    /// `None` = cold start (hedge until cells warm up online).
    pub table: Option<PolicyTable>,
}

impl PolicyParams {
    /// Default EMA smoothing factor: 0.2 weights ≈5 recent gaps, the
    /// setup the paper-era experiments were run with.
    pub const DEFAULT_EMA_ALPHA: f64 = 0.2;
    /// Default window length: 64 gaps ≈ a dozen bursts of the bundled
    /// bursty-IoT corpus shape.
    pub const DEFAULT_WINDOW: usize = 64;
    /// Default planning quantile: 0.9 plans conservatively against the
    /// long tail of recent gaps.
    pub const DEFAULT_QUANTILE: f64 = 0.9;
    /// Default mixture size: 3 components separate burst, nominal and
    /// silence gap modes on the bundled corpus.
    pub const DEFAULT_COMPONENTS: usize = 3;

    /// Decode a `policy_params` mapping (all keys optional; absent keys
    /// keep their paper-faithful defaults). `path` locates errors.
    /// Public because tuned-params fragments (`repro tune --emit`,
    /// loaded back by `repro multi --slot-*-params`) reuse the exact
    /// config decoding.
    pub fn from_json(v: &Json, path: &str) -> Result<PolicyParams, ConfigError> {
        let mut p = PolicyParams::default();
        if let Some(name) = v.get("saving") {
            let name = name
                .as_str()
                .ok_or_else(|| cerr(&format!("{path}.saving"), "expected a string"))?;
            p.saving = parse_saving(name).ok_or_else(|| {
                cerr(
                    &format!("{path}.saving"),
                    format!("unknown saving level '{name}' (expected baseline, m1 or m12)"),
                )
            })?;
        }
        if let Some(ms) = opt_f64(v, path, "timeout_ms")? {
            p.timeout = Some(Duration::from_millis(ms));
        }
        if let Some(a) = opt_f64(v, path, "ema_alpha")? {
            p.ema_alpha = a;
        }
        if let Some(w) = opt_u64(v, path, "window")? {
            p.window = w as usize;
        }
        if let Some(q) = opt_f64(v, path, "quantile")? {
            p.quantile = q;
        }
        if let Some(s) = opt_u64(v, path, "seed")? {
            p.seed = s;
        }
        if let Some(k) = opt_u64(v, path, "components")? {
            p.components = k as usize;
        }
        if let Some(t) = v.get("table") {
            if !matches!(t, Json::Null) {
                let text = t
                    .as_str()
                    .ok_or_else(|| cerr(&format!("{path}.table"), "expected a string"))?;
                p.table = Some(PolicyTable::parse(text).ok_or_else(|| {
                    cerr(
                        &format!("{path}.table"),
                        format!(
                            "expected {} letters from {{i, o, t}} (got {} chars)",
                            PolicyTable::CELLS,
                            text.chars().count()
                        ),
                    )
                })?);
            }
        }
        Ok(p)
    }

    /// Range-check every tunable; returns an actionable message on error.
    /// NaN, infinities and empty windows are rejected here so they cannot
    /// propagate into a sweep as silent NaN energy totals or panics.
    pub fn validate(&self) -> Result<(), String> {
        if let Some(t) = self.timeout {
            if !(t.secs().is_finite() && t.secs() > 0.0) {
                return Err(format!(
                    "policy_params.timeout_ms must be a positive, finite number of \
                     milliseconds (got {}); omit it to use the analytical break-even τ",
                    t.millis()
                ));
            }
        }
        if !(self.ema_alpha.is_finite() && self.ema_alpha > 0.0 && self.ema_alpha <= 1.0) {
            return Err(format!(
                "policy_params.ema_alpha must be in (0, 1] (got {}); \
                 1.0 tracks the newest gap only, small values smooth harder",
                self.ema_alpha
            ));
        }
        if self.window == 0 {
            return Err(
                "policy_params.window must be at least 1 gap (got 0); the windowed-quantile \
                 predictor needs history to plan from"
                    .into(),
            );
        }
        if !(self.quantile.is_finite() && self.quantile > 0.0 && self.quantile < 1.0) {
            return Err(format!(
                "policy_params.quantile must be strictly inside (0, 1) (got {}); \
                 e.g. 0.9 plans against the 90th-percentile gap",
                self.quantile
            ));
        }
        if !(2..=4).contains(&self.components) {
            return Err(format!(
                "policy_params.components must be in 2..=4 mixture components (got {}); \
                 2 separates burst/silence, 4 adds nominal and tail modes",
                self.components
            ));
        }
        Ok(())
    }
}

impl Default for PolicyParams {
    fn default() -> Self {
        PolicyParams {
            // M1+2 is the paper's best idle mode and what the advanced
            // policies have always been built with.
            saving: PowerSaving::M12,
            timeout: None,
            ema_alpha: Self::DEFAULT_EMA_ALPHA,
            window: Self::DEFAULT_WINDOW,
            quantile: Self::DEFAULT_QUANTILE,
            seed: 0,
            components: Self::DEFAULT_COMPONENTS,
            table: None,
        }
    }
}

/// Parse a power-saving level name (config + CLI surface).
pub fn parse_saving(s: &str) -> Option<PowerSaving> {
    match s.to_ascii_lowercase().replace('_', "-").as_str() {
        "baseline" | "none" => Some(PowerSaving::BASELINE),
        "m1" | "method1" => Some(PowerSaving::M1),
        "m12" | "m1+2" | "method1+2" | "method12" => Some(PowerSaving::M12),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Arrival process
// ---------------------------------------------------------------------------

/// How inference requests arrive. The paper studies `Periodic`; the other
/// processes implement its stated future work (irregular requests).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    /// Constant request period (the paper's T_req).
    Periodic { period: Duration },
    /// Period with additive Gaussian jitter (clamped at min_period).
    Jittered {
        period: Duration,
        std_dev: Duration,
        min_period: Duration,
    },
    /// Poisson process with the given mean inter-arrival time, clamped
    /// below at `min_gap` (symmetric with `Jittered`'s floor).
    Poisson {
        mean_period: Duration,
        min_gap: Duration,
    },
    /// Replay an inter-arrival trace file (one gap in ms per line; see
    /// `coordinator::requests::TraceReplay::from_file`). `nominal` is the
    /// declared mean period (`request_period_ms`), used for feasibility
    /// checks and reporting without reading the file at parse time.
    Trace { path: String, nominal: Duration },
}

impl ArrivalSpec {
    /// Default Poisson clamp (ms): an arrival cannot land inside the
    /// previous item's data-offload tail. Mirrors `Jittered`'s explicit
    /// `min_period_ms` floor so the two stochastic specs are symmetric.
    pub const DEFAULT_POISSON_MIN_GAP_MS: f64 = 0.05;

    /// The nominal mean inter-arrival time (the paper's T_req), used for
    /// feasibility checks and Eq 4 lifetimes.
    pub fn mean_period(&self) -> Duration {
        match self {
            ArrivalSpec::Periodic { period } => *period,
            ArrivalSpec::Jittered { period, .. } => *period,
            ArrivalSpec::Poisson { mean_period, .. } => *mean_period,
            ArrivalSpec::Trace { nominal, .. } => *nominal,
        }
    }

    fn from_json(v: &Json, path: &str) -> Result<ArrivalSpec, ConfigError> {
        // Plain number or missing "kind" → periodic.
        let kind = match v.get("arrival_kind") {
            Some(k) => k
                .as_str()
                .ok_or_else(|| cerr(&format!("{path}.arrival_kind"), "expected a string"))?,
            None => "periodic",
        };
        let period = Duration::from_millis(req_f64(v, path, "request_period_ms")?);
        match kind {
            "periodic" => Ok(ArrivalSpec::Periodic { period }),
            "jittered" => Ok(ArrivalSpec::Jittered {
                period,
                std_dev: Duration::from_millis(req_f64(v, path, "jitter_std_ms")?),
                min_period: Duration::from_millis(
                    opt_f64(v, path, "min_period_ms")?.unwrap_or(0.1),
                ),
            }),
            "poisson" => Ok(ArrivalSpec::Poisson {
                mean_period: period,
                min_gap: Duration::from_millis(
                    opt_f64(v, path, "min_period_ms")?
                        .unwrap_or(Self::DEFAULT_POISSON_MIN_GAP_MS),
                ),
            }),
            "trace" => Ok(ArrivalSpec::Trace {
                path: req_str(v, path, "trace_path")?.to_string(),
                nominal: period,
            }),
            other => Err(cerr(
                &format!("{path}.arrival_kind"),
                format!("unknown arrival kind '{other}'"),
            )),
        }
    }
}

// ---------------------------------------------------------------------------
// Workload description (paper §5.1: budget + request period)
// ---------------------------------------------------------------------------

/// The paper's §5.1 workload description: an energy budget, an arrival
/// process and the gap policy (plus its tunables) that serves it.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Battery budget the run draws down (paper: 4147 J).
    pub energy_budget: Energy,
    /// How inference requests arrive.
    pub arrival: ArrivalSpec,
    /// The gap policy serving the workload.
    pub policy: PolicySpec,
    /// Per-policy tunables (`policy_params` block; all optional).
    pub params: PolicyParams,
    /// Optional hard cap on simulated items (for bounded runs); None = run
    /// until the budget is exhausted, as in the paper.
    pub max_items: Option<u64>,
    /// RNG seed for stochastic arrival processes.
    pub seed: u64,
}

impl WorkloadSpec {
    /// Decode the `workload` mapping (or the document root, for flat
    /// configs). `policy` is the current key; `strategy` the legacy one.
    pub fn from_json(root: &Json) -> Result<WorkloadSpec, ConfigError> {
        let v = root.get("workload").unwrap_or(root);
        let path = "workload";
        // "policy" is the current key; "strategy" the pre-rename legacy one.
        let (policy_key, policy_name) = match v.get("policy") {
            Some(_) => ("policy", req_str(v, path, "policy")?),
            None => ("strategy", req_str(v, path, "strategy")?),
        };
        let policy = PolicySpec::parse(policy_name).ok_or_else(|| {
            cerr(
                &format!("{path}.{policy_key}"),
                format!(
                    "unknown strategy '{policy_name}' (expected one of: {})",
                    PolicySpec::ALL.map(|s| s.name()).join(", ")
                ),
            )
        })?;
        let max_items = match v.get("max_items") {
            None | Some(Json::Null) => None,
            Some(x) => Some(x.as_u64().ok_or_else(|| {
                cerr(&format!("{path}.max_items"), "expected a non-negative integer")
            })?),
        };
        let params = match v.get("policy_params") {
            None | Some(Json::Null) => PolicyParams::default(),
            Some(p) => PolicyParams::from_json(p, &format!("{path}.policy_params"))?,
        };
        Ok(WorkloadSpec {
            energy_budget: Energy::from_joules(req_f64(v, path, "energy_budget_j")?),
            arrival: ArrivalSpec::from_json(v, path)?,
            policy,
            params,
            max_items,
            seed: opt_f64(v, path, "seed")?.unwrap_or(0.0) as u64,
        })
    }
}

// ---------------------------------------------------------------------------
// Workload item description (paper Table 2)
// ---------------------------------------------------------------------------

/// One named phase of a workload item with its average power and duration.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSpec {
    /// Phase name (`configuration`, `data_loading`, …).
    pub name: String,
    /// Average power over the phase (Table 2 column).
    pub power: Power,
    /// Phase duration (Table 2 column).
    pub time: Duration,
}

impl PhaseSpec {
    /// Phase energy: `power × time`.
    pub fn energy(&self) -> Energy {
        self.power * self.time
    }
}

/// The paper's workload-item description: the active phases (configuration,
/// data loading, inference, data offloading) plus the idle power used by
/// Idle-Waiting. Mirrors Table 2 exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadItemSpec {
    /// FPGA configuration phase (the dominant cost at 36.145 ms).
    pub configuration: PhaseSpec,
    /// Input-transfer phase.
    pub data_loading: PhaseSpec,
    /// The accelerated inference itself.
    pub inference: PhaseSpec,
    /// Output-transfer phase.
    pub data_offloading: PhaseSpec,
    /// Idle power for the Idle-Waiting strategy (duration varies with T_req).
    pub idle_power: Power,
    /// Extra energy On-Off pays per power cycle (rail ramp + inrush). The
    /// paper's published n_max implies this constant; see DESIGN.md §6.
    pub power_on_transient: Energy,
}

impl WorkloadItemSpec {
    /// Decode the `workload_item` mapping (or the document root): the
    /// four named phases plus idle power and power-on transient.
    pub fn from_json(root: &Json) -> Result<WorkloadItemSpec, ConfigError> {
        let v = root.get("workload_item").unwrap_or(root);
        let path = "workload_item";
        let phases = req(v, path, "phases")?
            .as_arr()
            .ok_or_else(|| cerr(&format!("{path}.phases"), "expected a sequence"))?;
        let mut by_name: Vec<PhaseSpec> = Vec::new();
        for (i, p) in phases.iter().enumerate() {
            let ppath = format!("{path}.phases[{i}]");
            by_name.push(PhaseSpec {
                name: req_str(p, &ppath, "name")?.to_string(),
                power: Power::from_milliwatts(req_f64(p, &ppath, "power_mw")?),
                time: Duration::from_millis(req_f64(p, &ppath, "time_ms")?),
            });
        }
        let find = |name: &str| -> Result<PhaseSpec, ConfigError> {
            by_name
                .iter()
                .find(|p| p.name == name)
                .cloned()
                .ok_or_else(|| cerr(&format!("{path}.phases"), format!("missing phase '{name}'")))
        };
        Ok(WorkloadItemSpec {
            configuration: find("configuration")?,
            data_loading: find("data_loading")?,
            inference: find("inference")?,
            data_offloading: find("data_offloading")?,
            idle_power: Power::from_milliwatts(req_f64(v, path, "idle_power_mw")?),
            power_on_transient: Energy::from_millijoules(
                opt_f64(v, path, "power_on_transient_mj")?.unwrap_or(0.0),
            ),
        })
    }

    /// Latency of one workload item including configuration (On-Off path).
    pub fn latency_with_config(&self) -> Duration {
        self.configuration.time
            + self.data_loading.time
            + self.inference.time
            + self.data_offloading.time
    }

    /// Latency excluding configuration (Idle-Waiting path after init).
    pub fn latency_without_config(&self) -> Duration {
        self.data_loading.time + self.inference.time + self.data_offloading.time
    }

    /// Energy of the non-configuration phases.
    pub fn active_energy_without_config(&self) -> Energy {
        self.data_loading.energy() + self.inference.energy() + self.data_offloading.energy()
    }
}

// ---------------------------------------------------------------------------
// Platform description (device substrate parameters)
// ---------------------------------------------------------------------------

/// Supported FPGA models (paper evaluates XC7S15 and XC7S25).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FpgaModel {
    /// Spartan-7 XC7S15 (the paper's main device).
    Xc7s15,
    /// Spartan-7 XC7S25 (the paper's larger comparison device).
    Xc7s25,
}

impl FpgaModel {
    /// Parse a model name (case-insensitive).
    pub fn parse(s: &str) -> Option<FpgaModel> {
        match s.to_ascii_uppercase().as_str() {
            "XC7S15" => Some(FpgaModel::Xc7s15),
            "XC7S25" => Some(FpgaModel::Xc7s25),
            _ => None,
        }
    }

    /// Canonical (datasheet) model name.
    pub fn name(&self) -> &'static str {
        match self {
            FpgaModel::Xc7s15 => "XC7S15",
            FpgaModel::Xc7s25 => "XC7S25",
        }
    }

    /// Full configuration bitstream length in bits (UG470 Table 1-1).
    pub fn bitstream_bits(&self) -> u64 {
        match self {
            FpgaModel::Xc7s15 => 4_310_752,
            FpgaModel::Xc7s25 => 9_934_432,
        }
    }
}

impl fmt::Display for FpgaModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// SPI configuration-port parameters swept in Experiment 1 (Table 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpiConfig {
    /// Bus width in data lines: 1 (single), 2 (dual), 4 (quad).
    pub buswidth: u8,
    /// Clock frequency in MHz (3..=66 per the flash/config port).
    pub freq_mhz: f64,
    /// Bitstream compression option (7-series MFWR-based).
    pub compressed: bool,
}

impl SpiConfig {
    /// Valid SPI bus widths (single/dual/quad).
    pub const BUSWIDTHS: [u8; 3] = [1, 2, 4];
    /// The clock frequencies Experiment 1 sweeps (Table 1).
    pub const FREQS_MHZ: [f64; 11] = [
        3.0, 6.0, 9.0, 12.0, 16.0, 22.0, 26.0, 33.0, 40.0, 50.0, 66.0,
    ];

    /// The paper's optimal setting: Quad SPI, 66 MHz, compressed.
    pub fn optimal() -> SpiConfig {
        SpiConfig {
            buswidth: 4,
            freq_mhz: 66.0,
            compressed: true,
        }
    }

    /// The paper's least-efficient setting: Single SPI, 3 MHz, uncompressed.
    pub fn worst() -> SpiConfig {
        SpiConfig {
            buswidth: 1,
            freq_mhz: 3.0,
            compressed: false,
        }
    }

    /// All 66 sweep points of Experiment 1.
    pub fn sweep() -> Vec<SpiConfig> {
        let mut out = Vec::with_capacity(66);
        for &compressed in &[false, true] {
            for &buswidth in &Self::BUSWIDTHS {
                for &freq_mhz in &Self::FREQS_MHZ {
                    out.push(SpiConfig {
                        buswidth,
                        freq_mhz,
                        compressed,
                    });
                }
            }
        }
        out
    }

    /// Human-readable setting label (`Quad SPI @ 66 MHz, compressed`).
    pub fn label(&self) -> String {
        let bus = match self.buswidth {
            1 => "Single",
            2 => "Dual",
            4 => "Quad",
            _ => "?",
        };
        format!(
            "{bus} SPI @ {} MHz, {}",
            self.freq_mhz,
            if self.compressed { "compressed" } else { "uncompressed" }
        )
    }
}

/// Platform description: everything the device substrate needs.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    /// The FPGA on the board.
    pub fpga: FpgaModel,
    /// Configuration-port parameters (Experiment 1's sweep axes).
    pub spi: SpiConfig,
    /// Battery energy budget (defaults to the paper's 4147 J).
    pub battery_budget: Energy,
    /// Flash standby power (paper §5.4: ≈15.2 mW floor).
    pub flash_standby: Power,
    /// Enable Method 1 (gate IOs + clock reference while idle).
    pub method1: bool,
    /// Enable Method 2 (undervolt VCCINT 1.0→0.75 V, VCCAUX 1.8→1.5 V).
    pub method2: bool,
}

impl Default for PlatformSpec {
    fn default() -> Self {
        PlatformSpec {
            fpga: FpgaModel::Xc7s15,
            spi: SpiConfig::optimal(),
            battery_budget: Energy::from_joules(4147.0),
            flash_standby: Power::from_milliwatts(15.2),
            method1: false,
            method2: false,
        }
    }
}

impl PlatformSpec {
    /// Decode the optional `platform` mapping; absent keys keep the
    /// paper defaults.
    pub fn from_json(root: &Json) -> Result<PlatformSpec, ConfigError> {
        let v = match root.get("platform") {
            Some(p) => p,
            None => return Ok(PlatformSpec::default()),
        };
        let path = "platform";
        let mut spec = PlatformSpec::default();
        if let Some(f) = v.get("fpga") {
            let model = req_str(f, &format!("{path}.fpga"), "model")?;
            spec.fpga = FpgaModel::parse(model).ok_or_else(|| {
                cerr(
                    &format!("{path}.fpga.model"),
                    format!("unknown FPGA model '{model}' (expected XC7S15 or XC7S25)"),
                )
            })?;
        }
        if let Some(s) = v.get("spi") {
            let spath = format!("{path}.spi");
            let buswidth = req_f64(s, &spath, "buswidth")? as u8;
            spec.spi = SpiConfig {
                buswidth,
                freq_mhz: req_f64(s, &spath, "freq_mhz")?,
                compressed: opt_bool(s, &spath, "compressed", true)?,
            };
        }
        if let Some(b) = opt_f64(v, path, "battery_budget_j")? {
            spec.battery_budget = Energy::from_joules(b);
        }
        if let Some(fl) = opt_f64(v, path, "flash_standby_mw")? {
            spec.flash_standby = Power::from_milliwatts(fl);
        }
        spec.method1 = opt_bool(v, path, "method1", false)?;
        spec.method2 = opt_bool(v, path, "method2", false)?;
        Ok(spec)
    }
}

// ---------------------------------------------------------------------------
// Fleet description (heterogeneous device mixture)
// ---------------------------------------------------------------------------

/// One device class of a heterogeneous fleet: a mixture weight plus the
/// gap policy, tunables and battery budget every device of the class
/// runs. Per-device RNG streams are derived on top of the class params
/// (SplitMix64 from the fleet seed), so two devices of one class still
/// make independent randomized decisions.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetClassSpec {
    /// Relative mixture weight (> 0; weights need not sum to 1).
    pub weight: f64,
    /// Gap policy devices of this class run.
    pub policy: PolicySpec,
    /// Per-policy tunables (`policy_params` block; all optional).
    pub params: PolicyParams,
    /// Battery budget per device; `None` = the workload's energy budget.
    pub battery: Option<Energy>,
}

/// The optional `fleet` block consumed by `repro fleet`: how many
/// devices, the heterogeneity mixture over device classes, and the
/// routing deadline. Absent block = a 1000-device fleet running the
/// workload's own policy.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    /// Number of simulated devices.
    pub devices: usize,
    /// Fleet base seed; per-device streams are derived from it.
    pub seed: u64,
    /// Device-class mixture; empty = one class from the workload policy.
    pub classes: Vec<FleetClassSpec>,
    /// Routing deadline; `None` = the arrival's mean period.
    pub deadline: Option<Duration>,
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec {
            devices: 1000,
            seed: 0,
            classes: Vec::new(),
            deadline: None,
        }
    }
}

impl FleetSpec {
    /// Decode the optional `fleet` mapping; absent keys keep defaults.
    pub fn from_json(root: &Json) -> Result<FleetSpec, ConfigError> {
        let v = match root.get("fleet") {
            Some(f) => f,
            None => return Ok(FleetSpec::default()),
        };
        let path = "fleet";
        let mut spec = FleetSpec::default();
        if let Some(d) = opt_u64(v, path, "devices")? {
            spec.devices = d as usize;
        }
        if let Some(s) = opt_u64(v, path, "seed")? {
            spec.seed = s;
        }
        if let Some(ms) = opt_f64(v, path, "deadline_ms")? {
            spec.deadline = Some(Duration::from_millis(ms));
        }
        if let Some(classes) = v.get("classes") {
            let arr = classes
                .as_arr()
                .ok_or_else(|| cerr(&format!("{path}.classes"), "expected a sequence"))?;
            for (i, c) in arr.iter().enumerate() {
                let cpath = format!("{path}.classes[{i}]");
                let policy_name = req_str(c, &cpath, "policy")?;
                let policy = PolicySpec::parse(policy_name).ok_or_else(|| {
                    cerr(
                        &format!("{cpath}.policy"),
                        format!(
                            "unknown policy '{policy_name}' (expected one of: {})",
                            PolicySpec::ALL.map(|s| s.name()).join(", ")
                        ),
                    )
                })?;
                let params = match c.get("policy_params") {
                    None | Some(Json::Null) => PolicyParams::default(),
                    Some(p) => PolicyParams::from_json(p, &format!("{cpath}.policy_params"))?,
                };
                spec.classes.push(FleetClassSpec {
                    weight: opt_f64(c, &cpath, "weight")?.unwrap_or(1.0),
                    policy,
                    params,
                    battery: opt_f64(c, &cpath, "battery_j")?.map(Energy::from_joules),
                });
            }
        }
        Ok(spec)
    }

    /// Range-check the fleet block; returns an actionable message.
    pub fn validate(&self) -> Result<(), String> {
        if self.devices == 0 {
            return Err("fleet.devices must be at least 1".into());
        }
        if let Some(d) = self.deadline {
            if !(d.secs().is_finite() && d.secs() > 0.0) {
                return Err(format!(
                    "fleet.deadline_ms must be positive and finite (got {})",
                    d.millis()
                ));
            }
        }
        for (i, c) in self.classes.iter().enumerate() {
            if !(c.weight.is_finite() && c.weight > 0.0) {
                return Err(format!(
                    "fleet.classes[{i}].weight must be positive and finite (got {})",
                    c.weight
                ));
            }
            c.params.validate().map_err(|e| format!("fleet.classes[{i}]: {e}"))?;
            if let Some(b) = c.battery {
                if !(b.joules().is_finite() && b.joules() > 0.0) {
                    return Err(format!(
                        "fleet.classes[{i}].battery_j must be positive and finite (got {})",
                        b.joules()
                    ));
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Serving description (multi-client coordinator)
// ---------------------------------------------------------------------------

/// The optional `serving` block consumed by `repro serve` when more than
/// one client source feeds the board: how many sources, the scheduler's
/// batching window, the admission queue bound, and the per-request
/// deadline slack. Absent block = a single-source serve loop with the
/// defaults below.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    /// Number of concurrent client sources.
    pub sources: usize,
    /// Scheduler look-ahead window (requests the batching policy may
    /// reorder across; also the single-source quantile window).
    pub window: usize,
    /// Admission bound: arrivals beyond this many queued requests drop.
    pub max_queue: usize,
    /// Deadline slack granted to every request (arrival + slack =
    /// deadline); `None` = one mean inter-arrival period per source.
    pub deadline_slack: Option<Duration>,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec {
            sources: 1,
            window: 8,
            max_queue: 64,
            deadline_slack: None,
        }
    }
}

impl ServeSpec {
    /// Decode the optional `serving` mapping; absent keys keep defaults.
    pub fn from_json(root: &Json) -> Result<ServeSpec, ConfigError> {
        let v = match root.get("serving") {
            Some(s) => s,
            None => return Ok(ServeSpec::default()),
        };
        let path = "serving";
        let mut spec = ServeSpec::default();
        if let Some(n) = opt_u64(v, path, "sources")? {
            spec.sources = n as usize;
        }
        if let Some(w) = opt_u64(v, path, "window")? {
            spec.window = w as usize;
        }
        if let Some(q) = opt_u64(v, path, "max_queue")? {
            spec.max_queue = q as usize;
        }
        if let Some(ms) = opt_f64(v, path, "deadline_slack_ms")? {
            spec.deadline_slack = Some(Duration::from_millis(ms));
        }
        Ok(spec)
    }

    /// Range-check the serving block; returns an actionable message.
    pub fn validate(&self) -> Result<(), String> {
        if self.sources == 0 {
            return Err("serving.sources must be at least 1 client source".into());
        }
        if self.window == 0 {
            return Err(
                "serving.window must be at least 1 request (got 0); the scheduler \
                 needs a look-ahead window to batch within"
                    .into(),
            );
        }
        if self.max_queue == 0 {
            return Err(
                "serving.max_queue must be at least 1 (got 0); a zero-length queue \
                 would drop every arrival at admission"
                    .into(),
            );
        }
        if let Some(s) = self.deadline_slack {
            if !(s.secs().is_finite() && s.secs() > 0.0) {
                return Err(format!(
                    "serving.deadline_slack_ms must be positive and finite (got {}); \
                     omit it to default to one mean inter-arrival period",
                    s.millis()
                ));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Fault-injection description (deterministic robustness layer)
// ---------------------------------------------------------------------------

/// The optional `faults` block: deterministic, seeded fault injection for
/// the device layer. Four per-attempt failure scenarios cover the realistic
/// configuration hazards (bitstream CRC mismatch, corrupted SPI transfer,
/// supply brownout mid-configuration, transient flash read error) plus a
/// brownout during inference, and a retry policy (attempt cap + capped
/// exponential backoff in **sim time**) governs recovery. All rates default
/// to zero — [`FaultSpec::none`] — in which case no fault stream is ever
/// instantiated and every simulation path is bit-identical to a build
/// without this block. See `docs/ROBUSTNESS.md`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Per-attempt probability that configuration aborts on a bitstream
    /// CRC mismatch (detected at the end of the load, so nearly the whole
    /// configuration energy is wasted).
    pub config_crc_rate: f64,
    /// Per-attempt probability that configuration aborts on a corrupted
    /// SPI transfer.
    pub spi_corrupt_rate: f64,
    /// Per-attempt probability that configuration aborts on a supply
    /// brownout.
    pub brownout_config_rate: f64,
    /// Per-attempt probability that configuration aborts on a transient
    /// flash read error (fails early: little energy wasted).
    pub flash_read_rate: f64,
    /// Per-item probability that a supply brownout interrupts the
    /// inference phases, clearing the configuration and forcing a full
    /// (fault-prone) reconfiguration before the item can be served.
    pub brownout_infer_rate: f64,
    /// Base seed of the fault draw streams; per-device streams derive
    /// from it via the `derive_seed` family so sweeps stay byte-identical
    /// at any `--threads`.
    pub seed: u64,
    /// Attempt cap: a configuration that has failed this many times in a
    /// row gives up ([`crate::device::board::BoardError::RetriesExhausted`]).
    pub retry_max: u32,
    /// Backoff spent powered off (sim time) after the first failed
    /// attempt; doubles per subsequent failure.
    pub backoff: Duration,
    /// Saturation cap on the exponential backoff.
    pub backoff_cap: Duration,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            config_crc_rate: 0.0,
            spi_corrupt_rate: 0.0,
            brownout_config_rate: 0.0,
            flash_read_rate: 0.0,
            brownout_infer_rate: 0.0,
            seed: 0xFA_17,
            retry_max: 3,
            backoff: Duration::from_millis(10.0),
            backoff_cap: Duration::from_millis(1000.0),
        }
    }
}

impl FaultSpec {
    /// The fault-free spec: all rates zero, retry policy at defaults.
    /// `FaultSpec::none() == FaultSpec::default()`, spelled explicitly so
    /// call sites read as a statement of intent.
    pub fn none() -> FaultSpec {
        FaultSpec::default()
    }

    /// Whether any fault scenario has a non-zero rate. When `false`, no
    /// RNG stream is created and the device layer takes the exact same
    /// code paths (and f64 operation order) as before this block existed.
    pub fn enabled(&self) -> bool {
        self.config_fault_rate() > 0.0 || self.brownout_infer_rate > 0.0
    }

    /// Total per-attempt probability that a configuration fails (the four
    /// configuration scenarios are disjoint, so rates add).
    pub fn config_fault_rate(&self) -> f64 {
        self.config_crc_rate
            + self.spi_corrupt_rate
            + self.brownout_config_rate
            + self.flash_read_rate
    }

    /// Decode the optional `faults` mapping; absent keys keep defaults.
    pub fn from_json(root: &Json) -> Result<FaultSpec, ConfigError> {
        let v = match root.get("faults") {
            Some(f) => f,
            None => return Ok(FaultSpec::none()),
        };
        let path = "faults";
        let mut spec = FaultSpec::none();
        if let Some(r) = opt_f64(v, path, "config_crc_rate")? {
            spec.config_crc_rate = r;
        }
        if let Some(r) = opt_f64(v, path, "spi_corrupt_rate")? {
            spec.spi_corrupt_rate = r;
        }
        if let Some(r) = opt_f64(v, path, "brownout_config_rate")? {
            spec.brownout_config_rate = r;
        }
        if let Some(r) = opt_f64(v, path, "flash_read_rate")? {
            spec.flash_read_rate = r;
        }
        if let Some(r) = opt_f64(v, path, "brownout_infer_rate")? {
            spec.brownout_infer_rate = r;
        }
        if let Some(s) = opt_u64(v, path, "seed")? {
            spec.seed = s;
        }
        if let Some(n) = opt_u64(v, path, "retry_max")? {
            spec.retry_max = n as u32;
        }
        if let Some(ms) = opt_f64(v, path, "backoff_ms")? {
            spec.backoff = Duration::from_millis(ms);
        }
        if let Some(ms) = opt_f64(v, path, "backoff_cap_ms")? {
            spec.backoff_cap = Duration::from_millis(ms);
        }
        Ok(spec)
    }

    /// Range-check the faults block; returns an actionable message.
    pub fn validate(&self) -> Result<(), String> {
        for (name, rate) in [
            ("config_crc_rate", self.config_crc_rate),
            ("spi_corrupt_rate", self.spi_corrupt_rate),
            ("brownout_config_rate", self.brownout_config_rate),
            ("flash_read_rate", self.flash_read_rate),
            ("brownout_infer_rate", self.brownout_infer_rate),
        ] {
            if !(rate.is_finite() && (0.0..=1.0).contains(&rate)) {
                return Err(format!(
                    "faults.{name} must be a probability in [0, 1] (got {rate})"
                ));
            }
        }
        if self.config_fault_rate() > 1.0 {
            return Err(format!(
                "faults: the four configuration fault rates are disjoint scenarios \
                 and must sum to at most 1 (got {})",
                self.config_fault_rate()
            ));
        }
        if self.retry_max == 0 {
            return Err(
                "faults.retry_max must be at least 1 attempt (got 0); a device that \
                 may never try cannot configure at all"
                    .into(),
            );
        }
        if !(self.backoff.secs().is_finite() && self.backoff.secs() >= 0.0) {
            return Err(format!(
                "faults.backoff_ms must be non-negative and finite (got {})",
                self.backoff.millis()
            ));
        }
        if !(self.backoff_cap.secs().is_finite() && self.backoff_cap >= self.backoff) {
            return Err(format!(
                "faults.backoff_cap_ms must be finite and at least backoff_ms \
                 (got cap {} < base {})",
                self.backoff_cap.millis(),
                self.backoff.millis()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::yaml;

    fn paper_item_yaml() -> &'static str {
        "\
workload_item:
  phases:
    - name: configuration
      power_mw: 327.9
      time_ms: 36.145
    - name: data_loading
      power_mw: 138.7
      time_ms: 0.0100
    - name: inference
      power_mw: 171.4
      time_ms: 0.0281
    - name: data_offloading
      power_mw: 144.1
      time_ms: 0.0020
  idle_power_mw: 134.3
  power_on_transient_mj: 0.1244
"
    }

    #[test]
    fn workload_item_matches_table2() {
        let v = yaml::parse(paper_item_yaml()).unwrap();
        let item = WorkloadItemSpec::from_json(&v).unwrap();
        assert!((item.configuration.energy().millijoules() - 11.852).abs() < 1e-2);
        assert!((item.idle_power.milliwatts() - 134.3).abs() < 1e-9);
        assert!((item.latency_with_config().millis() - 36.1851).abs() < 1e-6);
        assert!((item.latency_without_config().millis() - 0.0401).abs() < 1e-9);
    }

    #[test]
    fn workload_spec_parses() {
        let v = yaml::parse(
            "workload:\n  energy_budget_j: 4147\n  request_period_ms: 40\n  strategy: idle-waiting\n",
        )
        .unwrap();
        let w = WorkloadSpec::from_json(&v).unwrap();
        assert_eq!(w.energy_budget, Energy::from_joules(4147.0));
        assert_eq!(w.policy, PolicySpec::IdleWaiting);
        assert_eq!(w.arrival.mean_period(), Duration::from_millis(40.0));
        assert_eq!(w.max_items, None);
    }

    #[test]
    fn policy_key_preferred_over_legacy_strategy_key() {
        let v = yaml::parse(
            "energy_budget_j: 1\nrequest_period_ms: 40\npolicy: timeout\n",
        )
        .unwrap();
        assert_eq!(WorkloadSpec::from_json(&v).unwrap().policy, PolicySpec::Timeout);
    }

    #[test]
    fn poisson_arrival_parses() {
        let v = yaml::parse(
            "energy_budget_j: 100\nrequest_period_ms: 40\narrival_kind: poisson\nstrategy: on-off\nseed: 7\n",
        )
        .unwrap();
        let w = WorkloadSpec::from_json(&v).unwrap();
        match w.arrival {
            ArrivalSpec::Poisson { mean_period, min_gap } => {
                assert_eq!(mean_period, Duration::from_millis(40.0));
                assert_eq!(
                    min_gap,
                    Duration::from_millis(ArrivalSpec::DEFAULT_POISSON_MIN_GAP_MS)
                );
            }
            other => panic!("expected poisson, got {other:?}"),
        }
        assert_eq!(w.seed, 7);
    }

    #[test]
    fn poisson_min_gap_overridable() {
        let v = yaml::parse(
            "energy_budget_j: 100\nrequest_period_ms: 40\narrival_kind: poisson\nmin_period_ms: 1.5\nstrategy: on-off\n",
        )
        .unwrap();
        let w = WorkloadSpec::from_json(&v).unwrap();
        assert!(matches!(
            w.arrival,
            ArrivalSpec::Poisson { min_gap, .. } if min_gap == Duration::from_millis(1.5)
        ));
    }

    #[test]
    fn trace_arrival_parses() {
        let v = yaml::parse(
            "energy_budget_j: 100\nrequest_period_ms: 40\narrival_kind: trace\ntrace_path: /tmp/gaps.csv\nstrategy: on-off\n",
        )
        .unwrap();
        let w = WorkloadSpec::from_json(&v).unwrap();
        match &w.arrival {
            ArrivalSpec::Trace { path, nominal } => {
                assert_eq!(path, "/tmp/gaps.csv");
                assert_eq!(*nominal, Duration::from_millis(40.0));
            }
            other => panic!("expected trace, got {other:?}"),
        }
        assert_eq!(w.arrival.mean_period(), Duration::from_millis(40.0));
    }

    #[test]
    fn trace_arrival_requires_path() {
        let v = yaml::parse(
            "energy_budget_j: 100\nrequest_period_ms: 40\narrival_kind: trace\nstrategy: on-off\n",
        )
        .unwrap();
        let e = WorkloadSpec::from_json(&v).unwrap_err();
        assert!(e.path.contains("trace_path"));
    }

    #[test]
    fn missing_phase_is_error() {
        let v = yaml::parse(
            "workload_item:\n  phases:\n    - name: configuration\n      power_mw: 1\n      time_ms: 1\n  idle_power_mw: 1\n",
        )
        .unwrap();
        let e = WorkloadItemSpec::from_json(&v).unwrap_err();
        assert!(e.msg.contains("missing phase"));
    }

    #[test]
    fn unknown_strategy_is_error() {
        let v = yaml::parse(
            "energy_budget_j: 1\nrequest_period_ms: 1\nstrategy: warp-drive\n",
        )
        .unwrap();
        let e = WorkloadSpec::from_json(&v).unwrap_err();
        assert!(e.msg.contains("unknown strategy"));
    }

    #[test]
    fn policy_names_round_trip() {
        for spec in PolicySpec::ALL {
            assert_eq!(PolicySpec::parse(spec.name()), Some(spec));
        }
        assert_eq!(PolicySpec::parse("Method1+2"), Some(PolicySpec::IdleWaitingM12));
        // the pre-rename name keeps loading old configs
        assert_eq!(PolicySpec::parse("adaptive"), Some(PolicySpec::Oracle));
        assert_eq!(PolicySpec::parse("ema"), Some(PolicySpec::EmaPredictor));
        assert_eq!(
            PolicySpec::parse("quantile"),
            Some(PolicySpec::WindowedQuantile)
        );
        assert_eq!(
            PolicySpec::parse("rand-ski-rental"),
            Some(PolicySpec::RandomizedSkiRental)
        );
        assert_eq!(PolicySpec::parse("bayes"), Some(PolicySpec::BayesMixture));
        assert_eq!(
            PolicySpec::parse("contextual-bandit"),
            Some(PolicySpec::BanditPolicy)
        );
        assert_eq!(PolicySpec::parse("tabular-q"), Some(PolicySpec::BanditPolicy));
    }

    #[test]
    fn policy_params_default_when_absent() {
        let v = yaml::parse(
            "energy_budget_j: 1\nrequest_period_ms: 40\npolicy: windowed-quantile\n",
        )
        .unwrap();
        let w = WorkloadSpec::from_json(&v).unwrap();
        assert_eq!(w.params, PolicyParams::default());
        assert_eq!(w.params.window, PolicyParams::DEFAULT_WINDOW);
        assert_eq!(w.params.saving, PowerSaving::M12);
        assert_eq!(w.params.timeout, None);
    }

    #[test]
    fn policy_params_block_parses() {
        let v = yaml::parse(
            "energy_budget_j: 1\nrequest_period_ms: 40\npolicy: windowed-quantile\n\
             policy_params:\n  saving: m1\n  timeout_ms: 120.5\n  ema_alpha: 0.35\n\
             \x20 window: 16\n  quantile: 0.75\n  seed: 9\n",
        )
        .unwrap();
        let p = WorkloadSpec::from_json(&v).unwrap().params;
        assert_eq!(p.saving, PowerSaving::M1);
        assert_eq!(p.timeout, Some(Duration::from_millis(120.5)));
        assert!((p.ema_alpha - 0.35).abs() < 1e-12);
        assert_eq!(p.window, 16);
        assert!((p.quantile - 0.75).abs() < 1e-12);
        assert_eq!(p.seed, 9);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn policy_table_round_trips_and_rejects_bad_text() {
        let text: String = (0..64)
            .map(|i| match i % 3 {
                0 => 'i',
                1 => 'o',
                _ => 't',
            })
            .collect();
        let table = PolicyTable::parse(&text).unwrap();
        assert_eq!(table.render(), text);
        assert_eq!(PolicyTable::parse(&table.render()), Some(table));
        assert_eq!(PolicyTable::hedge().render(), "t".repeat(64));
        assert_eq!(PolicyTable::parse("iot"), None, "wrong length");
        assert_eq!(PolicyTable::parse(&"x".repeat(64)), None, "bad letter");
    }

    #[test]
    fn learned_policy_params_parse() {
        let table_text = "t".repeat(64);
        let v = yaml::parse(&format!(
            "energy_budget_j: 1\nrequest_period_ms: 40\npolicy: bandit\n\
             policy_params:\n  components: 4\n  table: {table_text}\n",
        ))
        .unwrap();
        let p = WorkloadSpec::from_json(&v).unwrap().params;
        assert_eq!(p.components, 4);
        assert_eq!(p.table, Some(PolicyTable::hedge()));
        assert!(p.validate().is_ok());

        // a malformed table string is an actionable config error
        let v = yaml::parse(
            "energy_budget_j: 1\nrequest_period_ms: 40\npolicy: bandit\n\
             policy_params:\n  table: short\n",
        )
        .unwrap();
        let e = WorkloadSpec::from_json(&v).unwrap_err();
        assert!(e.path.contains("table"), "{e}");
        assert!(e.msg.contains("64 letters"), "{e}");
    }

    #[test]
    fn policy_params_bad_saving_is_error() {
        let v = yaml::parse(
            "energy_budget_j: 1\nrequest_period_ms: 40\npolicy: timeout\n\
             policy_params:\n  saving: turbo\n",
        )
        .unwrap();
        let e = WorkloadSpec::from_json(&v).unwrap_err();
        assert!(e.msg.contains("unknown saving level"), "{e}");
    }

    #[test]
    fn policy_params_validate_rejects_out_of_range() {
        let bad = [
            PolicyParams {
                quantile: 1.5,
                ..PolicyParams::default()
            },
            PolicyParams {
                quantile: 0.0,
                ..PolicyParams::default()
            },
            PolicyParams {
                quantile: f64::NAN,
                ..PolicyParams::default()
            },
            PolicyParams {
                window: 0,
                ..PolicyParams::default()
            },
            PolicyParams {
                timeout: Some(Duration::from_millis(-5.0)),
                ..PolicyParams::default()
            },
            PolicyParams {
                timeout: Some(Duration::from_millis(f64::INFINITY)),
                ..PolicyParams::default()
            },
            PolicyParams {
                ema_alpha: 0.0,
                ..PolicyParams::default()
            },
            PolicyParams {
                ema_alpha: 1.5,
                ..PolicyParams::default()
            },
            PolicyParams {
                components: 1,
                ..PolicyParams::default()
            },
            PolicyParams {
                components: 5,
                ..PolicyParams::default()
            },
        ];
        for p in bad {
            assert!(p.validate().is_err(), "{p:?} should be rejected");
        }
        assert!(PolicyParams::default().validate().is_ok());
    }

    #[test]
    fn saving_levels_parse() {
        assert_eq!(parse_saving("baseline"), Some(PowerSaving::BASELINE));
        assert_eq!(parse_saving("M1"), Some(PowerSaving::M1));
        assert_eq!(parse_saving("method1+2"), Some(PowerSaving::M12));
        assert_eq!(parse_saving("turbo"), None);
    }

    #[test]
    fn fleet_defaults_when_absent() {
        let spec = FleetSpec::from_json(&Json::Null).unwrap();
        assert_eq!(spec, FleetSpec::default());
        assert_eq!(spec.devices, 1000);
        assert!(spec.classes.is_empty());
        assert_eq!(spec.deadline, None);
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn fleet_block_parses() {
        let v = yaml::parse(
            "fleet:\n  devices: 5000\n  seed: 11\n  deadline_ms: 45.5\n  classes:\n\
             \x20   - weight: 3\n      policy: timeout\n      battery_j: 2000\n\
             \x20   - weight: 1\n      policy: windowed-quantile\n      policy_params:\n\
             \x20       window: 16\n",
        )
        .unwrap();
        let spec = FleetSpec::from_json(&v).unwrap();
        assert_eq!(spec.devices, 5000);
        assert_eq!(spec.seed, 11);
        assert_eq!(spec.deadline, Some(Duration::from_millis(45.5)));
        assert_eq!(spec.classes.len(), 2);
        assert_eq!(spec.classes[0].policy, PolicySpec::Timeout);
        assert_eq!(spec.classes[0].battery, Some(Energy::from_joules(2000.0)));
        assert!((spec.classes[0].weight - 3.0).abs() < 1e-12);
        assert_eq!(spec.classes[1].params.window, 16);
        assert_eq!(spec.classes[1].battery, None);
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn fleet_validate_rejects_bad_values() {
        let mut spec = FleetSpec {
            devices: 0,
            ..FleetSpec::default()
        };
        assert!(spec.validate().unwrap_err().contains("devices"));
        spec.devices = 10;
        spec.classes.push(FleetClassSpec {
            weight: -1.0,
            policy: PolicySpec::Timeout,
            params: PolicyParams::default(),
            battery: None,
        });
        assert!(spec.validate().unwrap_err().contains("weight"));
        spec.classes[0].weight = 1.0;
        spec.classes[0].battery = Some(Energy::from_joules(0.0));
        assert!(spec.validate().unwrap_err().contains("battery_j"));
    }

    #[test]
    fn fleet_unknown_policy_is_error() {
        let v = yaml::parse("fleet:\n  classes:\n    - policy: warp-drive\n").unwrap();
        let e = FleetSpec::from_json(&v).unwrap_err();
        assert!(e.msg.contains("unknown policy"), "{e}");
        assert!(e.path.contains("classes[0]"), "{e}");
    }

    #[test]
    fn serving_defaults_when_absent() {
        let spec = ServeSpec::from_json(&Json::Null).unwrap();
        assert_eq!(spec, ServeSpec::default());
        assert_eq!(spec.sources, 1);
        assert_eq!(spec.window, 8);
        assert_eq!(spec.max_queue, 64);
        assert_eq!(spec.deadline_slack, None);
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn serving_block_parses() {
        let v = yaml::parse(
            "serving:\n  sources: 4\n  window: 16\n  max_queue: 32\n  deadline_slack_ms: 120.5\n",
        )
        .unwrap();
        let spec = ServeSpec::from_json(&v).unwrap();
        assert_eq!(spec.sources, 4);
        assert_eq!(spec.window, 16);
        assert_eq!(spec.max_queue, 32);
        assert_eq!(spec.deadline_slack, Some(Duration::from_millis(120.5)));
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn serving_validate_rejects_bad_values() {
        let mut spec = ServeSpec {
            sources: 0,
            ..ServeSpec::default()
        };
        assert!(spec.validate().unwrap_err().contains("sources"));
        spec.sources = 2;
        spec.window = 0;
        assert!(spec.validate().unwrap_err().contains("window"));
        spec.window = 8;
        spec.max_queue = 0;
        assert!(spec.validate().unwrap_err().contains("max_queue"));
        spec.max_queue = 64;
        spec.deadline_slack = Some(Duration::from_millis(-5.0));
        assert!(spec.validate().unwrap_err().contains("deadline_slack_ms"));
    }

    #[test]
    fn platform_defaults() {
        let spec = PlatformSpec::from_json(&Json::Null).unwrap();
        assert_eq!(spec.fpga, FpgaModel::Xc7s15);
        assert_eq!(spec.spi, SpiConfig::optimal());
        assert!((spec.battery_budget.joules() - 4147.0).abs() < 1e-9);
        assert!(!spec.method1);
    }

    #[test]
    fn platform_parses_overrides() {
        let v = yaml::parse(
            "platform:\n  fpga:\n    model: xc7s25\n  spi:\n    buswidth: 1\n    freq_mhz: 3\n    compressed: false\n  method1: true\n  method2: true\n",
        )
        .unwrap();
        let spec = PlatformSpec::from_json(&v).unwrap();
        assert_eq!(spec.fpga, FpgaModel::Xc7s25);
        assert_eq!(spec.spi, SpiConfig::worst());
        assert!(spec.method1 && spec.method2);
    }

    #[test]
    fn spi_sweep_covers_table1() {
        let sweep = SpiConfig::sweep();
        assert_eq!(sweep.len(), 66); // 3 widths × 11 freqs × 2 compression
        assert!(sweep.contains(&SpiConfig::optimal()));
        assert!(sweep.contains(&SpiConfig::worst()));
    }

    #[test]
    fn fpga_bitstream_sizes_from_ug470() {
        assert_eq!(FpgaModel::Xc7s15.bitstream_bits(), 4_310_752);
        assert_eq!(FpgaModel::Xc7s25.bitstream_bits(), 9_934_432);
    }

    #[test]
    fn spi_labels() {
        assert_eq!(SpiConfig::optimal().label(), "Quad SPI @ 66 MHz, compressed");
        assert_eq!(SpiConfig::worst().label(), "Single SPI @ 3 MHz, uncompressed");
    }

    #[test]
    fn faults_default_when_absent_and_disabled() {
        let spec = FaultSpec::from_json(&Json::Null).unwrap();
        assert_eq!(spec, FaultSpec::none());
        assert!(!spec.enabled());
        assert_eq!(spec.config_fault_rate(), 0.0);
        assert_eq!(spec.retry_max, 3);
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn faults_block_parses() {
        let v = yaml::parse(
            "faults:\n  config_crc_rate: 0.02\n  spi_corrupt_rate: 0.01\n  \
             brownout_config_rate: 0.005\n  flash_read_rate: 0.015\n  \
             brownout_infer_rate: 0.001\n  seed: 99\n  retry_max: 5\n  \
             backoff_ms: 20\n  backoff_cap_ms: 640\n",
        )
        .unwrap();
        let spec = FaultSpec::from_json(&v).unwrap();
        assert!(spec.enabled());
        assert!((spec.config_fault_rate() - 0.05).abs() < 1e-12);
        assert_eq!(spec.seed, 99);
        assert_eq!(spec.retry_max, 5);
        assert_eq!(spec.backoff, Duration::from_millis(20.0));
        assert_eq!(spec.backoff_cap, Duration::from_millis(640.0));
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn faults_validate_rejects_bad_values() {
        let mut spec = FaultSpec {
            config_crc_rate: 1.5,
            ..FaultSpec::none()
        };
        assert!(spec.validate().unwrap_err().contains("config_crc_rate"));
        spec.config_crc_rate = 0.8;
        spec.spi_corrupt_rate = 0.8;
        assert!(spec.validate().unwrap_err().contains("sum to at most 1"));
        spec.spi_corrupt_rate = 0.0;
        spec.retry_max = 0;
        assert!(spec.validate().unwrap_err().contains("retry_max"));
        spec.retry_max = 3;
        spec.backoff = Duration::from_millis(-1.0);
        assert!(spec.validate().unwrap_err().contains("backoff_ms"));
        spec.backoff = Duration::from_millis(50.0);
        spec.backoff_cap = Duration::from_millis(10.0);
        assert!(spec.validate().unwrap_err().contains("backoff_cap_ms"));
    }
}
