//! Configuration system: YAML-subset/JSON parsing, typed schema mirroring
//! the paper's simulator inputs (§5.1), loading and semantic validation.

pub mod loader;
pub mod schema;
pub mod validate;
pub mod yaml;

pub use loader::{load_file, load_str, paper_default, SimConfig};
pub use schema::{
    ArrivalSpec, FaultSpec, FleetClassSpec, FleetSpec, FpgaModel, PhaseSpec, PlatformSpec,
    PolicyParams, PolicySpec, SpiConfig, WorkloadItemSpec, WorkloadSpec,
};
