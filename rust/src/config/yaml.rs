//! Indentation-based YAML-subset parser.
//!
//! The paper's simulator (§5.1) is driven by two YAML documents: a
//! *workload* description (energy budget, request period) and a *workload
//! item* description (per-phase power/duration). The offline vendor set has
//! no YAML crate, so this is a purpose-built parser for the subset those
//! documents (and our platform descriptions) use:
//!
//! * block mappings (`key: value`, nested by indentation)
//! * block sequences (`- item`, including sequences of mappings)
//! * scalars: strings (bare / single / double-quoted), numbers, booleans
//!   (`true`/`false`), `null`/`~`
//! * inline sequences of scalars (`[1, 2, 4]`)
//! * `#` comments and blank lines
//!
//! Not supported (rejected with errors, never silently misparsed): anchors,
//! aliases, tags, multi-document streams, flow mappings, block scalars.
//!
//! Parsed values are represented as [`Json`] so the schema layer has a
//! single accessor API for both formats.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// A YAML-subset parse error with its source line.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("yaml parse error at line {line}: {msg}")]
pub struct YamlError {
    /// 1-based line of the offending input.
    pub line: usize,
    /// Parser diagnostics.
    pub msg: String,
}

/// A pre-processed line: indentation, content, original line number.
#[derive(Debug)]
struct Line<'a> {
    indent: usize,
    text: &'a str,
    number: usize,
}

/// Parse the YAML subset this project uses into a `Json` value.
pub fn parse(input: &str) -> Result<Json, YamlError> {
    let lines = preprocess(input)?;
    if lines.is_empty() {
        return Ok(Json::Null);
    }
    let mut pos = 0;
    let value = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos != lines.len() {
        return Err(err(lines[pos].number, "unexpected dedent/content"));
    }
    Ok(value)
}

fn err(line: usize, msg: impl Into<String>) -> YamlError {
    YamlError {
        line,
        msg: msg.into(),
    }
}

/// Strip comments/blank lines, compute indentation, reject tabs.
fn preprocess(input: &str) -> Result<Vec<Line<'_>>, YamlError> {
    let mut out = Vec::new();
    for (i, raw) in input.lines().enumerate() {
        let number = i + 1;
        if raw.contains('\t') {
            return Err(err(number, "tabs are not allowed in indentation"));
        }
        let content = strip_comment(raw);
        let trimmed_end = content.trim_end();
        let indent = trimmed_end.len() - trimmed_end.trim_start().len();
        let text = trimmed_end.trim_start();
        if text.is_empty() {
            continue;
        }
        if text == "---" {
            if !out.is_empty() {
                return Err(err(number, "multi-document streams are unsupported"));
            }
            continue; // allow a single leading document marker
        }
        out.push(Line {
            indent,
            text,
            number,
        });
    }
    Ok(out)
}

/// Remove a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b'\\' if in_double => i += 1, // skip escaped char
            b'#' if !in_single && !in_double => {
                // yaml requires '#' to be preceded by space/start to be a comment
                if i == 0 || bytes[i - 1] == b' ' {
                    return &line[..i];
                }
            }
            _ => {}
        }
        i += 1;
    }
    line
}

fn parse_block(lines: &[Line<'_>], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let first = &lines[*pos];
    if first.indent != indent {
        return Err(err(first.number, "inconsistent indentation"));
    }
    if first.text.starts_with("- ") || first.text == "-" {
        parse_sequence(lines, pos, indent)
    } else {
        parse_mapping(lines, pos, indent)
    }
}

fn parse_sequence(
    lines: &[Line<'_>],
    pos: &mut usize,
    indent: usize,
) -> Result<Json, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(err(line.number, "unexpected indent inside sequence"));
        }
        if !(line.text.starts_with("- ") || line.text == "-") {
            break;
        }
        let number = line.number;
        let rest = line.text[1..].trim_start();
        *pos += 1;
        if rest.is_empty() {
            // nested block on following lines
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent)?);
            } else {
                items.push(Json::Null);
            }
        } else if let Some((key, value)) = split_key_value(rest) {
            // "- key: value" — a mapping item starting inline
            let item_indent = indent + (line.text.len() - rest.len());
            items.push(parse_inline_mapping_item(
                lines,
                pos,
                item_indent,
                key,
                value,
                number,
            )?);
        } else {
            items.push(parse_scalar(rest, number)?);
        }
    }
    Ok(Json::Arr(items))
}

/// Handle `- key: value` followed by further keys at the item's indent.
fn parse_inline_mapping_item(
    lines: &[Line<'_>],
    pos: &mut usize,
    item_indent: usize,
    first_key: &str,
    first_value: &str,
    number: usize,
) -> Result<Json, YamlError> {
    let mut map = BTreeMap::new();
    insert_entry(&mut map, lines, pos, item_indent, first_key, first_value, number)?;
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent != item_indent || line.text.starts_with("- ") {
            break;
        }
        let (key, value) = split_key_value(line.text)
            .ok_or_else(|| err(line.number, "expected 'key: value'"))?;
        let number = line.number;
        *pos += 1;
        insert_entry(&mut map, lines, pos, item_indent, key, value, number)?;
    }
    Ok(Json::Obj(map))
}

fn parse_mapping(
    lines: &[Line<'_>],
    pos: &mut usize,
    indent: usize,
) -> Result<Json, YamlError> {
    let mut map = BTreeMap::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(err(line.number, "unexpected indent inside mapping"));
        }
        if line.text.starts_with("- ") {
            return Err(err(line.number, "sequence item inside mapping"));
        }
        let (key, value) = split_key_value(line.text)
            .ok_or_else(|| err(line.number, "expected 'key: value'"))?;
        let number = line.number;
        *pos += 1;
        insert_entry(&mut map, lines, pos, indent, key, value, number)?;
    }
    Ok(Json::Obj(map))
}

fn insert_entry(
    map: &mut BTreeMap<String, Json>,
    lines: &[Line<'_>],
    pos: &mut usize,
    indent: usize,
    key: &str,
    value: &str,
    number: usize,
) -> Result<(), YamlError> {
    let key = unquote(key, number)?;
    if map.contains_key(&key) {
        return Err(err(number, format!("duplicate key '{key}'")));
    }
    let parsed = if value.is_empty() {
        // nested block (or empty value)
        if *pos < lines.len() && lines[*pos].indent > indent {
            let child_indent = lines[*pos].indent;
            parse_block(lines, pos, child_indent)?
        } else {
            Json::Null
        }
    } else {
        parse_scalar(value, number)?
    };
    map.insert(key, parsed);
    Ok(())
}

/// Split "key: value" at the first unquoted `: ` (or trailing `:`).
fn split_key_value(text: &str) -> Option<(&str, &str)> {
    let bytes = text.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b'\\' if in_double => i += 1,
            b':' if !in_single && !in_double => {
                if i + 1 == bytes.len() {
                    return Some((text[..i].trim(), ""));
                }
                if bytes[i + 1] == b' ' {
                    return Some((text[..i].trim(), text[i + 2..].trim()));
                }
            }
            _ => {}
        }
        i += 1;
    }
    None
}

fn parse_scalar(text: &str, number: usize) -> Result<Json, YamlError> {
    debug_assert!(!text.is_empty());
    // inline sequence [a, b, c]
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(number, "unterminated inline sequence"))?;
        if inner.trim().is_empty() {
            return Ok(Json::Arr(Vec::new()));
        }
        let items = split_inline_items(inner, number)?
            .into_iter()
            .map(|item| parse_scalar(item.trim(), number))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Json::Arr(items));
    }
    if text.starts_with('{') {
        return Err(err(number, "flow mappings are unsupported"));
    }
    if text.starts_with('&') || text.starts_with('*') || text.starts_with('!') {
        return Err(err(number, "anchors/aliases/tags are unsupported"));
    }
    if text.starts_with('|') || text.starts_with('>') {
        return Err(err(number, "block scalars are unsupported"));
    }
    if text.starts_with('"') || text.starts_with('\'') {
        return Ok(Json::Str(unquote(text, number)?));
    }
    match text {
        "null" | "~" | "Null" | "NULL" => return Ok(Json::Null),
        "true" | "True" | "TRUE" => return Ok(Json::Bool(true)),
        "false" | "False" | "FALSE" => return Ok(Json::Bool(false)),
        _ => {}
    }
    if let Ok(n) = text.parse::<f64>() {
        if n.is_finite() {
            return Ok(Json::Num(n));
        }
    }
    Ok(Json::Str(text.to_string()))
}

/// Split inline-sequence items at top-level commas (no nesting support
/// beyond quoted strings — sufficient for `[1, 2, 4]`-style lists).
fn split_inline_items(inner: &str, number: usize) -> Result<Vec<&str>, YamlError> {
    let bytes = inner.as_bytes();
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_single = false;
    let mut in_double = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b'\\' if in_double => i += 1,
            b'[' if !in_single && !in_double => {
                return Err(err(number, "nested inline sequences are unsupported"))
            }
            b',' if !in_single && !in_double => {
                items.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
        i += 1;
    }
    items.push(&inner[start..]);
    Ok(items)
}

fn unquote(text: &str, number: usize) -> Result<String, YamlError> {
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| err(number, "unterminated double-quoted string"))?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some(other) => {
                        return Err(err(number, format!("unknown escape '\\{other}'")))
                    }
                    None => return Err(err(number, "dangling escape")),
                }
            } else {
                out.push(c);
            }
        }
        Ok(out)
    } else if let Some(inner) = text.strip_prefix('\'') {
        let inner = inner
            .strip_suffix('\'')
            .ok_or_else(|| err(number, "unterminated single-quoted string"))?;
        Ok(inner.replace("''", "'"))
    } else {
        Ok(text.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_workload_description() {
        let doc = "\
# workload description (paper §5.1)
workload:
  energy_budget_j: 4147
  request_period_ms: 40.0
  strategy: idle-waiting
";
        let v = parse(doc).unwrap();
        let w = v.get("workload").unwrap();
        assert_eq!(w.get("energy_budget_j").unwrap().as_f64(), Some(4147.0));
        assert_eq!(w.get("request_period_ms").unwrap().as_f64(), Some(40.0));
        assert_eq!(w.get("strategy").unwrap().as_str(), Some("idle-waiting"));
    }

    #[test]
    fn parses_workload_item_phases() {
        let doc = "\
phases:
  - name: configuration
    power_mw: 327.9
    time_ms: 36.145
  - name: inference
    power_mw: 171.4
    time_ms: 0.0281
";
        let v = parse(doc).unwrap();
        let phases = v.get("phases").unwrap().as_arr().unwrap();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].get("name").unwrap().as_str(), Some("configuration"));
        assert_eq!(phases[1].get("power_mw").unwrap().as_f64(), Some(171.4));
    }

    #[test]
    fn parses_inline_sequences() {
        let v = parse("buswidths: [1, 2, 4]\nfreqs_mhz: [3, 66]\n").unwrap();
        let b: Vec<f64> = v
            .get("buswidths")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        assert_eq!(b, vec![1.0, 2.0, 4.0]);
    }

    #[test]
    fn parses_scalars() {
        let v = parse("a: true\nb: null\nc: ~\nd: 'qu''oted'\ne: \"x\\ny\"\nf: bare str\n")
            .unwrap();
        assert_eq!(v.get("a").unwrap().as_bool(), Some(true));
        assert_eq!(*v.get("b").unwrap(), Json::Null);
        assert_eq!(*v.get("c").unwrap(), Json::Null);
        assert_eq!(v.get("d").unwrap().as_str(), Some("qu'oted"));
        assert_eq!(v.get("e").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("f").unwrap().as_str(), Some("bare str"));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let v = parse("# header\n\na: 1 # trailing\n\n# tail\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let v = parse("a: \"x # y\"\nb: 'p # q'\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_str(), Some("x # y"));
        assert_eq!(v.get("b").unwrap().as_str(), Some("p # q"));
    }

    #[test]
    fn nested_mappings() {
        let doc = "\
platform:
  fpga:
    model: XC7S15
    vccint_v: 1.0
  mcu:
    model: RP2040
";
        let v = parse(doc).unwrap();
        assert_eq!(
            v.get("platform").unwrap().get("fpga").unwrap().get("model").unwrap().as_str(),
            Some("XC7S15")
        );
    }

    #[test]
    fn sequence_of_scalars() {
        let v = parse("- 1\n- 2\n- three\n").unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].as_str(), Some("three"));
    }

    #[test]
    fn duplicate_keys_rejected() {
        let e = parse("a: 1\na: 2\n").unwrap_err();
        assert!(e.msg.contains("duplicate"));
    }

    #[test]
    fn tabs_rejected() {
        assert!(parse("a:\n\tb: 1\n").is_err());
    }

    #[test]
    fn unsupported_features_rejected() {
        assert!(parse("a: &anchor 1\n").is_err());
        assert!(parse("a: |\n  block\n").is_err());
        assert!(parse("a: {flow: map}\n").is_err());
        assert!(parse("---\na: 1\n---\nb: 2\n").is_err());
    }

    #[test]
    fn bad_indent_rejected() {
        assert!(parse("a: 1\n   b: 2\n").is_err());
    }

    #[test]
    fn empty_doc_is_null() {
        assert_eq!(parse("").unwrap(), Json::Null);
        assert_eq!(parse("# only comments\n").unwrap(), Json::Null);
    }

    #[test]
    fn leading_document_marker_ok() {
        let v = parse("---\na: 1\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn key_with_colon_in_quoted_string() {
        let v = parse("note: \"time: 36.15 ms\"\n").unwrap();
        assert_eq!(v.get("note").unwrap().as_str(), Some("time: 36.15 ms"));
    }
}
