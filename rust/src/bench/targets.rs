//! Shared hot-path benchmark bodies.
//!
//! The perf acceptance gates compare `cargo bench --bench hotpath`
//! numbers against `repro bench --json` recordings of the same targets;
//! both harnesses call these functions, so the measured workloads
//! cannot drift apart while the comparison silently keeps "passing".
//! Each function times one canonical body under the caller's name and
//! returns the collected result.

use crate::bench::{black_box, Bench, BenchResult};
use crate::config::loader::SimConfig;
use crate::config::schema::{PolicyParams, PolicySpec};
use crate::coordinator::fleet::{run_fleet, FleetOptions, Placement};
use crate::coordinator::requests::Periodic;
use crate::coordinator::scheduler::Policy as SchedPolicy;
use crate::coordinator::serving::{poisson_sources, serve_multi, MultiServeOptions};
use crate::energy::analytical::Analytical;
use crate::runner::SweepRunner;
use crate::sim::{EventQueue, SimTime};
use crate::strategies::simulate::{simulate_batch, simulate_golden, SimWorker};
use crate::strategies::strategy::{build_with, IdleWaiting, OnOff};
use crate::util::units::Duration;

/// The canonical DES request period (the paper's 40 ms duty cycle).
fn arrivals() -> Periodic {
    Periodic {
        period: Duration::from_millis(40.0),
    }
}

/// `config` capped at `items` workload items per run.
fn capped(config: &SimConfig, items: u64) -> SimConfig {
    let mut cfg = config.clone();
    cfg.workload.max_items = Some(items);
    cfg
}

/// A materialized 40 ms gap trace for `items` items (`items − 1` gaps),
/// plus the label/mean [`SimWorker::run_batch`] expects.
fn trace_for(items: u64) -> (Vec<Duration>, String) {
    let gaps = vec![Duration::from_millis(40.0); items.saturating_sub(1) as usize];
    let label = format!("trace({} gaps)", gaps.len());
    (gaps, label)
}

/// Lifetime DES, Idle-Waiting (configure once, idle every gap): `items`
/// items per iteration on a reused [`SimWorker`] over a materialized
/// trace — the **batched** structure-of-arrays kernel, the production
/// sweep/tuner shape. Throughput unit: simulated items.
pub fn des_idle_waiting<'a>(
    bench: &'a mut Bench,
    name: &str,
    config: &SimConfig,
    items: u64,
) -> &'a BenchResult {
    let cfg = capped(config, items);
    let mut worker = SimWorker::new(&cfg);
    let (gaps, label) = trace_for(items);
    bench.bench_units(name, items as f64, move || {
        black_box(
            worker
                .run_batch(
                    &cfg,
                    &mut IdleWaiting::baseline(),
                    &gaps,
                    &label,
                    Duration::from_millis(40.0),
                )
                .items,
        );
    })
}

/// Lifetime DES, On-Off (power-cycle + full configuration every item) on
/// the batched kernel: the configuration-preamble hot loop. Throughput
/// unit: simulated items.
pub fn des_onoff<'a>(
    bench: &'a mut Bench,
    name: &str,
    config: &SimConfig,
    items: u64,
) -> &'a BenchResult {
    let cfg = capped(config, items);
    let mut worker = SimWorker::new(&cfg);
    let (gaps, label) = trace_for(items);
    bench.bench_units(name, items as f64, move || {
        black_box(
            worker
                .run_batch(&cfg, &mut OnOff, &gaps, &label, Duration::from_millis(40.0))
                .items,
        );
    })
}

/// [`des_idle_waiting`]'s workload on the scalar event-driven fast path
/// (per-gap `execute_plan` through the event queue) — the baseline the
/// batched kernel's ≥2× gate is measured against.
pub fn des_idle_waiting_scalar<'a>(
    bench: &'a mut Bench,
    name: &str,
    config: &SimConfig,
    items: u64,
) -> &'a BenchResult {
    let cfg = capped(config, items);
    let mut worker = SimWorker::new(&cfg);
    bench.bench_units(name, items as f64, move || {
        let mut arrivals = arrivals();
        black_box(
            worker
                .run(&cfg, &mut IdleWaiting::baseline(), &mut arrivals)
                .items,
        );
    })
}

/// [`des_onoff`]'s workload on the scalar event-driven fast path.
pub fn des_onoff_scalar<'a>(
    bench: &'a mut Bench,
    name: &str,
    config: &SimConfig,
    items: u64,
) -> &'a BenchResult {
    let cfg = capped(config, items);
    let mut worker = SimWorker::new(&cfg);
    bench.bench_units(name, items as f64, move || {
        let mut arrivals = arrivals();
        black_box(worker.run(&cfg, &mut OnOff, &mut arrivals).items);
    })
}

/// The On-Off DES on the golden `Board`-FSM reference path — the
/// pre-kernel cost, kept measurable for an in-run speedup readout.
pub fn des_onoff_golden<'a>(
    bench: &'a mut Bench,
    name: &str,
    config: &SimConfig,
    items: u64,
) -> &'a BenchResult {
    let cfg = capped(config, items);
    bench.bench_units(name, items as f64, move || {
        let mut arrivals = arrivals();
        black_box(simulate_golden(&cfg, &mut OnOff, &mut arrivals).items);
    })
}

/// Event queue: 1000 interleaved schedules then a full drain, on a
/// reused (reset) queue. Throughput unit: queue events.
pub fn event_queue<'a>(bench: &'a mut Bench, name: &str) -> &'a BenchResult {
    let mut queue: EventQueue<u64> = EventQueue::with_capacity(1024);
    bench.bench_units(name, 1000.0, move || {
        queue.reset();
        for i in 0..1000u64 {
            queue.schedule(SimTime::from_nanos(i * 7919 % 4096), i);
        }
        let mut acc = 0u64;
        while let Some((_, id)) = queue.pop() {
            acc = acc.wrapping_add(id);
        }
        black_box(acc);
    })
}

/// Fleet survey throughput: every device replays a shared gap trace
/// through the batched kernel, folded into streaming aggregates — the
/// whole survey phase of [`run_fleet`] (routing disabled) on a
/// single-thread runner, so the number is a per-core figure independent
/// of the host's core count. Throughput unit: device-gap steps
/// (devices × steps per iteration).
pub fn fleet_step_devices<'a>(
    bench: &'a mut Bench,
    name: &str,
    config: &SimConfig,
    quick: bool,
) -> &'a BenchResult {
    let (devices, steps) = if quick { (64, 100) } else { (256, 400) };
    let mut cfg = config.clone();
    cfg.fleet.devices = devices;
    cfg.fleet.seed = 7;
    let options = FleetOptions {
        steps,
        requests: 0,
        placement: Placement::RoundRobin,
    };
    let runner = SweepRunner::single();
    bench.bench_units(name, (devices * steps) as f64, move || {
        black_box(
            run_fleet(&cfg, &options, &runner)
                .expect("fleet survey bench")
                .step
                .items,
        );
    })
}

/// Fleet routing throughput: the shared arrival stream routed across the
/// compact device states by the least-loaded placement (the O(devices)
/// argmin scan, the most expensive picker). Survey disabled; includes
/// building the per-device policies each iteration. Throughput unit:
/// routed requests.
pub fn fleet_route_requests<'a>(
    bench: &'a mut Bench,
    name: &str,
    config: &SimConfig,
    quick: bool,
) -> &'a BenchResult {
    let (devices, requests) = if quick { (64, 1000) } else { (256, 4000) };
    let mut cfg = config.clone();
    cfg.fleet.devices = devices;
    cfg.fleet.seed = 7;
    let options = FleetOptions {
        steps: 0,
        requests,
        placement: Placement::LeastLoaded,
    };
    let runner = SweepRunner::single();
    bench.bench_units(name, requests as f64, move || {
        black_box(
            run_fleet(&cfg, &options, &runner)
                .expect("fleet routing bench")
                .route
                .served,
        );
    })
}

/// Multi-client serving coordinator throughput: N Poisson sources merged
/// into one admission queue, batch-by-slot scheduling, and every dispatch
/// executed on the shared energy ledger — the whole [`serve_multi`]
/// engine including source materialization each iteration. Throughput
/// unit: offered requests (sources × per-source requests).
pub fn serve_queue_requests<'a>(
    bench: &'a mut Bench,
    name: &str,
    config: &SimConfig,
    quick: bool,
) -> &'a BenchResult {
    let (sources, per_source) = if quick { (4, 250) } else { (8, 1000) };
    let opts = MultiServeOptions {
        sched: SchedPolicy::BatchBySlot { window: 8 },
        max_queue: 64,
        gap_policy: PolicySpec::IdleWaitingM12,
        params: PolicyParams::default(),
    };
    let cfg = config.clone();
    bench.bench_units(name, (sources * per_source) as f64, move || {
        let mean_gap = Duration::from_millis(40.0 * sources as f64);
        let streams = poisson_sources(sources, per_source, mean_gap, mean_gap, 7);
        black_box(serve_multi(&cfg, &opts, &streams).served);
    })
}

/// The learned policies' batched planning hot path: one Bayes-mixture
/// and one bandit pass over a materialized trace through the batched
/// structure-of-arrays kernel. Their `plan_gaps` overrides interleave
/// plan/observe faithfully, so this times the online posterior/feature
/// updates too — the cost the sweep and tuner pay per gap. Throughput
/// unit: simulated items (both policies per iteration).
pub fn learned_policy_plan_gaps<'a>(
    bench: &'a mut Bench,
    name: &str,
    config: &SimConfig,
    items: u64,
) -> &'a BenchResult {
    let cfg = capped(config, items);
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let (gaps, _) = trace_for(items);
    bench.bench_units(name, 2.0 * items as f64, move || {
        for spec in [PolicySpec::BayesMixture, PolicySpec::BanditPolicy] {
            let mut policy = build_with(spec, &model, &cfg.workload.params);
            black_box(simulate_batch(&cfg, policy.as_mut(), &gaps).items);
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    #[test]
    fn shared_targets_run_and_report_units() {
        let cfg = paper_default();
        let mut bench = Bench::new("targets-test").quick();
        let r = des_idle_waiting(&mut bench, "iw", &cfg, 5);
        assert_eq!(r.units_per_iter, 5.0);
        let r = des_onoff(&mut bench, "onoff", &cfg, 5);
        assert!(r.throughput() > 0.0);
        let r = des_idle_waiting_scalar(&mut bench, "iw-scalar", &cfg, 5);
        assert_eq!(r.units_per_iter, 5.0);
        let r = des_onoff_scalar(&mut bench, "onoff-scalar", &cfg, 5);
        assert!(r.throughput() > 0.0);
        let r = des_onoff_golden(&mut bench, "golden", &cfg, 5);
        assert!(r.ns_per_iter() > 0.0);
        let r = event_queue(&mut bench, "queue");
        assert_eq!(r.units_per_iter, 1000.0);
        let r = fleet_step_devices(&mut bench, "fleet-step", &cfg, true);
        assert_eq!(r.units_per_iter, 6400.0);
        let r = fleet_route_requests(&mut bench, "fleet-route", &cfg, true);
        assert_eq!(r.units_per_iter, 1000.0);
        let r = serve_queue_requests(&mut bench, "serve-queue", &cfg, true);
        assert_eq!(r.units_per_iter, 1000.0);
        let r = learned_policy_plan_gaps(&mut bench, "learned", &cfg, 5);
        assert_eq!(r.units_per_iter, 10.0);
        assert_eq!(bench.results().len(), 10);
    }
}
