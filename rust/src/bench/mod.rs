//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! `harness = false` bench binaries use [`Bench`] to time closures with
//! warmup, fixed-duration sampling, and p50/p95 reporting, and to print
//! one consistent table per bench target. Wall-clock timing via
//! `std::time::Instant`; a `black_box` re-export prevents the optimizer
//! from deleting measured work.

pub mod targets;

pub use std::hint::black_box;

use std::time::{Duration as StdDuration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::table::{fnum, Table};

/// One benchmark's collected results.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Total timed iterations.
    pub iterations: u64,
    /// Distribution of per-iteration times (ns).
    pub summary: Summary,
    /// Work units one iteration performs (items, cells, events …);
    /// `throughput` = units × iterations/sec. Defaults to 1.
    pub units_per_iter: f64,
}

impl BenchResult {
    /// Median nanoseconds per iteration.
    pub fn ns_per_iter(&self) -> f64 {
        self.summary.p50
    }

    /// Iterations per second at the median.
    pub fn iters_per_sec(&self) -> f64 {
        1e9 / self.summary.p50
    }

    /// Work units per second at the median (items/sec, cells/sec, …).
    pub fn throughput(&self) -> f64 {
        self.units_per_iter * self.iters_per_sec()
    }

    /// This result as one row of the published `repro bench --json`
    /// schema: `{name, iters, ns_per_iter, throughput}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iterations as f64)),
            ("ns_per_iter", Json::num(self.ns_per_iter())),
            ("throughput", Json::num(self.throughput())),
        ])
    }
}

/// The harness: collects results, prints a table on drop/finish.
pub struct Bench {
    title: String,
    warmup: StdDuration,
    measure: StdDuration,
    results: Vec<BenchResult>,
}

impl Bench {
    /// A harness with default warmup/measure windows.
    pub fn new(title: impl Into<String>) -> Bench {
        Bench {
            title: title.into(),
            warmup: StdDuration::from_millis(200),
            measure: StdDuration::from_millis(800),
            results: Vec::new(),
        }
    }

    /// Shorter windows for CI/quick runs.
    pub fn quick(mut self) -> Bench {
        self.warmup = StdDuration::from_millis(50);
        self.measure = StdDuration::from_millis(200);
        self
    }

    /// Time `f` (called repeatedly): warmup, then sample batches until the
    /// measurement window elapses. Batch size auto-scales so that cheap
    /// closures aren't dominated by timer overhead.
    pub fn bench(&mut self, name: impl Into<String>, f: impl FnMut()) -> &BenchResult {
        self.bench_units(name, 1.0, f)
    }

    /// [`bench`](Bench::bench) with an explicit work-unit count per
    /// iteration (simulated items, sweep cells, queue events …), so the
    /// JSON report can carry a meaningful `throughput`.
    pub fn bench_units(
        &mut self,
        name: impl Into<String>,
        units_per_iter: f64,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        let name = name.into();
        // warmup + batch-size calibration
        let warm_start = Instant::now();
        let mut calls = 0u64;
        while warm_start.elapsed() < self.warmup {
            f();
            calls += 1;
        }
        let per_call = self.warmup.as_nanos() as f64 / calls.max(1) as f64;
        // target ≥ ~2 µs per timed batch
        let batch = ((2_000.0 / per_call).ceil() as u64).clamp(1, 1 << 20);

        let mut samples_ns = Vec::new();
        let mut iterations = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.measure {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let elapsed = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples_ns.push(elapsed);
            iterations += batch;
        }
        let summary = Summary::of(&samples_ns).expect("at least one sample");
        self.results.push(BenchResult {
            name,
            iterations,
            summary,
            units_per_iter,
        });
        self.results.last().unwrap()
    }

    /// Every collected result in the `repro bench --json` schema (a JSON
    /// array of `{name, iters, ns_per_iter, throughput}` objects).
    pub fn to_json(&self) -> Json {
        Json::arr(self.results.iter().map(BenchResult::to_json).collect())
    }

    /// Render the results table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "benchmark",
            "iters",
            "p50 (ns)",
            "p95 (ns)",
            "mean (ns)",
            "ops/sec",
        ])
        .with_title(format!("bench: {}", self.title));
        for r in &self.results {
            t.row(&[
                r.name.clone(),
                r.iterations.to_string(),
                fnum(r.summary.p50, 1),
                fnum(r.summary.p95, 1),
                fnum(r.summary.mean, 1),
                fnum(r.iters_per_sec(), 0),
            ]);
        }
        t.render()
    }

    /// Print the table (bench binaries call this at the end).
    pub fn finish(self) {
        print!("{}", self.render());
    }

    /// All collected results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// True when `cargo bench` should run abbreviated (CI smoke): set
/// IDLEWAIT_BENCH_QUICK=1.
pub fn quick_mode() -> bool {
    std::env::var("IDLEWAIT_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benches_a_closure() {
        let mut b = Bench::new("test").quick();
        let mut acc = 0u64;
        let r = b.bench("increment", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iterations > 1000);
        assert!(r.summary.p50 > 0.0);
        assert!(r.iters_per_sec() > 1000.0);
    }

    #[test]
    fn json_schema_carries_name_iters_ns_and_throughput() {
        let mut b = Bench::new("json-test").quick();
        b.bench_units("ten-units", 10.0, || {
            black_box(3u64.wrapping_mul(7));
        });
        let json = b.to_json();
        let rows = json.as_arr().expect("array of results");
        assert_eq!(rows.len(), 1);
        let row = &rows[0];
        assert_eq!(row.get("name").and_then(Json::as_str), Some("ten-units"));
        assert!(row.get("iters").and_then(Json::as_f64).unwrap() >= 1.0);
        let ns = row.get("ns_per_iter").and_then(Json::as_f64).unwrap();
        let tput = row.get("throughput").and_then(Json::as_f64).unwrap();
        assert!(ns > 0.0);
        assert!((tput - 10.0 * 1e9 / ns).abs() / tput < 1e-9);
        // the schema round-trips through the in-tree parser
        let parsed = Json::parse(&json.render()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn render_lists_benchmarks() {
        let mut b = Bench::new("render-test").quick();
        b.bench("noop", || {});
        let s = b.render();
        assert!(s.contains("bench: render-test"));
        assert!(s.contains("noop"));
        assert!(s.contains("ops/sec"));
    }

    #[test]
    fn slower_work_measures_slower() {
        let mut b = Bench::new("ordering").quick();
        let fast = b.bench("fast", || {
            black_box(1 + 1);
        }).ns_per_iter();
        let slow = b
            .bench("slow", || {
                let mut s = 0f64;
                for i in 0..100 {
                    s += black_box(i as f64).sqrt();
                }
                black_box(s);
            })
            .ns_per_iter();
        assert!(slow > fast, "slow={slow} fast={fast}");
    }
}
