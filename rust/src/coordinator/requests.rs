//! Inference-request arrival generators.
//!
//! The paper studies strictly periodic requests (constant T_req); its
//! stated future work is "irregularly occurring inference requests". Both
//! are covered here: periodic, periodic-with-jitter, Poisson, and replay
//! of an explicit inter-arrival trace. Generators are deterministic given
//! their seed.

use std::sync::Arc;

use crate::config::schema::ArrivalSpec;
use crate::util::rng::Xoshiro256ss;
use crate::util::units::Duration;

/// Mean of a gap slice — *the* trace-mean formula (`f64` seconds summed
/// in trace order, divided by the count). One shared implementation for
/// [`TraceReplay`], the prefix simulation and its reports, so the
/// bit-for-bit resume-equals-scratch contract cannot be broken by one
/// copy of the fold drifting.
pub fn trace_mean(gaps: &[Duration]) -> Duration {
    let total: f64 = gaps.iter().map(|g| g.secs()).sum();
    Duration::from_secs(total / gaps.len() as f64)
}

/// A source of inter-arrival gaps (time from one request to the next).
pub trait ArrivalProcess: Send {
    /// The next inter-arrival gap.
    fn next_gap(&mut self) -> Duration;

    /// Mean inter-arrival time (for reporting / analytical comparison).
    fn mean(&self) -> Duration;

    /// Human-readable process label for reports.
    fn label(&self) -> String;
}

/// Strictly periodic arrivals — the paper's T_req.
#[derive(Debug, Clone)]
pub struct Periodic {
    /// The constant inter-arrival period.
    pub period: Duration,
}

impl ArrivalProcess for Periodic {
    fn next_gap(&mut self) -> Duration {
        self.period
    }

    fn mean(&self) -> Duration {
        self.period
    }

    fn label(&self) -> String {
        format!("periodic({:.2} ms)", self.period.millis())
    }
}

/// Periodic with additive Gaussian jitter, clamped below at `min_gap`.
#[derive(Debug, Clone)]
pub struct Jittered {
    /// Nominal period before jitter.
    pub period: Duration,
    /// Standard deviation of the additive Gaussian jitter.
    pub std_dev: Duration,
    /// Lower clamp on the jittered gap.
    pub min_gap: Duration,
    rng: Xoshiro256ss,
}

impl Jittered {
    /// A jittered process drawing from its own seeded stream.
    pub fn new(period: Duration, std_dev: Duration, min_gap: Duration, seed: u64) -> Jittered {
        Jittered {
            period,
            std_dev,
            min_gap,
            rng: Xoshiro256ss::new(seed),
        }
    }
}

impl ArrivalProcess for Jittered {
    fn next_gap(&mut self) -> Duration {
        let gap = self.rng.normal(self.period.secs(), self.std_dev.secs());
        Duration::from_secs(gap.max(self.min_gap.secs()))
    }

    fn mean(&self) -> Duration {
        self.period
    }

    fn label(&self) -> String {
        format!(
            "jittered({:.2} ± {:.2} ms)",
            self.period.millis(),
            self.std_dev.millis()
        )
    }
}

/// Poisson arrivals (exponential gaps), clamped below at `min_gap` so an
/// arrival cannot land inside the previous item's latency.
#[derive(Debug, Clone)]
pub struct Poisson {
    /// Mean of the exponential inter-arrival gaps.
    pub mean_gap: Duration,
    /// Lower clamp on drawn gaps.
    pub min_gap: Duration,
    rng: Xoshiro256ss,
}

impl Poisson {
    /// A Poisson process drawing from its own seeded stream.
    pub fn new(mean_gap: Duration, min_gap: Duration, seed: u64) -> Poisson {
        Poisson {
            mean_gap,
            min_gap,
            rng: Xoshiro256ss::new(seed),
        }
    }
}

impl ArrivalProcess for Poisson {
    fn next_gap(&mut self) -> Duration {
        let gap = self.rng.exponential(self.mean_gap.secs());
        Duration::from_secs(gap.max(self.min_gap.secs()))
    }

    fn mean(&self) -> Duration {
        self.mean_gap
    }

    fn label(&self) -> String {
        format!("poisson(mean {:.2} ms)", self.mean_gap.millis())
    }
}

/// Replay an explicit gap trace, cycling when exhausted.
///
/// The gap sequence is `Arc`-shared: cloning a replayer (one per sweep
/// cell in the trace-driven experiment columns) shares the parsed trace
/// instead of copying it.
#[derive(Debug, Clone)]
pub struct TraceReplay {
    gaps: Arc<[Duration]>,
    pos: usize,
}

impl TraceReplay {
    /// Replay an in-memory gap sequence (panics if empty).
    pub fn new(gaps: Vec<Duration>) -> TraceReplay {
        TraceReplay::shared(gaps.into())
    }

    /// Replay a shared gap sequence without copying it (panics if empty).
    pub fn shared(gaps: Arc<[Duration]>) -> TraceReplay {
        assert!(!gaps.is_empty(), "empty arrival trace");
        TraceReplay { gaps, pos: 0 }
    }

    /// The shared gap sequence (a refcount bump, not a copy) — what the
    /// tuner and the experiment grids hand to every evaluation.
    pub fn shared_gaps(&self) -> Arc<[Duration]> {
        self.gaps.clone()
    }

    /// Number of gaps in one cycle of the trace.
    pub fn len(&self) -> usize {
        self.gaps.len()
    }

    /// Whether the trace holds no gaps (never true: construction rejects
    /// empty traces).
    pub fn is_empty(&self) -> bool {
        self.gaps.is_empty()
    }

    /// The full gap sequence of one cycle (the tuner reads it to split
    /// train/validation without replaying).
    pub fn gaps(&self) -> &[Duration] {
        &self.gaps
    }

    /// Load a gap trace from a text/CSV file: one inter-arrival gap in
    /// milliseconds per line; `#` comments, blank lines and an optional
    /// `gap_ms` header are skipped. Errors name the offending path and
    /// line so a bad trace in a sweep config is locatable directly.
    pub fn from_file(path: impl AsRef<std::path::Path>) -> std::io::Result<TraceReplay> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            std::io::Error::new(
                e.kind(),
                format!("reading gap trace {}: {e}", path.display()),
            )
        })?;
        let mut gaps = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') || line.eq_ignore_ascii_case("gap_ms")
            {
                continue;
            }
            let ms: f64 = line.parse().map_err(|_| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!(
                        "{}:{}: cannot parse '{line}' as a gap in ms",
                        path.display(),
                        i + 1
                    ),
                )
            })?;
            if !(ms.is_finite() && ms > 0.0) {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!(
                        "{}:{}: gap must be positive ({ms})",
                        path.display(),
                        i + 1
                    ),
                ));
            }
            gaps.push(Duration::from_millis(ms));
        }
        if gaps.is_empty() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "gap trace {} contains no gaps (only comments/headers)",
                    path.display()
                ),
            ));
        }
        Ok(TraceReplay::new(gaps))
    }
}

impl ArrivalProcess for TraceReplay {
    fn next_gap(&mut self) -> Duration {
        let gap = self.gaps[self.pos];
        self.pos = (self.pos + 1) % self.gaps.len();
        gap
    }

    fn mean(&self) -> Duration {
        trace_mean(&self.gaps)
    }

    fn label(&self) -> String {
        format!("trace({} gaps)", self.gaps.len())
    }
}

/// Build an arrival process from its config spec. Only `Trace` touches
/// the filesystem (loading the gap file), hence the `io::Result`.
pub fn build(spec: &ArrivalSpec, seed: u64) -> std::io::Result<Box<dyn ArrivalProcess>> {
    Ok(match spec {
        ArrivalSpec::Periodic { period } => Box::new(Periodic { period: *period }),
        ArrivalSpec::Jittered {
            period,
            std_dev,
            min_period,
        } => Box::new(Jittered::new(*period, *std_dev, *min_period, seed)),
        ArrivalSpec::Poisson { mean_period, min_gap } => {
            Box::new(Poisson::new(*mean_period, *min_gap, seed))
        }
        ArrivalSpec::Trace { path, .. } => Box::new(TraceReplay::from_file(path)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_is_constant() {
        let mut p = Periodic {
            period: Duration::from_millis(40.0),
        };
        for _ in 0..10 {
            assert_eq!(p.next_gap().millis(), 40.0);
        }
        assert_eq!(p.mean().millis(), 40.0);
    }

    #[test]
    fn jittered_mean_converges_and_respects_floor() {
        let mut j = Jittered::new(
            Duration::from_millis(40.0),
            Duration::from_millis(10.0),
            Duration::from_millis(1.0),
            42,
        );
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let g = j.next_gap();
            assert!(g.millis() >= 1.0);
            sum += g.millis();
        }
        let mean = sum / n as f64;
        assert!((mean - 40.0).abs() < 0.3, "mean={mean}");
    }

    #[test]
    fn poisson_mean_converges() {
        let mut p = Poisson::new(Duration::from_millis(40.0), Duration::from_millis(0.05), 7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| p.next_gap().millis()).sum::<f64>() / n as f64;
        assert!((mean - 40.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn poisson_is_deterministic_per_seed() {
        let mut a = Poisson::new(Duration::from_millis(40.0), Duration::ZERO, 3);
        let mut b = Poisson::new(Duration::from_millis(40.0), Duration::ZERO, 3);
        for _ in 0..100 {
            assert_eq!(a.next_gap().secs(), b.next_gap().secs());
        }
    }

    #[test]
    fn trace_replay_cycles() {
        let mut t = TraceReplay::new(vec![
            Duration::from_millis(10.0),
            Duration::from_millis(20.0),
        ]);
        assert_eq!(t.next_gap().millis(), 10.0);
        assert_eq!(t.next_gap().millis(), 20.0);
        assert_eq!(t.next_gap().millis(), 10.0);
        assert_eq!(t.mean().millis(), 15.0);
    }

    #[test]
    #[should_panic(expected = "empty arrival trace")]
    fn empty_trace_rejected() {
        TraceReplay::new(vec![]);
    }

    #[test]
    fn trace_file_round_trip() {
        let dir = std::env::temp_dir().join("idlewait_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("gaps.csv");
        std::fs::write(&path, "# sensor trace\ngap_ms\n40.0\n\n55.5\n12.25\n").unwrap();
        let mut t = TraceReplay::from_file(&path).unwrap();
        assert_eq!(t.next_gap().millis(), 40.0);
        assert_eq!(t.next_gap().millis(), 55.5);
        assert_eq!(t.next_gap().millis(), 12.25);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trace_file_rejects_garbage_naming_path_and_line() {
        let dir = std::env::temp_dir().join("idlewait_trace_bad");
        std::fs::create_dir_all(&dir).unwrap();
        // (file, content, expected line marker in the error)
        for (name, content, line) in [
            ("nonnum.csv", "40\nnot-a-number\n", Some(":2:")),
            ("negative.csv", "gap_ms\n40\n-1\n", Some(":3:")),
            ("empty.csv", "# nothing here\n", None),
        ] {
            let path = dir.join(name);
            std::fs::write(&path, content).unwrap();
            let err = TraceReplay::from_file(&path).unwrap_err().to_string();
            assert!(err.contains(name), "{name}: error must name the file: {err}");
            if let Some(line) = line {
                assert!(err.contains(line), "{name}: error must name the line: {err}");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_trace_file_error_names_the_path() {
        let err = TraceReplay::from_file("/nonexistent/gaps.csv")
            .unwrap_err()
            .to_string();
        assert!(err.contains("/nonexistent/gaps.csv"), "{err}");
    }

    #[test]
    fn gaps_accessor_exposes_one_cycle() {
        let t = TraceReplay::new(vec![
            Duration::from_millis(10.0),
            Duration::from_millis(20.0),
        ]);
        assert_eq!(t.gaps().len(), 2);
        assert_eq!(t.gaps()[1], Duration::from_millis(20.0));
    }

    #[test]
    fn build_from_spec() {
        let p = build(
            &ArrivalSpec::Periodic {
                period: Duration::from_millis(40.0),
            },
            0,
        )
        .unwrap();
        assert!(p.label().starts_with("periodic"));
        let p = build(
            &ArrivalSpec::Poisson {
                mean_period: Duration::from_millis(40.0),
                min_gap: Duration::from_millis(ArrivalSpec::DEFAULT_POISSON_MIN_GAP_MS),
            },
            0,
        )
        .unwrap();
        assert!(p.label().starts_with("poisson"));
    }

    #[test]
    fn build_poisson_honours_the_config_min_gap() {
        let mut p = build(
            &ArrivalSpec::Poisson {
                mean_period: Duration::from_millis(5.0),
                min_gap: Duration::from_millis(4.0),
            },
            11,
        )
        .unwrap();
        for _ in 0..1_000 {
            assert!(p.next_gap().millis() >= 4.0);
        }
    }

    #[test]
    fn build_trace_spec_loads_the_file() {
        let dir = std::env::temp_dir().join("idlewait_trace_spec");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("gaps.csv");
        std::fs::write(&path, "25.0\n75.0\n").unwrap();
        let mut p = build(
            &ArrivalSpec::Trace {
                path: path.to_str().unwrap().to_string(),
                nominal: Duration::from_millis(50.0),
            },
            0,
        )
        .unwrap();
        assert_eq!(p.next_gap().millis(), 25.0);
        assert_eq!(p.next_gap().millis(), 75.0);
        assert_eq!(p.mean().millis(), 50.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn build_trace_spec_missing_file_is_io_error() {
        assert!(build(
            &ArrivalSpec::Trace {
                path: "/nonexistent/gaps.csv".into(),
                nominal: Duration::from_millis(40.0),
            },
            0,
        )
        .is_err());
    }
}
