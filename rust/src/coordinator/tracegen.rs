//! Synthetic gap-trace generators — the `workloads/` corpus and the
//! `repro gen-trace` command.
//!
//! Three workload shapes motivated by the pervasive-computing
//! deployments the paper targets (and by the bursty edge workloads of
//! the ElasticAI line of work):
//!
//! * **bursty-iot** — short intra-burst gaps followed by long silences;
//!   the shape where online policies separate (bursts reward idling,
//!   silences reward powering off).
//! * **diurnal-poisson** — a Poisson process whose mean is modulated by
//!   a sinusoidal "day/night" cycle, so the winning decision drifts
//!   slowly through the trace.
//! * **onoff-mmpp** — a two-state Markov-modulated Poisson process
//!   (active ↔ quiet), the standard bursty-traffic model: dense gaps in
//!   the ON state, sparse gaps in the OFF state.
//!
//! Generators are pure functions of `(kind, gaps, period_ms, seed)` via
//! [`Xoshiro256ss`], so traces regenerate bit-for-bit anywhere. Gaps are
//! produced directly in milliseconds (the trace-file unit) and written
//! with Rust's shortest round-trip float formatting, so
//! generate → write → [`TraceReplay`](super::requests::TraceReplay) →
//! replay yields the *identical* gap sequence.

use std::io::Write;

use crate::util::rng::Xoshiro256ss;
use crate::util::units::Duration;

/// Smallest gap any generator emits (ms) — arrivals cannot land inside
/// the previous item's data-offload tail (mirrors
/// `ArrivalSpec::DEFAULT_POISSON_MIN_GAP_MS`).
pub const MIN_GAP_MS: f64 = 0.05;

/// The bundled workload shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TraceKind {
    /// Request bursts separated by long silences.
    BurstyIot,
    /// Poisson arrivals with a sinusoidal day/night rate.
    DiurnalPoisson,
    /// Two-state Markov-modulated Poisson process (active/quiet).
    OnOffMmpp,
}

impl TraceKind {
    /// Every bundled shape, in corpus order.
    pub const ALL: [TraceKind; 3] = [
        TraceKind::BurstyIot,
        TraceKind::DiurnalPoisson,
        TraceKind::OnOffMmpp,
    ];

    /// Parse a CLI/config trace-kind name.
    pub fn parse(s: &str) -> Option<TraceKind> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "bursty-iot" | "bursty" | "iot" => Some(TraceKind::BurstyIot),
            "diurnal-poisson" | "diurnal" => Some(TraceKind::DiurnalPoisson),
            "onoff-mmpp" | "mmpp" | "on-off-mmpp" => Some(TraceKind::OnOffMmpp),
            _ => None,
        }
    }

    /// Canonical name (file headers, CLI).
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::BurstyIot => "bursty-iot",
            TraceKind::DiurnalPoisson => "diurnal-poisson",
            TraceKind::OnOffMmpp => "onoff-mmpp",
        }
    }

    /// One-line description for help text and file headers.
    pub fn description(&self) -> &'static str {
        match self {
            TraceKind::BurstyIot => "request bursts separated by long silences",
            TraceKind::DiurnalPoisson => "Poisson arrivals with a sinusoidal day/night rate",
            TraceKind::OnOffMmpp => "two-state Markov-modulated Poisson (active/quiet)",
        }
    }
}

impl std::fmt::Display for TraceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Generate `gaps` inter-arrival gaps (in ms) around the nominal
/// `period_ms`, deterministically from `seed`.
pub fn generate(kind: TraceKind, gaps: usize, period_ms: f64, seed: u64) -> Vec<f64> {
    assert!(
        period_ms.is_finite() && period_ms > 0.0,
        "nominal period must be positive"
    );
    let mut rng = Xoshiro256ss::new(seed);
    let mut out = Vec::with_capacity(gaps);
    match kind {
        TraceKind::BurstyIot => {
            // bursts of 2–6 sub-period gaps, then a silence that sits
            // beyond every idle mode's crossover at the 40 ms nominal
            while out.len() < gaps {
                for _ in 0..rng.range_inclusive(2, 6) {
                    if out.len() < gaps {
                        out.push(period_ms * rng.uniform(0.2, 0.6));
                    }
                }
                if out.len() < gaps {
                    out.push(period_ms * rng.uniform(13.0, 20.0));
                }
            }
        }
        TraceKind::DiurnalPoisson => {
            // one "day" per 96 gaps; amplitude 0.8 swings the mean gap
            // between 0.2× and 1.8× the nominal
            const CYCLE: f64 = 96.0;
            const AMPLITUDE: f64 = 0.8;
            for i in 0..gaps {
                let phase = 2.0 * std::f64::consts::PI * (i as f64) / CYCLE;
                let mean = period_ms * (1.0 + AMPLITUDE * phase.sin());
                out.push(rng.exponential(mean.max(MIN_GAP_MS)).max(MIN_GAP_MS));
            }
        }
        TraceKind::OnOffMmpp => {
            // ON: dense arrivals at 0.4× the nominal; OFF: sparse at 8×.
            // Per-gap state persistence 0.9 (ON) / 0.7 (OFF).
            let mut on = true;
            for _ in 0..gaps {
                let mean = if on { 0.4 * period_ms } else { 8.0 * period_ms };
                out.push(rng.exponential(mean).max(MIN_GAP_MS));
                let stay = if on { 0.9 } else { 0.7 };
                if !rng.bernoulli(stay) {
                    on = !on;
                }
            }
        }
    }
    out
}

/// Convenience: the generated gaps as [`Duration`]s, quantized exactly
/// like a written-then-replayed trace file (`Duration::from_millis` on
/// the emitted ms values), so in-memory replay matches file replay.
pub fn generate_durations(
    kind: TraceKind,
    gaps: usize,
    period_ms: f64,
    seed: u64,
) -> Vec<Duration> {
    generate(kind, gaps, period_ms, seed)
        .into_iter()
        .map(Duration::from_millis)
        .collect()
}

/// Render a trace as the `workloads/` file format: a provenance comment
/// (including the exact regeneration command), the `gap_ms` header, one
/// gap per line in shortest round-trip float formatting.
pub fn render(kind: TraceKind, gaps: &[f64], period_ms: f64, seed: u64) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "# idlewait gap trace: kind={} gaps={} period_ms={} seed={}\n",
        kind.name(),
        gaps.len(),
        period_ms,
        seed
    ));
    out.push_str(&format!("# {}\n", kind.description()));
    out.push_str(&format!(
        "# regenerate: repro gen-trace --kind {} --gaps {} --period {} --seed {}\n",
        kind.name(),
        gaps.len(),
        period_ms,
        seed
    ));
    out.push_str("gap_ms\n");
    for g in gaps {
        out.push_str(&format!("{g}\n"));
    }
    out
}

/// Generate and write a trace file; returns the gaps written. IO errors
/// name the offending path (e.g. an unwritable `--out` directory) so
/// `repro gen-trace` failures are locatable without strace archaeology.
pub fn write_file(
    path: impl AsRef<std::path::Path>,
    kind: TraceKind,
    gaps: usize,
    period_ms: f64,
    seed: u64,
) -> std::io::Result<Vec<f64>> {
    let path = path.as_ref();
    let with_path = |e: std::io::Error| {
        std::io::Error::new(
            e.kind(),
            format!("writing trace file {}: {e}", path.display()),
        )
    };
    let values = generate(kind, gaps, period_ms, seed);
    let mut file = std::fs::File::create(path).map_err(with_path)?;
    file.write_all(render(kind, &values, period_ms, seed).as_bytes())
        .map_err(with_path)?;
    Ok(values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::requests::TraceReplay;

    #[test]
    fn generators_are_deterministic_and_positive() {
        for kind in TraceKind::ALL {
            let a = generate(kind, 128, 40.0, 7);
            let b = generate(kind, 128, 40.0, 7);
            assert_eq!(a, b, "{kind}: same seed must reproduce bit-for-bit");
            assert_eq!(a.len(), 128, "{kind}");
            assert!(a.iter().all(|&g| g.is_finite() && g >= MIN_GAP_MS), "{kind}");
            let c = generate(kind, 128, 40.0, 8);
            assert_ne!(a, c, "{kind}: different seeds must differ");
        }
    }

    #[test]
    fn bursty_iot_mixes_short_and_long_gaps() {
        let gaps = generate(TraceKind::BurstyIot, 256, 40.0, 1);
        // intra-burst gaps sit at 0.2–0.6× the period, silences at 13–20×
        assert!(gaps.iter().any(|&g| g < 40.0 * 0.6 + 1e-9));
        assert!(gaps.iter().any(|&g| g > 40.0 * 13.0 - 1e-9));
        assert!(gaps.iter().all(|&g| g <= 40.0 * 20.0));
    }

    #[test]
    fn diurnal_mean_tracks_the_nominal() {
        let gaps = generate(TraceKind::DiurnalPoisson, 9_600, 40.0, 2);
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        // the sinusoid integrates out over whole cycles
        assert!((mean - 40.0).abs() < 2.0, "mean={mean}");
    }

    #[test]
    fn mmpp_has_two_visible_modes() {
        let gaps = generate(TraceKind::OnOffMmpp, 512, 40.0, 3);
        let dense = gaps.iter().filter(|&&g| g < 40.0).count();
        let sparse = gaps.iter().filter(|&&g| g > 160.0).count();
        assert!(dense > 100, "dense={dense}");
        assert!(sparse > 30, "sparse={sparse}");
    }

    #[test]
    fn kind_names_round_trip() {
        for kind in TraceKind::ALL {
            assert_eq!(TraceKind::parse(kind.name()), Some(kind));
            assert!(!kind.description().is_empty());
        }
        assert_eq!(TraceKind::parse("MMPP"), Some(TraceKind::OnOffMmpp));
        assert_eq!(TraceKind::parse("warp"), None);
    }

    /// The golden round-trip: generate → render to a file → replay the
    /// file → the identical gap sequence (same f64 bits), because the
    /// shortest round-trip float formatting is lossless.
    #[test]
    fn file_round_trip_is_exact() {
        let dir = std::env::temp_dir().join("idlewait_tracegen_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        for kind in TraceKind::ALL {
            let path = dir.join(format!("{}.csv", kind.name()));
            let written = write_file(&path, kind, 64, 40.0, 11).unwrap();
            let mut replay = TraceReplay::from_file(&path).unwrap();
            assert_eq!(replay.len(), 64);
            let expect = generate_durations(kind, 64, 40.0, 11);
            for (i, want) in expect.iter().enumerate() {
                assert_eq!(replay.next_gap(), *want, "{kind} gap {i}");
            }
            assert_eq!(written, generate(kind, 64, 40.0, 11));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[should_panic(expected = "nominal period must be positive")]
    fn zero_period_rejected() {
        generate(TraceKind::BurstyIot, 8, 0.0, 0);
    }

    #[test]
    fn write_file_errors_name_the_path() {
        let err = write_file("/nonexistent/dir/trace.csv", TraceKind::BurstyIot, 8, 40.0, 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("/nonexistent/dir/trace.csv"), "{err}");
    }
}
