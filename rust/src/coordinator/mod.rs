//! The duty-cycle serving coordinator (L3): request generation, metrics,
//! and the serving loop that executes real inference via the PJRT runtime
//! while accounting energy on the simulated board.

pub mod fleet;
pub mod metrics;
pub mod requests;
pub mod multi_sim;
pub mod scheduler;
pub mod server;
pub mod serving;
pub mod tracegen;

pub use fleet::{
    run_fleet, survey_device, FleetOptions, FleetReport, FleetRouteReport, FleetStepReport,
    Placement,
};
pub use metrics::Metrics;
pub use requests::{ArrivalProcess, Periodic, Poisson, TraceReplay};
pub use tracegen::TraceKind;
pub use server::{serve, serve_with, Compute, SensorSource, ServeReport, ServerConfig, Served};
pub use serving::{
    poisson_sources, serve_multi, MultiServeOptions, MultiServeReport, ServeSource,
};
