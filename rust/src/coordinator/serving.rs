//! The async multi-client serving coordinator: N concurrent arrival
//! sources, one FPGA, one clock.
//!
//! This is the serving-side counterpart of the event-driven
//! multi-accelerator simulation: requests from several client sources
//! (each tagged with an accelerator slot and a deadline slack) merge
//! into one [`Engine`](crate::sim::Engine) event stream, pass a bounded
//! admission queue, get ordered by the [`MultiAccelScheduler`] within
//! its batching window, and execute on the shared [`ReplayCore`] energy
//! ledger. Queueing delay, reconfiguration switches and gap-policy
//! decisions therefore all live on *one* clock: the scheduler's
//! deadline projections are re-anchored to the ledger time at every
//! dispatch ([`MultiAccelScheduler::next_at`]), so its private
//! projection can never drift from the energy accounting.
//!
//! Between servicings the gap policy plans inactivity online, wrapped
//! in [`BurstHold`]: while the admission queue is non-empty the fabric
//! never powers off (the next dispatch is imminent), which keeps
//! aggressive policies like On-Off from thrashing under bursts.

use std::sync::Arc;

use crate::config::loader::SimConfig;
use crate::config::schema::{ArrivalSpec, FpgaModel, PolicyParams, PolicySpec};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::requests::{ArrivalProcess, Poisson};
use crate::coordinator::scheduler::{
    Dispatch, MultiAccelScheduler, Policy as SchedPolicy, SlotRequest,
};
use crate::device::bitstream::Bitstream;
use crate::device::board::BoardError;
use crate::device::rails::PowerSaving;
use crate::energy::analytical::Analytical;
use crate::runner::grid::derive_seed;
use crate::sim::{Ctx, Engine, SimTime};
use crate::strategies::replay::{ReplayCore, SlotId};
use crate::strategies::strategy::{build_with, BurstHold, GapContext, GapPlan, Policy as GapPolicy};
use crate::util::units::Duration;

/// Events of the multi-client serving loop.
#[derive(Debug)]
enum Event {
    /// A client request arrives (admission-checked against the queue).
    Arrival {
        id: u64,
        slot: usize,
        deadline: Duration,
    },
    /// The fabric becomes free; pull the next scheduled request.
    FabricFree,
}

/// One client source feeding the coordinator: a materialized
/// inter-arrival gap column, the accelerator slot its requests target,
/// and the deadline slack every request is granted. Request `k` arrives
/// at the cumulative sum of `gaps[..=k]`, so a leading
/// [`Duration::ZERO`] gap places the first request at time zero.
#[derive(Debug, Clone)]
pub struct ServeSource {
    /// Accelerator slot the source's requests target.
    pub slot: usize,
    /// Materialized inter-arrival gaps (shareable across runs).
    pub gaps: Arc<[Duration]>,
    /// Deadline slack: a request arriving at `t` must finish by `t + slack`.
    pub slack: Duration,
}

/// Knobs of one multi-client serving run (the validated `serving`
/// config block plus the CLI flags resolve to exactly this).
#[derive(Debug, Clone)]
pub struct MultiServeOptions {
    /// Scheduling policy ordering the admission queue.
    pub sched: SchedPolicy,
    /// Admission bound: arrivals beyond this many queued requests drop.
    pub max_queue: usize,
    /// Gap policy planning inactivity between servicings (always wrapped
    /// in [`BurstHold`], so a non-empty queue pins the fabric on).
    pub gap_policy: PolicySpec,
    /// The gap policy's tunables.
    pub params: PolicyParams,
}

/// Outcome of a multi-client serving run.
#[derive(Debug, Clone)]
pub struct MultiServeReport {
    /// SLA + energy metrics (queue waits, sojourns, misses, drops, ledger).
    pub metrics: Metrics,
    /// Requests served to completion.
    pub served: u64,
    /// FPGA configurations performed (image switches + post-off reloads).
    pub reconfigurations: u64,
    /// Requests the scheduler served out of arrival order.
    pub reordered: u64,
    /// True if the energy budget ran out before the arrival stream did.
    pub budget_exhausted: bool,
}

struct State {
    core: ReplayCore,
    /// Interned slot of the active image (the recovering phase wrapper
    /// needs it to reconfigure after a mid-item brownout).
    slot: SlotId,
    scheduler: MultiAccelScheduler,
    gap_policy: Box<dyn GapPolicy>,
    metrics: Metrics,
    max_queue: usize,
    /// Plan governing the current inactivity window.
    current_plan: GapPlan,
    /// When the current plan took effect (for `IdleThenOff` timers).
    plan_started: SimTime,
    last_completion: SimTime,
    busy_until: SimTime,
    served: u64,
    /// Last time the core's ledger was advanced (for idle accounting).
    ledger_at: SimTime,
    dead: bool,
}

impl State {
    /// Advance the energy ledger to `now`, spending the inactivity per
    /// the current gap plan — including a mid-gap `IdleThenOff` cutoff.
    fn idle_until(&mut self, now: SimTime) {
        if now <= self.ledger_at {
            return;
        }
        let result = match self.current_plan {
            GapPlan::Idle(saving) => self.core.elapse(saving, now.since(self.ledger_at)),
            GapPlan::PowerOff => self
                .core
                .elapse(PowerSaving::BASELINE, now.since(self.ledger_at)),
            GapPlan::IdleThenOff { saving, timeout } => {
                let cutoff = self.plan_started + timeout;
                if self.core.is_ready() && now > cutoff {
                    let mut r = Ok(());
                    if cutoff > self.ledger_at {
                        r = self.core.elapse(saving, cutoff.since(self.ledger_at));
                    }
                    if r.is_ok() {
                        self.core.power_off();
                        let from = self.ledger_at.max(cutoff);
                        r = self.core.elapse(saving, now.since(from));
                    }
                    r
                } else {
                    self.core.elapse(saving, now.since(self.ledger_at))
                }
            }
        };
        if result.is_err() {
            self.dead = true;
        }
        self.ledger_at = now;
    }

    /// A dispatch exhausted its configuration retries mid-recovery:
    /// graceful degradation. The request is dropped (counted as
    /// degraded), the fabric stays off, and the fabric-busy window
    /// covers the stuck time (failed partial attempts + backoffs, read
    /// off the core's recovery ledger) so the serving clock and the
    /// board clock stay aligned. The coordinator then simply moves on
    /// to the next queued request.
    fn degrade(&mut self, now: SimTime, recovery_before: Duration) -> SimTime {
        self.metrics.record_degraded();
        let stuck = self.core.recovery().recovery_time - recovery_before;
        let finish = now + stuck;
        self.ledger_at = finish;
        finish
    }

    /// Serve one dispatch starting at `now`; returns the completion
    /// time. With a fault stream installed the configure and phase steps
    /// route through the recovering wrappers (identical calls when no
    /// fault is drawn); a dispatch whose retries are exhausted degrades
    /// via [`State::degrade`] instead of killing the run.
    fn serve(&mut self, now: SimTime, dispatch: &Dispatch) -> SimTime {
        self.idle_until(now);
        // feed the realized inactivity back to the policy that planned it
        if self.served > 0 && now > self.last_completion {
            self.gap_policy.observe(now.since(self.last_completion));
        }
        let mut finish = now;
        let recovery_before = self.core.recovery().recovery_time;
        if dispatch.reconfigure {
            // a switch means loading a different image: power-cycle path
            match self.core.power_cycle_configure_recovering("lstm") {
                Ok(rec) => finish += rec.total_time,
                Err(BoardError::RetriesExhausted(_)) => {
                    return self.degrade(now, recovery_before);
                }
                Err(_) => {
                    self.dead = true;
                    return now;
                }
            }
        } else if !self.core.is_ready() {
            // the gap policy cut power; pay the reconfiguration preamble
            match self.core.configure_recovering("lstm") {
                Ok(rec) => finish += rec.total_time,
                Err(BoardError::RetriesExhausted(_)) => {
                    return self.degrade(now, recovery_before);
                }
                Err(_) => {
                    self.dead = true;
                    return now;
                }
            }
        }
        match self.core.run_phases_recovering(self.slot) {
            Ok(ph) => finish += ph.latency,
            Err(BoardError::RetriesExhausted(_)) => {
                return self.degrade(now, recovery_before);
            }
            Err(_) => {
                self.dead = true;
                return now;
            }
        }
        self.ledger_at = finish;
        self.served += 1;
        let arrival = SimTime::ZERO + dispatch.request.arrival;
        self.metrics.record_sojourn(
            now.since(arrival),
            finish.since(arrival),
            finish.as_duration() > dispatch.request.deadline,
        );
        // plan the coming inactivity at completion time, gap unseen; the
        // queue depth lets BurstHold pin the fabric on under backlog
        let ctx = GapContext {
            items_done: self.served,
            now: finish.as_duration(),
            queued: self.scheduler.pending() as u64,
        };
        self.current_plan = self.gap_policy.plan_gap(&ctx);
        if self.current_plan == GapPlan::PowerOff {
            self.core.power_off();
        }
        self.plan_started = finish;
        self.last_completion = finish;
        finish
    }
}

/// Run the multi-client serving coordinator over the given sources.
///
/// Deterministic: the sources fully describe the arrival stream
/// (same-time arrivals tie-break in source order), and every decision —
/// admission, scheduling, gap planning, ledger accounting — runs on the
/// single event-engine clock.
pub fn serve_multi(
    config: &SimConfig,
    opts: &MultiServeOptions,
    sources: &[ServeSource],
) -> MultiServeReport {
    let mut core = ReplayCore::from_config(config);
    // program a second accelerator image (same geometry, distinct slot)
    core.board.flash.program(
        "lstm_b",
        Bitstream::synthesize(
            FpgaModel::Xc7s15,
            crate::device::calib::design_occupied_frames(FpgaModel::Xc7s15),
            0xB0B,
        ),
        config.platform.spi.compressed,
    );
    core.rebuild_table();
    let slot = core
        .slot_id("lstm")
        .expect("the serving platform programs the lstm image");
    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let gap_policy: Box<dyn GapPolicy> = Box::new(BurstHold::new(
        build_with(opts.gap_policy, &model, &opts.params),
        opts.params.saving,
    ));

    // Merge the sources into one arrival stream: cumulative times per
    // source, then a stable sort so same-time arrivals keep source order.
    let mut arrivals: Vec<(Duration, usize, Duration)> = Vec::new();
    for src in sources {
        let mut at = Duration::ZERO;
        for &gap in src.gaps.iter() {
            at += gap;
            arrivals.push((at, src.slot, at + src.slack));
        }
    }
    arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("arrival times are finite"));

    let mut engine: Engine<Event> = Engine::new();
    for (id, &(at, slot, deadline)) in arrivals.iter().enumerate() {
        engine.schedule_at(
            SimTime::ZERO + at,
            Event::Arrival {
                id: id as u64,
                slot,
                deadline,
            },
        );
    }

    let mut state = State {
        scheduler: MultiAccelScheduler::new(
            opts.sched,
            config.item.configuration.time,
            config.item.latency_without_config(),
        ),
        core,
        slot,
        gap_policy,
        metrics: Metrics::new(),
        max_queue: opts.max_queue,
        current_plan: GapPlan::Idle(PowerSaving::BASELINE),
        plan_started: SimTime::ZERO,
        last_completion: SimTime::ZERO,
        busy_until: SimTime::ZERO,
        served: 0,
        ledger_at: SimTime::ZERO,
        dead: false,
    };

    let handler = |ctx: &mut Ctx<Event>, state: &mut State, event: Event| {
        if state.dead {
            ctx.stop();
            return;
        }
        match event {
            Event::Arrival { id, slot, deadline } => {
                if state.scheduler.pending() >= state.max_queue {
                    state.metrics.record_drop();
                    return;
                }
                state.scheduler.submit(SlotRequest {
                    id,
                    slot,
                    arrival: ctx.now().as_duration(),
                    deadline,
                });
                if ctx.now() >= state.busy_until {
                    ctx.schedule_at(ctx.now(), Event::FabricFree);
                }
            }
            Event::FabricFree => {
                if ctx.now() < state.busy_until {
                    return; // stale wake-up
                }
                // anchor the scheduler's deadline clock to the ledger
                if let Some(dispatch) = state.scheduler.next_at(ctx.now().as_duration()) {
                    let finish = state.serve(ctx.now(), &dispatch);
                    state.busy_until = finish;
                    ctx.schedule_at(finish, Event::FabricFree);
                }
            }
        }
    };

    let stats = engine.run(&mut state, u64::MAX, handler);

    let recovery = state.core.recovery();
    let mut metrics = state.metrics;
    metrics.sim_energy = state.core.board.fpga_energy;
    metrics.sim_elapsed = stats.end_time.as_duration();
    // fold the core's cumulative fault ledger in once at the end (it
    // also covers the partial attempts of dispatches that gave up)
    metrics.record_recovery(recovery.retries, recovery.recovery_energy, recovery.recovery_time);
    MultiServeReport {
        metrics,
        served: state.served,
        reconfigurations: state.core.board.fpga.configurations,
        reordered: state.scheduler.stats.reordered,
        budget_exhausted: state.dead,
    }
}

/// Build `n` Poisson client sources with the given per-source mean
/// inter-arrival gap. Sources alternate between the two accelerator
/// slots; each gets an independent derived RNG stream, so the merged
/// arrival pattern is reproducible from `seed` alone.
pub fn poisson_sources(
    n: usize,
    requests_per_source: usize,
    mean_gap: Duration,
    slack: Duration,
    seed: u64,
) -> Vec<ServeSource> {
    (0..n)
        .map(|i| {
            let mut p = Poisson::new(
                mean_gap,
                Duration::from_millis(ArrivalSpec::DEFAULT_POISSON_MIN_GAP_MS),
                derive_seed(seed, i as u64),
            );
            let gaps: Vec<Duration> = (0..requests_per_source).map(|_| p.next_gap()).collect();
            ServeSource {
                slot: i % 2,
                gaps: gaps.into(),
                slack,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::util::units::Energy;

    fn opts(sched: SchedPolicy) -> MultiServeOptions {
        MultiServeOptions {
            sched,
            max_queue: 64,
            gap_policy: PolicySpec::IdleWaitingM12,
            params: PolicyParams::default(),
        }
    }

    /// `ticks` arrivals at 0, period, 2·period, … on one slot.
    fn periodic_source(slot: usize, ticks: usize, period_ms: f64, slack_ms: f64) -> ServeSource {
        let mut gaps = vec![Duration::ZERO];
        gaps.extend((1..ticks).map(|_| Duration::from_millis(period_ms)));
        ServeSource {
            slot,
            gaps: gaps.into(),
            slack: Duration::from_millis(slack_ms),
        }
    }

    /// The issue's acceptance schedule: two sources on alternating slots,
    /// same ticks. Fifo pays a switch per request (20); batching serves
    /// the in-fabric slot first at every tick (2 cold configs at t=0,
    /// then exactly one switch per tick → 11). Both meet every deadline,
    /// and the ledger matches the hand-computed energy of that schedule.
    #[test]
    fn alternating_slots_match_the_hand_computed_schedule() {
        let cfg = paper_default();
        let sources = [
            periodic_source(0, 10, 80.0, 100.0),
            periodic_source(1, 10, 80.0, 100.0),
        ];
        let fifo = serve_multi(&cfg, &opts(SchedPolicy::Fifo), &sources);
        let batched = serve_multi(
            &cfg,
            &opts(SchedPolicy::BatchBySlot { window: 8 }),
            &sources,
        );
        assert_eq!(fifo.served, 20);
        assert_eq!(batched.served, 20);
        assert_eq!(fifo.reconfigurations, 20);
        assert_eq!(batched.reconfigurations, 11);
        // equal deadline-miss rate (zero), yet batching wins on energy
        assert_eq!(fifo.metrics.deadline_misses, 0);
        assert_eq!(batched.metrics.deadline_misses, 0);
        assert_eq!(fifo.metrics.dropped, 0);
        assert!(batched.metrics.sim_energy < fifo.metrics.sim_energy);
        assert!(batched.reordered > 0);
        // ledger vs the hand-computed batch schedule: configs + items +
        // M1+2 idle over the remaining time, all on one clock
        for r in [&fifo, &batched] {
            let configs = r.reconfigurations as f64;
            let items = r.served as f64;
            let busy_ms = configs * cfg.item.configuration.time.millis()
                + items * cfg.item.latency_without_config().millis();
            let idle_ms = r.metrics.sim_elapsed.millis() - busy_ms;
            let expected_mj = configs * 11.98 + items * 0.0065 + 0.024 * idle_ms;
            assert!(
                (r.metrics.sim_energy.millijoules() - expected_mj).abs() / expected_mj < 0.02,
                "{} vs hand-computed {}",
                r.metrics.sim_energy.millijoules(),
                expected_mj
            );
        }
        // queue waits were recorded on the simulated clock
        assert_eq!(fifo.metrics.queue_wait_summary().unwrap().count, 20);
    }

    #[test]
    fn admission_bound_drops_the_overflow() {
        let cfg = paper_default();
        let sources = [ServeSource {
            slot: 0,
            gaps: vec![Duration::ZERO; 6].into(),
            slack: Duration::from_millis(1000.0),
        }];
        let r = serve_multi(
            &cfg,
            &MultiServeOptions {
                max_queue: 2,
                ..opts(SchedPolicy::Fifo)
            },
            &sources,
        );
        assert_eq!(r.served, 2);
        assert_eq!(r.metrics.dropped, 4);
        assert!((r.metrics.drop_rate() - 4.0 / 6.0).abs() < 1e-12);
        assert!(!r.budget_exhausted);
    }

    #[test]
    fn budget_exhaustion_stops_the_run() {
        let mut cfg = paper_default();
        cfg.workload.energy_budget = Energy::from_millijoules(30.0);
        let sources = [
            periodic_source(0, 50, 80.0, 100.0),
            periodic_source(1, 50, 80.0, 100.0),
        ];
        let r = serve_multi(&cfg, &opts(SchedPolicy::Fifo), &sources);
        assert!(r.budget_exhausted);
        assert!(r.served < 100, "served {}", r.served);
    }

    #[test]
    fn burst_hold_keeps_onoff_from_thrashing_within_a_tick() {
        // Two slot-0 sources on the same ticks: after the first request
        // of a tick the queue is non-empty, so the wrapped On-Off policy
        // idles instead of cutting power — one configuration per tick,
        // not one per request.
        let cfg = paper_default();
        let sources = [
            periodic_source(0, 8, 80.0, 1000.0),
            periodic_source(0, 8, 80.0, 1000.0),
        ];
        let r = serve_multi(
            &cfg,
            &MultiServeOptions {
                gap_policy: PolicySpec::OnOff,
                ..opts(SchedPolicy::Fifo)
            },
            &sources,
        );
        assert_eq!(r.served, 16);
        assert_eq!(r.reconfigurations, 8);
        // the second request of each tick queued behind a ~36 ms config
        let w = r.metrics.queue_wait_summary().unwrap();
        assert!(w.max > 30.0, "max queue wait {}", w.max);
    }

    #[test]
    fn identical_runs_are_byte_identical() {
        let cfg = paper_default();
        let sources = poisson_sources(
            4,
            50,
            Duration::from_millis(160.0),
            Duration::from_millis(160.0),
            7,
        );
        let a = serve_multi(&cfg, &opts(SchedPolicy::BatchBySlot { window: 8 }), &sources);
        let b = serve_multi(&cfg, &opts(SchedPolicy::BatchBySlot { window: 8 }), &sources);
        assert_eq!(a.metrics.render(), b.metrics.render());
        assert_eq!(a.metrics.sim_energy, b.metrics.sim_energy);
        assert_eq!(a.served, b.served);
        assert_eq!(a.reordered, b.reordered);
    }

    #[test]
    fn faulty_serving_degrades_gracefully_and_stays_deterministic() {
        let mut cfg = paper_default();
        cfg.faults.config_crc_rate = 0.35;
        cfg.faults.spi_corrupt_rate = 0.15;
        cfg.faults.brownout_infer_rate = 0.1;
        cfg.faults.retry_max = 2;
        let sources = [
            periodic_source(0, 10, 80.0, 1000.0),
            periodic_source(1, 10, 80.0, 1000.0),
        ];
        let r = serve_multi(&cfg, &opts(SchedPolicy::Fifo), &sources);
        // faults never kill the run — requests degrade, the rest serve
        assert!(!r.budget_exhausted);
        assert_eq!(r.served + r.metrics.degraded, 20);
        assert!(r.metrics.retries > 0, "rates this high must fault");
        assert!(r.metrics.recovery_energy.millijoules() > 0.0);
        assert!(r.metrics.availability() < 1.0);
        assert!(r.metrics.degraded_rate() <= 1.0);
        // the seeded fault stream makes the whole run reproducible
        let again = serve_multi(&cfg, &opts(SchedPolicy::Fifo), &sources);
        assert_eq!(r.served, again.served);
        assert_eq!(r.metrics.degraded, again.metrics.degraded);
        assert_eq!(r.metrics.render(), again.metrics.render());
    }

    #[test]
    fn poisson_sources_alternate_slots_and_derive_streams() {
        let srcs = poisson_sources(
            4,
            20,
            Duration::from_millis(100.0),
            Duration::from_millis(50.0),
            3,
        );
        assert_eq!(srcs.len(), 4);
        assert_eq!(
            srcs.iter().map(|s| s.slot).collect::<Vec<_>>(),
            vec![0, 1, 0, 1]
        );
        assert_eq!(srcs[0].gaps.len(), 20);
        // independent streams: the columns differ
        assert_ne!(srcs[0].gaps, srcs[1].gaps);
        assert_eq!(srcs[0].slack, Duration::from_millis(50.0));
    }
}
