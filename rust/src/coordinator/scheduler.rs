//! Multi-accelerator scheduling — the paper's stated out-of-scope case
//! ("The underlying assumption is that the same accelerator is
//! constantly (re)used for all inference requests. An analysis of
//! supporting different accelerators is outside the scope of this
//! work", §4.2) built out as a first-class coordinator feature.
//!
//! With several accelerators sharing one FPGA, Idle-Waiting only avoids
//! reconfiguration while consecutive requests target the *currently
//! loaded* accelerator; a switch always costs a full configuration
//! phase. The scheduler therefore decides *order*: within a small
//! reordering window (bounded by each request's deadline slack) it may
//! batch same-accelerator requests to amortize switches.
//!
//! Policies:
//! * [`Policy::Fifo`] — strict arrival order; switch whenever the next
//!   request's accelerator differs (the naive baseline).
//! * [`Policy::BatchBySlot`] — greedy same-slot batching inside the
//!   window; switches once per batch.

use std::collections::VecDeque;

use crate::util::units::{Duration, Energy};

/// A pending inference request for a named accelerator slot.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotRequest {
    /// Monotonic request id (arrival order).
    pub id: u64,
    /// Flash slot / accelerator identity.
    pub slot: usize,
    /// Arrival time offset (for latency accounting).
    pub arrival: Duration,
    /// Latest acceptable completion (arrival + deadline slack).
    pub deadline: Duration,
}

/// Scheduling policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Strict arrival order, whatever the slot cost.
    Fifo,
    /// Group same-slot requests within a lookahead window to amortize
    /// reconfigurations; bounded so no request starves.
    BatchBySlot {
        /// Maximum requests inspected for reordering.
        window: usize,
    },
}

/// One scheduling decision.
#[derive(Debug, Clone, PartialEq)]
pub struct Dispatch {
    /// The request being dispatched.
    pub request: SlotRequest,
    /// True if serving this request requires loading its accelerator.
    pub reconfigure: bool,
}

/// Outcome statistics for a scheduling run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedStats {
    /// Requests dispatched.
    pub dispatched: u64,
    /// Dispatches that required loading a different accelerator image.
    pub reconfigurations: u64,
    /// Dispatches served out of arrival order.
    pub reordered: u64,
    /// Dispatches whose queueing delay already exceeded the deadline.
    pub deadline_violations: u64,
}

/// The multi-accelerator scheduler.
#[derive(Debug)]
pub struct MultiAccelScheduler {
    policy: Policy,
    queue: VecDeque<SlotRequest>,
    /// Accelerator currently resident in the FPGA fabric (None = cold).
    loaded: Option<usize>,
    /// Configuration-phase duration (per switch).
    config_time: Duration,
    /// Per-item active latency (excluding configuration).
    item_latency: Duration,
    /// Aggregate scheduling counters.
    pub stats: SchedStats,
    /// Virtual clock for deadline accounting.
    now: Duration,
}

impl MultiAccelScheduler {
    /// A scheduler for the given policy and per-item timings.
    pub fn new(policy: Policy, config_time: Duration, item_latency: Duration) -> Self {
        MultiAccelScheduler {
            policy,
            queue: VecDeque::new(),
            loaded: None,
            config_time,
            item_latency,
            stats: SchedStats::default(),
            now: Duration::ZERO,
        }
    }

    /// The accelerator image currently configured, if any.
    pub fn loaded_slot(&self) -> Option<usize> {
        self.loaded
    }

    /// Requests waiting in the queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue a request.
    pub fn submit(&mut self, request: SlotRequest) {
        debug_assert!(request.deadline.secs() >= request.arrival.secs());
        self.queue.push_back(request);
    }

    /// [`next`](Self::next) with the scheduler clock re-anchored to the
    /// caller's clock first. The serving engine passes the `ReplayCore`
    /// ledger time here at every dispatch, so deadline accounting and
    /// energy accounting share one clock — the internal projection only
    /// bridges the decision itself, and drift can never accumulate.
    pub fn next_at(&mut self, now: Duration) -> Option<Dispatch> {
        self.now = self.now.max(now);
        self.next()
    }

    /// Pick the next request according to the policy. Returns `None` when
    /// the queue is empty.
    pub fn next(&mut self) -> Option<Dispatch> {
        if self.queue.is_empty() {
            return None;
        }
        let pick_index = match self.policy {
            Policy::Fifo => 0,
            Policy::BatchBySlot { window } => self.pick_batched(window),
        };
        let request = self.queue.remove(pick_index).expect("index in range");
        if pick_index != 0 {
            self.stats.reordered += 1;
        }
        let reconfigure = self.loaded != Some(request.slot);
        if reconfigure {
            self.loaded = Some(request.slot);
            self.stats.reconfigurations += 1;
            self.now += self.config_time;
        }
        self.now = self.now.max(request.arrival) + self.item_latency;
        if self.now > request.deadline {
            self.stats.deadline_violations += 1;
        }
        self.stats.dispatched += 1;
        Some(Dispatch {
            request,
            reconfigure,
        })
    }

    /// Greedy batching: prefer the earliest request using the loaded
    /// slot, but never jump past a request whose deadline would be
    /// violated by the extra wait (one item latency per skip, plus the
    /// eventual switch).
    fn pick_batched(&self, window: usize) -> usize {
        let Some(loaded) = self.loaded else {
            return 0; // cold fabric: any choice reconfigures; keep order
        };
        let horizon = window.min(self.queue.len());
        let mut candidate = None;
        for i in 0..horizon {
            if self.queue[i].slot == loaded {
                candidate = Some(i);
                break;
            }
        }
        let Some(i) = candidate else { return 0 };
        // a skipped request waits behind the *entire* same-slot batch the
        // scheduler will keep preferring within the window, not just the
        // i requests ahead of the candidate — bound the projection by the
        // full batch run-length, then veto the reorder if any skipped
        // request would blow its deadline
        let batch_len = (0..horizon).filter(|&k| self.queue[k].slot == loaded).count();
        let delay = self.item_latency * batch_len as f64 + self.config_time;
        for j in 0..i {
            let projected = self.now.max(self.queue[j].arrival) + delay + self.item_latency;
            if projected > self.queue[j].deadline {
                return 0;
            }
        }
        i
    }

    /// Energy attributable to reconfigurations so far.
    pub fn reconfiguration_energy(&self, per_config: Energy) -> Energy {
        per_config * self.stats.reconfigurations as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, slot: usize, arrival_ms: f64, slack_ms: f64) -> SlotRequest {
        SlotRequest {
            id,
            slot,
            arrival: Duration::from_millis(arrival_ms),
            deadline: Duration::from_millis(arrival_ms + slack_ms),
        }
    }

    fn scheduler(policy: Policy) -> MultiAccelScheduler {
        MultiAccelScheduler::new(
            policy,
            Duration::from_millis(36.15),
            Duration::from_millis(0.04),
        )
    }

    #[test]
    fn fifo_switches_on_every_alternation() {
        let mut s = scheduler(Policy::Fifo);
        for i in 0..10 {
            s.submit(req(i, (i % 2) as usize, i as f64 * 40.0, 1000.0));
        }
        let mut order = Vec::new();
        while let Some(d) = s.next() {
            order.push(d.request.id);
        }
        assert_eq!(order, (0..10).collect::<Vec<_>>());
        assert_eq!(s.stats.reconfigurations, 10); // A,B,A,B,... every one
        assert_eq!(s.stats.reordered, 0);
    }

    #[test]
    fn batching_amortizes_switches() {
        let mut s = scheduler(Policy::BatchBySlot { window: 8 });
        for i in 0..10 {
            s.submit(req(i, (i % 2) as usize, 0.0, 10_000.0));
        }
        let mut dispatched = Vec::new();
        while let Some(d) = s.next() {
            dispatched.push((d.request.slot, d.reconfigure));
        }
        assert_eq!(dispatched.len(), 10);
        // all slot-0 requests batch first, then all slot-1 → 2 configs
        assert_eq!(s.stats.reconfigurations, 2, "{dispatched:?}");
        assert!(s.stats.reordered > 0);
    }

    #[test]
    fn batching_respects_deadlines() {
        let mut s = scheduler(Policy::BatchBySlot { window: 8 });
        // load slot 0 first
        s.submit(req(0, 0, 0.0, 1000.0));
        assert!(s.next().unwrap().reconfigure);
        // a tight-deadline slot-1 request followed by slot-0 fillers:
        // skipping it (delay ≈ config 36.15 ms) would violate its 5 ms slack
        s.submit(req(1, 1, 40.0, 5.0));
        s.submit(req(2, 0, 41.0, 10_000.0));
        let d = s.next().unwrap();
        assert_eq!(d.request.id, 1, "tight deadline must not be skipped");
    }

    #[test]
    fn single_slot_never_reconfigures_after_first() {
        let mut s = scheduler(Policy::BatchBySlot { window: 4 });
        for i in 0..20 {
            s.submit(req(i, 0, i as f64 * 40.0, 1000.0));
        }
        while s.next().is_some() {}
        assert_eq!(s.stats.reconfigurations, 1);
        assert_eq!(s.stats.deadline_violations, 0);
    }

    #[test]
    fn reconfiguration_energy_accounting() {
        let mut s = scheduler(Policy::Fifo);
        for i in 0..4 {
            s.submit(req(i, i as usize % 2, 0.0, 10_000.0));
        }
        while s.next().is_some() {}
        let e = s.reconfiguration_energy(Energy::from_millijoules(11.85));
        assert!((e.millijoules() - 4.0 * 11.85).abs() < 1e-9);
    }

    #[test]
    fn empty_queue_returns_none() {
        let mut s = scheduler(Policy::Fifo);
        assert!(s.next().is_none());
        assert_eq!(s.stats.dispatched, 0);
    }

    #[test]
    fn deadline_violation_detected_under_fifo_thrash() {
        let mut s = scheduler(Policy::Fifo);
        // alternating slots with only 10 ms slack: each 36.15 ms switch
        // blows the deadline
        for i in 0..6 {
            s.submit(req(i, (i % 2) as usize, i as f64 * 1.0, 10.0));
        }
        while s.next().is_some() {}
        assert!(s.stats.deadline_violations > 0);
    }

    #[test]
    fn reorder_veto_accounts_for_the_full_batch_run_length() {
        // Regression: the veto used to project only `i` item latencies of
        // extra wait for a skipped request, but a skipped request waits
        // behind the *whole* same-slot batch inside the window. With a
        // 10 ms item latency, skipping one slot-1 request to serve a
        // 5-item slot-0 batch delays it by 5 items + the eventual switch
        // (≈ 86 ms), not 1 item + switch (≈ 46 ms) — the old projection
        // approved a reorder that blew the deadline it claimed to check.
        let mut s = MultiAccelScheduler::new(
            Policy::BatchBySlot { window: 8 },
            Duration::from_millis(36.15),
            Duration::from_millis(10.0),
        );
        // load slot 0; internal clock advances to 46.15 ms
        s.submit(req(0, 0, 0.0, 1000.0));
        assert!(s.next().unwrap().reconfigure);
        // one slot-1 request with 60 ms slack, then a 5-deep slot-0 batch
        s.submit(req(1, 1, 46.15, 60.0));
        for i in 2..7 {
            s.submit(req(i, 0, 46.15, 100_000.0));
        }
        let first = s.next().unwrap();
        assert_eq!(
            first.request.id, 1,
            "slot-1 request must not be skipped behind a 5-item batch"
        );
        while s.next().is_some() {}
        assert_eq!(s.stats.deadline_violations, 0);
    }

    #[test]
    fn next_at_anchors_the_clock_to_the_caller() {
        let mut s = scheduler(Policy::Fifo);
        s.submit(req(0, 0, 0.0, 1000.0));
        // the caller's (ledger) clock is already at 500 ms; the dispatch
        // projection must start there, not at the private zero
        let d = s.next_at(Duration::from_millis(500.0)).unwrap();
        assert_eq!(d.request.id, 0);
        // 500 + config 36.15 + item 0.04 < deadline 1000 → no violation
        assert_eq!(s.stats.deadline_violations, 0);
        // a second request with a deadline before the anchored clock
        // must now be counted as violated
        s.submit(req(1, 0, 0.0, 100.0));
        let _ = s.next_at(Duration::from_millis(500.0));
        assert_eq!(s.stats.deadline_violations, 1);
    }

    #[test]
    fn batching_beats_fifo_on_switch_count() {
        let run = |policy| {
            let mut s = scheduler(policy);
            let mut seq = 0u64;
            // bursty pattern: AABABBAB... seeded deterministic
            let mut rng = crate::util::rng::Xoshiro256ss::new(99);
            for i in 0..200 {
                let slot = if rng.bernoulli(0.5) { 0 } else { 1 };
                s.submit(req(seq, slot, i as f64 * 40.0, 40_000.0));
                seq += 1;
            }
            while s.next().is_some() {}
            s.stats.reconfigurations
        };
        let fifo = run(Policy::Fifo);
        let batched = run(Policy::BatchBySlot { window: 16 });
        assert!(
            batched < fifo,
            "batching ({batched}) must beat fifo ({fifo})"
        );
    }
}
