//! The duty-cycle serving loop — the end-to-end composition of all three
//! layers.
//!
//! Per request (paper Fig 1):
//! 1. The MCU (request source) wakes with a fresh sensor window.
//! 2. The coordinator drives the simulated board through the strategy's
//!    phases (configuration if needed, data loading, inference window,
//!    data offloading) — this is the *energy* ledger.
//! 3. The *computation* of the inference phase is real: the AOT-compiled
//!    LSTM HLO executes on the PJRT CPU client and its forecast is
//!    returned to the caller.
//!
//! Simulated time (duty-cycle energy accounting at Table 2 timings) and
//! host time (actual PJRT latency) are tracked separately: the host CPU
//! stands in for the FPGA fabric, so its latency is a functional check
//! (must fit the request period), not an energy input.

use anyhow::Result;

use crate::config::loader::SimConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::requests::ArrivalProcess;
use crate::runtime::inference::{LstmRuntime, Variant};
use crate::strategies::replay::ReplayCore;
use crate::strategies::strategy::{decide, GapContext, Policy};
use crate::util::units::Duration;

/// One served request's outcome.
#[derive(Debug, Clone, Copy)]
pub struct Served {
    /// Id of the request this output answers.
    pub request_id: u64,
    /// The LSTM forecast value.
    pub forecast: f32,
    /// Host-side inference latency.
    pub host_latency: Duration,
}

/// Configuration for a serving run.
pub struct ServerConfig<'a> {
    /// Platform/workload description the energy ledger runs on.
    pub sim: &'a SimConfig,
    /// LSTM variant to execute (f32 or int8).
    pub variant: Variant,
    /// Stop after this many requests (the budget still applies).
    pub max_requests: u64,
    /// Execute a gap plan after the final request too, charging n gaps
    /// for n requests. Off by default: the paper's Eq 2 charges exactly
    /// n−1 gaps (the service ends with the last request, not with an
    /// open-ended idle window).
    pub keep_alive: bool,
}

/// Outcome of a serving run.
pub struct ServeReport {
    /// Latency/deadline counters for the run.
    pub metrics: Metrics,
    /// Every forecast served, in order.
    pub served: Vec<Served>,
    /// FPGA configurations performed.
    pub configurations: u64,
    /// True if the run ended because the battery budget was exhausted.
    pub budget_exhausted: bool,
}

/// A rolling sensor-data source: synthesizes the next window per request
/// (the MCU "gathering data" between requests).
pub struct SensorSource {
    window: usize,
    channels: usize,
    t: f64,
    rng: crate::util::rng::Xoshiro256ss,
}

impl SensorSource {
    /// A deterministic synthetic sensor stream (window x channels).
    pub fn new(window: usize, channels: usize, seed: u64) -> SensorSource {
        SensorSource {
            window,
            channels,
            t: 0.0,
            rng: crate::util::rng::Xoshiro256ss::new(seed),
        }
    }

    /// Next (window × channels) row-major buffer: superposed sines plus
    /// noise, advancing in time — the synthetic stand-in for the paper's
    /// periodically-gathered sensor data.
    pub fn next_window(&mut self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.window * self.channels);
        for r in 0..self.window {
            let t = self.t + r as f64;
            for ch in 0..self.channels {
                let c = ch as f64;
                let base = (0.19 * t + 0.7 * c).sin() + 0.4 * (0.067 * t * (c + 1.0)).sin();
                let noise = 0.05 * self.rng.normal(0.0, 1.0);
                out.push((base + noise) as f32);
            }
        }
        self.t += self.window as f64;
        out
    }
}

/// One inference computation: consumes a sensor window, returns the
/// forecast value and the host-side latency. [`serve`] plugs in the PJRT
/// runtime; tests plug in a synthetic stand-in so the serving loop's
/// accounting is testable without compiled artifacts.
pub type Compute<'r> = dyn FnMut(&[f32]) -> Result<(f32, Duration)> + 'r;

/// Run the duty-cycle server: real inference, simulated energy.
pub fn serve(
    cfg: &ServerConfig<'_>,
    runtime: &LstmRuntime,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalProcess,
) -> Result<ServeReport> {
    let variant = cfg.variant;
    serve_with(
        cfg,
        runtime.window_shape(),
        &mut |window| {
            let result = runtime.forecast(window, variant)?;
            Ok((result.forecast, result.latency))
        },
        policy,
        arrivals,
    )
}

/// The serving loop behind [`serve`], generic over the compute step.
///
/// Deadline accounting follows the paper's per-request condition
/// T_latency < T_req: each request's deadline is the *realized* gap
/// before the next arrival, not the arrival process's mean. Energy
/// accounting follows Eq 2: n requests pay n−1 inter-request gaps —
/// the trailing gap is charged only with [`ServerConfig::keep_alive`].
/// The gap is drawn for every request either way, so the arrival
/// process's RNG stream is consumed identically in both modes.
pub fn serve_with(
    cfg: &ServerConfig<'_>,
    window_shape: (usize, usize),
    compute: &mut Compute<'_>,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalProcess,
) -> Result<ServeReport> {
    let sim = cfg.sim;
    // The same phase-replay core the simulations use: one accounting path.
    let mut core = ReplayCore::from_config(sim);
    let mut metrics = Metrics::new();
    let mut served = Vec::new();
    let (rows, cols) = window_shape;
    let mut sensor = SensorSource::new(rows, cols, sim.workload.seed ^ 0x5EED);
    let mut budget_exhausted = false;
    let mut config_time = sim.item.configuration.time;
    let item_latency = sim.item.latency_without_config();

    log::info!(
        "serving: policy={} arrivals={} variant={:?} max={}",
        policy.label(),
        arrivals.label(),
        cfg.variant,
        cfg.max_requests
    );

    for request_id in 0..cfg.max_requests {
        // 1. configure if needed (energy)
        if !core.is_ready() {
            match core.configure("lstm") {
                Ok(t) => config_time = t,
                Err(_) => {
                    budget_exhausted = true;
                    break;
                }
            }
        }
        // 2. energy for the active phases (Table 2 timings)
        if core.run_phases().is_err() {
            budget_exhausted = true;
            break;
        }
        // 3. real compute (PJRT in production, a stub under test)
        let window = sensor.next_window();
        let (forecast, host_latency) = compute(&window)?;
        // the realized gap until the next request IS this request's
        // deadline (T_latency < T_req, per request — not the mean)
        let gap = arrivals.next_gap();
        metrics.record_request(host_latency, gap);
        served.push(Served {
            request_id,
            forecast,
            host_latency,
        });

        // 4. gap handling per policy (shared gap-plan execution core).
        // The serving loop is offline in the same sense as the lifetime
        // DES (it draws the gap before spending it), so oracle policies
        // get clairvoyance via `decide`; online policies plan blind and
        // then observe the realized gap. Eq 2 charges n−1 gaps: the gap
        // after the final request is skipped unless keep-alive asks for
        // an open-ended idle window.
        if request_id + 1 == cfg.max_requests && !cfg.keep_alive {
            break;
        }
        let gap_ctx = GapContext {
            items_done: request_id + 1,
            now: core.board.now.as_duration(),
            queued: 0,
        };
        let plan = decide(policy, &gap_ctx, gap);
        if core.execute_plan(plan, gap, config_time, item_latency).is_err() {
            budget_exhausted = true;
            break;
        }
        policy.observe(gap);
    }

    metrics.sim_energy = core.board.fpga_energy;
    metrics.sim_elapsed = core.board.now.as_duration();
    Ok(ServeReport {
        metrics,
        served,
        configurations: core.board.fpga.configurations,
        budget_exhausted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::coordinator::requests::{Periodic, TraceReplay};
    use crate::strategies::strategy::{IdleWaiting, OnOff};

    fn runtime() -> Option<std::rc::Rc<LstmRuntime>> {
        let dir = crate::runtime::artifact::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(crate::runtime::pool::runtime(dir).unwrap())
    }

    #[test]
    fn serves_requests_with_real_inference() {
        let Some(rt) = runtime() else { return };
        let sim = paper_default();
        let cfg = ServerConfig {
            sim: &sim,
            variant: Variant::Forecast,
            max_requests: 25,
            keep_alive: false,
        };
        let mut arr = Periodic {
            period: Duration::from_millis(40.0),
        };
        let report = serve(&cfg, &rt, &mut IdleWaiting::baseline(), &mut arr).unwrap();
        assert_eq!(report.metrics.requests, 25);
        assert_eq!(report.configurations, 1);
        assert!(!report.budget_exhausted);
        // forecasts vary across windows and are finite
        let fs: Vec<f32> = report.served.iter().map(|s| s.forecast).collect();
        assert!(fs.iter().all(|f| f.is_finite()));
        assert!(fs.windows(2).any(|w| w[0] != w[1]));
        // energy ledger per Eq 2: init + 25 items + 24 inter-request gaps
        // (no trailing idle window after the final request)
        let e = report.metrics.sim_energy.millijoules();
        assert!((e - (11.98 + 25.0 * 0.0065 + 24.0 * 5.3666)).abs() < 0.5, "e={e}");
    }

    #[test]
    fn onoff_reconfigures_every_request() {
        let Some(rt) = runtime() else { return };
        let sim = paper_default();
        let cfg = ServerConfig {
            sim: &sim,
            variant: Variant::Forecast,
            max_requests: 10,
            keep_alive: false,
        };
        let mut arr = Periodic {
            period: Duration::from_millis(40.0),
        };
        let report = serve(&cfg, &rt, &mut OnOff, &mut arr).unwrap();
        assert_eq!(report.configurations, 10);
        assert!(report.metrics.sim_energy.millijoules() > 10.0 * 11.9);
    }

    #[test]
    fn int8_variant_serves() {
        let Some(rt) = runtime() else { return };
        let sim = paper_default();
        let cfg = ServerConfig {
            sim: &sim,
            variant: Variant::ForecastInt8,
            max_requests: 5,
            keep_alive: false,
        };
        let mut arr = Periodic {
            period: Duration::from_millis(40.0),
        };
        let report = serve(&cfg, &rt, &mut IdleWaiting::method12(), &mut arr).unwrap();
        assert_eq!(report.metrics.requests, 5);
    }

    /// A fixed-latency compute stand-in so the loop's accounting is
    /// testable without PJRT artifacts.
    fn stub(latency_ms: f64) -> impl FnMut(&[f32]) -> Result<(f32, Duration)> {
        move |_window| Ok((0.5, Duration::from_millis(latency_ms)))
    }

    #[test]
    fn eq2_charges_n_minus_one_gaps_by_default() {
        // Regression (Eq 2 off-by-one): the loop used to execute a gap
        // plan after the final request too, charging n idle gaps where
        // Eq 2 charges n−1.
        let sim = paper_default();
        let cfg = ServerConfig {
            sim: &sim,
            variant: Variant::Forecast,
            max_requests: 25,
            keep_alive: false,
        };
        let mut arr = Periodic {
            period: Duration::from_millis(40.0),
        };
        let report = serve_with(
            &cfg,
            (24, 6),
            &mut stub(1.0),
            &mut IdleWaiting::baseline(),
            &mut arr,
        )
        .unwrap();
        assert_eq!(report.metrics.requests, 25);
        // init + 25 items + 24 gaps idled at the 134.3 mW baseline
        let e = report.metrics.sim_energy.millijoules();
        let want = 11.98 + 25.0 * 0.0065 + 24.0 * 5.3666;
        assert!((e - want).abs() < 0.5, "e={e} want={want}");
    }

    #[test]
    fn keep_alive_charges_the_trailing_gap() {
        let sim = paper_default();
        let run = |keep_alive| {
            let cfg = ServerConfig {
                sim: &sim,
                variant: Variant::Forecast,
                max_requests: 25,
                keep_alive,
            };
            let mut arr = Periodic {
                period: Duration::from_millis(40.0),
            };
            serve_with(
                &cfg,
                (24, 6),
                &mut stub(1.0),
                &mut IdleWaiting::baseline(),
                &mut arr,
            )
            .unwrap()
        };
        let default = run(false).metrics.sim_energy.millijoules();
        let kept = run(true).metrics.sim_energy.millijoules();
        // exactly one extra 40 ms baseline idle gap (≈ 5.3666 mJ)
        assert!(
            ((kept - default) - 5.3666).abs() < 0.05,
            "kept={kept} default={default}"
        );
    }

    #[test]
    fn deadline_misses_count_against_the_realized_gap() {
        // Regression (deadline vs realized gap): misses used to be
        // counted against the arrival process's *mean* period. On a
        // bursty trace alternating 5 ms / 75 ms gaps (mean 40 ms) with a
        // fixed 10 ms host latency, the mean-based rule counts 0 misses;
        // the paper's per-request T_latency < T_req counts one miss per
        // 5 ms gap — half the requests.
        let sim = paper_default();
        let cfg = ServerConfig {
            sim: &sim,
            variant: Variant::Forecast,
            max_requests: 10,
            keep_alive: false,
        };
        let mut arr = TraceReplay::new(vec![
            Duration::from_millis(5.0),
            Duration::from_millis(75.0),
        ]);
        assert!((arr.mean().millis() - 40.0).abs() < 1e-9);
        let report = serve_with(
            &cfg,
            (24, 6),
            &mut stub(10.0),
            &mut IdleWaiting::baseline(),
            &mut arr,
        )
        .unwrap();
        assert_eq!(report.metrics.requests, 10);
        // every 5 ms realized gap is shorter than the 10 ms latency
        assert_eq!(report.metrics.deadline_misses, 5);
    }

    #[test]
    fn sensor_windows_advance() {
        let mut s = SensorSource::new(24, 6, 1);
        let a = s.next_window();
        let b = s.next_window();
        assert_eq!(a.len(), 144);
        assert_ne!(a, b);
    }
}
