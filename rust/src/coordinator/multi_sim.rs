//! Event-driven multi-accelerator workload simulation.
//!
//! Connects the [`Engine`](crate::sim::Engine) (discrete events), the
//! [`MultiAccelScheduler`] (the §4.2-extension scheduling layer) and the
//! shared [`ReplayCore`] (energy): requests for several accelerators
//! arrive as timed events, the scheduler picks service order within its
//! reordering window, and the core pays configuration/phase/idle energy
//! for every decision. This is the full-system version of the
//! closed-form multi-accel ablation — latency and energy emerge from the
//! event flow. The per-item energetics run through the same
//! [`ReplayCore`] as the single-accelerator lifetime simulation, so the
//! two runtimes cannot drift apart on accounting.
//!
//! The gap policy here is genuinely *online*: at each service completion
//! the [`Policy`](crate::strategies::strategy::Policy) plans the coming
//! inactivity without knowing when the fabric goes busy next (arrivals
//! are future events), and `IdleThenOff` timers are honoured mid-gap by
//! the ledger advance. Clairvoyant policies get no special treatment —
//! their blind `plan_gap` fallback is used, by construction.

use crate::config::loader::SimConfig;
use crate::config::schema::{FpgaModel, PolicyParams, PolicySpec};
use crate::coordinator::scheduler::{Dispatch, MultiAccelScheduler, Policy as SchedPolicy, SlotRequest};
use crate::device::bitstream::Bitstream;
use crate::device::rails::PowerSaving;
use crate::energy::analytical::Analytical;
use crate::sim::{Ctx, Engine, SimTime};
use crate::strategies::replay::ReplayCore;
use crate::strategies::strategy::{build_with, GapContext, GapPlan, Policy as GapPolicy};
use crate::util::rng::Xoshiro256ss;
use crate::util::stats::Welford;
use crate::util::units::{Duration, Energy};

/// Events of the multi-accelerator duty cycle.
#[derive(Debug)]
enum Event {
    /// A request for `slot` arrives.
    Arrival { id: u64, slot: usize },
    /// The fabric becomes free; pull the next scheduled request.
    FabricFree,
}

/// One accelerator's gap policy plus its tunables — the per-slot unit a
/// tuned heterogeneous fleet is described in. `repro tune --emit`
/// fragments load into exactly this shape
/// (via [`load_fragment`](crate::tuner::emit::load_fragment)).
#[derive(Debug, Clone, Copy)]
pub struct SlotPolicy {
    /// The gap policy for this accelerator.
    pub spec: PolicySpec,
    /// Its tunables (tuned per accelerator, or defaults).
    pub params: PolicyParams,
}

/// Per-run configuration.
#[derive(Debug, Clone)]
pub struct MultiSimConfig {
    /// Probability that a request targets accelerator B (slot 1).
    pub mix: f64,
    /// Total requests to generate.
    pub requests: u64,
    /// Requests arriving together per period tick (a sensor event fanning
    /// out to several model evaluations). `1` = the paper's duty cycle;
    /// >1 creates queue pressure, which is where scheduling matters.
    pub burst: u64,
    /// The scheduling policy ordering the queue.
    pub policy: SchedPolicy,
    /// Gap policy applied between servicings (built per run; decides
    /// online, without seeing when the next dispatch comes). The default
    /// for every slot without an override below.
    pub gap_policy: PolicySpec,
    /// Per-accelerator overrides: `slot_policies[i]` (when present and
    /// `Some`) replaces `gap_policy` + the config's `policy_params` for
    /// gaps planned after serving slot `i` — so a fleet can run, say, a
    /// tuned windowed-quantile on the bursty accelerator and a tuned
    /// timeout on the steady one. Empty (or all-`None`) = homogeneous
    /// fleet: one shared policy instance plans and observes every gap,
    /// bit-for-bit the pre-tuner behaviour even for stateful policies.
    pub slot_policies: Vec<Option<SlotPolicy>>,
    /// Arrival-mix RNG seed.
    pub seed: u64,
}

/// Outcome of a multi-accelerator run.
#[derive(Debug, Clone)]
pub struct MultiSimReport {
    /// Requests served to completion.
    pub served: u64,
    /// FPGA configurations performed (image switches + post-off reloads).
    pub reconfigurations: u64,
    /// Requests the scheduler served out of arrival order.
    pub reordered: u64,
    /// Exact FPGA-side energy drawn.
    pub energy: Energy,
    /// Mean arrival-to-completion latency.
    pub mean_latency: Duration,
    /// Fraction of requests served later than one period after arrival.
    pub p_late: f64,
    /// Final engine clock.
    pub sim_time: Duration,
}

struct State {
    core: ReplayCore,
    scheduler: MultiAccelScheduler,
    /// The fleet's gap policies: a single shared instance (homogeneous
    /// fleet — every gap, one history) or one per accelerator slot
    /// (heterogeneous — the gap after serving slot `s` is planned by
    /// `gap_policies[s]`, so each accelerator's policy learns from, and
    /// is tuned for, its own traffic). Slot indices clamp to the vector.
    gap_policies: Vec<Box<dyn GapPolicy>>,
    /// Which slot's policy planned the current gap (receives `observe`).
    gap_planned_by: usize,
    /// Plan governing the current inactivity window.
    current_plan: GapPlan,
    /// When the current plan took effect (for `IdleThenOff` timers).
    plan_started: SimTime,
    last_completion: SimTime,
    busy_until: SimTime,
    served: u64,
    late: u64,
    latency: Welford,
    period: Duration,
    /// Last time the core's ledger was advanced (for idle accounting).
    ledger_at: SimTime,
    dead: bool,
}

impl State {
    /// Advance the energy ledger to `now`, spending the inactivity per
    /// the current gap plan — including a mid-gap `IdleThenOff` cutoff.
    fn idle_until(&mut self, now: SimTime) {
        if now <= self.ledger_at {
            return;
        }
        let result = match self.current_plan {
            GapPlan::Idle(saving) => self.core.elapse(saving, now.since(self.ledger_at)),
            // the fabric was cut at plan time; elapse charges the
            // (paper-model) free off state
            GapPlan::PowerOff => self
                .core
                .elapse(PowerSaving::BASELINE, now.since(self.ledger_at)),
            GapPlan::IdleThenOff { saving, timeout } => {
                let cutoff = self.plan_started + timeout;
                if self.core.is_ready() && now > cutoff {
                    // idle up to the timer, cut power, then coast off
                    let mut r = Ok(());
                    if cutoff > self.ledger_at {
                        r = self.core.elapse(saving, cutoff.since(self.ledger_at));
                    }
                    if r.is_ok() {
                        self.core.power_off();
                        let from = self.ledger_at.max(cutoff);
                        r = self.core.elapse(saving, now.since(from));
                    }
                    r
                } else {
                    self.core.elapse(saving, now.since(self.ledger_at))
                }
            }
        };
        if result.is_err() {
            self.dead = true;
        }
        self.ledger_at = now;
    }

    /// Serve one dispatch starting at `now`; returns the completion time.
    fn serve(&mut self, now: SimTime, dispatch: &Dispatch) -> SimTime {
        self.idle_until(now);
        // feed the realized inactivity back to the policy that planned it
        if self.served > 0 && now > self.last_completion {
            let gap = now.since(self.last_completion);
            self.gap_policies[self.gap_planned_by].observe(gap);
        }
        let mut finish = now;
        if dispatch.reconfigure {
            // a switch means loading a different image: power-cycle path
            match self.core.power_cycle_configure("lstm") {
                Ok(t) => finish += t,
                Err(_) => {
                    self.dead = true;
                    return now;
                }
            }
        } else if !self.core.is_ready() {
            // the gap policy cut power; pay the reconfiguration preamble
            match self.core.configure("lstm") {
                Ok(t) => finish += t,
                Err(_) => {
                    self.dead = true;
                    return now;
                }
            }
        }
        match self.core.run_phases() {
            Ok(t) => finish += t,
            Err(_) => {
                self.dead = true;
                return now;
            }
        }
        self.ledger_at = finish;
        self.served += 1;
        let arrival = SimTime::ZERO + dispatch.request.arrival;
        self.latency.push(finish.since(arrival).millis());
        if finish.since(arrival) > self.period {
            self.late += 1;
        }
        // plan the coming inactivity at completion time, gap unseen; the
        // just-served slot's policy (and tunables) make the call
        let ctx = GapContext {
            items_done: self.served,
            now: finish.as_duration(),
            queued: self.scheduler.pending() as u64,
        };
        let slot = dispatch.request.slot.min(self.gap_policies.len() - 1);
        self.current_plan = self.gap_policies[slot].plan_gap(&ctx);
        self.gap_planned_by = slot;
        if self.current_plan == GapPlan::PowerOff {
            self.core.power_off();
        }
        self.plan_started = finish;
        self.last_completion = finish;
        finish
    }
}

/// Run the event-driven multi-accelerator simulation.
pub fn run(config: &SimConfig, ms: &MultiSimConfig) -> MultiSimReport {
    let period = config.workload.arrival.mean_period();
    let mut core = ReplayCore::from_config(config);
    // program a second accelerator image (same geometry, distinct slot)
    core.board.flash.program(
        "lstm_b",
        Bitstream::synthesize(
            FpgaModel::Xc7s15,
            crate::device::calib::design_occupied_frames(FpgaModel::Xc7s15),
            0xB0B,
        ),
        config.platform.spi.compressed,
    );
    // keep the precomputed gap-cost table in sync with the second slot
    core.rebuild_table();
    let model = Analytical::new(&config.item, config.workload.energy_budget);

    // With no overrides, ONE shared policy instance plans (and observes)
    // every gap — bit-for-bit the pre-tuner behaviour, which matters for
    // stateful policies (EMA, windowed-quantile) whose history would
    // otherwise be split across per-slot instances. With any override,
    // the fleet is heterogeneous: one instance per slot, each learning
    // from its own traffic.
    const SLOTS: usize = 2;
    let homogeneous = ms.slot_policies.iter().all(|s| s.is_none());
    let gap_policies: Vec<Box<dyn GapPolicy>> = if homogeneous {
        vec![build_with(ms.gap_policy, &model, &config.workload.params)]
    } else {
        (0..SLOTS)
            .map(|slot| {
                match ms.slot_policies.get(slot).copied().flatten() {
                    Some(sp) => build_with(sp.spec, &model, &sp.params),
                    None => build_with(ms.gap_policy, &model, &config.workload.params),
                }
            })
            .collect()
    };

    let mut state = State {
        scheduler: MultiAccelScheduler::new(
            ms.policy,
            config.item.configuration.time,
            config.item.latency_without_config(),
        ),
        core,
        gap_policies,
        gap_planned_by: 0,
        current_plan: GapPlan::Idle(PowerSaving::BASELINE),
        plan_started: SimTime::ZERO,
        last_completion: SimTime::ZERO,
        busy_until: SimTime::ZERO,
        served: 0,
        late: 0,
        latency: Welford::new(),
        period,
        ledger_at: SimTime::ZERO,
        dead: false,
    };

    let mut engine: Engine<Event> = Engine::new();
    let mut rng = Xoshiro256ss::new(ms.seed);
    let burst = ms.burst.max(1);
    for i in 0..ms.requests {
        let slot = if rng.bernoulli(ms.mix) { 1 } else { 0 };
        let tick = i / burst;
        engine.schedule_at(
            SimTime::ZERO + period * tick as f64,
            Event::Arrival { id: i, slot },
        );
    }

    let handler = |ctx: &mut Ctx<Event>, state: &mut State, event: Event| {
        if state.dead {
            ctx.stop();
            return;
        }
        match event {
            Event::Arrival { id, slot } => {
                let arrival = ctx.now().as_duration();
                state.scheduler.submit(SlotRequest {
                    id,
                    slot,
                    arrival,
                    deadline: arrival + state.period,
                });
                if ctx.now() >= state.busy_until {
                    ctx.schedule_at(ctx.now(), Event::FabricFree);
                }
            }
            Event::FabricFree => {
                if ctx.now() < state.busy_until {
                    return; // stale wake-up
                }
                if let Some(dispatch) = state.scheduler.next_at(ctx.now().as_duration()) {
                    let finish = state.serve(ctx.now(), &dispatch);
                    state.busy_until = finish;
                    ctx.schedule_at(finish, Event::FabricFree);
                }
            }
        }
    };

    let stats = engine.run(&mut state, u64::MAX, handler);

    MultiSimReport {
        served: state.served,
        reconfigurations: state.core.board.fpga.configurations,
        reordered: state.scheduler.stats.reordered,
        energy: state.core.board.fpga_energy,
        mean_latency: Duration::from_millis(if state.latency.count() > 0 {
            state.latency.mean()
        } else {
            0.0
        }),
        p_late: if state.served > 0 {
            state.late as f64 / state.served as f64
        } else {
            0.0
        },
        sim_time: stats.end_time.as_duration(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn base(mix: f64, policy: SchedPolicy) -> MultiSimConfig {
        MultiSimConfig {
            mix,
            requests: 500,
            burst: 1,
            policy,
            gap_policy: PolicySpec::IdleWaitingM12,
            slot_policies: Vec::new(),
            seed: 17,
        }
    }

    fn bursty(mix: f64, policy: SchedPolicy) -> MultiSimConfig {
        MultiSimConfig {
            burst: 4,
            ..base(mix, policy)
        }
    }

    #[test]
    fn single_slot_configures_once_and_serves_all() {
        let cfg = paper_default();
        let r = run(&cfg, &base(0.0, SchedPolicy::Fifo));
        assert_eq!(r.served, 500);
        assert_eq!(r.reconfigurations, 1);
        assert_eq!(r.p_late, 0.0);
        // energy ≈ init + 500 items + idle gaps at M12 24 mW
        let expected_mj = 11.98 + 500.0 * 0.0065 + 0.024 * (500.0 * 39.96);
        assert!(
            (r.energy.millijoules() - expected_mj).abs() / expected_mj < 0.02,
            "{} vs {}",
            r.energy.millijoules(),
            expected_mj
        );
    }

    #[test]
    fn mixed_slots_cost_switches_under_fifo() {
        let cfg = paper_default();
        let r = run(&cfg, &base(0.5, SchedPolicy::Fifo));
        assert_eq!(r.served, 500);
        assert!(r.reconfigurations > 100, "{}", r.reconfigurations);
        // with one request per period, a switch (36.19 ms) still fits the
        // 40 ms period — no lateness, but plenty of switch energy
        assert_eq!(r.p_late, 0.0);
        assert!(r.energy > run(&cfg, &base(0.0, SchedPolicy::Fifo)).energy * 2.0);
    }

    #[test]
    fn bursts_make_fifo_thrash_and_miss_deadlines() {
        let cfg = paper_default();
        let r = run(&cfg, &bursty(0.5, SchedPolicy::Fifo));
        assert_eq!(r.served, 500);
        // 4 requests per 40 ms tick, each switch 36 ms → queue backs up
        assert!(r.p_late > 0.1, "p_late={}", r.p_late);
    }

    #[test]
    fn batching_reduces_switches_energy_and_lateness() {
        let cfg = paper_default();
        let fifo = run(&cfg, &bursty(0.3, SchedPolicy::Fifo));
        let batched = run(&cfg, &bursty(0.3, SchedPolicy::BatchBySlot { window: 8 }));
        assert_eq!(fifo.served, batched.served);
        assert!(
            batched.reconfigurations < fifo.reconfigurations,
            "batched {} vs fifo {}",
            batched.reconfigurations,
            fifo.reconfigurations
        );
        assert!(batched.energy < fifo.energy);
        assert!(batched.reordered > 0);
        assert!(batched.p_late <= fifo.p_late);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = paper_default();
        let a = run(&cfg, &base(0.25, SchedPolicy::Fifo));
        let b = run(&cfg, &base(0.25, SchedPolicy::Fifo));
        assert_eq!(a.served, b.served);
        assert_eq!(a.reconfigurations, b.reconfigurations);
        assert_eq!(a.energy, b.energy);
    }

    #[test]
    fn onoff_gap_policy_reconfigures_every_service() {
        let cfg = paper_default();
        let r = run(
            &cfg,
            &MultiSimConfig {
                gap_policy: PolicySpec::OnOff,
                ..base(0.0, SchedPolicy::Fifo)
            },
        );
        assert_eq!(r.served, 500);
        // power cut after every completion → a configuration per service
        assert_eq!(r.reconfigurations, 500);
        // off gaps are free: cheaper than idling at M12 over 40 ms periods
        let iw = run(&cfg, &base(0.0, SchedPolicy::Fifo));
        assert!(r.energy > iw.energy, "on-off pays per-item config energy");
    }

    #[test]
    fn timeout_gap_policy_never_fires_within_the_period() {
        // 40 ms gaps are far below the M12 τ (~499 ms): the timer never
        // expires, so the run is identical to idle-waiting M12
        let cfg = paper_default();
        let timeout = run(
            &cfg,
            &MultiSimConfig {
                gap_policy: PolicySpec::Timeout,
                ..base(0.0, SchedPolicy::Fifo)
            },
        );
        let iw = run(&cfg, &base(0.0, SchedPolicy::Fifo));
        assert_eq!(timeout.reconfigurations, 1);
        assert_eq!(timeout.energy, iw.energy);
    }

    #[test]
    fn per_slot_policies_change_only_the_overridden_slot() {
        // Slot 0 keeps idle-waiting M1+2; slot 1 is overridden to On-Off.
        // With mix 0 (all traffic on slot 0) the override must be inert:
        // the run is identical to the homogeneous fleet.
        let cfg = paper_default();
        let onoff_b = |mix| MultiSimConfig {
            slot_policies: vec![
                None,
                Some(SlotPolicy {
                    spec: PolicySpec::OnOff,
                    params: PolicyParams::default(),
                }),
            ],
            ..base(mix, SchedPolicy::Fifo)
        };
        let homogeneous = run(&cfg, &base(0.0, SchedPolicy::Fifo));
        let inert = run(&cfg, &onoff_b(0.0));
        assert_eq!(inert.energy, homogeneous.energy);
        assert_eq!(inert.reconfigurations, homogeneous.reconfigurations);
        // with traffic on slot 1 the override bites: every B-gap cuts
        // power, so reconfigurations rise well above the mixed baseline
        let mixed = run(&cfg, &onoff_b(0.5));
        let mixed_homogeneous = run(&cfg, &base(0.5, SchedPolicy::Fifo));
        assert!(
            mixed.reconfigurations > mixed_homogeneous.reconfigurations,
            "override {} vs homogeneous {}",
            mixed.reconfigurations,
            mixed_homogeneous.reconfigurations
        );
    }

    #[test]
    fn all_none_slot_overrides_are_the_homogeneous_fleet() {
        // `vec![]` and `vec![None, None]` must take the same shared-
        // instance path: one policy observes every gap, as before the
        // per-slot split existed. Use a stateful policy (EMA) on mixed
        // traffic, where a per-slot history split would change plans.
        let cfg = paper_default();
        let ema = |slot_policies| MultiSimConfig {
            gap_policy: PolicySpec::EmaPredictor,
            slot_policies,
            ..bursty(0.5, SchedPolicy::Fifo)
        };
        let empty = run(&cfg, &ema(Vec::new()));
        let all_none = run(&cfg, &ema(vec![None, None]));
        assert_eq!(empty.energy, all_none.energy);
        assert_eq!(empty.reconfigurations, all_none.reconfigurations);
        assert_eq!(empty.mean_latency, all_none.mean_latency);
    }

    #[test]
    fn per_slot_tuned_params_are_honoured() {
        // Slot 1 runs a Timeout policy tuned to idle at the *baseline*
        // level: its 40 ms gaps never reach the τ timer, so B-gaps idle
        // at 134.3 mW instead of M1+2's 24 mW — per-slot `PolicyParams`
        // must show up as measurably higher fleet energy.
        let cfg = paper_default();
        let tuned_b = MultiSimConfig {
            slot_policies: vec![
                None,
                Some(SlotPolicy {
                    spec: PolicySpec::Timeout,
                    params: PolicyParams {
                        saving: PowerSaving::BASELINE,
                        ..PolicyParams::default()
                    },
                }),
            ],
            ..base(0.5, SchedPolicy::Fifo)
        };
        let heterogeneous = run(&cfg, &tuned_b);
        let homogeneous = run(&cfg, &base(0.5, SchedPolicy::Fifo));
        assert_eq!(heterogeneous.served, homogeneous.served);
        assert!(
            heterogeneous.energy > homogeneous.energy,
            "baseline-idle slot B must cost energy: {} vs {}",
            heterogeneous.energy.millijoules(),
            homogeneous.energy.millijoules()
        );
    }

    #[test]
    fn event_count_and_time_are_sane() {
        let cfg = paper_default();
        let r = run(&cfg, &base(0.1, SchedPolicy::Fifo));
        // 500 arrivals at 40 ms: run spans ≥ 499 periods
        assert!(r.sim_time.secs() >= 499.0 * 0.040);
        assert!(r.mean_latency.millis() > 0.0);
    }
}
