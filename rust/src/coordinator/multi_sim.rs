//! Event-driven multi-accelerator workload simulation.
//!
//! Connects the [`Engine`](crate::sim::Engine) (discrete events), the
//! [`MultiAccelScheduler`] (the §4.2-extension scheduling layer) and the
//! shared [`ReplayCore`] (energy): requests for several accelerators
//! arrive as timed events, the scheduler picks service order within its
//! reordering window, and the core pays configuration/phase/idle energy
//! for every decision. This is the full-system version of the
//! closed-form multi-accel ablation — latency and energy emerge from the
//! event flow. The per-item energetics run through the same
//! [`ReplayCore`] as the single-accelerator lifetime simulation, so the
//! two runtimes cannot drift apart on accounting.
//!
//! The gap policy here is genuinely *online*: at each service completion
//! the [`Policy`](crate::strategies::strategy::Policy) plans the coming
//! inactivity without knowing when the fabric goes busy next (arrivals
//! are future events), and `IdleThenOff` timers are honoured mid-gap by
//! the ledger advance. Clairvoyant policies get no special treatment —
//! their blind `plan_gap` fallback is used, by construction.

use crate::config::loader::SimConfig;
use crate::config::schema::{FpgaModel, PolicySpec};
use crate::coordinator::scheduler::{Dispatch, MultiAccelScheduler, Policy as SchedPolicy, SlotRequest};
use crate::device::bitstream::Bitstream;
use crate::device::rails::PowerSaving;
use crate::energy::analytical::Analytical;
use crate::sim::{Ctx, Engine, SimTime};
use crate::strategies::replay::ReplayCore;
use crate::strategies::strategy::{build_with, GapContext, GapPlan, Policy as GapPolicy};
use crate::util::rng::Xoshiro256ss;
use crate::util::stats::Welford;
use crate::util::units::{Duration, Energy};

/// Events of the multi-accelerator duty cycle.
#[derive(Debug)]
enum Event {
    /// A request for `slot` arrives.
    Arrival { id: u64, slot: usize },
    /// The fabric becomes free; pull the next scheduled request.
    FabricFree,
}

/// Per-run configuration.
#[derive(Debug, Clone)]
pub struct MultiSimConfig {
    /// Probability that a request targets accelerator B (slot 1).
    pub mix: f64,
    pub requests: u64,
    /// Requests arriving together per period tick (a sensor event fanning
    /// out to several model evaluations). `1` = the paper's duty cycle;
    /// >1 creates queue pressure, which is where scheduling matters.
    pub burst: u64,
    pub policy: SchedPolicy,
    /// Gap policy applied between servicings (built per run; decides
    /// online, without seeing when the next dispatch comes).
    pub gap_policy: PolicySpec,
    pub seed: u64,
}

/// Outcome of a multi-accelerator run.
#[derive(Debug, Clone)]
pub struct MultiSimReport {
    pub served: u64,
    pub reconfigurations: u64,
    pub reordered: u64,
    pub energy: Energy,
    pub mean_latency: Duration,
    pub p_late: f64,
    pub sim_time: Duration,
}

struct State {
    core: ReplayCore,
    scheduler: MultiAccelScheduler,
    gap_policy: Box<dyn GapPolicy>,
    /// Plan governing the current inactivity window.
    current_plan: GapPlan,
    /// When the current plan took effect (for `IdleThenOff` timers).
    plan_started: SimTime,
    last_completion: SimTime,
    busy_until: SimTime,
    served: u64,
    late: u64,
    latency: Welford,
    period: Duration,
    /// Last time the core's ledger was advanced (for idle accounting).
    ledger_at: SimTime,
    dead: bool,
}

impl State {
    /// Advance the energy ledger to `now`, spending the inactivity per
    /// the current gap plan — including a mid-gap `IdleThenOff` cutoff.
    fn idle_until(&mut self, now: SimTime) {
        if now <= self.ledger_at {
            return;
        }
        let result = match self.current_plan {
            GapPlan::Idle(saving) => self.core.elapse(saving, now.since(self.ledger_at)),
            // the fabric was cut at plan time; elapse charges the
            // (paper-model) free off state
            GapPlan::PowerOff => self
                .core
                .elapse(PowerSaving::BASELINE, now.since(self.ledger_at)),
            GapPlan::IdleThenOff { saving, timeout } => {
                let cutoff = self.plan_started + timeout;
                if self.core.is_ready() && now > cutoff {
                    // idle up to the timer, cut power, then coast off
                    let mut r = Ok(());
                    if cutoff > self.ledger_at {
                        r = self.core.elapse(saving, cutoff.since(self.ledger_at));
                    }
                    if r.is_ok() {
                        self.core.power_off();
                        let from = self.ledger_at.max(cutoff);
                        r = self.core.elapse(saving, now.since(from));
                    }
                    r
                } else {
                    self.core.elapse(saving, now.since(self.ledger_at))
                }
            }
        };
        if result.is_err() {
            self.dead = true;
        }
        self.ledger_at = now;
    }

    /// Serve one dispatch starting at `now`; returns the completion time.
    fn serve(&mut self, now: SimTime, dispatch: &Dispatch) -> SimTime {
        self.idle_until(now);
        // feed the realized inactivity back to the online policy
        if self.served > 0 && now > self.last_completion {
            self.gap_policy.observe(now.since(self.last_completion));
        }
        let mut finish = now;
        if dispatch.reconfigure {
            // a switch means loading a different image: power-cycle path
            match self.core.power_cycle_configure("lstm") {
                Ok(t) => finish += t,
                Err(_) => {
                    self.dead = true;
                    return now;
                }
            }
        } else if !self.core.is_ready() {
            // the gap policy cut power; pay the reconfiguration preamble
            match self.core.configure("lstm") {
                Ok(t) => finish += t,
                Err(_) => {
                    self.dead = true;
                    return now;
                }
            }
        }
        match self.core.run_phases() {
            Ok(t) => finish += t,
            Err(_) => {
                self.dead = true;
                return now;
            }
        }
        self.ledger_at = finish;
        self.served += 1;
        let arrival = SimTime::ZERO + dispatch.request.arrival;
        self.latency.push(finish.since(arrival).millis());
        if finish.since(arrival) > self.period {
            self.late += 1;
        }
        // plan the coming inactivity at completion time, gap unseen
        let ctx = GapContext {
            items_done: self.served,
            now: finish.as_duration(),
        };
        self.current_plan = self.gap_policy.plan_gap(&ctx);
        if self.current_plan == GapPlan::PowerOff {
            self.core.power_off();
        }
        self.plan_started = finish;
        self.last_completion = finish;
        finish
    }
}

/// Run the event-driven multi-accelerator simulation.
pub fn run(config: &SimConfig, ms: &MultiSimConfig) -> MultiSimReport {
    let period = config.workload.arrival.mean_period();
    let mut core = ReplayCore::from_config(config);
    // program a second accelerator image (same geometry, distinct slot)
    core.board.flash.program(
        "lstm_b",
        Bitstream::synthesize(
            FpgaModel::Xc7s15,
            crate::device::calib::design_occupied_frames(FpgaModel::Xc7s15),
            0xB0B,
        ),
        config.platform.spi.compressed,
    );
    let model = Analytical::new(&config.item, config.workload.energy_budget);

    let mut state = State {
        scheduler: MultiAccelScheduler::new(
            ms.policy,
            config.item.configuration.time,
            config.item.latency_without_config(),
        ),
        core,
        // the gap policy honours the config's `policy_params` tunables
        gap_policy: build_with(ms.gap_policy, &model, &config.workload.params),
        current_plan: GapPlan::Idle(PowerSaving::BASELINE),
        plan_started: SimTime::ZERO,
        last_completion: SimTime::ZERO,
        busy_until: SimTime::ZERO,
        served: 0,
        late: 0,
        latency: Welford::new(),
        period,
        ledger_at: SimTime::ZERO,
        dead: false,
    };

    let mut engine: Engine<Event> = Engine::new();
    let mut rng = Xoshiro256ss::new(ms.seed);
    let burst = ms.burst.max(1);
    for i in 0..ms.requests {
        let slot = if rng.bernoulli(ms.mix) { 1 } else { 0 };
        let tick = i / burst;
        engine.schedule_at(
            SimTime::ZERO + period * tick as f64,
            Event::Arrival { id: i, slot },
        );
    }

    let handler = |ctx: &mut Ctx<Event>, state: &mut State, event: Event| {
        if state.dead {
            ctx.stop();
            return;
        }
        match event {
            Event::Arrival { id, slot } => {
                let arrival = ctx.now().as_duration();
                state.scheduler.submit(SlotRequest {
                    id,
                    slot,
                    arrival,
                    deadline: arrival + state.period,
                });
                if ctx.now() >= state.busy_until {
                    ctx.schedule_at(ctx.now(), Event::FabricFree);
                }
            }
            Event::FabricFree => {
                if ctx.now() < state.busy_until {
                    return; // stale wake-up
                }
                if let Some(dispatch) = state.scheduler.next() {
                    let finish = state.serve(ctx.now(), &dispatch);
                    state.busy_until = finish;
                    ctx.schedule_at(finish, Event::FabricFree);
                }
            }
        }
    };

    let stats = engine.run(&mut state, u64::MAX, handler);

    MultiSimReport {
        served: state.served,
        reconfigurations: state.core.board.fpga.configurations,
        reordered: state.scheduler.stats.reordered,
        energy: state.core.board.fpga_energy,
        mean_latency: Duration::from_millis(if state.latency.count() > 0 {
            state.latency.mean()
        } else {
            0.0
        }),
        p_late: if state.served > 0 {
            state.late as f64 / state.served as f64
        } else {
            0.0
        },
        sim_time: stats.end_time.as_duration(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;

    fn base(mix: f64, policy: SchedPolicy) -> MultiSimConfig {
        MultiSimConfig {
            mix,
            requests: 500,
            burst: 1,
            policy,
            gap_policy: PolicySpec::IdleWaitingM12,
            seed: 17,
        }
    }

    fn bursty(mix: f64, policy: SchedPolicy) -> MultiSimConfig {
        MultiSimConfig {
            burst: 4,
            ..base(mix, policy)
        }
    }

    #[test]
    fn single_slot_configures_once_and_serves_all() {
        let cfg = paper_default();
        let r = run(&cfg, &base(0.0, SchedPolicy::Fifo));
        assert_eq!(r.served, 500);
        assert_eq!(r.reconfigurations, 1);
        assert_eq!(r.p_late, 0.0);
        // energy ≈ init + 500 items + idle gaps at M12 24 mW
        let expected_mj = 11.98 + 500.0 * 0.0065 + 0.024 * (500.0 * 39.96);
        assert!(
            (r.energy.millijoules() - expected_mj).abs() / expected_mj < 0.02,
            "{} vs {}",
            r.energy.millijoules(),
            expected_mj
        );
    }

    #[test]
    fn mixed_slots_cost_switches_under_fifo() {
        let cfg = paper_default();
        let r = run(&cfg, &base(0.5, SchedPolicy::Fifo));
        assert_eq!(r.served, 500);
        assert!(r.reconfigurations > 100, "{}", r.reconfigurations);
        // with one request per period, a switch (36.19 ms) still fits the
        // 40 ms period — no lateness, but plenty of switch energy
        assert_eq!(r.p_late, 0.0);
        assert!(r.energy > run(&cfg, &base(0.0, SchedPolicy::Fifo)).energy * 2.0);
    }

    #[test]
    fn bursts_make_fifo_thrash_and_miss_deadlines() {
        let cfg = paper_default();
        let r = run(&cfg, &bursty(0.5, SchedPolicy::Fifo));
        assert_eq!(r.served, 500);
        // 4 requests per 40 ms tick, each switch 36 ms → queue backs up
        assert!(r.p_late > 0.1, "p_late={}", r.p_late);
    }

    #[test]
    fn batching_reduces_switches_energy_and_lateness() {
        let cfg = paper_default();
        let fifo = run(&cfg, &bursty(0.3, SchedPolicy::Fifo));
        let batched = run(&cfg, &bursty(0.3, SchedPolicy::BatchBySlot { window: 8 }));
        assert_eq!(fifo.served, batched.served);
        assert!(
            batched.reconfigurations < fifo.reconfigurations,
            "batched {} vs fifo {}",
            batched.reconfigurations,
            fifo.reconfigurations
        );
        assert!(batched.energy < fifo.energy);
        assert!(batched.reordered > 0);
        assert!(batched.p_late <= fifo.p_late);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = paper_default();
        let a = run(&cfg, &base(0.25, SchedPolicy::Fifo));
        let b = run(&cfg, &base(0.25, SchedPolicy::Fifo));
        assert_eq!(a.served, b.served);
        assert_eq!(a.reconfigurations, b.reconfigurations);
        assert_eq!(a.energy, b.energy);
    }

    #[test]
    fn onoff_gap_policy_reconfigures_every_service() {
        let cfg = paper_default();
        let r = run(
            &cfg,
            &MultiSimConfig {
                gap_policy: PolicySpec::OnOff,
                ..base(0.0, SchedPolicy::Fifo)
            },
        );
        assert_eq!(r.served, 500);
        // power cut after every completion → a configuration per service
        assert_eq!(r.reconfigurations, 500);
        // off gaps are free: cheaper than idling at M12 over 40 ms periods
        let iw = run(&cfg, &base(0.0, SchedPolicy::Fifo));
        assert!(r.energy > iw.energy, "on-off pays per-item config energy");
    }

    #[test]
    fn timeout_gap_policy_never_fires_within_the_period() {
        // 40 ms gaps are far below the M12 τ (~499 ms): the timer never
        // expires, so the run is identical to idle-waiting M12
        let cfg = paper_default();
        let timeout = run(
            &cfg,
            &MultiSimConfig {
                gap_policy: PolicySpec::Timeout,
                ..base(0.0, SchedPolicy::Fifo)
            },
        );
        let iw = run(&cfg, &base(0.0, SchedPolicy::Fifo));
        assert_eq!(timeout.reconfigurations, 1);
        assert_eq!(timeout.energy, iw.energy);
    }

    #[test]
    fn event_count_and_time_are_sane() {
        let cfg = paper_default();
        let r = run(&cfg, &base(0.1, SchedPolicy::Fifo));
        // 500 arrivals at 40 ms: run spans ≥ 499 periods
        assert!(r.sim_time.secs() >= 499.0 * 0.040);
        assert!(r.mean_latency.millis() > 0.0);
    }
}
