//! Fleet-scale discrete-event simulation: 100k+ heterogeneous devices in
//! one process, with streaming aggregation and wake-placement routing.
//!
//! The per-device simulators (`strategies::simulate`) answer "how long
//! does *one* board live under policy X?". This module answers the fleet
//! operator's questions: what does the *distribution* of lifetime,
//! energy and lateness look like across a heterogeneous population, and
//! how should a shared request stream be routed across devices whose
//! wake state (configured / idle / powered off) the gap policies control?
//!
//! Two phases, both driven by the same config (`fleet` block + CLI):
//!
//! **Survey** — every device independently replays one shared
//! materialized gap trace through its class policy on the batched
//! structure-of-arrays kernel ([`SimWorker::run_batch`]). Devices are
//! grouped into fixed-size shards (a pure function of the fleet size,
//! never the thread count) and the shards are mapped over the
//! work-stealing [`SweepRunner`], one reusable [`SimWorker`] per worker
//! thread. Results are folded through *streaming* aggregates only —
//! exact Welford moments plus bounded reservoir quantile sketches
//! ([`ReservoirQuantiles`]) — so peak memory is O(shards + reservoir
//! capacity), never O(devices) result vectors.
//!
//! **Routing** — a shared arrival stream (the workload's
//! [`ArrivalSpec`](crate::config::ArrivalSpec), so the bundled
//! `workloads/` traces plug straight in) is routed request-by-request
//! across compact per-device states (policy + committed plan + battery +
//! completion time, a few hundred bytes each) by a pluggable
//! [`Placement`] policy. Device energetics
//! ride the calibrated [`DeviceCosts`] constants (measured off the real
//! [`ReplayCore`](crate::strategies::ReplayCore) ledgers), so fleet
//! totals agree with the per-device simulators by construction.
//!
//! # Determinism
//!
//! Output is byte-identical at any `--threads N`:
//! * every per-device stream is seeded `derive_seed(fleet_seed,
//!   device_index)` — a pure function of the fleet seed and the device's
//!   index, independent of which worker simulates it;
//! * class assignment draws from its own derived stream per device;
//! * shard boundaries depend only on the device count, and shard
//!   aggregates (including the reservoir sketches, whose eviction
//!   randomness is seeded per shard) are folded in shard order on the
//!   caller thread;
//! * the routing phase is sequential by construction.
//!
//! `tests/fleet_determinism.rs` pins the rendered report and CSV bytes
//! across thread counts, and pins a size-1 homogeneous fleet bit-equal
//! to [`simulate_batch`](crate::strategies::simulate_batch) on every
//! [`SimReport`] field.

use std::fmt;
use std::fmt::Write as _;

use crate::config::schema::{PolicyParams, PolicySpec};
use crate::config::SimConfig;
use crate::device::faults::FaultState;
use crate::coordinator::requests;
use crate::coordinator::requests::ArrivalProcess as _;
use crate::energy::analytical::Analytical;
use crate::runner::grid::derive_seed;
use crate::runner::{Grid, SweepRunner};
use crate::strategies::replay::DeviceCosts;
use crate::strategies::simulate::{SimReport, SimWorker};
use crate::strategies::strategy::{build_with, GapContext, GapPlan, Policy};
use crate::util::csv::Csv;
use crate::util::rng::Xoshiro256ss;
use crate::util::stats::{ReservoirQuantiles, Summary};
use crate::util::units::{Duration, Energy};

/// Devices per survey shard. A pure function of the fleet size (never
/// the thread count) so shard boundaries — and therefore the shard
/// reservoirs' push order and fold order — are identical at any
/// `--threads N`. Small enough to keep work stealing balanced on
/// heterogeneous class mixtures, large enough to amortize the per-shard
/// aggregate state.
const SHARD_DEVICES: usize = 256;

/// Capacity of the fleet-level reservoir sketches (exact below this many
/// devices, bounded-memory estimates above).
const FLEET_RESERVOIR_CAP: usize = 4096;

// Salts folded into the fleet seed so each derived stream family
// (class assignment, arrival materialization, reservoir eviction) is
// statistically independent of the per-device policy streams.
const CLASS_SALT: u64 = 0x666C_6565_7463_6C73;
const SURVEY_SALT: u64 = 0x666C_6565_7473_7276;
const ROUTE_SALT: u64 = 0x666C_6565_7472_7465;
const FLEET_FAULT_SALT: u64 = 0x666C_6565_7466_6C74;
const ENERGY_SALT: u64 = 0x666C_6565_7400_0001;
const LIFETIME_SALT: u64 = 0x666C_6565_7400_0002;
const LATE_SALT: u64 = 0x666C_6565_7400_0003;
const LATENCY_SALT: u64 = 0x666C_6565_7400_0004;
const DEV_ENERGY_SALT: u64 = 0x666C_6565_7400_0005;
const DEV_ITEMS_SALT: u64 = 0x666C_6565_7400_0006;

/// Index the global (fold-target) reservoirs are seeded with — far above
/// any real shard index, so the fold target's eviction stream never
/// collides with a shard's.
const GLOBAL_AGG: u64 = u64::MAX;

/// Wake-placement policy: which device serves the next request of the
/// shared arrival stream. All policies are deterministic (ties break to
/// the lowest device index) and scan the compact device array in O(N).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Rotate through the alive devices in index order.
    RoundRobin,
    /// The device with the earliest completion time (shortest queue).
    LeastLoaded,
    /// Prefer a device that is awake and configured (no reconfiguration
    /// energy); fall back to least-loaded.
    PreferConfigured,
    /// Prefer a device that is awake, configured *and* already free at
    /// the arrival time (zero queueing); then any awake device; then
    /// least-loaded.
    PreferIdleAwake,
    /// The device with the most battery remaining (wear levelling).
    BatteryAware,
}

impl Placement {
    /// Every placement policy, in documentation order.
    pub const ALL: [Placement; 5] = [
        Placement::RoundRobin,
        Placement::LeastLoaded,
        Placement::PreferConfigured,
        Placement::PreferIdleAwake,
        Placement::BatteryAware,
    ];

    /// The CLI name of this placement policy.
    pub fn name(self) -> &'static str {
        match self {
            Placement::RoundRobin => "round-robin",
            Placement::LeastLoaded => "least-loaded",
            Placement::PreferConfigured => "prefer-configured",
            Placement::PreferIdleAwake => "prefer-idle-awake",
            Placement::BatteryAware => "battery-aware",
        }
    }

    /// Parse a CLI name back into a placement policy.
    pub fn parse(s: &str) -> Option<Placement> {
        Placement::ALL.into_iter().find(|p| p.name() == s)
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Run-shape knobs of one fleet simulation (the config's `fleet` block
/// supplies the fleet itself: device count, seed, class mixture,
/// deadline).
#[derive(Debug, Clone, Copy)]
pub struct FleetOptions {
    /// Survey gaps replayed per device (`0` skips the survey phase).
    pub steps: usize,
    /// Requests in the shared routed arrival stream (`0` skips routing).
    pub requests: usize,
    /// Wake-placement policy routing the shared stream.
    pub placement: Placement,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            steps: 256,
            requests: 2000,
            placement: Placement::RoundRobin,
        }
    }
}

/// Aggregate outcome of the survey phase: per-device distributions over
/// the whole fleet, computed without ever materializing a per-device
/// result vector.
#[derive(Debug, Clone)]
pub struct FleetStepReport {
    /// Gaps replayed per device (total device-gap steps = devices × steps).
    pub steps: usize,
    /// Workload items completed across the fleet.
    pub items: u64,
    /// Devices whose budget died before finishing the trace.
    pub exhausted: u64,
    /// Distribution of per-device FPGA energy (mJ).
    pub energy_mj: Option<Summary>,
    /// Distribution of per-device Eq-4 lifetime (hours).
    pub lifetime_h: Option<Summary>,
    /// Distribution of per-device late-request rates.
    pub late_rate: Option<Summary>,
    /// Faulted configuration/inference attempts retried across the fleet
    /// (zero whenever fault injection is disabled).
    pub retries: u64,
    /// Requests shed after a device exhausted its retry cap.
    pub shed: u64,
    /// Energy destroyed by faulted attempts across the fleet.
    pub recovery_energy: Energy,
}

impl FleetStepReport {
    fn empty() -> FleetStepReport {
        FleetStepReport {
            steps: 0,
            items: 0,
            exhausted: 0,
            energy_mj: None,
            lifetime_h: None,
            late_rate: None,
            retries: 0,
            shed: 0,
            recovery_energy: Energy::ZERO,
        }
    }
}

/// Outcome of the routing phase: fleet-level service quality and energy
/// under one placement policy.
#[derive(Debug, Clone)]
pub struct FleetRouteReport {
    /// Placement policy that routed the stream.
    pub placement: Placement,
    /// Requests in the shared arrival stream.
    pub requests: usize,
    /// Requests actually served (`served + dropped == requests`).
    pub served: u64,
    /// Served requests that queued behind a busy device.
    pub late: u64,
    /// Deadline misses: dropped requests plus requests served past the
    /// fleet deadline.
    pub misses: u64,
    /// Requests dropped outright (the picked device's battery died, or
    /// no device was left alive).
    pub dropped: u64,
    /// Devices whose battery died while serving.
    pub deaths: u64,
    /// FPGA configurations paid across the fleet.
    pub configurations: u64,
    /// Total energy drawn across the fleet.
    pub total_energy: Energy,
    /// Latest completion time across all devices (the fleet's makespan).
    pub fleet_lifetime: Duration,
    /// Distribution of served latency (ms), in request order.
    pub latency_ms: Option<Summary>,
    /// Distribution of per-device drawn energy (mJ).
    pub device_energy_mj: Option<Summary>,
    /// Distribution of per-device served items.
    pub device_items: Option<Summary>,
    /// Faulted configuration/inference attempts retried across the fleet
    /// (zero whenever fault injection is disabled).
    pub retries: u64,
    /// Energy destroyed by faulted attempts across the fleet.
    pub recovery_energy: Energy,
    /// Requests whose first device gave up configuring (retry cap
    /// exhausted) and that were re-routed to an alternative device.
    pub rerouted: u64,
}

impl FleetRouteReport {
    fn empty(placement: Placement) -> FleetRouteReport {
        FleetRouteReport {
            placement,
            requests: 0,
            served: 0,
            late: 0,
            misses: 0,
            dropped: 0,
            deaths: 0,
            configurations: 0,
            total_energy: Energy::ZERO,
            fleet_lifetime: Duration::ZERO,
            latency_ms: None,
            device_energy_mj: None,
            device_items: None,
            retries: 0,
            recovery_energy: Energy::ZERO,
            rerouted: 0,
        }
    }
}

/// A full fleet-simulation report: the survey and routing phases plus
/// the fleet shape they ran over.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Number of simulated devices.
    pub devices: usize,
    /// Fleet base seed every per-device stream derives from.
    pub seed: u64,
    /// Number of device classes in the mixture.
    pub classes: usize,
    /// Survey-phase aggregates (zeroed when `steps == 0`).
    pub step: FleetStepReport,
    /// Routing-phase outcome (zeroed when `requests == 0`).
    pub route: FleetRouteReport,
}

fn summary_line(name: &str, s: &Option<Summary>) -> String {
    match s {
        None => format!("  {name}: (no samples)\n"),
        Some(s) => format!(
            "  {name}: n={} mean={:.4} sd={:.4} min={:.4} p50={:.4} p90={:.4} p99={:.4} max={:.4}\n",
            s.count, s.mean, s.std_dev, s.min, s.p50, s.p90, s.p99, s.max
        ),
    }
}

/// One CSV row per metric under the fixed fleet schema; scalar metrics
/// carry their value in the `mean` column, the other statistic columns
/// stay empty.
fn scalar_row(csv: &mut Csv, section: &str, metric: &str, value: String) {
    let empty = String::new;
    csv.row(&[
        section.to_string(),
        metric.to_string(),
        empty(),
        value,
        empty(),
        empty(),
        empty(),
        empty(),
        empty(),
        empty(),
        empty(),
    ]);
}

/// Emits the metric's row even with no observations ([`Summary::empty`]
/// zeros), so the CSV schema is fixed and zero-request runs stay
/// byte-comparable instead of silently dropping rows.
fn dist_row(csv: &mut Csv, section: &str, metric: &str, s: &Option<Summary>) {
    let s = s.clone().unwrap_or_else(Summary::empty);
    let f = |v: f64| format!("{v}");
    csv.row(&[
        section.to_string(),
        metric.to_string(),
        s.count.to_string(),
        f(s.mean),
        f(s.std_dev),
        f(s.min),
        f(s.p50),
        f(s.p90),
        f(s.p95),
        f(s.p99),
        f(s.max),
    ]);
}

impl FleetReport {
    /// Multi-line human-readable rendering of both phases.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "fleet: {} devices, {} class(es), seed {}",
            self.devices, self.classes, self.seed
        );
        let s = &self.step;
        if s.steps > 0 {
            let _ = writeln!(
                out,
                "survey: {} gaps/device, {} items served, {} device(s) exhausted",
                s.steps, s.items, s.exhausted
            );
            out.push_str(&summary_line("energy_mj", &s.energy_mj));
            out.push_str(&summary_line("lifetime_h", &s.lifetime_h));
            out.push_str(&summary_line("late_rate", &s.late_rate));
            if s.retries > 0 || s.shed > 0 {
                let _ = writeln!(
                    out,
                    "  faults: retries={} shed={} recovery_energy={:.4} mJ",
                    s.retries,
                    s.shed,
                    s.recovery_energy.millijoules()
                );
            }
        }
        let r = &self.route;
        if r.requests > 0 {
            let _ = writeln!(
                out,
                "routing: placement={} requests={} served={} late={} misses={} dropped={} deaths={}",
                r.placement, r.requests, r.served, r.late, r.misses, r.dropped, r.deaths
            );
            let _ = writeln!(
                out,
                "  total_energy={:.4} J  configurations={}  fleet_lifetime={:.4} s",
                r.total_energy.joules(),
                r.configurations,
                r.fleet_lifetime.secs()
            );
            out.push_str(&summary_line("latency_ms", &r.latency_ms));
            out.push_str(&summary_line("device_energy_mj", &r.device_energy_mj));
            out.push_str(&summary_line("device_items", &r.device_items));
            if r.retries > 0 || r.rerouted > 0 {
                let _ = writeln!(
                    out,
                    "  faults: retries={} rerouted={} recovery_energy={:.4} mJ",
                    r.retries,
                    r.rerouted,
                    r.recovery_energy.millijoules()
                );
            }
        }
        out
    }

    /// The report as a fixed-schema CSV document
    /// (`section,metric,count,mean,std_dev,min,p50,p90,p95,p99,max`):
    /// distribution metrics fill every column, scalar metrics carry
    /// their value in the `mean` column. Float cells use shortest
    /// round-trip formatting, so the bytes are a determinism witness.
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "section", "metric", "count", "mean", "std_dev", "min", "p50", "p90", "p95", "p99",
            "max",
        ]);
        scalar_row(&mut csv, "fleet", "devices", self.devices.to_string());
        scalar_row(&mut csv, "fleet", "classes", self.classes.to_string());
        scalar_row(&mut csv, "fleet", "seed", self.seed.to_string());
        let s = &self.step;
        scalar_row(&mut csv, "survey", "steps", s.steps.to_string());
        scalar_row(&mut csv, "survey", "items", s.items.to_string());
        scalar_row(&mut csv, "survey", "exhausted", s.exhausted.to_string());
        dist_row(&mut csv, "survey", "energy_mj", &s.energy_mj);
        dist_row(&mut csv, "survey", "lifetime_h", &s.lifetime_h);
        dist_row(&mut csv, "survey", "late_rate", &s.late_rate);
        scalar_row(&mut csv, "survey", "retries", s.retries.to_string());
        scalar_row(&mut csv, "survey", "shed", s.shed.to_string());
        scalar_row(
            &mut csv,
            "survey",
            "recovery_energy_mj",
            format!("{}", s.recovery_energy.millijoules()),
        );
        let r = &self.route;
        scalar_row(&mut csv, "route", "placement", r.placement.name().to_string());
        scalar_row(&mut csv, "route", "requests", r.requests.to_string());
        scalar_row(&mut csv, "route", "served", r.served.to_string());
        scalar_row(&mut csv, "route", "late", r.late.to_string());
        scalar_row(&mut csv, "route", "misses", r.misses.to_string());
        scalar_row(&mut csv, "route", "dropped", r.dropped.to_string());
        scalar_row(&mut csv, "route", "deaths", r.deaths.to_string());
        scalar_row(&mut csv, "route", "configurations", r.configurations.to_string());
        scalar_row(
            &mut csv,
            "route",
            "total_energy_j",
            format!("{}", r.total_energy.joules()),
        );
        scalar_row(
            &mut csv,
            "route",
            "fleet_lifetime_s",
            format!("{}", r.fleet_lifetime.secs()),
        );
        dist_row(&mut csv, "route", "latency_ms", &r.latency_ms);
        dist_row(&mut csv, "route", "device_energy_mj", &r.device_energy_mj);
        dist_row(&mut csv, "route", "device_items", &r.device_items);
        scalar_row(&mut csv, "route", "retries", r.retries.to_string());
        scalar_row(&mut csv, "route", "rerouted", r.rerouted.to_string());
        scalar_row(
            &mut csv,
            "route",
            "recovery_energy_mj",
            format!("{}", r.recovery_energy.millijoules()),
        );
        csv
    }
}

/// A device class with its policy constructor inputs resolved: the
/// config's optional fields defaulted against the workload block.
struct DeviceClass {
    policy: PolicySpec,
    params: PolicyParams,
    battery: Energy,
    model: Analytical,
}

/// Resolve the fleet's class mixture. An empty `fleet.classes` block
/// means one implicit class running the workload's own policy/params on
/// the workload budget. Returns the classes plus their cumulative
/// weights (for the per-device mixture draw).
fn resolve_classes(config: &SimConfig) -> (Vec<DeviceClass>, Vec<f64>) {
    let default_battery = config.workload.energy_budget;
    let mut classes = Vec::new();
    let mut cum = Vec::new();
    if config.fleet.classes.is_empty() {
        classes.push(DeviceClass {
            policy: config.workload.policy,
            params: config.workload.params,
            battery: default_battery,
            model: Analytical::new(&config.item, default_battery),
        });
        cum.push(1.0);
    } else {
        let mut total = 0.0;
        for c in &config.fleet.classes {
            let battery = c.battery.unwrap_or(default_battery);
            classes.push(DeviceClass {
                policy: c.policy,
                params: c.params,
                battery,
                model: Analytical::new(&config.item, battery),
            });
            total += c.weight;
            cum.push(total);
        }
    }
    (classes, cum)
}

/// Which class a device belongs to: a weighted draw from the device's
/// own derived stream, so the assignment is a pure function of
/// `(fleet_seed, device_index)` — independent of sharding and threads.
fn class_index(fleet_seed: u64, device: u64, cum: &[f64]) -> usize {
    if cum.len() <= 1 {
        return 0;
    }
    let total = cum[cum.len() - 1];
    let draw = Xoshiro256ss::new(derive_seed(fleet_seed ^ CLASS_SALT, device)).next_f64() * total;
    cum.iter()
        .position(|&c| draw < c)
        .unwrap_or(cum.len() - 1)
}

/// Build device `device`'s policy: its class's spec/params with the
/// per-device seed spliced in.
fn device_policy(
    classes: &[DeviceClass],
    class: usize,
    fleet_seed: u64,
    device: u64,
) -> Box<dyn Policy> {
    let c = &classes[class];
    let mut params = c.params;
    params.seed = derive_seed(fleet_seed, device);
    build_with(c.policy, &c.model, &params)
}

/// Replay one device's survey trace on `worker`. With fault injection
/// enabled the device gets its own fault stream — the spec's seed is
/// respliced through the `FLEET_FAULT_SALT` family, a pure function of
/// `(fleet_seed, device_index)` — so fault sequences are reproducible at
/// any thread count. Fault-free surveys pass the shared config through
/// untouched (no clone).
fn survey_one(
    worker: &mut SimWorker,
    config: &SimConfig,
    policy: &mut dyn Policy,
    gaps: &[Duration],
    label: &str,
    mean: Duration,
    device: u64,
) -> SimReport {
    if config.faults.enabled() {
        let mut dev_cfg = config.clone();
        dev_cfg.faults.seed = derive_seed(config.fleet.seed ^ FLEET_FAULT_SALT, device);
        worker.run_batch(&dev_cfg, policy, gaps, label, mean)
    } else {
        worker.run_batch(config, policy, gaps, label, mean)
    }
}

/// Materialize `count` inter-arrival gaps from the workload's arrival
/// spec on a salted fleet stream (IO only for `arrival: trace` specs).
fn materialize_gaps(config: &SimConfig, count: usize, salt: u64) -> std::io::Result<Vec<Duration>> {
    let mut process = requests::build(
        &config.workload.arrival,
        derive_seed(config.fleet.seed ^ salt, 0),
    )?;
    Ok((0..count).map(|_| process.next_gap()).collect())
}

/// Streaming per-shard aggregates: exact moments + bounded reservoir
/// sketches, mergeable in shard order.
#[derive(Debug, Clone)]
struct ShardAgg {
    energy_mj: ReservoirQuantiles,
    lifetime_h: ReservoirQuantiles,
    late_rate: ReservoirQuantiles,
    items: u64,
    exhausted: u64,
    retries: u64,
    shed: u64,
    recovery_energy: Energy,
}

impl ShardAgg {
    fn new(fleet_seed: u64, shard: u64, cap: usize) -> ShardAgg {
        ShardAgg {
            energy_mj: ReservoirQuantiles::new(cap, derive_seed(fleet_seed ^ ENERGY_SALT, shard)),
            lifetime_h: ReservoirQuantiles::new(
                cap,
                derive_seed(fleet_seed ^ LIFETIME_SALT, shard),
            ),
            late_rate: ReservoirQuantiles::new(cap, derive_seed(fleet_seed ^ LATE_SALT, shard)),
            items: 0,
            exhausted: 0,
            retries: 0,
            shed: 0,
            recovery_energy: Energy::ZERO,
        }
    }

    fn push(&mut self, report: &SimReport, expected_items: u64) {
        self.items += report.items;
        if report.items < expected_items {
            self.exhausted += 1;
        }
        self.retries += report.retries;
        self.shed += report.shed_requests;
        self.recovery_energy += report.recovery_energy;
        self.energy_mj.push(report.energy_exact.millijoules());
        self.lifetime_h.push(report.lifetime.hours());
        let rate = if report.items > 0 {
            report.late_requests as f64 / report.items as f64
        } else {
            0.0
        };
        self.late_rate.push(rate);
    }

    fn merge(&mut self, other: &ShardAgg) {
        self.items += other.items;
        self.exhausted += other.exhausted;
        self.retries += other.retries;
        self.shed += other.shed;
        self.recovery_energy += other.recovery_energy;
        self.energy_mj.merge(&other.energy_mj);
        self.lifetime_h.merge(&other.lifetime_h);
        self.late_rate.merge(&other.late_rate);
    }
}

/// The survey phase: shard the fleet, replay the shared trace on every
/// device, fold shard aggregates in shard order.
fn run_survey(
    config: &SimConfig,
    gaps: &[Duration],
    runner: &SweepRunner,
    classes: &[DeviceClass],
    cum: &[f64],
) -> FleetStepReport {
    let seed = config.fleet.seed;
    let devices = config.fleet.devices;
    let label = format!("trace({} gaps)", gaps.len());
    let mean = requests::trace_mean(gaps);
    // a device finishing the whole trace serves gaps+1 items (unless the
    // workload's own item cap is tighter); fewer means its budget died
    let expected = (gaps.len() as u64 + 1).min(config.workload.max_items.unwrap_or(u64::MAX));
    let shards: Vec<(usize, usize)> = (0..devices)
        .step_by(SHARD_DEVICES)
        .map(|start| (start, (start + SHARD_DEVICES).min(devices)))
        .collect();
    let grid = Grid::new(shards);
    let aggs: Vec<ShardAgg> = runner.run_with_state(
        &grid,
        || SimWorker::new(config),
        |worker, cell| {
            let (start, end) = *cell.params;
            let mut agg = ShardAgg::new(seed, cell.index as u64, SHARD_DEVICES);
            for device in start..end {
                let class = class_index(seed, device as u64, cum);
                let mut policy = device_policy(classes, class, seed, device as u64);
                let report =
                    survey_one(worker, config, policy.as_mut(), gaps, &label, mean, device as u64);
                agg.push(&report, expected);
            }
            agg
        },
    );
    let mut total = ShardAgg::new(seed, GLOBAL_AGG, FLEET_RESERVOIR_CAP);
    for shard in &aggs {
        total.merge(shard);
    }
    FleetStepReport {
        steps: gaps.len(),
        items: total.items,
        exhausted: total.exhausted,
        energy_mj: total.energy_mj.summary(),
        lifetime_h: total.lifetime_h.summary(),
        late_rate: total.late_rate.summary(),
        retries: total.retries,
        shed: total.shed,
        recovery_energy: total.recovery_energy,
    }
}

/// Replay exactly what the survey runs for one device — same class
/// assignment, same derived seed, same trace labeling — on a fresh
/// worker. A size-1 homogeneous fleet survey is therefore bit-equal to
/// [`simulate_batch`](crate::strategies::simulate_batch) with the
/// device-0 policy (pinned by `tests/fleet_determinism.rs`).
pub fn survey_device(config: &SimConfig, gaps: &[Duration], device: usize) -> SimReport {
    let (classes, cum) = resolve_classes(config);
    let seed = config.fleet.seed;
    let class = class_index(seed, device as u64, &cum);
    let mut policy = device_policy(&classes, class, seed, device as u64);
    survey_one(
        &mut SimWorker::new(config),
        config,
        policy.as_mut(),
        gaps,
        &format!("trace({} gaps)", gaps.len()),
        requests::trace_mean(gaps),
        device as u64,
    )
}

/// Compact per-device routing state — no `Board`, no event queue, no
/// per-gap history: the committed gap plan is applied lazily when the
/// next request lands on the device, using the calibrated
/// [`DeviceCosts`] arithmetic.
struct FleetDevice {
    policy: Box<dyn Policy>,
    /// Plan committed at the last completion, applied lazily on the next
    /// request (or peeked by wake-aware placement).
    plan: GapPlan,
    /// Battery remaining.
    battery: Energy,
    /// Energy drawn so far.
    used: Energy,
    /// Completion time of the last served request.
    completion: Duration,
    /// Arrival time of the last served request (the realized gap fed to
    /// `Policy::observe`).
    prev_arrival: Duration,
    /// The fabric currently holds its configuration.
    configured: bool,
    /// Per-device fault stream (`None` with fault injection disabled).
    faults: Option<FaultState>,
    items: u64,
    late: u64,
    configurations: u64,
    /// Faulted attempts this device retried or gave up on.
    retries: u64,
    /// Energy destroyed by this device's faulted attempts.
    recovery_energy: Energy,
    alive: bool,
}

/// What happened when a request was placed on a device.
enum ServeOutcome {
    /// Served; arrival-to-completion latency.
    Served(Duration),
    /// The device's battery died paying for this request — the device is
    /// dead and the request dropped.
    Died,
    /// The device exhausted its configuration retry cap: it paid for the
    /// destroyed partial attempts, stays alive but unconfigured, and the
    /// request should be re-routed to another device.
    GaveUp,
}

/// Outcome of one (possibly retried) configuration under a device's
/// fault stream, in [`DeviceCosts`] arithmetic: the productive charge
/// (zero on give-up), the destroyed partial-attempt energy, the elapsed
/// time (partial walks + backoffs + the final clean configure) and the
/// faulted-attempt count.
struct ConfigAttempt {
    charge: Energy,
    destroyed: Energy,
    time: Duration,
    retries: u32,
    gave_up: bool,
}

/// Mirror of the replay core's recovering configure on the calibrated
/// constants: each faulted attempt destroys `fraction` of the nominal
/// configuration energy/time, backs off exponentially, and gives up
/// after `retry_max` faulted attempts (no backoff after the last).
fn attempt_configure(faults: &mut Option<FaultState>, costs: &DeviceCosts) -> ConfigAttempt {
    let mut out = ConfigAttempt {
        charge: Energy::ZERO,
        destroyed: Energy::ZERO,
        time: Duration::ZERO,
        retries: 0,
        gave_up: false,
    };
    if let Some(f) = faults.as_mut() {
        while let Some(fault) = f.next_config_fault() {
            out.retries += 1;
            out.destroyed += costs.config_energy * fault.fraction;
            out.time += costs.config_time * fault.fraction;
            if out.retries >= f.retry_max() {
                out.gave_up = true;
                return out;
            }
            out.time += f.backoff_after(out.retries);
        }
    }
    out.charge = costs.config_energy;
    out.time += costs.config_time;
    out
}

impl FleetDevice {
    fn new(policy: Box<dyn Policy>, battery: Energy, faults: Option<FaultState>) -> FleetDevice {
        FleetDevice {
            policy,
            // devices start powered off and unconfigured
            plan: GapPlan::PowerOff,
            battery,
            used: Energy::ZERO,
            completion: Duration::ZERO,
            prev_arrival: Duration::ZERO,
            configured: false,
            faults,
            items: 0,
            late: 0,
            configurations: 0,
            retries: 0,
            recovery_energy: Energy::ZERO,
            alive: true,
        }
    }

    /// Whether the device would be awake and configured at time `t`
    /// under its committed plan (busy devices count as awake). Used by
    /// the wake-aware placement policies; pure read, no state change.
    fn awake_at(&self, t: Duration) -> bool {
        if !self.alive || !self.configured || self.items == 0 {
            return false;
        }
        match self.plan {
            GapPlan::Idle(_) => true,
            GapPlan::PowerOff => false,
            GapPlan::IdleThenOff { timeout, .. } => (t - self.completion) <= timeout,
        }
    }

    /// Pay for a given-up configure: the destroyed partial-attempt
    /// energy is drawn from the battery (Eq-2 honesty — retries spend
    /// real budget), the fabric is left unconfigured, and the device
    /// stays alive unless even the partial attempts exceeded its
    /// battery. Completion time and the committed plan are untouched,
    /// so the pending idle window is still charged lazily at the next
    /// successful serve.
    fn give_up(&mut self, retries: u64, destroyed: Energy) -> ServeOutcome {
        self.retries += retries;
        self.configured = false;
        if destroyed > self.battery {
            self.alive = false;
            return ServeOutcome::Died;
        }
        self.battery -= destroyed;
        self.used += destroyed;
        self.recovery_energy += destroyed;
        ServeOutcome::GaveUp
    }

    /// Serve a request arriving at `t`: lazily charge the idle window
    /// since the last completion under the committed plan, reconfigure
    /// if the fabric lost its image (retrying through the device's
    /// fault stream, if any), pay the item, then commit the next plan.
    /// The whole charge is checked against the battery up front — a
    /// device that cannot afford it dies and the request is dropped. A
    /// configure that exhausts its retry cap returns
    /// [`ServeOutcome::GaveUp`] so the router can re-place the request.
    fn serve(&mut self, t: Duration, costs: &DeviceCosts) -> ServeOutcome {
        let mut charge = Energy::ZERO;
        if self.items > 0 {
            let window = (t - self.completion).max(Duration::ZERO);
            match self.plan {
                GapPlan::Idle(saving) => charge += costs.idle_power(saving) * window,
                GapPlan::PowerOff => {}
                GapPlan::IdleThenOff { saving, timeout } => {
                    charge += costs.idle_power(saving) * window.min(timeout);
                    if window > timeout {
                        self.configured = false;
                    }
                }
            }
        }
        let reconfigure = !self.configured;
        let mut serve_time = costs.item_latency;
        let mut destroyed = Energy::ZERO;
        let mut retries = 0u64;
        let mut extra_configs = 0u64;
        if reconfigure {
            let a = attempt_configure(&mut self.faults, costs);
            retries += a.retries as u64;
            destroyed += a.destroyed;
            if a.gave_up {
                return self.give_up(retries, destroyed);
            }
            charge += a.charge;
            serve_time += a.time;
        }
        // at most one brownout per item (the per-device simulators'
        // convention): the partial phases are destroyed, the image is
        // lost, and the recovery configure runs the same retry policy
        if let Some(frac) = self.faults.as_mut().and_then(|f| f.next_infer_fault()) {
            destroyed += costs.item_energy * frac;
            serve_time += costs.item_latency * frac;
            let a = attempt_configure(&mut self.faults, costs);
            retries += 1 + a.retries as u64;
            destroyed += a.destroyed;
            if a.gave_up {
                return self.give_up(retries, destroyed);
            }
            charge += a.charge;
            serve_time += a.time;
            extra_configs += 1;
        }
        charge += destroyed;
        charge += costs.item_energy;
        if charge > self.battery {
            self.retries += retries;
            self.alive = false;
            return ServeOutcome::Died;
        }
        self.battery -= charge;
        self.used += charge;
        self.retries += retries;
        self.recovery_energy += destroyed;
        if reconfigure {
            self.configured = true;
            self.configurations += 1;
        }
        self.configurations += extra_configs;
        let start = t.max(self.completion);
        if self.completion > t {
            self.late += 1;
        }
        self.completion = start + serve_time;
        self.items += 1;
        // the policy observes the realized gap it planned for, then
        // plans the gap that starts now — the same plan/observe
        // interleaving the per-device simulators maintain
        if self.items > 1 {
            self.policy.observe(t - self.prev_arrival);
        }
        self.prev_arrival = t;
        self.plan = self.policy.plan_gap(&GapContext {
            items_done: self.items,
            now: self.completion,
            queued: 0,
        });
        if self.plan == GapPlan::PowerOff {
            self.configured = false;
        }
        ServeOutcome::Served(self.completion - t)
    }
}

/// The lowest-index alive device passing `pred` with the earliest
/// completion time, skipping `exclude` (a device that just gave up on
/// this request).
fn least_completion(
    devices: &[FleetDevice],
    exclude: Option<usize>,
    pred: impl Fn(&FleetDevice) -> bool,
) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, d) in devices.iter().enumerate() {
        if Some(i) == exclude || !d.alive || !pred(d) {
            continue;
        }
        let better = match best {
            None => true,
            Some(b) => d.completion < devices[b].completion,
        };
        if better {
            best = Some(i);
        }
    }
    best
}

/// Pick the device that serves a request arriving at `t`. `exclude`
/// skips a device that already gave up on this request (re-routing
/// after graceful degradation).
fn pick(
    placement: Placement,
    devices: &[FleetDevice],
    t: Duration,
    cursor: &mut usize,
    exclude: Option<usize>,
) -> Option<usize> {
    match placement {
        Placement::RoundRobin => {
            let n = devices.len();
            for k in 0..n {
                let i = (*cursor + k) % n;
                if devices[i].alive && Some(i) != exclude {
                    *cursor = (i + 1) % n;
                    return Some(i);
                }
            }
            None
        }
        Placement::LeastLoaded => least_completion(devices, exclude, |_| true),
        Placement::PreferConfigured => least_completion(devices, exclude, |d| d.awake_at(t))
            .or_else(|| least_completion(devices, exclude, |_| true)),
        Placement::PreferIdleAwake => {
            least_completion(devices, exclude, |d| d.awake_at(t) && d.completion <= t)
                .or_else(|| least_completion(devices, exclude, |d| d.awake_at(t)))
                .or_else(|| least_completion(devices, exclude, |_| true))
        }
        Placement::BatteryAware => {
            let mut best: Option<usize> = None;
            for (i, d) in devices.iter().enumerate() {
                if !d.alive || Some(i) == exclude {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => d.battery > devices[b].battery,
                };
                if better {
                    best = Some(i);
                }
            }
            best
        }
    }
}

/// The routing phase: drive the shared arrival stream through the
/// placement policy across the compact device states. Sequential —
/// deterministic regardless of the thread count.
fn run_routing(
    config: &SimConfig,
    gaps: &[Duration],
    placement: Placement,
    classes: &[DeviceClass],
    cum: &[f64],
) -> FleetRouteReport {
    let seed = config.fleet.seed;
    let costs = DeviceCosts::measure(config);
    let deadline = config
        .fleet
        .deadline
        .unwrap_or_else(|| config.workload.arrival.mean_period());
    let faults_on = config.faults.enabled();
    let mut devices: Vec<FleetDevice> = (0..config.fleet.devices)
        .map(|i| {
            let class = class_index(seed, i as u64, cum);
            // the routing fault stream shares the survey's per-device
            // seed family: a pure function of (fleet_seed, device)
            let faults = faults_on.then(|| {
                FaultState::with_seed(
                    &config.faults,
                    derive_seed(seed ^ FLEET_FAULT_SALT, i as u64),
                )
            });
            FleetDevice::new(
                device_policy(classes, class, seed, i as u64),
                classes[class].battery,
                faults,
            )
        })
        .collect();
    let mut latency = ReservoirQuantiles::new(
        FLEET_RESERVOIR_CAP,
        derive_seed(seed ^ LATENCY_SALT, GLOBAL_AGG),
    );
    let mut cursor = 0usize;
    let (mut served, mut misses, mut dropped, mut deaths) = (0u64, 0u64, 0u64, 0u64);
    let mut rerouted = 0u64;
    let mut t = Duration::ZERO;
    let mut remaining = gaps.iter();
    loop {
        // first placement, plus at most one re-route after a give-up:
        // graceful degradation sheds the request to another device
        // instead of dropping it outright
        let mut excluded: Option<usize> = None;
        loop {
            match pick(placement, &devices, t, &mut cursor, excluded) {
                None => {
                    dropped += 1;
                    misses += 1;
                }
                Some(i) => match devices[i].serve(t, &costs) {
                    ServeOutcome::Died => {
                        deaths += 1;
                        dropped += 1;
                        misses += 1;
                    }
                    ServeOutcome::GaveUp => {
                        if excluded.is_none() {
                            rerouted += 1;
                            excluded = Some(i);
                            continue;
                        }
                        // the re-routed device gave up too
                        dropped += 1;
                        misses += 1;
                    }
                    ServeOutcome::Served(l) => {
                        served += 1;
                        latency.push(l.millis());
                        if l > deadline {
                            misses += 1;
                        }
                    }
                },
            }
            break;
        }
        match remaining.next() {
            Some(gap) => t += *gap,
            None => break,
        }
    }
    // fold per-device tallies into the streaming sketches in device
    // order (deterministic; never a per-device result vector upstream)
    let mut device_energy = ReservoirQuantiles::new(
        FLEET_RESERVOIR_CAP,
        derive_seed(seed ^ DEV_ENERGY_SALT, GLOBAL_AGG),
    );
    let mut device_items = ReservoirQuantiles::new(
        FLEET_RESERVOIR_CAP,
        derive_seed(seed ^ DEV_ITEMS_SALT, GLOBAL_AGG),
    );
    let mut total_energy = Energy::ZERO;
    let mut configurations = 0u64;
    let mut late = 0u64;
    let mut retries = 0u64;
    let mut recovery_energy = Energy::ZERO;
    let mut fleet_lifetime = Duration::ZERO;
    for d in &devices {
        device_energy.push(d.used.millijoules());
        device_items.push(d.items as f64);
        total_energy += d.used;
        configurations += d.configurations;
        late += d.late;
        retries += d.retries;
        recovery_energy += d.recovery_energy;
        fleet_lifetime = fleet_lifetime.max(d.completion);
    }
    FleetRouteReport {
        placement,
        requests: gaps.len() + 1,
        served,
        late,
        misses,
        dropped,
        deaths,
        configurations,
        total_energy,
        fleet_lifetime,
        latency_ms: latency.summary(),
        device_energy_mj: device_energy.summary(),
        device_items: device_items.summary(),
        retries,
        recovery_energy,
        rerouted,
    }
}

/// Run a full fleet simulation of `config`'s fleet block: the survey
/// phase (sharded over `runner`, byte-identical at any thread count)
/// and the routing phase (sequential). IO can only fail while
/// materializing a `trace:`-file arrival stream.
pub fn run_fleet(
    config: &SimConfig,
    options: &FleetOptions,
    runner: &SweepRunner,
) -> std::io::Result<FleetReport> {
    let (classes, cum) = resolve_classes(config);
    let step = if options.steps > 0 {
        let gaps = materialize_gaps(config, options.steps, SURVEY_SALT)?;
        run_survey(config, &gaps, runner, &classes, &cum)
    } else {
        FleetStepReport::empty()
    };
    let route = if options.requests > 0 {
        let gaps = materialize_gaps(config, options.requests - 1, ROUTE_SALT)?;
        run_routing(config, &gaps, options.placement, &classes, &cum)
    } else {
        FleetRouteReport::empty(options.placement)
    };
    Ok(FleetReport {
        devices: config.fleet.devices,
        seed: config.fleet.seed,
        classes: classes.len(),
        step,
        route,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::config::schema::{FaultSpec, FleetClassSpec};

    fn fleet_config(devices: usize) -> SimConfig {
        let mut cfg = paper_default();
        cfg.fleet.devices = devices;
        cfg.fleet.seed = 42;
        cfg
    }

    fn opts(steps: usize, requests: usize, placement: Placement) -> FleetOptions {
        FleetOptions {
            steps,
            requests,
            placement,
        }
    }

    #[test]
    fn placement_names_round_trip() {
        for p in Placement::ALL {
            assert_eq!(Placement::parse(p.name()), Some(p));
            assert_eq!(format!("{p}"), p.name());
        }
        assert_eq!(Placement::parse("nope"), None);
    }

    #[test]
    fn homogeneous_survey_devices_are_identical() {
        // one implicit idle-waiting class on a periodic trace: every
        // device's replay is deterministic and identical, so the spread
        // collapses to zero while counts stay per-device
        let cfg = fleet_config(4);
        let report = run_fleet(&cfg, &opts(16, 0, Placement::RoundRobin), &SweepRunner::single())
            .unwrap();
        assert_eq!(report.step.steps, 16);
        assert_eq!(report.step.items, 4 * 17);
        assert_eq!(report.step.exhausted, 0);
        let s = report.step.energy_mj.unwrap();
        assert_eq!(s.count, 4);
        assert!(s.std_dev.abs() < 1e-12, "{}", s.std_dev);
        assert_eq!(s.min, s.max);
        // routing skipped
        assert_eq!(report.route.requests, 0);
        assert!(report.route.latency_ms.is_none());
    }

    #[test]
    fn mixed_classes_partition_devices_deterministically() {
        let mut cfg = fleet_config(32);
        cfg.fleet.classes = vec![
            FleetClassSpec {
                weight: 1.0,
                policy: PolicySpec::IdleWaiting,
                params: PolicyParams::default(),
                battery: None,
            },
            FleetClassSpec {
                weight: 1.0,
                policy: PolicySpec::OnOff,
                params: PolicyParams::default(),
                battery: None,
            },
        ];
        let (classes, cum) = resolve_classes(&cfg);
        assert_eq!(classes.len(), 2);
        let picks: Vec<usize> = (0..32).map(|i| class_index(cfg.fleet.seed, i, &cum)).collect();
        let again: Vec<usize> = (0..32).map(|i| class_index(cfg.fleet.seed, i, &cum)).collect();
        assert_eq!(picks, again, "class assignment must be pure");
        assert!(picks.contains(&0) && picks.contains(&1), "{picks:?}");
    }

    #[test]
    fn round_robin_spreads_requests_evenly() {
        let cfg = fleet_config(3);
        let r = run_fleet(&cfg, &opts(0, 9, Placement::RoundRobin), &SweepRunner::single())
            .unwrap()
            .route;
        assert_eq!(r.requests, 9);
        assert_eq!(r.served, 9);
        assert_eq!(r.dropped, 0);
        assert_eq!(r.deaths, 0);
        // 3 devices × 3 requests each, every device configured once
        let items = r.device_items.unwrap();
        assert_eq!(items.count, 3);
        assert_eq!(items.min, 3.0);
        assert_eq!(items.max, 3.0);
        assert_eq!(r.configurations, 3);
    }

    #[test]
    fn prefer_configured_sticks_to_one_device() {
        let cfg = fleet_config(3);
        let r = run_fleet(
            &cfg,
            &opts(0, 12, Placement::PreferConfigured),
            &SweepRunner::single(),
        )
        .unwrap()
        .route;
        assert_eq!(r.served, 12);
        // the first device stays configured (idle-waiting) and absorbs
        // the whole stream: exactly one configuration fleet-wide
        assert_eq!(r.configurations, 1);
        let items = r.device_items.unwrap();
        assert_eq!(items.max, 12.0);
        assert_eq!(items.min, 0.0);
        // 40 ms deadline (the arrival mean) is never missed at 36.2 ms
        assert_eq!(r.misses, 0);
    }

    #[test]
    fn battery_aware_balances_the_fleet() {
        let cfg = fleet_config(2);
        let r = run_fleet(
            &cfg,
            &opts(0, 10, Placement::BatteryAware),
            &SweepRunner::single(),
        )
        .unwrap()
        .route;
        assert_eq!(r.served, 10);
        let items = r.device_items.unwrap();
        assert_eq!(items.min, 5.0);
        assert_eq!(items.max, 5.0);
    }

    #[test]
    fn tiny_batteries_die_and_drop_requests() {
        // 13 mJ covers exactly one On-Off configure+item (~11.98 mJ);
        // the second request per device cannot be paid
        let mut cfg = fleet_config(2);
        cfg.fleet.classes = vec![FleetClassSpec {
            weight: 1.0,
            policy: PolicySpec::OnOff,
            params: PolicyParams::default(),
            battery: Some(Energy::from_joules(0.013)),
        }];
        let r = run_fleet(&cfg, &opts(0, 10, Placement::RoundRobin), &SweepRunner::single())
            .unwrap()
            .route;
        assert_eq!(r.deaths, 2);
        assert_eq!(r.served, 2);
        assert_eq!(r.dropped, 8);
        assert_eq!(r.served + r.dropped, 10);
        assert_eq!(r.misses, 8);
    }

    #[test]
    fn certain_faults_shed_every_request() {
        // every configuration attempt CRC-faults, so every device gives
        // up after retry_max attempts: each request is re-routed once,
        // gives up again, and is dropped — nothing is ever served, but
        // the destroyed partial attempts are still paid for
        let mut cfg = fleet_config(3);
        cfg.faults.config_crc_rate = 1.0;
        cfg.faults.retry_max = 2;
        let r = run_fleet(&cfg, &opts(0, 6, Placement::RoundRobin), &SweepRunner::single())
            .unwrap()
            .route;
        assert_eq!(r.served, 0);
        assert_eq!(r.dropped, 6);
        assert_eq!(r.rerouted, 6);
        // 2 faulted attempts per give-up, 2 give-ups per request
        assert_eq!(r.retries, 24);
        assert!(r.recovery_energy > Energy::ZERO);
        assert_eq!(r.deaths, 0);
        assert_eq!(r.configurations, 0);
    }

    #[test]
    fn faulty_fleet_is_deterministic_across_threads() {
        let mut cfg = fleet_config(64);
        cfg.faults.spi_corrupt_rate = 0.2;
        cfg.faults.brownout_infer_rate = 0.05;
        let o = opts(12, 60, Placement::LeastLoaded);
        let a = run_fleet(&cfg, &o, &SweepRunner::single()).unwrap();
        let b = run_fleet(&cfg, &o, &SweepRunner::new(4)).unwrap();
        assert_eq!(a.render(), b.render(), "faulty fleet must not depend on threads");
        assert_eq!(a.to_csv().render(), b.to_csv().render());
        // ~64 survey configures at a 20% fault rate: some retries fired,
        // and the recovery spend is visible in the fleet aggregates
        assert!(a.step.retries > 0, "{}", a.step.retries);
        assert!(a.step.recovery_energy > Energy::ZERO);
        let r = &a.route;
        assert_eq!(r.served + r.dropped, 60);
        // the fault-free control run reports all-zero fault scalars
        let clean = fleet_config(64);
        let c = run_fleet(&clean, &o, &SweepRunner::single()).unwrap();
        assert_eq!(c.step.retries, 0);
        assert_eq!(c.route.retries, 0);
        assert_eq!(c.route.rerouted, 0);
        assert_eq!(c.step.recovery_energy, Energy::ZERO);
    }

    #[test]
    fn csv_has_the_documented_schema() {
        let cfg = fleet_config(2);
        let report = run_fleet(&cfg, &opts(4, 4, Placement::LeastLoaded), &SweepRunner::single())
            .unwrap();
        let rendered = report.to_csv().render();
        let mut lines = rendered.lines();
        assert_eq!(
            lines.next().unwrap(),
            "section,metric,count,mean,std_dev,min,p50,p90,p95,p99,max"
        );
        for line in lines {
            assert_eq!(line.split(',').count(), 11, "{line}");
        }
        let text = report.render();
        assert!(text.contains("least-loaded"), "{text}");
        assert!(text.contains("2 devices"), "{text}");
    }

    #[test]
    fn zero_observation_csv_rows_render_defined_zeros() {
        // routing skipped entirely: the distribution rows must still be
        // emitted, with Summary::empty zeros rather than NaN or absence
        let cfg = fleet_config(2);
        let report = run_fleet(&cfg, &opts(4, 0, Placement::RoundRobin), &SweepRunner::single())
            .unwrap();
        let rendered = report.to_csv().render();
        assert!(
            rendered.contains("route,latency_ms,0,0,0,0,0,0,0,0,0"),
            "{rendered}"
        );
        assert!(
            rendered.contains("route,device_energy_mj,0,0,0,0,0,0,0,0,0"),
            "{rendered}"
        );
        assert!(!rendered.contains("NaN"), "{rendered}");
        // byte-stable on repeat
        let again = run_fleet(&cfg, &opts(4, 0, Placement::RoundRobin), &SweepRunner::single())
            .unwrap();
        assert_eq!(rendered, again.to_csv().render());
    }

    #[test]
    fn survey_device_reproduces_the_sharded_run() {
        let cfg = fleet_config(3);
        let gaps: Vec<Duration> = (0..12)
            .map(|i| Duration::from_millis(if i % 4 == 3 { 300.0 } else { 30.0 }))
            .collect();
        let solo = survey_device(&cfg, &gaps, 1);
        assert_eq!(solo.items, 13);
        // deterministic on repeat
        let again = survey_device(&cfg, &gaps, 1);
        crate::testing::assert_sim_reports_bit_identical(&solo, &again, "survey_device repeat");
    }
}
