//! Serving metrics: request latencies, deadline tracking, energy summary.
//!
//! The duty-cycle server records per-request host latency (PJRT inference
//! wall time), deadline misses (a request must finish before the next one
//! arrives — the paper's T_latency < T_req condition) and the simulated
//! energy ledger, and renders the summary the e2e example prints.

use crate::util::stats::{ReservoirQuantiles, Summary};
use crate::util::table::{fnum, Table};
use crate::util::units::{Duration, Energy};

/// Latency samples retained for percentile estimation. Bounds serving
/// memory at O(this) regardless of run length; percentiles stay exact
/// up to this many requests and become an unbiased reservoir estimate
/// beyond it (mean/min/max stay exact forever).
const LATENCY_RESERVOIR_CAP: usize = 4096;

/// Fixed seed for the latency reservoir's replacement decisions, so two
/// identical serving runs render identical summaries.
const LATENCY_RESERVOIR_SEED: u64 = 0x1D1E_5EED;

/// Rolling serving metrics.
#[derive(Debug, Clone)]
pub struct Metrics {
    latencies: ReservoirQuantiles,
    /// Simulated arrival-to-dispatch queueing delays (multi-source runs).
    queue_waits: ReservoirQuantiles,
    /// Simulated arrival-to-completion sojourn times (multi-source runs).
    sojourns: ReservoirQuantiles,
    /// Requests served.
    pub requests: u64,
    /// Requests whose serve latency exceeded the deadline.
    pub deadline_misses: u64,
    /// Requests rejected at admission because the queue was full.
    pub dropped: u64,
    /// Forecast outputs produced by the LSTM runtime.
    pub forecasts_emitted: u64,
    /// Simulated FPGA-side energy attributed to served requests.
    pub sim_energy: Energy,
    /// Simulated elapsed duty-cycle time.
    pub sim_elapsed: Duration,
    /// Faulted configuration/inference attempts that were retried
    /// (fault injection; zero when disabled).
    pub retries: u64,
    /// Energy destroyed by faulted attempts — drawn from the budget but
    /// producing nothing (partial configurations, interrupted inference).
    pub recovery_energy: Energy,
    /// Simulated time spent in fault recovery (partial attempts,
    /// backoffs, brownout reconfigurations) instead of useful serving.
    pub recovery_time: Duration,
    /// Requests degraded: shed by the retry policy after its attempt cap
    /// ([`BoardError`](crate::device::board::BoardError)`::RetriesExhausted`),
    /// or dropped because their device was stuck recovering.
    pub degraded: u64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    /// An empty metrics ledger.
    pub fn new() -> Metrics {
        Metrics {
            latencies: ReservoirQuantiles::new(LATENCY_RESERVOIR_CAP, LATENCY_RESERVOIR_SEED),
            queue_waits: ReservoirQuantiles::new(
                LATENCY_RESERVOIR_CAP,
                LATENCY_RESERVOIR_SEED ^ 1,
            ),
            sojourns: ReservoirQuantiles::new(
                LATENCY_RESERVOIR_CAP,
                LATENCY_RESERVOIR_SEED ^ 2,
            ),
            requests: 0,
            deadline_misses: 0,
            dropped: 0,
            forecasts_emitted: 0,
            sim_energy: Energy::ZERO,
            sim_elapsed: Duration::ZERO,
            retries: 0,
            recovery_energy: Energy::ZERO,
            recovery_time: Duration::ZERO,
            degraded: 0,
        }
    }

    /// Record one served request: its host latency vs the deadline.
    pub fn record_request(&mut self, host_latency: Duration, deadline: Duration) {
        self.requests += 1;
        self.forecasts_emitted += 1;
        self.latencies.push(host_latency.millis());
        if host_latency > deadline {
            self.deadline_misses += 1;
        }
    }

    /// Record one request served by the multi-source coordinator, all on
    /// simulated time: its queueing delay (arrival → dispatch), its
    /// sojourn (arrival → completion), and whether the completion missed
    /// the request's deadline. Increments `requests`/`deadline_misses`
    /// itself — the coordinator path does not also call
    /// [`record_request`](Self::record_request), which tracks *host*
    /// latency for the PJRT-backed single-source loop.
    pub fn record_sojourn(&mut self, wait: Duration, sojourn: Duration, missed: bool) {
        self.requests += 1;
        self.queue_waits.push(wait.millis());
        self.sojourns.push(sojourn.millis());
        if missed {
            self.deadline_misses += 1;
        }
    }

    /// Record one request rejected at admission (queue full).
    pub fn record_drop(&mut self) {
        self.dropped += 1;
    }

    /// Deadline-miss rate over served requests (0 before any request).
    pub fn miss_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.deadline_misses as f64 / self.requests as f64
        }
    }

    /// Drop rate over offered requests (served + dropped).
    pub fn drop_rate(&self) -> f64 {
        let offered = self.requests + self.dropped;
        if offered == 0 {
            0.0
        } else {
            self.dropped as f64 / offered as f64
        }
    }

    /// Percentile summary of recorded latencies (None before any
    /// request). Served from a bounded reservoir: exact for the first
    /// `LATENCY_RESERVOIR_CAP` (4096) requests, an unbiased
    /// deterministic sample after — memory never grows with run length.
    pub fn latency_summary(&self) -> Option<Summary> {
        self.latencies.summary()
    }

    /// Percentile summary of simulated queueing delays (None before any
    /// [`record_sojourn`](Self::record_sojourn)).
    pub fn queue_wait_summary(&self) -> Option<Summary> {
        self.queue_waits.summary()
    }

    /// Percentile summary of simulated sojourn times (None before any
    /// [`record_sojourn`](Self::record_sojourn)).
    pub fn sojourn_summary(&self) -> Option<Summary> {
        self.sojourns.summary()
    }

    /// Mean recorded host latency in ms (`NaN` before any request —
    /// mirrors [`Welford::mean`](crate::util::stats::Welford::mean)).
    pub fn mean_latency_ms(&self) -> f64 {
        self.latencies.mean()
    }

    /// Requests per simulated second.
    pub fn throughput_per_sim_sec(&self) -> f64 {
        if self.sim_elapsed.secs() == 0.0 {
            0.0
        } else {
            self.requests as f64 / self.sim_elapsed.secs()
        }
    }

    /// Fraction of simulated time the device was doing useful work (or
    /// idling by choice) rather than fault recovery: `1 −
    /// recovery_time / sim_elapsed`. Defined as `1.0` before any time
    /// has elapsed, so zero-observation runs render a number, not NaN.
    pub fn availability(&self) -> f64 {
        if self.sim_elapsed.secs() <= 0.0 {
            1.0
        } else {
            (1.0 - self.recovery_time.secs() / self.sim_elapsed.secs()).max(0.0)
        }
    }

    /// Degraded-request rate over offered requests (served + dropped +
    /// degraded); 0 before any request is offered.
    pub fn degraded_rate(&self) -> f64 {
        let offered = self.requests + self.dropped + self.degraded;
        if offered == 0 {
            0.0
        } else {
            self.degraded as f64 / offered as f64
        }
    }

    /// Record one degraded request (shed by the retry policy or dropped
    /// because its device was stuck in recovery).
    pub fn record_degraded(&mut self) {
        self.degraded += 1;
    }

    /// Fold a device's fault-recovery ledger into the serving tally.
    pub fn record_recovery(&mut self, retries: u64, energy: Energy, time: Duration) {
        self.retries += retries;
        self.recovery_energy += energy;
        self.recovery_time += time;
    }

    /// Render the end-of-run report table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["metric", "value"]).with_title("serving metrics");
        t.row(&["requests".into(), self.requests.to_string()]);
        t.row(&["deadline misses".into(), self.deadline_misses.to_string()]);
        if let Some(s) = self.latency_summary() {
            t.row(&["host latency p50 (ms)".into(), fnum(s.p50, 4)]);
            t.row(&["host latency p95 (ms)".into(), fnum(s.p95, 4)]);
            t.row(&["host latency p99 (ms)".into(), fnum(s.p99, 4)]);
            t.row(&["host latency max (ms)".into(), fnum(s.max, 4)]);
        }
        if let Some(s) = self.queue_wait_summary() {
            t.row(&["queue wait p50 (ms)".into(), fnum(s.p50, 4)]);
            t.row(&["queue wait p95 (ms)".into(), fnum(s.p95, 4)]);
            t.row(&["queue wait p99 (ms)".into(), fnum(s.p99, 4)]);
        }
        if let Some(s) = self.sojourn_summary() {
            t.row(&["sojourn p50 (ms)".into(), fnum(s.p50, 4)]);
            t.row(&["sojourn p95 (ms)".into(), fnum(s.p95, 4)]);
            t.row(&["sojourn p99 (ms)".into(), fnum(s.p99, 4)]);
            t.row(&["deadline-miss rate".into(), fnum(self.miss_rate(), 4)]);
            t.row(&["dropped".into(), self.dropped.to_string()]);
            t.row(&["drop rate".into(), fnum(self.drop_rate(), 4)]);
        }
        t.row(&[
            "sim energy (J)".into(),
            fnum(self.sim_energy.joules(), 4),
        ]);
        t.row(&[
            "sim elapsed (s)".into(),
            fnum(self.sim_elapsed.secs(), 3),
        ]);
        t.row(&[
            "throughput (req/sim-s)".into(),
            fnum(self.throughput_per_sim_sec(), 2),
        ]);
        if self.retries > 0 || self.degraded > 0 {
            t.row(&["fault retries".into(), self.retries.to_string()]);
            t.row(&[
                "recovery energy (mJ)".into(),
                fnum(self.recovery_energy.millijoules(), 4),
            ]);
            t.row(&["degraded requests".into(), self.degraded.to_string()]);
            t.row(&["degraded rate".into(), fnum(self.degraded_rate(), 4)]);
            t.row(&["availability".into(), fnum(self.availability(), 6)]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new();
        for i in 0..100 {
            m.record_request(
                Duration::from_millis(0.5 + i as f64 * 0.01),
                Duration::from_millis(40.0),
            );
        }
        assert_eq!(m.requests, 100);
        assert_eq!(m.deadline_misses, 0);
        let s = m.latency_summary().unwrap();
        assert!(s.p50 > 0.5 && s.p50 < 1.5);
    }

    #[test]
    fn latency_memory_is_bounded_beyond_reservoir_cap() {
        let mut m = Metrics::new();
        for i in 0..10_000u64 {
            m.record_request(
                Duration::from_millis(1.0 + (i % 100) as f64 * 0.1),
                Duration::from_millis(40.0),
            );
        }
        assert_eq!(m.requests, 10_000);
        let s = m.latency_summary().unwrap();
        assert_eq!(s.count, 10_000); // counts the stream, not the reservoir
        assert!(s.p50 > 1.0 && s.p50 < 11.0, "p50={}", s.p50);
        assert!((m.mean_latency_ms() - s.mean).abs() < 1e-12); // mean exact
    }

    #[test]
    fn deadline_misses_counted() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_millis(50.0), Duration::from_millis(40.0));
        m.record_request(Duration::from_millis(1.0), Duration::from_millis(40.0));
        assert_eq!(m.deadline_misses, 1);
    }

    #[test]
    fn throughput_from_sim_time() {
        let mut m = Metrics::new();
        m.requests = 250;
        m.sim_elapsed = Duration::from_secs(10.0);
        assert!((m.throughput_per_sim_sec() - 25.0).abs() < 1e-12);
    }

    #[test]
    fn sojourns_track_sla_rates_and_render() {
        let mut m = Metrics::new();
        for i in 0..10 {
            m.record_sojourn(
                Duration::from_millis(i as f64),
                Duration::from_millis(5.0 + i as f64),
                i >= 8,
            );
        }
        m.record_drop();
        assert_eq!(m.requests, 10);
        assert_eq!(m.deadline_misses, 2);
        assert_eq!(m.dropped, 1);
        assert!((m.miss_rate() - 0.2).abs() < 1e-12);
        assert!((m.drop_rate() - 1.0 / 11.0).abs() < 1e-12);
        let w = m.queue_wait_summary().unwrap();
        assert_eq!(w.count, 10);
        let s = m.sojourn_summary().unwrap();
        assert!(s.p50 >= 5.0 && s.p99 <= 14.0, "p50={} p99={}", s.p50, s.p99);
        let rendered = m.render();
        assert!(rendered.contains("queue wait p95"));
        assert!(rendered.contains("sojourn p99"));
        assert!(rendered.contains("deadline-miss rate"));
        assert!(rendered.contains("drop rate"));
        // no host-latency rows: nothing called record_request
        assert!(!rendered.contains("host latency"));
    }

    #[test]
    fn empty_rates_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.miss_rate(), 0.0);
        assert_eq!(m.drop_rate(), 0.0);
        assert!(m.queue_wait_summary().is_none());
        assert!(m.sojourn_summary().is_none());
    }

    #[test]
    fn render_contains_key_rows() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_millis(0.8), Duration::from_millis(40.0));
        m.sim_energy = Energy::from_joules(1.5);
        let s = m.render();
        assert!(s.contains("requests"));
        assert!(s.contains("host latency p95"));
        assert!(s.contains("1.5000"));
    }

    #[test]
    fn empty_metrics_render() {
        let m = Metrics::new();
        let s = m.render();
        assert!(s.contains("requests"));
        assert!(!s.contains("p50")); // no latency rows without data
        assert!(!s.contains("fault retries")); // no fault rows either
    }

    #[test]
    fn availability_and_degradation_accounting() {
        let mut m = Metrics::new();
        // no time elapsed: availability is defined, not NaN
        assert_eq!(m.availability(), 1.0);
        assert_eq!(m.degraded_rate(), 0.0);
        m.requests = 8;
        m.sim_elapsed = Duration::from_secs(10.0);
        m.record_recovery(3, Energy::from_millijoules(7.5), Duration::from_secs(2.5));
        m.record_degraded();
        m.record_degraded();
        assert_eq!(m.retries, 3);
        assert!((m.recovery_energy.millijoules() - 7.5).abs() < 1e-12);
        assert!((m.availability() - 0.75).abs() < 1e-12);
        assert!((m.degraded_rate() - 0.2).abs() < 1e-12);
        let s = m.render();
        assert!(s.contains("fault retries"));
        assert!(s.contains("availability"));
        assert!(s.contains("degraded rate"));
    }

    #[test]
    fn availability_saturates_at_zero() {
        let mut m = Metrics::new();
        m.sim_elapsed = Duration::from_secs(1.0);
        m.recovery_time = Duration::from_secs(5.0);
        assert_eq!(m.availability(), 0.0);
    }
}
